#!/usr/bin/env python3
"""Bench-regression gate: compare freshly produced BENCH_*.json files
against a checked-in baseline spec and fail CI on regressions.

Usage: bench_check.py <baseline.json> [--dir DIR]

The baseline spec is JSON:

    {
      "tolerance": 0.25,
      "checks": [
        {"file": "BENCH_decode.json", "metric": "retrieval_speedup",
         "min": 1.2},
        {"file": "BENCH_score.json",  "metric": "popcnt_tokens_per_sec",
         "baseline": 2.0e8, "tolerance": 0.5}
      ]
    }

Three check kinds:

* "min"      — a hard floor, used for machine-relative ratios (a speedup
               of the same workload on the same host must not dip below
               it regardless of how fast the runner is).
* "max"      — a hard ceiling, used for latency-style metrics (TTFT p99,
               deadline-miss rate) where regression means the value GREW.
* "baseline" — an absolute reference value; the measured metric must be
               >= baseline * (1 - tolerance). The per-check "tolerance"
               overrides the spec-level default (0.25 = fail on a >25%
               regression).

Metrics are dotted paths into the bench JSON ("stage_us.score_select_us").
A missing file or metric is a FAILURE — silently skipping a gate because
a bench stopped emitting it would hide exactly the regressions this
exists to catch. Stdlib only; exit code 1 on any failure.
"""

import argparse
import json
import os
import sys

DEFAULT_TOLERANCE = 0.25


def lookup(doc, dotted):
    """Resolve a dotted path into nested dicts; None if absent."""
    node = doc
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def run_check(check, bench_dir, default_tol, cache):
    path = os.path.join(bench_dir, check["file"])
    if path not in cache:
        try:
            with open(path, encoding="utf-8") as f:
                cache[path] = json.load(f)
        except (OSError, ValueError) as e:
            cache[path] = e
    doc = cache[path]
    name = "%s :: %s" % (check["file"], check["metric"])
    if isinstance(doc, Exception):
        return False, name, "cannot read %s: %s" % (check["file"], doc)

    value = lookup(doc, check["metric"])
    if not isinstance(value, (int, float)):
        return False, name, "metric missing or non-numeric (got %r)" % (value,)

    if "min" in check:
        floor = float(check["min"])
        ok = value >= floor
        detail = "%.4g >= floor %.4g" % (value, floor)
    elif "max" in check:
        ceil = float(check["max"])
        ok = value <= ceil
        detail = "%.4g <= ceiling %.4g" % (value, ceil)
    elif "baseline" in check:
        tol = float(check.get("tolerance", default_tol))
        floor = float(check["baseline"]) * (1.0 - tol)
        ok = value >= floor
        detail = "%.4g >= baseline %.4g * (1 - %.2f) = %.4g" % (
            value,
            float(check["baseline"]),
            tol,
            floor,
        )
    else:
        return False, name, "check has none of 'min', 'max', 'baseline'"
    return ok, name, detail


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="path to the baseline spec JSON")
    ap.add_argument(
        "--dir",
        default=None,
        help="directory holding the BENCH_*.json files "
        "(default: the baseline spec's directory)",
    )
    args = ap.parse_args()

    with open(args.baseline, encoding="utf-8") as f:
        spec = json.load(f)
    bench_dir = args.dir or os.path.dirname(os.path.abspath(args.baseline))
    default_tol = float(spec.get("tolerance", DEFAULT_TOLERANCE))
    checks = spec.get("checks", [])
    if not checks:
        print("bench_check: baseline spec has no checks", file=sys.stderr)
        return 1

    cache = {}
    failures = 0
    print("bench regression gate (%d checks, default tolerance %.0f%%)" % (
        len(checks), default_tol * 100))
    for check in checks:
        ok, name, detail = run_check(check, bench_dir, default_tol, cache)
        status = "PASS" if ok else "FAIL"
        print("  [%s] %-55s %s" % (status, name, detail))
        if not ok:
            failures += 1
    if failures:
        print("bench_check: %d of %d checks failed" % (failures, len(checks)),
              file=sys.stderr)
        return 1
    print("bench_check: all %d checks passed" % len(checks))
    return 0


if __name__ == "__main__":
    sys.exit(main())
