"""Golden-vector exporter: deterministic test tensors for the Rust side.

Writes artifacts/golden.bin in the weights.bin format (all f32; small
integers are exact in f32). The Rust unit tests (rust/src/selfindex,
rust/src/quant, rust/src/attention) recompute each stage natively and
compare: codes/topk bit-exact, floats within tolerance. This pins the
Python↔Rust contract far more tightly than shape checks.

Usage: python -m compile.golden [--out ../artifacts/golden.bin]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .train import MAGIC

L, D, K_SEL, N_SINK = 256, 64, 32, 8


def tensors():
    r = np.random.default_rng(12345)
    # clustered keys: the regime retrieval targets (see test_kernels.py)
    dirs = r.standard_normal((8, D)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    assign = r.integers(0, 8, L)
    k = jnp.asarray((4.0 * dirs[assign]
                     + 0.8 * r.standard_normal((L, D))
                     + 0.5 * r.standard_normal(D)).astype(np.float32))
    v = jnp.asarray(r.standard_normal((L, D)).astype(np.float32))
    q = jnp.asarray((4.0 * dirs[0]
                     + 0.4 * r.standard_normal(D)).astype(np.float32))

    kn, mu = ref.normalize_keys(k)
    st = ref.compress_prefill(k, v)
    lut = ref.build_lut(q, st["codebook"])
    scores = ref.lut_scores(lut, st["codes"])
    exact = ref.exact_scores(q, kn)
    topk = ref.topk_indices(scores, K_SEL)

    k_rec = ref.dequantize_key(st["codes"], st["k_q"], st["k_qs"],
                               st["k_zp"], st["alpha"])
    v_rec = ref.dequantize_token_wise(st["v_q"], st["v_qs"], st["v_zp"])
    dense_out = ref.attention_ref(q, kn, v)
    sink = jnp.arange(N_SINK, dtype=jnp.int32)
    sparse_out, sel = ref.retrieve_and_attend(q, st, K_SEL, sink_idx=sink)

    out = {
        "k": k, "v": v, "q": q, "mu": mu, "kn": kn,
        "codes": st["codes"].astype(jnp.float32),
        "codebook": st["codebook"], "alpha": st["alpha"],
        "k_q": st["k_q"].astype(jnp.float32),
        "k_qs": st["k_qs"], "k_zp": st["k_zp"],
        "v_q": st["v_q"].astype(jnp.float32),
        "v_qs": st["v_qs"], "v_zp": st["v_zp"],
        "lut": lut, "scores": scores, "exact_scores": exact,
        "topk": topk.astype(jnp.float32),
        "sel": sel.astype(jnp.float32),
        "k_rec": k_rec, "v_rec": v_rec,
        "dense_out": dense_out, "sparse_out": sparse_out,
    }
    return out


def save(path, named):
    with open(path, "wb") as f:
        f.write(np.array([MAGIC, 1, len(named)], dtype="<u4").tobytes())
        for name, arr in named.items():
            arr = np.asarray(arr, dtype="<f4")
            nb = name.encode()
            f.write(np.array([len(nb)], dtype="<u4").tobytes())
            f.write(nb)
            f.write(bytes([0, arr.ndim]))
            f.write(np.array(arr.shape, dtype="<u4").tobytes())
            f.write(arr.tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/golden.bin")
    args = ap.parse_args()
    save(args.out, tensors())
    print(f"golden vectors -> {args.out}")


if __name__ == "__main__":
    main()
