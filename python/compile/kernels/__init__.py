"""Self-Indexing KVCache — Layer-1 Pallas kernels (build-time only).

Modules:
  ref         pure-jnp correctness oracle for everything below
  sign_vq     one-pass sign-based VQ: codes + codebook        (Eq. 1-4)
  lut_gemv    compressed-domain retrieval scoring             (Eq. 8)
  quant       token-wise 2-bit quantization                   (Eq. 9-13)
  sparse_attn dequant-fused sparse attention over sinks+top-k
"""

from . import lut_gemv, quant, ref, sign_vq, sparse_attn  # noqa: F401
