"""L1 Pallas kernel: token-wise low-bit quantization (Eq. 9-13).

Token-wise (not channel-wise à la KIVI) so that a *single* retrieved token
can be dequantized from a contiguous record — the property that makes the
compressed cache random-access and therefore compatible with top-k sparse
attention (paper §Token-Wise Quantization Format).

Two entry points:

  * `quantize_tokens`  — asymmetric min/max uint{B} quantization of V (or of
    |K'|/α for keys) per (token × 32-channel group).
  * `dequantize_tokens`— the inverse, used by tests; the serving path fuses
    dequantization into the sparse-attention kernel instead (sparse_attn.py).

The kernel is elementwise-per-token: a 1-D grid over token tiles, every
tile touched exactly once (quantization is a single HBM pass, part of the
paper's "minimal prefill overhead" claim).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import QUANT_BITS, QUANT_GROUP

TOKEN_TILE = 256


def _quant_kernel(v_ref, q_ref, qs_ref, zp_ref, *, bits, group):
    v = v_ref[...]                                   # (T, D)
    t, d = v.shape
    ng = d // group
    grouped = v.reshape(t, ng, group)
    vmin = jnp.min(grouped, axis=-1)
    vmax = jnp.max(grouped, axis=-1)
    qs = (vmax - vmin) / (2**bits - 1)
    qs = jnp.where(qs <= 0, 1.0, qs)                 # constant group guard
    q = jnp.clip(
        jnp.round((grouped - vmin[:, :, None]) / qs[:, :, None]),
        0, 2**bits - 1,
    )
    q_ref[...] = q.reshape(t, d).astype(jnp.uint8)
    qs_ref[...] = qs
    zp_ref[...] = vmin


def quantize_tokens(v, *, bits=QUANT_BITS, group=QUANT_GROUP,
                    token_tile=TOKEN_TILE, interpret=True):
    """v: (L, D) -> (qvals uint8 (L, D), qs (L, D/group), zp (L, D/group))."""
    l, d = v.shape
    assert d % group == 0, (d, group)
    assert l % token_tile == 0, (l, token_tile)
    ng = d // group
    n_tiles = l // token_tile

    return pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits, group=group),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((token_tile, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((token_tile, d), lambda i: (i, 0)),
            pl.BlockSpec((token_tile, ng), lambda i: (i, 0)),
            pl.BlockSpec((token_tile, ng), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, d), jnp.uint8),
            jax.ShapeDtypeStruct((l, ng), v.dtype),
            jax.ShapeDtypeStruct((l, ng), v.dtype),
        ],
        interpret=interpret,
    )(v)


def _dequant_kernel(q_ref, qs_ref, zp_ref, v_ref, *, group):
    q = q_ref[...]
    t, d = q.shape
    ng = d // group
    grouped = q.reshape(t, ng, group).astype(qs_ref.dtype)
    v_ref[...] = (
        grouped * qs_ref[...][:, :, None] + zp_ref[...][:, :, None]
    ).reshape(t, d)


def dequantize_tokens(qvals, qs, zp, *, group=QUANT_GROUP,
                      token_tile=TOKEN_TILE, interpret=True):
    """Inverse of `quantize_tokens` (Eq. 11)."""
    l, d = qvals.shape
    ng = d // group
    assert qs.shape == (l, ng) and zp.shape == (l, ng)
    assert l % token_tile == 0, (l, token_tile)
    n_tiles = l // token_tile

    return pl.pallas_call(
        functools.partial(_dequant_kernel, group=group),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((token_tile, d), lambda i: (i, 0)),
            pl.BlockSpec((token_tile, ng), lambda i: (i, 0)),
            pl.BlockSpec((token_tile, ng), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((token_tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, d), qs.dtype),
        interpret=interpret,
    )(qvals, qs, zp)
