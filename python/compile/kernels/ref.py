"""Pure-jnp reference oracle for every Self-Indexing KVCache kernel.

Everything here is written for clarity, not speed: it is the correctness
ground truth that the Pallas kernels (sign_vq / lut_gemv / quant /
sparse_attn) and the Rust-native hot-path implementations are tested
against (pytest + hypothesis on the Python side, golden-vector files on the
Rust side — see python/tests/test_golden.py).

Shapes follow the paper's notation: K ∈ R^{L×D}, groups of VQ_GROUP=4
channels, G = D/4 groups, 16 sign-pattern clusters per group.
"""

import jax.numpy as jnp
import numpy as np

from ..config import QUANT_BITS, QUANT_GROUP, VQ_CLUSTERS, VQ_GROUP

# ---------------------------------------------------------------------------
# Entropy-aware normalization (Eq. 5-7)
# ---------------------------------------------------------------------------


def normalize_keys(k):
    """Channel-wise mean subtraction: K' = K - mu, mu_d = mean_i K[i, d].

    Maximizes sign-bit entropy (Eq. 6).  Softmax over q·K'ᵀ differs from
    q·Kᵀ by the token-independent constant q·mu, so attention weights are
    unchanged (Eq. 7).

    Returns (K', mu) with mu of shape (D,).
    """
    mu = jnp.mean(k, axis=0)
    return k - mu[None, :], mu


# ---------------------------------------------------------------------------
# One-pass sign-based clustering (Eq. 1-4)
# ---------------------------------------------------------------------------


def sign_codes(k):
    """Map each 4-channel subvector to its 4-bit sign pattern (Eq. 2-3).

    Bit order per Eq. 3: channel 0 of the group is the MSB (weight 8),
    channel 3 the LSB (weight 1); sign >= 0 encodes as bit 1.

    k: (L, D) -> codes: (L, G) int32 in [0, 16).
    """
    l, d = k.shape
    g = d // VQ_GROUP
    sub = k.reshape(l, g, VQ_GROUP)
    bits = (sub >= 0).astype(jnp.int32)
    weights = 2 ** jnp.arange(VQ_GROUP - 1, -1, -1, dtype=jnp.int32)  # [8,4,2,1]
    return jnp.sum(bits * weights[None, None, :], axis=-1)


def build_codebook(k, codes):
    """Per-group centroids: mean of the subvectors sharing a sign pattern (Eq. 4).

    Empty clusters get the zero vector (they are never looked up for this K,
    and zero contributes nothing if a future key lands there before the
    codebook is refreshed — matching the Rust implementation).

    k: (L, D), codes: (L, G) -> codebook: (G, 16, VQ_GROUP) f32.
    """
    l, d = k.shape
    g = d // VQ_GROUP
    sub = k.reshape(l, g, VQ_GROUP)                      # (L, G, 4)
    onehot = (codes[:, :, None] == jnp.arange(VQ_CLUSTERS)[None, None, :])
    onehot = onehot.astype(k.dtype)                      # (L, G, 16)
    sums = jnp.einsum("lgc,lgv->gcv", onehot, sub)       # (G, 16, 4)
    counts = jnp.sum(onehot, axis=0)                     # (G, 16)
    safe = jnp.maximum(counts, 1.0)
    return sums / safe[:, :, None]


# ---------------------------------------------------------------------------
# Compressed-domain retrieval: LUT build + LUT-GEMV (Eq. 8)
# ---------------------------------------------------------------------------


def build_lut(q, codebook):
    """Dot each query subvector with its group's 16 centroids.

    q: (D,), codebook: (G, 16, 4) -> lut: (G, 16).
    """
    g = codebook.shape[0]
    qsub = q.reshape(g, VQ_GROUP)
    return jnp.einsum("gv,gcv->gc", qsub, codebook)


def lut_scores(lut, codes):
    """score(token) = sum_g lut[g, codes[token, g]]  (Eq. 8).

    lut: (G, 16), codes: (L, G) -> scores: (L,).
    """
    g = lut.shape[0]
    per_group = lut[jnp.arange(g)[None, :], codes]       # (L, G)
    return jnp.sum(per_group, axis=-1)


def exact_scores(q, k):
    """Full-precision retrieval scores q·Kᵀ (what LUT-GEMV approximates)."""
    return k @ q


def topk_indices(scores, k):
    """Indices of the k largest scores, descending — ties broken by lower index.

    Matches the Rust `selfindex::topk` contract exactly so golden vectors
    compare bit-for-bit: sort key is (-score, index).
    """
    scores = np.asarray(scores)
    order = np.lexsort((np.arange(len(scores)), -scores))
    return jnp.asarray(order[:k])


# ---------------------------------------------------------------------------
# Token-wise quantization (Eq. 9-13)
# ---------------------------------------------------------------------------


def quantize_token_wise(v, bits=QUANT_BITS, group=QUANT_GROUP):
    """Asymmetric min/max quantization per (token, channel-group) (Eq. 9-10).

    v: (L, D) -> (qvals uint8 (L, D), scale (L, D/group), zp (L, D/group)).
    qs == 0 (constant group) is clamped to 1 so dequant returns the constant.
    """
    l, d = v.shape
    ng = d // group
    grouped = v.reshape(l, ng, group)
    vmin = jnp.min(grouped, axis=-1)
    vmax = jnp.max(grouped, axis=-1)
    qs = (vmax - vmin) / (2**bits - 1)
    qs = jnp.where(qs <= 0, 1.0, qs)
    zp = vmin
    q = jnp.clip(
        jnp.round((grouped - zp[:, :, None]) / qs[:, :, None]), 0, 2**bits - 1
    )
    return q.reshape(l, d).astype(jnp.uint8), qs, zp


def dequantize_token_wise(qvals, qs, zp, group=QUANT_GROUP):
    """D(V) = qs * Q(V) + zp  (Eq. 11)."""
    l, d = qvals.shape
    ng = d // group
    grouped = qvals.reshape(l, ng, group).astype(qs.dtype)
    return (grouped * qs[:, :, None] + zp[:, :, None]).reshape(l, d)


def channel_alpha(k):
    """Per-channel magnitude normalizer alpha_j = max_i |K'[i, j]|  (Eq. 12)."""
    alpha = jnp.max(jnp.abs(k), axis=0)
    return jnp.where(alpha <= 0, 1.0, alpha)


def quantize_key_mag(k, alpha, bits=QUANT_BITS, group=QUANT_GROUP):
    """Quantize |K'|/alpha token-wise; signs live in the VQ codes (Eq. 12-13)."""
    khat = jnp.abs(k) / alpha[None, :]
    return quantize_token_wise(khat, bits=bits, group=group)


def code_signs(codes, d):
    """Expand 4-bit sign codes back to a (L, D) ±1 sign plane (MSB-first)."""
    l = codes.shape[0]
    shifts = jnp.arange(VQ_GROUP - 1, -1, -1, dtype=jnp.int32)    # MSB-first
    bits = (codes[:, :, None] >> shifts[None, None, :]) & 1       # (L, G, 4)
    return (bits * 2 - 1).astype(jnp.float32).reshape(l, d)


def dequantize_key(codes, qvals, qs, zp, alpha, group=QUANT_GROUP):
    """Reconstruct K' from sign codes + quantized magnitudes (Eq. 13):

        D(K') = sign ⊙ (alpha ⊙ (qs·Q + zp))
    """
    l, d = qvals.shape
    mag = dequantize_token_wise(qvals, qs, zp, group=group) * alpha[None, :]
    return code_signs(codes, d) * mag


# ---------------------------------------------------------------------------
# Attention references
# ---------------------------------------------------------------------------


def attention_ref(q, k, v, scale=None):
    """Dense single-query attention: softmax(q·Kᵀ/sqrt(D))·V.

    q: (D,), k/v: (L, D) -> (D,).
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = (k @ q) * scale
    w = jnp.exp(logits - jnp.max(logits))
    w = w / jnp.sum(w)
    return w @ v


def sparse_attention_ref(q, k_sel, v_sel, k_sink, v_sink, scale=None):
    """Sparse attention over [sink tokens ++ selected tokens] (paper Fig. 2).

    All inputs full precision — quantized variants dequantize first and then
    call this. q: (D,), *_sel: (S, D), *_sink: (T, D) -> (D,).
    """
    k_all = jnp.concatenate([k_sink, k_sel], axis=0)
    v_all = jnp.concatenate([v_sink, v_sel], axis=0)
    return attention_ref(q, k_all, v_all, scale=scale)


# ---------------------------------------------------------------------------
# End-to-end pipeline (prefill-side compression + decode-side retrieval)
# ---------------------------------------------------------------------------


def compress_prefill(k, v):
    """Everything the paper does to one head's K/V at prefill, as one function.

    Returns a dict mirroring the Rust `kvcache::layout` per-head state.
    """
    k_norm, mu = normalize_keys(k)
    codes = sign_codes(k_norm)
    codebook = build_codebook(k_norm, codes)
    alpha = channel_alpha(k_norm)
    k_q, k_qs, k_zp = quantize_key_mag(k_norm, alpha)
    v_q, v_qs, v_zp = quantize_token_wise(v)
    return {
        "mu": mu, "codes": codes, "codebook": codebook, "alpha": alpha,
        "k_q": k_q, "k_qs": k_qs, "k_zp": k_zp,
        "v_q": v_q, "v_qs": v_qs, "v_zp": v_zp,
    }


def retrieve_and_attend(q, state, k_budget, sink_idx=None, scale=None):
    """Decode-side reference: LUT-GEMV scores → top-k → dequant → attention.

    Sink tokens always attend (in full reconstruction here; the engine keeps
    them fp16) and are excluded from dynamic selection.
    """
    lut = build_lut(q, state["codebook"])
    scores = lut_scores(lut, state["codes"])
    if sink_idx is None:
        sink_idx = jnp.zeros((0,), dtype=jnp.int32)
    sink_idx = jnp.asarray(sink_idx, dtype=jnp.int32)
    if sink_idx.shape[0] > 0:
        scores = scores.at[sink_idx].set(-jnp.inf)
    sel = topk_indices(scores, k_budget)
    k_rec = dequantize_key(state["codes"], state["k_q"], state["k_qs"],
                           state["k_zp"], state["alpha"])
    v_rec = dequantize_token_wise(state["v_q"], state["v_qs"], state["v_zp"])
    out = sparse_attention_ref(
        q, k_rec[sel], v_rec[sel], k_rec[sink_idx], v_rec[sink_idx], scale=scale
    )
    return out, sel
