"""L1 Pallas kernel: compressed-domain retrieval scoring — LUT-GEMV (Eq. 8).

Two pieces, matching the paper's Figure 3:

  1. `build_lut`   — q's G subvectors · 16 centroids each → (G, 16) table.
     A (16·G × 4) GEMV; tiny, one MXU pass, done once per (query, head).
  2. `lut_gemv`    — score every cached token by summing G table lookups
     indexed by its stored 4-bit codes.  This replaces the O(L·D) f32
     dot-product sweep with O(L·G) int-indexed loads: the paper's 4×+
     retrieval speedup and the core "self-indexing" operation.

TPU mapping: the LUT (G×16 f32 = 1 KB at G=16) is broadcast to every token
tile and stays VMEM-resident (the shared-memory LUT of the CUDA version);
token code tiles stream HBM→VMEM once.  The gather is expressed as a
one-hot contraction so it maps onto the MXU rather than scalar loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import VQ_CLUSTERS, VQ_GROUP

TOKEN_TILE = 512


def build_lut(q, codebook):
    """q: (D,), codebook: (G, 16, 4) -> lut: (G, 16).  Pure-jnp on purpose:
    a G×16×4 einsum is a single tiny MXU op; a custom kernel adds nothing."""
    g = codebook.shape[0]
    qsub = q.reshape(g, VQ_GROUP)
    return jnp.einsum("gv,gcv->gc", qsub, codebook)


def _lut_gemv_kernel(lut_ref, codes_ref, scores_ref):
    lut = lut_ref[...]                               # (G, 16)
    codes = codes_ref[...]                           # (T, G)
    # One-hot contraction == gather: onehot (T, G, 16) · lut (G, 16) -> (T,)
    # (iota instead of jnp.arange: pallas kernels may not capture constants)
    cluster_ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, VQ_CLUSTERS), 2)
    onehot = (codes[:, :, None] == cluster_ids)
    scores_ref[...] = jnp.einsum(
        "tgc,gc->t", onehot.astype(lut.dtype), lut
    )


def lut_gemv(lut, codes, *, token_tile=TOKEN_TILE, interpret=True):
    """Approximate scores q·K'ᵀ from the compressed domain.

    lut: (G, 16) f32, codes: (L, G) int32 -> scores: (L,) f32.
    """
    l, g = codes.shape
    assert l % token_tile == 0, (l, token_tile)
    n_tiles = l // token_tile

    return pl.pallas_call(
        _lut_gemv_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((g, VQ_CLUSTERS), lambda i: (0, 0)),   # LUT: resident
            pl.BlockSpec((token_tile, g), lambda i: (i, 0)),    # codes: stream
        ],
        out_specs=pl.BlockSpec((token_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), lut.dtype),
        interpret=interpret,
    )(lut, codes)


def retrieval_scores(q, codebook, codes, *, interpret=True, token_tile=TOKEN_TILE):
    """Fused convenience wrapper: LUT build + LUT-GEMV for one (query, head)."""
    return lut_gemv(build_lut(q, codebook), codes,
                    token_tile=token_tile, interpret=interpret)
