"""L1 Pallas kernel: one-pass sign-based vector quantization (Eq. 1-4).

Produces, for one attention head's normalized key matrix K' ∈ R^{L×D}:

  * codes     (L, G) int32   — 4-bit sign pattern per 4-channel group
  * codebook  (G, 16, 4) f32 — centroid = mean of member subvectors

The kernel runs a 1-D grid over token tiles.  The (G, 16, 4) sums and
(G, 16) counts outputs map every grid step to the same block (index_map
→ 0), so they act as accumulators living in VMEM for the whole pass —
this is the "one pass" property the paper contrasts with k-means: each
key subvector is read exactly once from HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): a token tile of 256×64 f32
is 64 KB; sums+counts are 16×16×4 + 16×16 f32 ≈ 5 KB — everything stays
VMEM-resident.  interpret=True is mandatory on this CPU backend.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import VQ_CLUSTERS, VQ_GROUP

TOKEN_TILE = 256


def _sign_vq_kernel(k_ref, codes_ref, sums_ref, counts_ref, *, g):
    step = pl.program_id(0)

    k = k_ref[...]                                   # (T, D)
    t = k.shape[0]
    sub = k.reshape(t, g, VQ_GROUP)                  # (T, G, 4)

    # Eq. 2-3: sign pattern -> 4-bit code, channel 0 of the group = MSB.
    # (iota instead of jnp.arange: pallas kernels may not capture constants)
    bits = (sub >= 0).astype(jnp.int32)
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, VQ_GROUP), 2)
    weights = jnp.left_shift(1, VQ_GROUP - 1 - pos)
    codes = jnp.sum(bits * weights, axis=-1)         # (T, G)
    codes_ref[...] = codes

    # Eq. 4 numerators: scatter-add subvectors into their cluster slot via
    # a one-hot contraction (no data-dependent writes — TPU friendly).
    cluster_ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, VQ_CLUSTERS), 2)
    onehot = (codes[:, :, None] == cluster_ids)
    onehot = onehot.astype(k.dtype)                  # (T, G, 16)
    tile_sums = jnp.einsum("tgc,tgv->gcv", onehot, sub)
    tile_counts = jnp.sum(onehot, axis=0)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = tile_sums
        counts_ref[...] = tile_counts

    @pl.when(step != 0)
    def _acc():
        sums_ref[...] += tile_sums
        counts_ref[...] += tile_counts


def sign_vq(k, *, token_tile=TOKEN_TILE, interpret=True):
    """One-pass sign-VQ over K' (L, D) -> (codes (L,G) i32, codebook (G,16,4)).

    L must be a multiple of `token_tile` (the callers pad; static shapes are
    required for AOT lowering anyway).
    """
    l, d = k.shape
    assert d % VQ_GROUP == 0, d
    g = d // VQ_GROUP
    assert l % token_tile == 0, (l, token_tile)
    n_tiles = l // token_tile

    codes, sums, counts = pl.pallas_call(
        functools.partial(_sign_vq_kernel, g=g),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((token_tile, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((token_tile, g), lambda i: (i, 0)),
            pl.BlockSpec((g, VQ_CLUSTERS, VQ_GROUP), lambda i: (0, 0, 0)),
            pl.BlockSpec((g, VQ_CLUSTERS), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, g), jnp.int32),
            jax.ShapeDtypeStruct((g, VQ_CLUSTERS, VQ_GROUP), k.dtype),
            jax.ShapeDtypeStruct((g, VQ_CLUSTERS), k.dtype),
        ],
        interpret=interpret,
    )(k)

    codebook = sums / jnp.maximum(counts, 1.0)[:, :, None]
    return codes, codebook
