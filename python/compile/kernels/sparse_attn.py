"""L1 Pallas kernel: dequantization-fused sparse attention.

The paper's third kernel: attend over [64 fp sink tokens ++ top-k selected
tokens], where the selected tokens arrive *still quantized* (sign codes +
2-bit magnitudes + per-token scales) and are dequantized inside the same
kernel pass that computes softmax·V — one HBM→VMEM round-trip, the fusion
that beats KIVI's decompress-then-compute (paper Fig. 5 discussion).

Geometry: at the paper's budget (k = 96 selected + 64 sink = 160 tokens,
head_dim 64) a whole head's working set is 160×64 f32 ≈ 40 KB — far under
VMEM, so the kernel is single-tile per head with the grid ranging over
heads.  For larger budgets the BlockSpec tiles the token axis and carries
an online-softmax (m, l) pair; this configuration is exercised by
`tile_tokens=...` in the tests.

interpret=True is mandatory on this CPU backend (Mosaic custom-calls are
TPU-only); the kernel body is written to lower cleanly either way.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import QUANT_GROUP, VQ_GROUP


def _dequant_k_block(codes, kq, kqs, kzp, alpha, group):
    """Reconstruct K' rows (Eq. 13) from a gathered block, inside the kernel."""
    s, d = kq.shape
    ng = d // group
    mag = (
        kq.reshape(s, ng, group).astype(kqs.dtype) * kqs[:, :, None]
        + kzp[:, :, None]
    ).reshape(s, d) * alpha[None, :]
    # (iota instead of jnp.arange: pallas kernels may not capture constants)
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, VQ_GROUP), 2)
    shifts = VQ_GROUP - 1 - pos
    bits = (codes[:, :, None] >> shifts) & 1
    signs = (bits * 2 - 1).astype(mag.dtype).reshape(s, d)
    return signs * mag


def _dequant_v_block(vq, vqs, vzp, group):
    s, d = vq.shape
    ng = d // group
    return (
        vq.reshape(s, ng, group).astype(vqs.dtype) * vqs[:, :, None]
        + vzp[:, :, None]
    ).reshape(s, d)


def _sparse_attn_kernel(q_ref, codes_ref, kq_ref, kqs_ref, kzp_ref,
                        vq_ref, vqs_ref, vzp_ref, alpha_ref,
                        ksink_ref, vsink_ref, o_ref, *, group, scale):
    q = q_ref[0]                                       # (D,)
    alpha = alpha_ref[0]                               # (D,)

    k_sel = _dequant_k_block(codes_ref[0], kq_ref[0], kqs_ref[0],
                             kzp_ref[0], alpha, group)     # (S, D)
    v_sel = _dequant_v_block(vq_ref[0], vqs_ref[0], vzp_ref[0], group)

    k_all = jnp.concatenate([ksink_ref[0], k_sel], axis=0)  # (T+S, D)
    v_all = jnp.concatenate([vsink_ref[0], v_sel], axis=0)

    logits = (k_all @ q) * scale
    m = jnp.max(logits)
    w = jnp.exp(logits - m)
    o_ref[0] = (w @ v_all) / jnp.sum(w)


def sparse_attention(q, codes, k_q, k_qs, k_zp, v_q, v_qs, v_zp, alpha,
                     k_sink, v_sink, *, group=QUANT_GROUP, scale=None,
                     interpret=True):
    """Fused dequant + sparse attention for a batch of heads.

    Per-head shapes (leading axis H = number of heads in this call):
      q       (H, D)        f32   query
      codes   (H, S, G)     i32   sign codes of the top-k selected tokens
      k_q     (H, S, D)     u8    2-bit key magnitudes (unpacked to u8)
      k_qs/k_zp (H, S, D/32) f32  per-token quant params for keys
      v_q     (H, S, D)     u8    2-bit values
      v_qs/v_zp (H, S, D/32) f32  per-token quant params for values
      alpha   (H, D)        f32   per-channel key magnitude normalizer
      k_sink  (H, T, D)     f32   full-precision sink keys (already K')
      v_sink  (H, T, D)     f32   full-precision sink values
    Returns o (H, D) f32.
    """
    h, d = q.shape
    s = codes.shape[1]
    t = k_sink.shape[1]
    ng = d // group
    g = d // VQ_GROUP
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    def spec(*blk):
        return pl.BlockSpec((1,) + blk, lambda i: (i,) + (0,) * len(blk))

    return pl.pallas_call(
        functools.partial(_sparse_attn_kernel, group=group, scale=scale),
        grid=(h,),
        in_specs=[
            spec(d),            # q
            spec(s, g),         # codes
            spec(s, d),         # k_q
            spec(s, ng),        # k_qs
            spec(s, ng),        # k_zp
            spec(s, d),         # v_q
            spec(s, ng),        # v_qs
            spec(s, ng),        # v_zp
            spec(d),            # alpha
            spec(t, d),         # k_sink
            spec(t, d),         # v_sink
        ],
        out_specs=spec(d),
        out_shape=jax.ShapeDtypeStruct((h, d), q.dtype),
        interpret=interpret,
    )(q, codes, k_q, k_qs, k_zp, v_q, v_qs, v_zp, alpha, k_sink, v_sink)
