"""Build-time training of the tiny served model (never runs at serve time).

The corpus is synthetic but *structured for long-range retrieval*: a mix of
key-value recall, span copying, and zipf-ish filler. A few hundred Adam
steps teach the model induction/retrieval attention heads — giving the key
cache the clustered, anisotropic statistics that the paper's sign-VQ
retrieval is designed for (and that the LongBench/RULER-proxy workloads
exercise; see DESIGN.md §Substitutions).

Usage: python -m compile.train [--steps N] [--out artifacts/weights.bin]
"""

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, default_model
from .model import forward, init_params, param_spec

# ---------------------------------------------------------------------------
# Synthetic long-range corpus (byte-level)
# ---------------------------------------------------------------------------

FILLER_WORDS = [
    b"the", b"of", b"and", b"to", b"in", b"is", b"that", b"for", b"as",
    b"with", b"on", b"by", b"at", b"from", b"system", b"cache", b"token",
    b"memory", b"sparse", b"attention", b"index", b"query", b"model",
]


def _rand_word(r, lo=2, hi=5):
    n = int(r.integers(lo, hi + 1))
    return bytes(r.integers(97, 123, n).tolist())  # a-z


def make_sequence(r, t):
    """One training sequence of exactly t bytes with embedded recall tasks."""
    out = bytearray()
    pending = []  # (key, val) pairs planted, waiting to be queried
    while len(out) < t:
        roll = r.random()
        if roll < 0.3:
            k, v = _rand_word(r, 2, 3), _rand_word(r, 3, 4)
            out += b"@" + k + b"=" + v + b";"
            pending.append((k, v))
        elif roll < 0.65 and pending:
            idx = int(r.integers(0, len(pending)))
            k, v = pending.pop(idx)
            out += b"?" + k + b":" + v + b";"
        elif roll < 0.72:
            span = _rand_word(r, 4, 8)
            out += b"[" + span + b"|" + span + b"]"
        else:
            out += FILLER_WORDS[int(r.integers(0, len(FILLER_WORDS)))] + b" "
    return bytes(out[:t])


def make_batch(r, b, t):
    """Token batch (B, T+1) uint8 — inputs tokens[:, :-1], targets [:, 1:]."""
    return np.stack(
        [np.frombuffer(make_sequence(r, t + 1), dtype=np.uint8) for _ in range(b)]
    ).astype(np.int32)


# ---------------------------------------------------------------------------
# Loss + Adam (hand-rolled: optax is not in this image)
# ---------------------------------------------------------------------------


def loss_fn(params, batch, cfg):
    logits = forward(params, batch[:, :-1], cfg)
    targets = batch[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2**t), v)
    new = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


def train(cfg: ModelConfig, steps=200, batch=4, seq=384, lr=1e-3, seed=0,
          log_every=20, log=print):
    """Train and return (params, loss_history)."""
    r = np.random.default_rng(seed)
    params = init_params(seed, cfg)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt_mv, opt_t, batch_arr, lr_now):
        opt_state = {"m": opt_mv[0], "v": opt_mv[1], "t": opt_t}
        loss, grads = jax.value_and_grad(loss_fn)(params, batch_arr, cfg)
        new_params, new_state = adam_update(params, grads, opt_state, lr_now)
        return new_params, (new_state["m"], new_state["v"]), loss

    history = []
    t0 = time.time()
    for i in range(steps):
        lr_now = lr * 0.5 * (1 + math.cos(math.pi * i / steps))  # cosine
        batch_arr = jnp.asarray(make_batch(r, batch, seq))
        params, (opt["m"], opt["v"]), loss = step(
            params, (opt["m"], opt["v"]), opt["t"], batch_arr, lr_now)
        opt["t"] += 1
        history.append(float(loss))
        if i % log_every == 0 or i == steps - 1:
            log(f"step {i:4d}  loss {float(loss):.4f}  "
                f"({time.time() - t0:.1f}s elapsed)")
    return params, history


# ---------------------------------------------------------------------------
# weights.bin — the Rust-side contract (rust/src/model/weights.rs)
# ---------------------------------------------------------------------------

MAGIC = 0x53494B56  # "SIKV"


def save_weights(path, params, cfg):
    """magic u32 | version u32 | count u32 | per tensor:
    name_len u32 | name | dtype u8 (0=f32) | ndim u8 | dims u32* | data LE."""
    spec = param_spec(cfg)
    with open(path, "wb") as f:
        f.write(np.array([MAGIC, 1, len(spec)], dtype="<u4").tobytes())
        for name, shape in spec:
            arr = np.asarray(params[name], dtype="<f4")
            assert arr.shape == shape, (name, arr.shape, shape)
            nb = name.encode()
            f.write(np.array([len(nb)], dtype="<u4").tobytes())
            f.write(nb)
            f.write(bytes([0, arr.ndim]))
            f.write(np.array(arr.shape, dtype="<u4").tobytes())
            f.write(arr.tobytes())


def load_weights(path, cfg):
    """Inverse of save_weights (used to skip retraining on rebuilds)."""
    params = {}
    with open(path, "rb") as f:
        magic, version, count = np.frombuffer(f.read(12), dtype="<u4")
        assert magic == MAGIC and version == 1, (magic, version)
        for _ in range(count):
            (nlen,) = np.frombuffer(f.read(4), dtype="<u4")
            name = f.read(int(nlen)).decode()
            dtype, ndim = f.read(2)
            assert dtype == 0
            dims = np.frombuffer(f.read(4 * ndim), dtype="<u4")
            n = int(np.prod(dims))
            params[name] = jnp.asarray(
                np.frombuffer(f.read(4 * n), dtype="<f4").reshape(dims))
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=384)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts/weights.bin")
    args = ap.parse_args()
    cfg = default_model()
    params, history = train(cfg, steps=args.steps, batch=args.batch,
                            seq=args.seq, seed=args.seed)
    save_weights(args.out, params, cfg)
    print(f"final loss {history[-1]:.4f} -> {args.out}")


if __name__ == "__main__":
    main()
