"""Self-Indexing KVCache compile path (build-time only; see DESIGN.md)."""
