"""L2: the served model — a tiny GQA transformer in JAX (build-time only).

Exposes the *exact* entry points the Rust coordinator executes via PJRT
(lowered to HLO text by aot.py).  The decode path is split per-layer so the
KV cache never crosses the PJRT boundary: the compressed cache lives in
Rust, which performs compress/append/score/top-k/gather between the
`decode_qkv` and `sparse_attn_step` executables — exactly the paper's
split, where retrieval runs where the cache lives and attention arithmetic
runs in kernels.

Entry points (all functional, weights passed as leading args):
  prefill            tokens -> per-layer K/V + last-token logits
  decode_qkv         x, pos -> q, k, v for ONE layer (shared program,
                     per-layer weights passed as buffers)
  sparse_attn_step   dequant + sparse attention with padding masks (AOT path)
  sparse_attn_step_pallas  full-slot fast path via the fused Pallas kernel
  dense_attn_step    full-cache attention (parity/baseline)
  decode_out         attention output -> next-layer input (o-proj + MLP)
  logits_head        final norm + tied unembedding
  quantize_block     prefill-side sign-VQ + 2-bit quantization (Pallas)

Conventions: f32 activations, RMSNorm, RoPE applied to q/k before caching
(the compressed cache therefore stores *rotated* keys; retrieval scores
use the rotated query — self-consistent).
"""

import functools
import math

import jax
import jax.numpy as jnp

from .config import ModelConfig, QUANT_GROUP, VQ_CLUSTERS, VQ_GROUP
from .kernels import quant as quant_k
from .kernels import sign_vq as sign_vq_k
from .kernels import sparse_attn as sparse_attn_k

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

LAYER_PARAM_NAMES = ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2")


def param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list — the weights.bin / manifest contract
    shared with rust/src/model/weights.rs.  Order is load-bearing."""
    d, h, kvh, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.head_dim, cfg.d_ff)
    spec = [("emb", (cfg.vocab_size, d))]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.ln1", (d,)),
            (f"l{i}.wq", (d, h * hd)),
            (f"l{i}.wk", (d, kvh * hd)),
            (f"l{i}.wv", (d, kvh * hd)),
            (f"l{i}.wo", (h * hd, d)),
            (f"l{i}.ln2", (d,)),
            (f"l{i}.w1", (d, ff)),
            (f"l{i}.w2", (ff, d)),
        ]
    spec.append(("ln_f", (d,)))
    return spec


def init_params(seed, cfg: ModelConfig):
    """He-ish init as a flat {name: array} dict (f32)."""
    params = {}
    key = jax.random.PRNGKey(seed)
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = (jax.random.normal(sub, shape, jnp.float32)
                            / math.sqrt(fan_in))
    return params


def layer_params(params, i):
    return [params[f"l{i}.{n}"] for n in LAYER_PARAM_NAMES]


def params_to_list(params, cfg):
    return [params[name] for name, _ in param_spec(cfg)]


def _dict_from_list(params_list, cfg):
    return {name: arr for (name, _), arr in zip(param_spec(cfg), params_list)}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, g, eps=1e-5):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def rope(x, pos, theta):
    """Rotary embedding.  x: (..., T, n_heads, head_dim), pos: (..., T) i32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., :, None, None].astype(jnp.float32) * freqs[None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)          # (..., T, 1, half)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _gqa_expand(k, r):
    """(..., S, KVH, hd) -> (..., S, KVH*r, hd) repeating each kv head r×."""
    return jnp.repeat(k, r, axis=-2)


# ---------------------------------------------------------------------------
# Full forward (training + prefill)
# ---------------------------------------------------------------------------


def forward(params, tokens, cfg: ModelConfig, *, collect_kv=False):
    """Causal forward over tokens (B, T) -> logits (B, T, vocab).

    With collect_kv=True also returns (K, V): (layers, B, T, KVH, hd),
    post-RoPE — exactly what the Rust cache ingests after prefill — and
    Q: (layers, B, T, H, hd) for SnapKV sink selection.
    """
    b, t = tokens.shape
    r = cfg.gqa_ratio
    scale = 1.0 / math.sqrt(cfg.head_dim)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None, :], (b, t))
    causal = jnp.where(
        jnp.arange(t)[:, None] >= jnp.arange(t)[None, :], 0.0, -jnp.inf
    )[None, None, :, :]                                   # (1, 1, T, S)

    x = params["emb"][tokens]                             # (B, T, d)
    kv_out = []
    for i in range(cfg.n_layers):
        ln1, wq, wk, wv, wo, ln2, w1, w2 = layer_params(params, i)
        h = rmsnorm(x, ln1)
        q = (h @ wq).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (h @ wk).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ wv).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        if collect_kv:
            kv_out.append((k, v, q))
        kx = _gqa_expand(k, r)
        vx = _gqa_expand(v, r)
        logits = jnp.einsum("bthd,bshd->bhts", q, kx) * scale + causal
        m = jnp.max(logits, axis=-1, keepdims=True)
        w = jnp.exp(logits - m)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        o = jnp.einsum("bhts,bshd->bthd", w, vx).reshape(b, t, -1)
        x = x + o @ wo
        h2 = rmsnorm(x, ln2)
        x = x + jax.nn.gelu(h2 @ w1) @ w2

    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["emb"].T
    if collect_kv:
        ks = jnp.stack([k for k, _, _ in kv_out])         # (L*, B, T, KVH, hd)
        vs = jnp.stack([v for _, v, _ in kv_out])
        qs = jnp.stack([q for _, _, q in kv_out])         # (L*, B, T, H, hd)
        return logits, ks, vs, qs
    return logits


SNAPKV_WINDOW = 32


def prefill(params_list, tokens, true_len, cfg: ModelConfig):
    """AOT prefill entry: tokens (1, T) padded, true_len scalar i32.

    Returns (k_cache, v_cache, last_logits, q_window):
      k_cache/v_cache: (layers, T, KVH, hd) f32 (RoPE'd)
      last_logits:     (vocab,) — logits at position true_len-1
      q_window:        (layers, W, H, hd) — the last W=32 *real* queries
                       (positions true_len-W .. true_len-1), for SnapKV
                       sink selection on the Rust side.
    params_list follows param_spec order (flat, AOT-friendly).
    """
    params = _dict_from_list(params_list, cfg)
    logits, ks, vs, qs = forward(params, tokens, cfg, collect_kv=True)
    last = jnp.take(logits[0], true_len - 1, axis=0)
    start = jnp.maximum(true_len - SNAPKV_WINDOW, 0)
    q_window = jax.lax.dynamic_slice_in_dim(
        qs[:, 0], start, SNAPKV_WINDOW, axis=1)           # (L*, W, H, hd)
    return ks[:, 0], vs[:, 0], last, q_window


# ---------------------------------------------------------------------------
# Decode-path entry points (per layer, batch B)
# ---------------------------------------------------------------------------


def decode_qkv(ln1, wq, wk, wv, x, pos, cfg: ModelConfig):
    """One layer's pre-attention: x (B, d), pos (B,) i32 ->
    q (B, H, hd), k (B, KVH, hd), v (B, KVH, hd)."""
    b = x.shape[0]
    h = rmsnorm(x, ln1)
    q = (h @ wq).reshape(b, cfg.n_heads, cfg.head_dim)
    k = (h @ wk).reshape(b, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ wv).reshape(b, cfg.n_kv_heads, cfg.head_dim)
    # RoPE with per-sequence positions: insert a singleton token axis.
    q = rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k = rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    return q, k, v


def sparse_attn_step(q, codes, k_q, k_qs, k_zp, v_q, v_qs, v_zp, alpha,
                     k_sink, v_sink, sel_mask, sink_mask, cfg: ModelConfig):
    """Dequant + sparse attention with GQA and padding masks (AOT decode path).

    Shapes (S = dynamic budget, T = sink slots, G = hd/4, NG = hd/32):
      q        (B, H, hd)       f32
      codes    (B, KVH, S, G)   i32
      k_q/v_q  (B, KVH, S, hd)  u8    2-bit payloads (unpacked)
      *_qs/zp  (B, KVH, S, NG)  f32
      alpha    (B, KVH, hd)     f32
      k_sink/v_sink (B, KVH, T, hd) f32
      sel_mask (B, KVH, S)      f32   0 = live, -inf = padded slot
      sink_mask(B, KVH, T)      f32
    Returns o (B, H, hd).

    The dequantization math is identical to the fused Pallas kernel
    (sparse_attn.py); this masked variant is what aot.py lowers because the
    engine must handle short contexts with padded slots at static shapes.
    """
    b, hq, hd = q.shape
    kvh = codes.shape[1]
    r = hq // kvh
    scale = 1.0 / math.sqrt(hd)

    k_sel = _dequant_keys(codes, k_q, k_qs, k_zp, alpha)       # (B,KVH,S,hd)
    v_sel = _dequant_vals(v_q, v_qs, v_zp)

    k_all = jnp.concatenate([k_sink, k_sel], axis=2)           # (B,KVH,T+S,hd)
    v_all = jnp.concatenate([v_sink, v_sel], axis=2)
    mask = jnp.concatenate([sink_mask, sel_mask], axis=2)      # (B,KVH,T+S)

    qg = q.reshape(b, kvh, r, hd)
    logits = jnp.einsum("bkrd,bksd->bkrs", qg, k_all) * scale
    logits = logits + mask[:, :, None, :]
    m = jnp.max(logits, axis=-1, keepdims=True)
    w = jnp.exp(logits - m)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o = jnp.einsum("bkrs,bksd->bkrd", w, v_all)
    return o.reshape(b, hq, hd)


def sparse_attn_step_pallas(q, codes, k_q, k_qs, k_zp, v_q, v_qs, v_zp,
                            alpha, k_sink, v_sink, cfg: ModelConfig,
                            *, interpret=True):
    """Full-slot fast path through the fused Pallas kernel (no padding).

    Same shapes as sparse_attn_step minus the masks. GQA is realized by
    flattening (B, KVH, R) -> heads and repeating the kv blocks R×.
    """
    b, hq, hd = q.shape
    kvh = codes.shape[1]
    r = hq // kvh

    def rep(x):  # (B, KVH, ...) -> (B*KVH*R, ...)
        x = jnp.repeat(x[:, :, None], r, axis=2)
        return x.reshape((b * kvh * r,) + x.shape[3:])

    qf = q.reshape(b * hq, hd)
    o = sparse_attn_k.sparse_attention(
        qf, rep(codes), rep(k_q), rep(k_qs), rep(k_zp),
        rep(v_q), rep(v_qs), rep(v_zp), rep(alpha),
        rep(k_sink), rep(v_sink), interpret=interpret,
    )
    return o.reshape(b, hq, hd)


def _dequant_keys(codes, k_q, k_qs, k_zp, alpha):
    """Vectorized Eq. 13 over arbitrary leading axes."""
    lead = k_q.shape[:-2]
    s, hd = k_q.shape[-2:]
    ng = hd // QUANT_GROUP
    mag = (k_q.reshape(lead + (s, ng, QUANT_GROUP)).astype(jnp.float32)
           * k_qs[..., None] + k_zp[..., None]).reshape(lead + (s, hd))
    mag = mag * alpha[..., None, :]
    shifts = jnp.arange(VQ_GROUP - 1, -1, -1, dtype=jnp.int32)
    bits = (codes[..., None] >> shifts) & 1
    signs = (bits * 2 - 1).astype(jnp.float32).reshape(lead + (s, hd))
    return signs * mag


def _dequant_vals(v_q, v_qs, v_zp):
    lead = v_q.shape[:-2]
    s, hd = v_q.shape[-2:]
    ng = hd // QUANT_GROUP
    return (v_q.reshape(lead + (s, ng, QUANT_GROUP)).astype(jnp.float32)
            * v_qs[..., None] + v_zp[..., None]).reshape(lead + (s, hd))


def dense_attn_step(q, k_cache, v_cache, cache_len, cfg: ModelConfig):
    """Full-cache decode attention (the FlashAttention-2 baseline role).

    q (B, H, hd), k_cache/v_cache (B, Lmax, KVH, hd), cache_len (B,) i32.
    """
    b, hq, hd = q.shape
    lmax = k_cache.shape[1]
    kvh = k_cache.shape[2]
    r = hq // kvh
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, kvh, r, hd)
    kx = k_cache.swapaxes(1, 2)                              # (B, KVH, L, hd)
    vx = v_cache.swapaxes(1, 2)
    mask = jnp.where(
        jnp.arange(lmax)[None, :] < cache_len[:, None], 0.0, -jnp.inf
    )[:, None, None, :]                                      # (B,1,1,L)
    logits = jnp.einsum("bkrd,bkld->bkrl", qg, kx) * scale + mask
    m = jnp.max(logits, axis=-1, keepdims=True)
    w = jnp.exp(logits - m)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o = jnp.einsum("bkrl,bkld->bkrd", w, vx)
    return o.reshape(b, hq, hd)


def decode_out(o, x, wo, ln2, w1, w2):
    """Post-attention half of a layer: o (B,H,hd) flat-proj + MLP residual."""
    b = x.shape[0]
    x = x + o.reshape(b, -1) @ wo
    h2 = rmsnorm(x, ln2)
    return x + jax.nn.gelu(h2 @ w1) @ w2


def logits_head(x, ln_f, emb):
    """Final RMSNorm + tied unembedding: x (B, d) -> (B, vocab)."""
    return rmsnorm(x, ln_f) @ emb.T


def embed(emb, tokens):
    """Token embedding lookup (B,) -> (B, d)."""
    return emb[tokens]


# ---------------------------------------------------------------------------
# Prefill-side compression (AOT program exercising the Pallas kernels)
# ---------------------------------------------------------------------------


def quantize_block(k_block, v_block, mu, alpha, *, interpret=True):
    """Compress one kv-head block of T tokens with the Pallas kernels.

    k_block/v_block (T, hd) f32; mu/alpha (hd,) — prefill statistics.
    Returns (codes i32 (T,G), sums f32 (G,16,4), counts f32 (G,16),
             k_q u8, k_qs, k_zp, v_q u8, v_qs, v_zp).
    sums/counts let the caller accumulate the codebook across blocks
    (preserving the one-pass property when prefill streams in chunks).
    """
    t, hd = k_block.shape
    kn = k_block - mu[None, :]
    codes, sums, counts = _sign_vq_sums(kn, interpret=interpret)
    khat = jnp.abs(kn) / alpha[None, :]
    k_q, k_qs, k_zp = quant_k.quantize_tokens(
        khat, token_tile=t, interpret=interpret)
    v_q, v_qs, v_zp = quant_k.quantize_tokens(
        v_block, token_tile=t, interpret=interpret)
    return codes, sums, counts, k_q, k_qs, k_zp, v_q, v_qs, v_zp


def _sign_vq_sums(kn, *, interpret):
    """sign_vq but returning raw sums/counts (pre-division) for streaming."""
    from jax.experimental import pallas as pl
    l, d = kn.shape
    g = d // VQ_GROUP
    return pl.pallas_call(
        functools.partial(sign_vq_k._sign_vq_kernel, g=g),
        grid=(1,),
        in_specs=[pl.BlockSpec((l, d), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((l, g), lambda i: (0, 0)),
            pl.BlockSpec((g, VQ_CLUSTERS, VQ_GROUP), lambda i: (0, 0, 0)),
            pl.BlockSpec((g, VQ_CLUSTERS), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, g), jnp.int32),
            jax.ShapeDtypeStruct((g, VQ_CLUSTERS, VQ_GROUP), kn.dtype),
            jax.ShapeDtypeStruct((g, VQ_CLUSTERS), kn.dtype),
        ],
        interpret=interpret,
    )(kn)
