"""AOT pipeline: train (or load) weights, lower every entry point to HLO
text, and write the artifact manifest the Rust runtime consumes.

HLO *text* — not serialized HloModuleProto — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  weights.bin        model parameters (contract: rust/src/model/weights.rs)
  manifest.json      model config + per-artifact input/output specs
  <name>.hlo.txt     one per entry point × static-shape bucket

Usage: python -m compile.aot [--steps N] [--out-dir DIR] [--force]
       python -m compile.aot --skip-train   # random weights (CI / tests)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .config import (DECODE_BATCHES, QUANT_GROUP, SINK_TOKENS,
                     SPARSE_K, VQ_CLUSTERS, VQ_GROUP, default_model)
from .train import load_weights, save_weights, train

PREFILL_LENS = (256, 1024, 4096)
DENSE_PARITY = ((1, 256), (4, 1024))   # dense_attn buckets for tests/baseline
QUANT_T = 256                          # quantize_block token tile


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _iospec(args, names):
    assert len(args) == len(names), (len(args), names)
    return [
        {"name": n, "dtype": str(a.dtype), "shape": list(a.shape)}
        for n, a in zip(names, args)
    ]


def build_entries(cfg):
    """Yield (artifact_name, fn, arg_specs, arg_names, output_names)."""
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g, ng = cfg.vq_groups, cfg.quant_groups
    vocab, ff = cfg.vocab_size, cfg.d_ff
    pspec = M.param_spec(cfg)
    pnames = [f"param:{n}" for n, _ in pspec]
    pargs = [spec(s) for _, s in pspec]

    entries = []

    for L in PREFILL_LENS:
        entries.append((
            f"prefill_l{L}",
            lambda *a, cfg=cfg: M.prefill(a[:-2], a[-2], a[-1], cfg),
            pargs + [spec((1, L), jnp.int32), spec((), jnp.int32)],
            pnames + ["tokens", "true_len"],
            ["k_cache", "v_cache", "last_logits", "q_window"],
        ))

    for B in DECODE_BATCHES:
        entries.append((
            f"embed_b{B}",
            lambda emb, tok: (M.embed(emb, tok),),
            [spec((vocab, d)), spec((B,), jnp.int32)],
            ["param:emb", "tokens"],
            ["x"],
        ))
        entries.append((
            f"decode_qkv_b{B}",
            lambda ln1, wq, wk, wv, x, pos, cfg=cfg: M.decode_qkv(
                ln1, wq, wk, wv, x, pos, cfg),
            [spec((d,)), spec((d, h * hd)), spec((d, kvh * hd)),
             spec((d, kvh * hd)), spec((B, d)), spec((B,), jnp.int32)],
            ["layer:ln1", "layer:wq", "layer:wk", "layer:wv", "x", "pos"],
            ["q", "k", "v"],
        ))
        s, t = SPARSE_K, SINK_TOKENS
        entries.append((
            f"sparse_attn_b{B}",
            lambda *a, cfg=cfg: (M.sparse_attn_step(*a, cfg),),
            [spec((B, h, hd)), spec((B, kvh, s, g), jnp.int32),
             spec((B, kvh, s, hd), jnp.uint8), spec((B, kvh, s, ng)),
             spec((B, kvh, s, ng)), spec((B, kvh, s, hd), jnp.uint8),
             spec((B, kvh, s, ng)), spec((B, kvh, s, ng)),
             spec((B, kvh, hd)), spec((B, kvh, t, hd)), spec((B, kvh, t, hd)),
             spec((B, kvh, s)), spec((B, kvh, t))],
            ["q", "codes", "k_q", "k_qs", "k_zp", "v_q", "v_qs", "v_zp",
             "alpha", "k_sink", "v_sink", "sel_mask", "sink_mask"],
            ["o"],
        ))
        entries.append((
            f"decode_out_b{B}",
            lambda o, x, wo, ln2, w1, w2: (M.decode_out(o, x, wo, ln2, w1, w2),),
            [spec((B, h, hd)), spec((B, d)), spec((h * hd, d)), spec((d,)),
             spec((d, ff)), spec((ff, d))],
            ["o", "x", "layer:wo", "layer:ln2", "layer:w1", "layer:w2"],
            ["x_next"],
        ))
        entries.append((
            f"logits_b{B}",
            lambda x, ln_f, emb: (M.logits_head(x, ln_f, emb),),
            [spec((B, d)), spec((d,)), spec((vocab, d))],
            ["x", "param:ln_f", "param:emb"],
            ["logits"],
        ))

    for B, L in DENSE_PARITY:
        entries.append((
            f"dense_attn_b{B}_l{L}",
            lambda q, k, v, n, cfg=cfg: (M.dense_attn_step(q, k, v, n, cfg),),
            [spec((B, h, hd)), spec((B, L, kvh, hd)), spec((B, L, kvh, hd)),
             spec((B,), jnp.int32)],
            ["q", "k_cache", "v_cache", "cache_len"],
            ["o"],
        ))

    entries.append((
        f"quantize_t{QUANT_T}",
        lambda k, v, mu, alpha: M.quantize_block(k, v, mu, alpha),
        [spec((QUANT_T, hd)), spec((QUANT_T, hd)), spec((hd,)), spec((hd,))],
        ["k_block", "v_block", "mu", "alpha"],
        ["codes", "sums", "counts", "k_q", "k_qs", "k_zp",
         "v_q", "v_qs", "v_zp"],
    ))
    return entries


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("TRAIN_STEPS", 240)))
    ap.add_argument("--skip-train", action="store_true",
                    help="random-init weights (fast; tests/CI)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    cfg = default_model()

    wpath = os.path.join(out, "weights.bin")
    if os.path.exists(wpath) and not args.force:
        print(f"weights: reusing {wpath}")
        params = load_weights(wpath, cfg)
    elif args.skip_train or os.environ.get("SKIP_TRAIN"):
        print("weights: random init (--skip-train)")
        params = M.init_params(0, cfg)
        save_weights(wpath, params, cfg)
    else:
        print(f"weights: training {args.steps} steps ...", flush=True)
        params, history = train(cfg, steps=args.steps)
        save_weights(wpath, params, cfg)
        with open(os.path.join(out, "train_log.json"), "w") as f:
            json.dump({"loss": history}, f)
        print(f"weights: final loss {history[-1]:.4f}")

    manifest = {
        "model": {
            "vocab_size": cfg.vocab_size, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
            "rope_theta": cfg.rope_theta,
        },
        "selfindex": {
            "vq_group": VQ_GROUP, "vq_clusters": VQ_CLUSTERS,
            "quant_bits": 2, "quant_group": QUANT_GROUP,
            "sink_tokens": SINK_TOKENS, "sparse_k": SPARSE_K,
        },
        "params": [{"name": n, "shape": list(s)} for n, s in M.param_spec(cfg)],
        "artifacts": {},
    }

    for name, fn, arg_specs, arg_names, out_names in build_entries(cfg):
        path = os.path.join(out, f"{name}.hlo.txt")
        if os.path.exists(path) and not args.force:
            print(f"lower: reusing {name}")
        else:
            print(f"lower: {name} ...", flush=True)
            lowered = jax.jit(fn).lower(*arg_specs)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
        out_shapes = jax.eval_shape(fn, *arg_specs)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _iospec(arg_specs, arg_names),
            "outputs": [
                {"name": n, "dtype": str(o.dtype), "shape": list(o.shape)}
                for n, o in zip(out_names, out_shapes)
            ],
        }

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts"
          f" -> {out}/manifest.json")


if __name__ == "__main__":
    main()
