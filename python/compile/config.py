"""Shared model / cache hyper-parameters for the compile path.

These mirror `rust/src/config` — the Rust side re-validates every value at
artifact-load time (shape metadata is embedded in `artifacts/manifest.json`).

Paper defaults (Self-Indexing KVCache, AAAI 2026):
  * sign-VQ group size   = 4 channels  -> 16 clusters / group   (Eq. 1-3)
  * codebook             = 16 centroids per group, one-pass      (Eq. 4)
  * quantization         = 2-bit token-wise, groups of 32        (Eq. 9-11)
  * sink tokens          = 64 full-precision (SnapKV-selected)
  * decode sparsity      = 7.5 % of context (dynamic top-k)
"""

from dataclasses import dataclass, field


VQ_GROUP: int = 4          # channels per sign-VQ group
VQ_CLUSTERS: int = 16      # 2**VQ_GROUP sign patterns
QUANT_BITS: int = 2        # bits per magnitude / value element
QUANT_GROUP: int = 32      # channels per quant scale/zero-point group
SINK_TOKENS: int = 64
DEFAULT_SPARSITY: float = 0.075


@dataclass(frozen=True)
class ModelConfig:
    """Tiny GQA transformer served by the Rust coordinator.

    Sized so that build-time training (a few hundred steps, CPU) and
    interpret-mode Pallas stay tractable while keeping the attention
    geometry of the paper's targets (GQA, head_dim that divides into
    4-channel VQ groups and 32-channel quant groups).
    """

    vocab_size: int = 256          # byte-level
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8               # query heads
    n_kv_heads: int = 2            # GQA 4:1 like Llama-3.1 (32:8)
    head_dim: int = 64             # -> G = 16 sign-VQ groups, 2 quant groups
    d_ff: int = 512
    max_seq: int = 8192
    rope_theta: float = 10000.0

    @property
    def vq_groups(self) -> int:
        assert self.head_dim % VQ_GROUP == 0
        return self.head_dim // VQ_GROUP

    @property
    def quant_groups(self) -> int:
        assert self.head_dim % QUANT_GROUP == 0
        return self.head_dim // QUANT_GROUP

    @property
    def gqa_ratio(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads


# Static shape buckets lowered to HLO (PJRT executables are shape-specialized).
PREFILL_CHUNKS = (128, 512)        # tokens per prefill call
DECODE_BATCHES = (1, 4, 8)         # sequences per decode step
SPARSE_K = 96                      # dynamically selected tokens (paper: 160 budget - 64 sink)


def default_model() -> ModelConfig:
    return ModelConfig()
