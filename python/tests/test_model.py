"""L2 model invariants: the per-layer decode decomposition must replay the
monolithic forward() exactly — this is THE parity contract the Rust engine
relies on (it executes the same decomposed programs via PJRT).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.config import ModelConfig
from compile.kernels import ref as ref_k

CFG = ModelConfig(vocab_size=64, d_model=64, n_layers=2, n_heads=4,
                  n_kv_heads=2, head_dim=32, d_ff=128, max_seq=512)


@pytest.fixture(scope="module")
def params():
    return M.init_params(1, CFG)


def test_prefill_matches_forward(params):
    r = np.random.default_rng(0)
    t = 32
    tokens = jnp.asarray(r.integers(0, CFG.vocab_size, (1, t)), jnp.int32)
    logits = M.forward(params, tokens, CFG)
    plist = M.params_to_list(params, CFG)
    ks, vs, last, qw = M.prefill(plist, tokens, jnp.int32(t), CFG)
    np.testing.assert_allclose(last, logits[0, -1], rtol=1e-5, atol=1e-5)
    assert ks.shape == (CFG.n_layers, t, CFG.n_kv_heads, CFG.head_dim)
    assert qw.shape == (CFG.n_layers, M.SNAPKV_WINDOW, CFG.n_heads, CFG.head_dim)
    # true_len < t picks interior position
    _, _, mid, _ = M.prefill(plist, tokens, jnp.int32(t // 2), CFG)
    np.testing.assert_allclose(mid, logits[0, t // 2 - 1], rtol=1e-5, atol=1e-5)


def test_prefill_q_window_matches_forward_queries(params):
    """q_window rows must equal the true last-W queries of each layer —
    the SnapKV contract with rust/src/kvcache/sink.rs."""
    r = np.random.default_rng(9)
    t = 48
    tokens = jnp.asarray(r.integers(0, CFG.vocab_size, (1, t)), jnp.int32)
    _, _, _, qw = M.prefill(M.params_to_list(params, CFG), tokens,
                            jnp.int32(t), CFG)
    # recompute layer-0 queries directly
    x = params["emb"][tokens]
    ln1, wq, *_ = M.layer_params(params, 0)
    h = M.rmsnorm(x, ln1)
    q = (h @ wq).reshape(1, t, CFG.n_heads, CFG.head_dim)
    pos = jnp.arange(t, dtype=jnp.int32)[None]
    q = M.rope(q, pos, CFG.rope_theta)
    np.testing.assert_allclose(
        qw[0], q[0, t - M.SNAPKV_WINDOW:], rtol=2e-4, atol=2e-5)


def test_decode_decomposition_replays_forward(params):
    """Prefill T-1 tokens, decode token T-1 via the per-layer path (dense
    attention), and match forward()'s logits at the last position."""
    r = np.random.default_rng(1)
    t = 24
    tokens = jnp.asarray(r.integers(0, CFG.vocab_size, (1, t)), jnp.int32)
    full_logits, ks, vs, _ = M.forward(params, tokens, CFG, collect_kv=True)

    # decode position t-1 given cache of t-1 tokens
    pos = jnp.asarray([t - 1], jnp.int32)
    x = M.embed(params["emb"], tokens[:, t - 1])
    for i in range(CFG.n_layers):
        ln1, wq, wk, wv, wo, ln2, w1, w2 = M.layer_params(params, i)
        q, k_new, v_new = M.decode_qkv(ln1, wq, wk, wv, x, pos, CFG)
        # cache = first t-1 prefill rows + this step's k/v
        k_cache = jnp.concatenate([ks[i, :, : t - 1], k_new[:, None]], axis=1)
        v_cache = jnp.concatenate([vs[i, :, : t - 1], v_new[:, None]], axis=1)
        np.testing.assert_allclose(k_new, ks[i, :, t - 1], rtol=2e-4, atol=2e-5)
        o = M.dense_attn_step(q, k_cache, v_cache, jnp.asarray([t], jnp.int32), CFG)
        x = M.decode_out(o, x, wo, ln2, w1, w2)
    logits = M.logits_head(x, params["ln_f"], params["emb"])
    np.testing.assert_allclose(
        logits[0], full_logits[0, -1], rtol=2e-4, atol=2e-4)


def test_dense_attn_respects_cache_len(params):
    r = np.random.default_rng(2)
    b, lmax = 2, 16
    q = jnp.asarray(r.standard_normal((b, CFG.n_heads, CFG.head_dim)), jnp.float32)
    k = jnp.asarray(r.standard_normal((b, lmax, CFG.n_kv_heads, CFG.head_dim)), jnp.float32)
    v = jnp.asarray(r.standard_normal((b, lmax, CFG.n_kv_heads, CFG.head_dim)), jnp.float32)
    n = jnp.asarray([5, 12], jnp.int32)
    o = M.dense_attn_step(q, k, v, n, CFG)
    # garbage beyond cache_len must not affect the output
    k2 = k.at[0, 5:].set(1e6)
    v2 = v.at[0, 5:].set(-1e6)
    o2 = M.dense_attn_step(q, k2, v2, n, CFG)
    np.testing.assert_allclose(o[0], o2[0], rtol=1e-6)


def test_sparse_attn_masked_matches_pallas_on_full_slots():
    """The AOT (masked-jnp) program and the fused Pallas kernel agree when
    every slot is live — same dequant math, two implementations."""
    r = np.random.default_rng(3)
    cfg = CFG
    b, s, t = 2, 16, 8
    hd, kvh, h = cfg.head_dim, cfg.n_kv_heads, cfg.n_heads
    g, ng = hd // 4, hd // 32

    q = jnp.asarray(r.standard_normal((b, h, hd)), jnp.float32)
    codes = jnp.asarray(r.integers(0, 16, (b, kvh, s, g)), jnp.int32)
    k_q = jnp.asarray(r.integers(0, 4, (b, kvh, s, hd)), jnp.uint8)
    k_qs = jnp.asarray(r.uniform(0.1, 0.4, (b, kvh, s, ng)), jnp.float32)
    k_zp = jnp.asarray(r.uniform(0.0, 0.1, (b, kvh, s, ng)), jnp.float32)
    v_q = jnp.asarray(r.integers(0, 4, (b, kvh, s, hd)), jnp.uint8)
    v_qs = jnp.asarray(r.uniform(0.1, 0.4, (b, kvh, s, ng)), jnp.float32)
    v_zp = jnp.asarray(r.uniform(-0.5, 0.0, (b, kvh, s, ng)), jnp.float32)
    alpha = jnp.asarray(r.uniform(0.5, 2.0, (b, kvh, hd)), jnp.float32)
    k_sink = jnp.asarray(r.standard_normal((b, kvh, t, hd)), jnp.float32)
    v_sink = jnp.asarray(r.standard_normal((b, kvh, t, hd)), jnp.float32)
    zeros_s = jnp.zeros((b, kvh, s), jnp.float32)
    zeros_t = jnp.zeros((b, kvh, t), jnp.float32)

    o_masked = M.sparse_attn_step(q, codes, k_q, k_qs, k_zp, v_q, v_qs, v_zp,
                                  alpha, k_sink, v_sink, zeros_s, zeros_t, CFG)
    o_pallas = M.sparse_attn_step_pallas(q, codes, k_q, k_qs, k_zp, v_q, v_qs,
                                         v_zp, alpha, k_sink, v_sink, CFG)
    np.testing.assert_allclose(o_masked, o_pallas, rtol=1e-4, atol=1e-5)


def test_sparse_attn_mask_excludes_padding():
    r = np.random.default_rng(4)
    cfg = CFG
    b, s, t = 1, 8, 4
    hd, kvh, h = cfg.head_dim, cfg.n_kv_heads, cfg.n_heads
    g, ng = hd // 4, hd // 32
    mk = lambda *sh, dt=jnp.float32: jnp.asarray(r.standard_normal(sh), dt)
    q = mk(b, h, hd)
    codes = jnp.asarray(r.integers(0, 16, (b, kvh, s, g)), jnp.int32)
    k_q = jnp.asarray(r.integers(0, 4, (b, kvh, s, hd)), jnp.uint8)
    v_q = jnp.asarray(r.integers(0, 4, (b, kvh, s, hd)), jnp.uint8)
    k_qs = jnp.abs(mk(b, kvh, s, ng)) + 0.1
    k_zp, v_qs, v_zp = mk(b, kvh, s, ng), jnp.abs(mk(b, kvh, s, ng)) + 0.1, mk(b, kvh, s, ng)
    alpha = jnp.abs(mk(b, kvh, hd)) + 0.5
    k_sink, v_sink = mk(b, kvh, t, hd), mk(b, kvh, t, hd)
    neg = jnp.full((b, kvh, s), -jnp.inf).at[:, :, :4].set(0.0)  # last 4 padded
    zt = jnp.zeros((b, kvh, t), jnp.float32)

    o1 = M.sparse_attn_step(q, codes, k_q, k_qs, k_zp, v_q, v_qs, v_zp,
                            alpha, k_sink, v_sink, neg, zt, CFG)
    # mutate the padded slots wildly — output must not change
    k_q2 = k_q.at[:, :, 4:].set(3)
    v_zp2 = v_zp.at[:, :, 4:].set(99.0)
    o2 = M.sparse_attn_step(q, codes, k_q2, k_qs, k_zp, v_q, v_qs, v_zp2,
                            alpha, k_sink, v_sink, neg, zt, CFG)
    np.testing.assert_allclose(o1, o2, rtol=1e-6)


def test_quantize_block_matches_ref():
    r = np.random.default_rng(5)
    t, hd = 256, 64
    k = jnp.asarray(r.standard_normal((t, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((t, hd)), jnp.float32)
    mu = jnp.mean(k, axis=0)
    kn = k - mu
    alpha = ref_k.channel_alpha(kn)
    codes, sums, counts, k_q, k_qs, k_zp, v_q, v_qs, v_zp = M.quantize_block(
        k, v, mu, alpha)
    np.testing.assert_array_equal(codes, ref_k.sign_codes(kn))
    cb = np.asarray(sums) / np.maximum(np.asarray(counts), 1.0)[:, :, None]
    np.testing.assert_allclose(
        cb, ref_k.build_codebook(kn, ref_k.sign_codes(kn)), rtol=1e-4, atol=1e-5)
    kq_r, kqs_r, kzp_r = ref_k.quantize_key_mag(kn, alpha)
    np.testing.assert_array_equal(k_q, kq_r)
    vq_r, vqs_r, vzp_r = ref_k.quantize_token_wise(v)
    np.testing.assert_array_equal(v_q, vq_r)
    np.testing.assert_allclose(v_qs, vqs_r, rtol=1e-6)


def test_rope_position_consistency():
    """decode_qkv at position p must equal forward()'s K at position p —
    guarantees cache coherence between prefill (batch RoPE) and decode."""
    params = M.init_params(7, CFG)
    r = np.random.default_rng(8)
    t = 12
    tokens = jnp.asarray(r.integers(0, CFG.vocab_size, (1, t)), jnp.int32)
    _, ks, vs, _ = M.forward(params, tokens, CFG, collect_kv=True)
    # replay every position through the decode path
    x_seq = params["emb"][tokens]
    ln1, wq, wk, wv, *_ = M.layer_params(params, 0)
    for p in [0, 3, t - 1]:
        x = x_seq[:, p]
        q, k, v = M.decode_qkv(ln1, wq, wk, wv, x, jnp.asarray([p], jnp.int32), CFG)
        np.testing.assert_allclose(k[0], ks[0, 0, p], rtol=2e-4, atol=2e-5)
