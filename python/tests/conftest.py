import os
import sys

# Make `compile` importable as a package from the python/ directory, and
# keep JAX on CPU with deterministic, quiet behaviour.
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
