"""Pallas kernels vs the pure-jnp oracle (ref.py) — the core L1 signal.

Hypothesis sweeps shapes/seeds/dtypes; every kernel must match ref within
float tolerance, and structural invariants of the paper (code ranges,
entropy balance, softmax shift-invariance, quant error bounds) must hold.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import QUANT_GROUP, VQ_CLUSTERS, VQ_GROUP
from compile.kernels import lut_gemv, quant, ref, sign_vq, sparse_attn

DIMS = st.sampled_from([8, 32, 64, 128])
LENS = st.sampled_from([64, 256, 512])


def keys(seed, l, d, scale=1.0, mean=0.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(mean + scale * r.standard_normal((l, d), dtype=np.float32))


# ---------------------------------------------------------------------------
# sign codes + codebook
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), l=LENS, d=DIMS)
def test_sign_vq_matches_ref(seed, l, d):
    k = keys(seed, l, d)
    codes_p, cb_p = sign_vq.sign_vq(k, token_tile=64)
    codes_r = ref.sign_codes(k)
    cb_r = ref.build_codebook(k, codes_r)
    np.testing.assert_array_equal(codes_p, codes_r)
    np.testing.assert_allclose(cb_p, cb_r, rtol=1e-5, atol=1e-6)


def test_sign_code_bit_order():
    # channel 0 is the MSB: [+,-,-,-] -> 0b1000 = 8, [-,-,-,+] -> 1.
    k = jnp.asarray([[1.0, -1.0, -1.0, -1.0], [-1.0, -1.0, -1.0, 1.0]])
    codes = ref.sign_codes(k)
    np.testing.assert_array_equal(np.asarray(codes).ravel(), [8, 1])


def test_codes_in_range(rng):
    k = keys(1, 256, 64)
    codes = ref.sign_codes(k)
    assert codes.min() >= 0 and codes.max() < VQ_CLUSTERS


def test_codebook_centroid_sign_consistency():
    # Each centroid must lie in the orthant of its own sign pattern
    # (mean of vectors sharing sign s has sign s componentwise).
    k = keys(2, 512, 32)
    codes = ref.sign_codes(k)
    cb = np.asarray(ref.build_codebook(k, codes))
    counts = np.zeros((cb.shape[0], VQ_CLUSTERS))
    for g in range(cb.shape[0]):
        cg = np.asarray(codes)[:, g]
        for c in range(VQ_CLUSTERS):
            n = (cg == c).sum()
            if n == 0:
                continue
            bits = [(c >> (VQ_GROUP - 1 - i)) & 1 for i in range(VQ_GROUP)]
            for i, b in enumerate(bits):
                v = cb[g, c, i]
                assert (v >= 0) == bool(b), (g, c, i, v)


def test_normalization_balances_signs():
    # Entropy-aware normalization (Eq. 5-6): post-normalization sign rates
    # are ~50/50 even when the raw keys have strong channel offsets.
    k = keys(3, 4096, 64, mean=2.5)  # heavily biased positive
    kn, _ = ref.normalize_keys(k)
    pos_rate = float((np.asarray(kn) >= 0).mean())
    assert abs(pos_rate - 0.5) < 0.02
    raw_rate = float((np.asarray(k) >= 0).mean())
    assert raw_rate > 0.95  # sanity: it *was* unbalanced


def test_normalization_preserves_softmax():
    # Eq. 7: subtracting mu from every key shifts all logits by q·mu,
    # leaving softmax weights (and attention output) unchanged.
    k = keys(4, 128, 64, mean=1.0)
    v = keys(5, 128, 64)
    q = keys(6, 1, 64)[0]
    kn, _ = ref.normalize_keys(k)
    out_raw = ref.attention_ref(q, k, v)
    out_norm = ref.attention_ref(q, kn, v)
    np.testing.assert_allclose(out_raw, out_norm, rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# LUT-GEMV retrieval
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), l=LENS, d=DIMS)
def test_lut_gemv_matches_ref(seed, l, d):
    k = keys(seed, l, d)
    q = keys(seed + 1, 1, d)[0]
    codes = ref.sign_codes(k)
    cb = ref.build_codebook(k, codes)
    lut = lut_gemv.build_lut(q, cb)
    np.testing.assert_allclose(lut, ref.build_lut(q, cb), rtol=1e-5, atol=1e-6)
    s_p = lut_gemv.lut_gemv(lut, codes, token_tile=64)
    s_r = ref.lut_scores(lut, codes)
    np.testing.assert_allclose(s_p, s_r, rtol=1e-5, atol=1e-5)


def test_lut_scores_exact_when_keys_are_centroids():
    # If, within every group, all subvectors sharing a sign pattern are
    # identical, then each centroid IS that subvector and LUT scores equal
    # exact q·K' scores. Build one prototype per (group, pattern) whose
    # signs realize the pattern, then compose keys from prototypes.
    r = np.random.default_rng(7)
    d, g, l = 32, 32 // VQ_GROUP, 256
    signs = np.array(
        [[1 if (c >> (VQ_GROUP - 1 - i)) & 1 else -1 for i in range(VQ_GROUP)]
         for c in range(VQ_CLUSTERS)], dtype=np.float32)          # (16, 4)
    protos = signs[None] * r.uniform(0.5, 1.5, (g, VQ_CLUSTERS, VQ_GROUP))
    protos = protos.astype(np.float32)                            # (G, 16, 4)
    pick = r.integers(0, VQ_CLUSTERS, size=(l, g))
    k = jnp.asarray(
        np.stack([protos[gi, pick[:, gi]] for gi in range(g)], axis=1)
        .reshape(l, d))
    q = jnp.asarray(r.standard_normal(d).astype(np.float32))
    codes = ref.sign_codes(k)
    cb = ref.build_codebook(k, codes)
    approx = ref.lut_scores(ref.build_lut(q, cb), codes)
    exact = ref.exact_scores(q, k)
    np.testing.assert_allclose(approx, exact, rtol=1e-3, atol=1e-3)


def _recall_at_k(k, q, kk):
    kn, _ = ref.normalize_keys(k)
    codes = ref.sign_codes(kn)
    cb = ref.build_codebook(kn, codes)
    approx = ref.lut_scores(ref.build_lut(q, cb), codes)
    exact = ref.exact_scores(q, kn)
    sel_a = set(np.asarray(ref.topk_indices(approx, kk)).tolist())
    sel_e = set(np.asarray(ref.topk_indices(exact, kk)).tolist())
    return len(sel_a & sel_e) / kk


def test_topk_recall_beats_random():
    # The headline accuracy claim in miniature: compressed-domain top-k
    # overlaps with exact top-k far above chance. Isotropic gaussian keys
    # are the *worst case* for sign-VQ (no directional structure at all);
    # real transformer keys are anisotropic with channel outliers, where
    # recall is much higher (next test).
    l, d, kk = 2048, 64, 128
    recall = _recall_at_k(keys(8, l, d), keys(9, 1, d)[0], kk)
    assert recall > 0.3, recall  # random selection would give kk/l ≈ 0.06


def _clustered_keys(seed, l, d, n_dir=12, spread=0.6, offset=0.0):
    """Keys drawn from a mixture of directions — the semantic-cluster
    structure of trained-transformer key caches (what makes cosine-space
    retrieval work in the first place; cf. ClusterKV/PQCache)."""
    r = np.random.default_rng(seed)
    dirs = r.standard_normal((n_dir, d)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    assign = r.integers(0, n_dir, l)
    k = 3.0 * dirs[assign] + spread * r.standard_normal((l, d)).astype(np.float32)
    if offset:
        k = k + offset * r.standard_normal(d).astype(np.float32)
    q = 3.0 * dirs[0] + 0.3 * r.standard_normal(d).astype(np.float32)
    return jnp.asarray(k.astype(np.float32)), jnp.asarray(q.astype(np.float32))


def test_topk_recall_high_on_clustered_keys():
    # Keys with directional cluster structure (trained-LLM-like): recall
    # is far higher than the isotropic worst case, and per-channel offsets
    # (which break raw-sign codes) are absorbed by the normalization.
    l, d, kk = 2048, 64, 128
    k, q = _clustered_keys(10, l, d)
    assert _recall_at_k(k, q, kk) > 0.7
    k_off, q2 = _clustered_keys(10, l, d, offset=2.0)
    assert _recall_at_k(k_off, q2, kk) > 0.7


# ---------------------------------------------------------------------------
# token-wise quantization
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), l=LENS,
       d=st.sampled_from([32, 64, 128]), bits=st.sampled_from([2, 4]))
def test_quant_matches_ref(seed, l, d, bits):
    v = keys(seed, l, d, scale=3.0)
    q_p, qs_p, zp_p = quant.quantize_tokens(v, bits=bits, token_tile=64)
    q_r, qs_r, zp_r = ref.quantize_token_wise(v, bits=bits)
    # values sitting exactly on a rounding boundary may flip by one code
    # between the pallas and jnp paths (fma/ordering); allow a tiny rate
    diff = np.abs(np.asarray(q_p, dtype=np.int32) - np.asarray(q_r, np.int32))
    assert diff.max() <= 1, diff.max()
    assert (diff > 0).sum() <= max(1, q_p.size // 1000), (diff > 0).sum()
    np.testing.assert_allclose(qs_p, qs_r, rtol=1e-6)
    np.testing.assert_allclose(zp_p, zp_r, rtol=1e-6)
    d_p = quant.dequantize_tokens(q_p, qs_p, zp_p, token_tile=64)
    d_r = ref.dequantize_token_wise(q_r, qs_r, zp_r)
    # atol covers fma/ordering differences between pallas and jnp paths
    np.testing.assert_allclose(d_p, d_r, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([2, 4, 8]))
def test_quant_error_bound(seed, bits):
    # |D(Q(v)) - v| <= qs/2 per element (round-to-nearest within range).
    v = keys(seed, 64, 64, scale=2.0)
    q, qs, zp = ref.quantize_token_wise(v, bits=bits)
    dq = ref.dequantize_token_wise(q, qs, zp)
    err = np.abs(np.asarray(dq - v))
    bound = np.repeat(np.asarray(qs) / 2, QUANT_GROUP, axis=1)
    assert (err <= bound + 1e-6).all()


def test_quant_constant_group():
    v = jnp.ones((4, 64)) * 3.25
    q, qs, zp = ref.quantize_token_wise(v)
    dq = ref.dequantize_token_wise(q, qs, zp)
    np.testing.assert_allclose(dq, v)


def test_key_reconstruction_roundtrip():
    # Sign plane ⊙ quantized magnitudes reconstructs K' (Eq. 13) with error
    # bounded by alpha * qs / 2.
    k = keys(10, 256, 64)
    kn, _ = ref.normalize_keys(k)
    codes = ref.sign_codes(kn)
    alpha = ref.channel_alpha(kn)
    kq, kqs, kzp = ref.quantize_key_mag(kn, alpha)
    krec = ref.dequantize_key(codes, kq, kqs, kzp, alpha)
    # signs always match (stored exactly); magnitudes within quant bound
    np.testing.assert_array_equal(np.sign(krec), np.where(np.asarray(kn) >= 0, 1, -1))
    rel = np.abs(np.asarray(krec) - np.asarray(kn)).mean() / np.abs(np.asarray(kn)).mean()
    assert rel < 0.35, rel  # 2-bit magnitudes: coarse but bounded


def test_sign_preservation_lowers_error_vs_unsigned():
    # Ablation "w/o sign in quant" (Table 5): quantizing the raw signed K'
    # at 2 bits is worse than sign-plane + 2-bit magnitudes.
    k = keys(11, 512, 64)
    kn, _ = ref.normalize_keys(k)
    codes = ref.sign_codes(kn)
    alpha = ref.channel_alpha(kn)
    kq, kqs, kzp = ref.quantize_key_mag(kn, alpha)
    ours = np.asarray(ref.dequantize_key(codes, kq, kqs, kzp, alpha))
    q2, qs2, zp2 = ref.quantize_token_wise(kn)   # signed 2-bit, no sign plane
    plain = np.asarray(ref.dequantize_token_wise(q2, qs2, zp2))
    e_ours = ((ours - np.asarray(kn)) ** 2).mean()
    e_plain = ((plain - np.asarray(kn)) ** 2).mean()
    assert e_ours < e_plain


# ---------------------------------------------------------------------------
# fused sparse attention
# ---------------------------------------------------------------------------


def _build_state(seed, l, d):
    k = keys(seed, l, d)
    v = keys(seed + 1, l, d)
    return k, v, ref.compress_prefill(k, v)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       s=st.sampled_from([32, 96]), t=st.sampled_from([8, 64]))
def test_sparse_attn_kernel_matches_ref(seed, s, t):
    d, l, h = 64, 256, 3
    k, v, st_ = _build_state(seed, l, d)
    r = np.random.default_rng(seed + 2)
    q = jnp.asarray(r.standard_normal((h, d)).astype(np.float32))
    sel = jnp.asarray(r.choice(l, size=s, replace=False))
    sink = jnp.asarray(r.choice(l, size=t, replace=False))

    k_rec = ref.dequantize_key(st_["codes"], st_["k_q"], st_["k_qs"],
                               st_["k_zp"], st_["alpha"])
    v_rec = ref.dequantize_token_wise(st_["v_q"], st_["v_qs"], st_["v_zp"])

    def tile(x):
        return jnp.broadcast_to(x[None], (h,) + x.shape)

    out = sparse_attn.sparse_attention(
        q,
        tile(st_["codes"][sel]),
        tile(st_["k_q"][sel]), tile(st_["k_qs"][sel]), tile(st_["k_zp"][sel]),
        tile(st_["v_q"][sel]), tile(st_["v_qs"][sel]), tile(st_["v_zp"][sel]),
        tile(st_["alpha"]),
        tile(k_rec[sink]), tile(v_rec[sink]),
    )
    for i in range(h):
        expect = ref.sparse_attention_ref(
            q[i], k_rec[sel], v_rec[sel], k_rec[sink], v_rec[sink])
        np.testing.assert_allclose(out[i], expect, rtol=1e-4, atol=1e-5)


def test_sparse_attention_approaches_dense_as_k_grows():
    # With k = L (everything selected) sparse-quantized attention equals
    # dense attention over the dequantized cache.
    d, l = 64, 128
    k, v, st_ = _build_state(12, l, d)
    q = keys(13, 1, d)[0]
    k_rec = ref.dequantize_key(st_["codes"], st_["k_q"], st_["k_qs"],
                               st_["k_zp"], st_["alpha"])
    v_rec = ref.dequantize_token_wise(st_["v_q"], st_["v_qs"], st_["v_zp"])
    out, sel = ref.retrieve_and_attend(q, st_, k_budget=l)
    dense = ref.attention_ref(q, k_rec, v_rec)
    np.testing.assert_allclose(out, dense, rtol=1e-4, atol=1e-5)


def test_retrieval_pipeline_output_close_to_exact_attention():
    # End-to-end quality: sparse+quantized output vs exact dense attention
    # over the true K'/V. This is the mechanism behind Table 1/2 parity.
    # Clustered keys with peaked attention (the long-context regime sparse
    # attention targets): top-k keeps essentially all attention mass, so
    # the only residual error is 2-bit quantization.
    d, l = 64, 1024
    r = np.random.default_rng(14)
    dirs = r.standard_normal((12, d)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    assign = r.integers(0, 12, l)
    k = jnp.asarray((6.0 * dirs[assign]
                     + 0.3 * r.standard_normal((l, d))).astype(np.float32))
    q = jnp.asarray((6.0 * dirs[0]
                     + 0.3 * r.standard_normal(d)).astype(np.float32))
    v = keys(15, l, d)
    kn, _ = ref.normalize_keys(k)
    st_ = ref.compress_prefill(k, v)
    exact = ref.attention_ref(q, kn, v)
    out, _ = ref.retrieve_and_attend(q, st_, k_budget=int(l * 0.15))

    def cos(a, b):
        a, b = np.asarray(a), np.asarray(b)
        return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))

    # (1) vs exact fp attention: bounded by quantization error only
    assert cos(out, exact) > 0.9, cos(out, exact)
    # (2) vs dense attention over the *dequantized* cache: selection is
    # near-free — the self-indexing claim proper.
    k_rec = ref.dequantize_key(st_["codes"], st_["k_q"], st_["k_qs"],
                               st_["k_zp"], st_["alpha"])
    v_rec = ref.dequantize_token_wise(st_["v_q"], st_["v_qs"], st_["v_zp"])
    dense_dq = ref.attention_ref(q, k_rec, v_rec)
    assert cos(out, dense_dq) > 0.98, cos(out, dense_dq)
