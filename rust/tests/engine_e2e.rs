//! End-to-end engine tests: full serving loop over real artifacts.
//! Requires `make artifacts` (skips with a notice otherwise).

use std::path::Path;

use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{Engine, MethodKind};
use selfindex_kv::workloads::corpus::{context_with_facts, KvFact};
use selfindex_kv::substrate::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts missing — run `make artifacts`; skipping");
        None
    }
}

fn needle_prompt(seed: u64, len: usize) -> (Vec<u8>, Vec<u8>) {
    let mut r = Rng::new(seed);
    let fact = KvFact::random(&mut r);
    let mut p = context_with_facts(&mut r, len - 8, &[fact.clone()], &[0.4]);
    p.extend_from_slice(&fact.query());
    (p, fact.val)
}

#[test]
fn serves_batched_requests_selfindex() {
    let Some(dir) = artifacts() else { return };
    let mut cfg = EngineConfig::default();
    cfg.max_batch = 4;
    cfg.max_new_tokens = 6;
    let mut engine = Engine::new(&dir, cfg, MethodKind::SelfIndex).unwrap();

    for seed in 0..6 {
        let (p, _) = needle_prompt(seed, 240);
        engine.submit(p, 6).unwrap();
    }
    let results = engine.run_to_completion().unwrap();
    assert_eq!(results.len(), 6);
    for r in &results {
        assert_eq!(r.generated.len(), 6);
        assert!(r.ttft.as_nanos() > 0);
        assert!(r.latency >= r.ttft);
        assert!(r.decode_steps >= 6);
    }
    assert!(engine.idle());
    assert_eq!(engine.metrics.counter("engine.prefills").get(), 6);
}

#[test]
fn methods_agree_on_first_tokens() {
    // The first generated token comes straight from prefill logits and is
    // method-independent; later tokens should usually agree between the
    // full cache and ours (identical model, near-lossless attention).
    let Some(dir) = artifacts() else { return };
    let (p, _) = needle_prompt(42, 240);

    let mut generated = vec![];
    for kind in [MethodKind::Full, MethodKind::SelfIndex] {
        let mut cfg = EngineConfig::default();
        cfg.max_batch = 1;
        cfg.max_new_tokens = 4;
        let mut engine = Engine::new(&dir, cfg, kind).unwrap();
        engine.submit(p.clone(), 4).unwrap();
        let results = engine.run_to_completion().unwrap();
        generated.push(results[0].generated.clone());
    }
    assert_eq!(generated[0][0], generated[1][0], "prefill token must match");
    let agree = generated[0]
        .iter()
        .zip(&generated[1])
        .filter(|(a, b)| a == b)
        .count();
    assert!(agree >= 2, "full vs ours agreement too low: {generated:?}");
}

#[test]
fn continuous_batching_interleaves() {
    let Some(dir) = artifacts() else { return };
    let mut cfg = EngineConfig::default();
    cfg.max_batch = 2;
    cfg.max_new_tokens = 3;
    let mut engine = Engine::new(&dir, cfg, MethodKind::SelfIndex).unwrap();
    // more requests than batch slots: later ones admitted as slots free up
    for seed in 0..5 {
        let (p, _) = needle_prompt(100 + seed, 200);
        engine.submit(p, 3).unwrap();
    }
    let results = engine.run_to_completion().unwrap();
    assert_eq!(results.len(), 5);
    // all prefills happened, none lost
    assert_eq!(engine.metrics.counter("engine.prefills").get(), 5);
}

#[test]
fn queue_backpressure_rejects() {
    let Some(dir) = artifacts() else { return };
    let mut cfg = EngineConfig::default();
    cfg.queue_limit = 2;
    let mut engine = Engine::new(&dir, cfg, MethodKind::Full).unwrap();
    let (p, _) = needle_prompt(7, 200);
    engine.submit(p.clone(), 1).unwrap();
    engine.submit(p.clone(), 1).unwrap();
    assert!(engine.submit(p, 1).is_err(), "third submit must be rejected");
}
