//! Memory-manager integration tests: the REAL serving loop
//! ([`ServingEngine`] over the PJRT-free [`NativeExecutor`]) on ONE
//! shared block pool — admission on exact free-block accounting,
//! pool-exhaustion → preemption → re-admission with **bit-exact** final
//! outputs, prefix-block sharing across identical prompts, and leak-free
//! refcount accounting (`free_blocks == capacity_blocks` once every
//! sequence is gone).
//!
//! The oversubscription trace drives `ServingEngine::step` itself (no
//! hand-rolled mirror of the policy): what ships is what's tested. The
//! direct-API tests below it pin the block-sharing and task-failure
//! contracts at the cache layer.

use std::collections::HashMap;
use std::sync::Arc;

use selfindex_kv::baselines::{AttentionMethod, SelfIndexing};
use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{NativeExecutor, Outcome, RequestId, ServingEngine};
use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::method::registry::{lookup, BuildCtx, CacheMethod};
use selfindex_kv::method::{DecodePlan, HeadTask, SequenceCache};
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::rng::Rng;

const DIM: usize = 64;
const LAYERS: usize = 1;
const KVH: usize = 1;
const R: usize = 1;
const BT: usize = 64;
const BUDGET: usize = 32;

/// Deterministic per-request prompt K/V (kv-head-major, one layer).
fn prompt_kv(id: u64, tokens: usize) -> (Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(0x9000 + id);
    let keys = (0..KVH * tokens * DIM).map(|_| r.normal_f32()).collect();
    let vals = (0..KVH * tokens * DIM).map(|_| r.normal_f32()).collect();
    (keys, vals)
}

/// Deterministic per-(request, step) decode inputs — a preempted request
/// replays the identical stream, which is what makes recomputation
/// bit-exact.
fn step_rows(id: u64, step: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(id * 10_000 + step as u64 + 1);
    let k = (0..KVH * DIM).map(|_| r.normal_f32()).collect();
    let v = (0..KVH * DIM).map(|_| r.normal_f32()).collect();
    let q = (0..KVH * R * DIM).map(|_| r.normal_f32()).collect();
    (k, v, q)
}

/// Distinct prompt bytes per request: [`NativeExecutor`] seeds each
/// request's synthetic K/V stream from prompt CONTENT, so distinct
/// prompts exercise distinct caches (identical prompts would collapse
/// into prefix sharing, which the dedicated test below covers).
fn prompt_bytes(id: u64, tokens: usize) -> Vec<u8> {
    (0..tokens)
        .map(|t| (id as u8 + 1) ^ (t as u8).wrapping_mul(31))
        .collect()
}

struct TraceResult {
    /// last decode step's attention output per request
    finals: HashMap<RequestId, Vec<f32>>,
    /// full streamed token output per request
    generated: HashMap<RequestId, Vec<u8>>,
    preemptions: u64,
    peak_used_blocks: usize,
}

/// Drive the shipped [`ServingEngine`] over a [`NativeExecutor`] bound to
/// `mgr`'s pool until every request finishes, sampling pool occupancy
/// after each step.
fn serve_trace(
    mgr: &Arc<KvManager>,
    prompt_tokens: usize,
    max_new: usize,
    n_requests: u64,
    max_batch: usize,
) -> TraceResult {
    let exec = NativeExecutor::new(
        DIM,
        LAYERS,
        KVH,
        R,
        BUDGET,
        SelfIndexConfig::default(),
        Arc::clone(mgr),
    );
    let cfg = EngineConfig {
        max_batch,
        block_tokens: BT,
        // a generous eviction allowance: this trace measures the memory
        // manager under churn; the thrash cutoff is chaos_engine.rs's job
        preempt_budget: 100,
        ..EngineConfig::default()
    };
    let mut eng = ServingEngine::new(cfg, exec).expect("valid config");
    for id in 0..n_requests {
        eng.submit(prompt_bytes(id, prompt_tokens), max_new)
            .expect("queue admits the whole trace");
    }
    let mut peak = 0usize;
    for _ in 0..100_000 {
        if eng.is_drained() {
            let generated = eng
                .take_results()
                .into_iter()
                .inspect(|r| assert_eq!(r.outcome, Outcome::Completed, "request {:?}", r.id))
                .map(|r| (r.id, r.generated))
                .collect();
            return TraceResult {
                finals: eng.executor().finals().clone(),
                generated,
                preemptions: eng.metrics.counter("engine.preemptions").get(),
                peak_used_blocks: peak,
            };
        }
        eng.step().expect("no state drift");
        peak = peak.max(mgr.pool().used_blocks());
    }
    panic!("trace did not converge (livelock in the admission/preemption policy)");
}

#[test]
fn oversubscribed_trace_preempts_and_finishes_bit_exact() {
    let si = SelfIndexConfig::default();
    // each request: 2 prompt blocks + 2 decode-growth blocks (128 → 207
    // tokens crosses 128 and 192). 7 blocks cannot host three such
    // lifetimes (12 blocks) — or even two — without preemption.
    let prompt = 128;
    let max_new = 80;
    let tight = Arc::new(KvManager::for_head(DIM, &si, BT, 7));
    let contended = serve_trace(&tight, prompt, max_new, 3, 3);
    assert_eq!(contended.finals.len(), 3, "all requests finished");
    assert!(
        contended.preemptions > 0,
        "7-block pool must preempt at least once"
    );
    assert!(contended.peak_used_blocks <= 7);
    assert_eq!(
        tight.pool().free_blocks(),
        tight.pool().capacity_blocks(),
        "all blocks returned after every sequence finished"
    );

    // uncontended reference: same requests, pool big enough for all
    let loose = Arc::new(KvManager::for_head(DIM, &si, BT, 64));
    let reference = serve_trace(&loose, prompt, max_new, 3, 3);
    assert_eq!(reference.preemptions, 0, "64 blocks never preempt");
    assert_eq!(reference.finals.len(), 3);
    for (id, out) in &reference.finals {
        assert_eq!(
            contended.finals[id], *out,
            "request {id:?}: preempted-and-recomputed output must be \
             bit-identical to the uncontended run"
        );
        assert_eq!(
            contended.generated[id], reference.generated[id],
            "request {id:?}: streamed tokens must match across pool sizes"
        );
    }
    assert_eq!(loose.pool().free_blocks(), loose.pool().capacity_blocks());
}

#[test]
fn identical_prompts_share_prefix_blocks_and_attend_bit_exact() {
    let si = SelfIndexConfig::default();
    let overlay = vec![];
    let entry = lookup("selfindex").unwrap();
    let shared = Arc::new(KvManager::for_head(DIM, &si, BT, 32));
    let ctx = BuildCtx {
        dim: DIM,
        n_layers: LAYERS,
        kv_heads: KVH,
        gqa_ratio: R,
        budget_hint: 256,
        mgr: &shared,
        selfindex: &si,
        overlay: &overlay,
        prompt_hash: 0,
    };
    let (keys, vals) = prompt_kv(77, 256); // exactly 4 full blocks

    let mut a = entry.build_seq(&ctx);
    a.prefill_layer(0, &keys, &vals, &[]);
    let single_blocks = shared.pool().used_blocks();
    let single_bytes = shared.pool().used_bytes();
    assert_eq!(single_blocks, 4);

    let mut b = entry.build_seq(&ctx);
    b.prefill_layer(0, &keys, &vals, &[]);
    assert_eq!(
        shared.pool().used_blocks(),
        single_blocks,
        "an identical prompt adopts every block — zero new allocations"
    );
    assert_eq!(shared.prefix_hits(), 4, "all four full blocks adopted");
    assert!(
        shared.pool().used_bytes() < 2 * single_bytes,
        "the acceptance bar: B sequences sharing a prefix stay strictly \
         below B x the single-sequence footprint"
    );

    // an independent sequence (own pool) is the semantic reference: block
    // sharing must not perturb attention by a single bit
    let solo_mgr = Arc::new(KvManager::for_head(DIM, &si, BT, 32));
    let solo_ctx = BuildCtx {
        dim: DIM,
        n_layers: LAYERS,
        kv_heads: KVH,
        gqa_ratio: R,
        budget_hint: 256,
        mgr: &solo_mgr,
        selfindex: &si,
        overlay: &overlay,
        prompt_hash: 0,
    };
    let mut solo = entry.build_seq(&solo_ctx);
    solo.prefill_layer(0, &keys, &vals, &[]);

    let (k, v, q) = step_rows(77, 0);
    let plan = DecodePlan {
        layer: 0,
        dim: DIM,
        kv_heads: KVH,
        gqa_ratio: R,
        budget: BUDGET,
        k_rows: &k,
        v_rows: &v,
        queries: &q,
    };
    let mut out_a = vec![0.0f32; KVH * R * DIM];
    let mut out_b = vec![0.0f32; KVH * R * DIM];
    let mut out_solo = vec![0.0f32; KVH * R * DIM];
    a.attend_step(&plan, &mut out_a);
    b.attend_step(&plan, &mut out_b);
    solo.attend_step(&plan, &mut out_solo);
    assert_eq!(out_a, out_solo, "sharing must not change attention");
    assert_eq!(out_b, out_solo, "adopted blocks attend identically");

    // decode appends land in private tail blocks (one each), never in the
    // shared prefix
    assert_eq!(shared.pool().used_blocks(), single_blocks + 2);

    // refcount accounting: sequences release their references on drop;
    // with the registry holding none, the pool drains completely
    drop(a);
    assert_eq!(shared.pool().used_blocks(), single_blocks + 1);
    drop(b);
    assert_eq!(
        shared.pool().free_blocks(),
        shared.pool().capacity_blocks(),
        "no leak after all sequences finish"
    );
    drop(solo);
    assert_eq!(solo_mgr.pool().free_blocks(), solo_mgr.pool().capacity_blocks());
}

#[test]
fn exhausted_append_flags_the_task_instead_of_panicking() {
    let si = SelfIndexConfig::default();
    let mgr = Arc::new(KvManager::for_head(DIM, &si, BT, 2));
    let mut m = SelfIndexing::with_manager(DIM, si.clone(), Arc::clone(&mgr));
    let (keys, vals) = prompt_kv(5, 128); // exactly fills both blocks
    m.prefill(&keys, &vals, &[], 1);
    assert_eq!(mgr.pool().free_blocks(), 0);
    assert_eq!(m.blocks_for_append(), 1, "next append needs a fresh block");

    let (k, v, q) = step_rows(5, 0);
    let len_before = m.cache().len();
    assert!(m.try_append(&k, &v).is_err(), "exhaustion is an Err, not a panic");
    assert_eq!(m.cache().len(), len_before, "failed append records nothing");

    // the work-queue path surfaces the same failure as a task flag the
    // engine maps back to a sequence and preempts
    let mut out = vec![0.0f32; R * DIM];
    let mut task = HeadTask {
        method: &mut m,
        k_row: &k,
        v_row: &v,
        queries: &q[..DIM],
        dim: DIM,
        budget: BUDGET,
        out: &mut out,
        failed: false,
        panicked: false,
    };
    task.run();
    assert!(task.failed, "pool exhaustion must flag the task");
    assert!(!task.panicked, "exhaustion is a clean failure, not a panic");
    assert!(out.iter().all(|&x| x == 0.0), "failed task leaves out zeroed");

    // the sequence is still coherent: attention over the existing cache
    // works (the engine preempts it, but nothing is poisoned)
    m.attend(&q[..DIM], BUDGET, &mut out);
    assert!(out.iter().any(|&x| x != 0.0));
    drop(m);
    assert_eq!(mgr.pool().free_blocks(), 2);
}
