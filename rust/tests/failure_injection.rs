//! Failure injection: malformed artifacts, corrupt inputs, and boundary
//! conditions must fail loudly and cleanly (no panics in library code,
//! typed errors at the API surface).

use std::io::Write as _;
use std::path::PathBuf;

use selfindex_kv::model::{Manifest, WeightStore};
use selfindex_kv::substrate::json::Json;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sikv_fail_{name}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn truncated_weights_rejected() {
    let d = tmpdir("trunc");
    let p = d.join("w.bin");
    // valid header claiming 1 tensor, then EOF mid-entry
    let mut f = std::fs::File::create(&p).unwrap();
    f.write_all(&0x53494B56u32.to_le_bytes()).unwrap();
    f.write_all(&1u32.to_le_bytes()).unwrap();
    f.write_all(&1u32.to_le_bytes()).unwrap();
    f.write_all(&4u32.to_le_bytes()).unwrap();
    f.write_all(b"ab").unwrap(); // name cut short
    drop(f);
    assert!(WeightStore::load(&p).is_err());
}

#[test]
fn absurd_name_length_rejected() {
    let d = tmpdir("namelen");
    let p = d.join("w.bin");
    let mut f = std::fs::File::create(&p).unwrap();
    f.write_all(&0x53494B56u32.to_le_bytes()).unwrap();
    f.write_all(&1u32.to_le_bytes()).unwrap();
    f.write_all(&1u32.to_le_bytes()).unwrap();
    f.write_all(&u32::MAX.to_le_bytes()).unwrap(); // 4 GiB name
    drop(f);
    let err = WeightStore::load(&p);
    assert!(err.is_err(), "must reject, not allocate 4GiB");
}

#[test]
fn manifest_missing_fields_rejected() {
    let bad = [
        r#"{}"#,
        r#"{"model": {}}"#,
        // model ok but selfindex missing
        r#"{"model":{"vocab_size":256,"d_model":64,"n_layers":1,"n_heads":2,
            "n_kv_heads":1,"head_dim":32,"d_ff":64,"max_seq":128,
            "rope_theta":10000.0}}"#,
    ];
    for src in bad {
        let j = Json::parse(src).unwrap();
        assert!(
            Manifest::from_json(&j, std::path::Path::new("/tmp")).is_err(),
            "{src}"
        );
    }
}

#[test]
fn manifest_load_missing_dir_errors() {
    assert!(Manifest::load(std::path::Path::new("/nonexistent_sikv")).is_err());
}

#[test]
fn engine_rejects_missing_artifacts() {
    use selfindex_kv::config::EngineConfig;
    use selfindex_kv::coordinator::{Engine, MethodKind};
    let r = Engine::new(
        std::path::Path::new("/nonexistent_sikv"),
        EngineConfig::default(),
        MethodKind::SelfIndex,
    );
    assert!(r.is_err());
}

#[test]
fn config_validation_rejects_nonsense() {
    use selfindex_kv::config::EngineConfig;
    let mut c = EngineConfig::default();
    c.sparsity = 1.5;
    assert!(c.validate().is_err());
    let mut c = EngineConfig::default();
    c.max_batch = 0;
    assert!(c.validate().is_err());
}

#[test]
fn topk_degenerate_inputs() {
    use selfindex_kv::selfindex::topk::top_k_indices;
    assert!(top_k_indices(&[], 5).is_empty());
    let all_nan = [f32::NAN, f32::NAN];
    assert_eq!(top_k_indices(&all_nan, 1), vec![0]); // ties -> lowest idx
    let all_neg_inf = [f32::NEG_INFINITY; 3];
    assert_eq!(top_k_indices(&all_neg_inf, 2), vec![0, 1]);
}

#[test]
fn json_pathological_inputs() {
    // deep nesting must not blow the stack unreasonably (bounded input)
    let deep = "[".repeat(200) + &"]".repeat(200);
    let _ = Json::parse(&deep); // ok or err, must not crash
    assert!(Json::parse("").is_err());
    assert!(Json::parse("\u{0}").is_err());
    // duplicate keys: last wins (documented BTreeMap behaviour)
    let v = Json::parse(r#"{"a":1,"a":2}"#).unwrap();
    assert_eq!(v.get("a").unwrap().as_f64(), Some(2.0));
}

#[test]
fn quantizer_extreme_values() {
    use selfindex_kv::quant::quantize_tokens;
    // huge magnitudes: fp16 params saturate but must stay finite
    let x = vec![1e30f32, -1e30, 0.0, 5.0].repeat(16);
    let q = quantize_tokens(&x, 64, 32, 2);
    for p in &q.params {
        assert!(p.scale_f32().is_infinite() || p.scale_f32() > 0.0);
    }
    // NaN-free dequant for finite inputs
    let x = vec![0.25f32; 64];
    let q = quantize_tokens(&x, 64, 32, 2);
    assert!(q.dequantize().iter().all(|v| v.is_finite()));
}

#[test]
fn sink_store_empty_is_harmless() {
    use selfindex_kv::kvcache::SinkStore;
    let s = SinkStore::default();
    assert_eq!(s.len(), 0);
    let (k, v) = s.rows_f32();
    assert!(k.is_empty() && v.is_empty());
    assert_eq!(s.bytes(), 0);
}
