//! Serving front-end integration tests.
//!
//! * Chunked prefill must bound head-of-line blocking: while a 100K-token
//!   prompt prefills, an in-flight decode never stalls for more than ONE
//!   chunk — the scheduler alternates `PrefillChunk` with `Decode` turns.
//! * The continuous-batching path is an execution schedule, not a model
//!   change: a served trace (staggered submissions, chunked prefill)
//!   finishes **bit-identical** to the closed-batch
//!   `run_to_completion` over the same requests with chunking off.
//!
//! Both tests run on the PJRT-free [`NativeExecutor`], whose synthetic
//! K/V streams derive only from prompt content — so outputs are
//! comparable across engines, schedules, and pool sizes.

use std::collections::HashMap;
use std::sync::Arc;

use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{
    NativeExecutor, Outcome, RequestId, ServingEngine, StepPlan,
};
use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::selfindex::SelfIndexConfig;

const DIM: usize = 32;
const BT: usize = 64;
const BUDGET: usize = 32;

fn si_cfg() -> SelfIndexConfig {
    SelfIndexConfig { sink_tokens: 16, sparse_k: 16, ..SelfIndexConfig::default() }
}

fn engine(capacity_blocks: usize, chunk: usize) -> ServingEngine<NativeExecutor> {
    let si = si_cfg();
    let mgr = Arc::new(KvManager::for_head(DIM, &si, BT, capacity_blocks));
    let exec = NativeExecutor::new(DIM, 1, 1, 1, BUDGET, si, mgr);
    let cfg = EngineConfig {
        block_tokens: BT,
        prefill_chunk_tokens: chunk,
        max_batch: 4,
        preempt_budget: 4,
        ..EngineConfig::default()
    };
    ServingEngine::new(cfg, exec).expect("valid config")
}

fn prompt(seed: u8, len: usize) -> Vec<u8> {
    (0..len).map(|t| seed ^ (t as u8).wrapping_mul(31)).collect()
}

/// The ISSUE's acceptance bar: submit a short request, let it decode,
/// then submit a 100K-token prompt. With `prefill_chunk_tokens` set, the
/// long prefill must interleave — while anything is running, no two
/// consecutive steps may both be prefill turns (a decode gap of at most
/// one chunk).
#[test]
fn long_prompt_prefill_never_stalls_inflight_decode_beyond_one_chunk() {
    const LONG: usize = 100_000;
    const CHUNK: usize = 1024;
    // 100K tokens = 1563 blocks for the long prompt + slack for the
    // decoding neighbour: nothing here should preempt
    let mut eng = engine(1600, CHUNK);

    let a = eng.submit(prompt(7, BT), 300).expect("short request admitted");
    while eng.running() == 0 {
        eng.step().expect("no state drift");
    }
    let b = eng.submit(prompt(9, LONG), 4).expect("long request admitted");

    let mut consecutive_prefill = 0u32;
    let mut interleaved_chunks = 0u32;
    while !eng.is_drained() {
        let running_before = eng.running();
        let plan = eng.step().expect("no state drift");
        match plan {
            StepPlan::Prefill | StepPlan::PrefillChunk => {
                if matches!(plan, StepPlan::PrefillChunk) && running_before > 0 {
                    interleaved_chunks += 1;
                }
                if running_before > 0 {
                    consecutive_prefill += 1;
                    assert!(
                        consecutive_prefill <= 1,
                        "two consecutive prefill turns while a decode was \
                         in flight — the stall exceeded one chunk"
                    );
                } else {
                    // nothing to decode: back-to-back chunks are correct
                    consecutive_prefill = 0;
                }
            }
            StepPlan::Decode(_) => consecutive_prefill = 0,
            StepPlan::Preempt(_) | StepPlan::Shed(_) => {
                panic!("this pool is sized to avoid preemption")
            }
            StepPlan::Idle => {}
        }
    }

    assert!(
        interleaved_chunks >= 50,
        "a {LONG}-token prompt at {CHUNK}-token chunks must interleave \
         many chunks with live decodes (saw {interleaved_chunks})"
    );
    let mut results: Vec<_> = eng.take_results();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].id, a.id);
    assert_eq!(results[0].outcome, Outcome::Completed);
    assert_eq!(results[0].generated.len(), 300);
    assert_eq!(results[1].id, b.id);
    assert_eq!(results[1].outcome, Outcome::Completed);
    assert_eq!(results[1].generated.len(), 4);
    assert_eq!(eng.metrics.counter("engine.preemptions").get(), 0);
}

type Served = (Vec<(RequestId, Outcome, Vec<u8>)>, HashMap<RequestId, Vec<f32>>);

/// Run the same three requests either staggered + chunked (the serving
/// path) or submitted up front with chunking off (closed batch).
fn serve(chunk: usize, staggered: bool) -> Served {
    let mut eng = engine(64, chunk);
    let specs: [(u8, usize); 3] = [(3, 200), (5, 333), (11, 512)];
    for (i, &(seed, len)) in specs.iter().enumerate() {
        if staggered && i > 0 {
            // arrivals mid-decode: the batch composition differs from the
            // closed-batch run, the outputs must not
            for _ in 0..3 {
                eng.step().expect("no state drift");
            }
        }
        eng.submit(prompt(seed, len), 12).expect("admitted");
    }
    let mut results = eng.run_to_completion().expect("no state drift");
    results.sort_by_key(|r| r.id);
    let outs = results.into_iter().map(|r| (r.id, r.outcome, r.generated)).collect();
    (outs, eng.executor().finals().clone())
}

#[test]
fn served_trace_is_bit_identical_to_closed_batch() {
    let (closed_outs, closed_finals) = serve(0, false);
    let (served_outs, served_finals) = serve(128, true);
    assert_eq!(closed_outs.len(), 3);
    for (id, outcome, _) in &closed_outs {
        assert_eq!(*outcome, Outcome::Completed, "request {id} in closed batch");
    }
    assert_eq!(
        served_outs, closed_outs,
        "streamed tokens must not depend on arrival timing or chunking"
    );
    for (id, out) in &closed_finals {
        assert_eq!(
            served_finals[id], *out,
            "request {id}: final attention output must be bit-identical \
             between the served and closed-batch schedules"
        );
    }
}
