//! Chaos suite: the hardened serving lifecycle under deterministic,
//! seeded fault injection (`substrate::faults`).
//!
//! The loop below mirrors `Engine::step`'s hardened policy — prefill
//! under `catch_unwind` charging the preemption budget, decode fan-out
//! through `HeadTask::run_isolated`, pin-after-N aging, the 2N thrashing
//! cutoff, step deadlines, and `StepPlan::Shed` — minus the PJRT
//! boundary, so it runs without artifacts (same trade as
//! `tests/memory_manager.rs`).
//!
//! Invariants asserted across every scenario:
//! * no fault schedule panics the process — every request ends in a
//!   structured [`Fin`];
//! * the pool drains leak-free (`free_blocks == capacity_blocks`);
//! * requests untouched by a fault finish **bit-identical** to the
//!   fault-free baseline (greedy recomputation is deterministic).
//!
//! `SIKV_CHAOS_SEED` (default 1) seeds the probabilistic scenarios; CI
//! runs the suite across a seed matrix and uploads the
//! `CHAOS_summary.json` written at the end.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use selfindex_kv::coordinator::{PoolPressure, Scheduler, StepPlan};
use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::kvcache::RecordLayout;
use selfindex_kv::method::registry::{lookup, BuildCtx};
use selfindex_kv::method::{DecodePlan, HeadTask, SequenceCache};
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::faults::FaultInjector;
use selfindex_kv::substrate::json::Json;
use selfindex_kv::substrate::rng::Rng;

const DIM: usize = 64;
const LAYERS: usize = 1;
const KVH: usize = 1;
const R: usize = 1;
const BT: usize = 64;
const BUDGET: usize = 32;
const PROMPT: usize = 128;

/// Deterministic per-content prompt K/V (kv-head-major, one layer).
fn prompt_kv(content: u64, tokens: usize) -> (Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(0x9000 + content);
    let keys = (0..KVH * tokens * DIM).map(|_| r.normal_f32()).collect();
    let vals = (0..KVH * tokens * DIM).map(|_| r.normal_f32()).collect();
    (keys, vals)
}

/// Deterministic per-(content, step) decode inputs — recomputation after
/// eviction replays the identical stream, making outputs bit-exact.
fn step_rows(content: u64, step: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(content * 10_000 + step as u64 + 1);
    let k = (0..KVH * DIM).map(|_| r.normal_f32()).collect();
    let v = (0..KVH * DIM).map(|_| r.normal_f32()).collect();
    let q = (0..KVH * R * DIM).map(|_| r.normal_f32()).collect();
    (k, v, q)
}

/// `(content, max_new, deadline_step)` — content keys the deterministic
/// prompt/decode streams, so two requests with equal content are
/// byte-identical submissions (and share prefix blocks).
type Spec = (u64, usize, Option<u64>);

/// Structured terminal state — the harness's `Outcome` mirror.
#[derive(Clone, Debug, PartialEq)]
enum Fin {
    /// last decode step's attention output
    Completed(Vec<f32>),
    Thrashing,
    WorkerPanic,
    DeadlineExceeded { steps_done: usize },
}

struct Running {
    cache: Box<dyn SequenceCache>,
    steps_done: usize,
    out: Vec<f32>,
}

struct ChaosRun {
    /// terminal state per request, same order as the spec slice
    fins: Vec<Fin>,
    evictions: usize,
    integrity_failures: u64,
    prefix_hits: u64,
    drained: bool,
}

impl ChaosRun {
    fn completed(&self, i: usize) -> &[f32] {
        match &self.fins[i] {
            Fin::Completed(out) => out,
            other => panic!("request {i} expected Completed, got {other:?}"),
        }
    }

    fn count(&self, pred: fn(&Fin) -> bool) -> usize {
        self.fins.iter().filter(|&f| pred(f)).count()
    }
}

/// The engine's hardened serving policy, verbatim: admit from the FIFO
/// stash (then the queue) with prefill contained by `catch_unwind`,
/// decode through `run_isolated`, expire deadlines against the step
/// counter, charge every eviction to the request's preemption budget.
fn run_chaos(
    faults_spec: &str,
    fault_seed: u64,
    capacity_blocks: usize,
    preempt_budget: u32,
    max_batch: usize,
    reqs: &[Spec],
) -> ChaosRun {
    let si = SelfIndexConfig::default();
    let faults = Arc::new(FaultInjector::parse(faults_spec, fault_seed).unwrap());
    let mgr = Arc::new(KvManager::with_faults(
        RecordLayout::new(DIM, &si),
        BT,
        capacity_blocks,
        Arc::clone(&faults),
    ));
    let entry = lookup("selfindex").unwrap();
    let overlay = vec![];

    let n = reqs.len();
    let mut scheduler = Scheduler::new(max_batch);
    let mut queue: VecDeque<usize> = (0..n).collect();
    let mut stash: VecDeque<usize> = VecDeque::new();
    let mut running: HashMap<usize, Running> = HashMap::new();
    let mut fins: Vec<Option<Fin>> = vec![None; n];
    let mut evict_count = vec![0u32; n];
    let mut evictions = 0usize;
    let mut step: u64 = 0;

    for _ in 0..200_000 {
        if queue.is_empty() && stash.is_empty() && running.is_empty() {
            return ChaosRun {
                fins: fins.into_iter().map(Option::unwrap).collect(),
                evictions,
                integrity_failures: mgr.integrity_failures(),
                prefix_hits: mgr.prefix_hits(),
                drained: mgr.pool().free_blocks() == mgr.pool().capacity_blocks(),
            };
        }
        step += 1;

        // deadlines first, against the pre-plan counter: running expire
        // with partial progress, stashed/queued with none
        let mut expired: Vec<u64> = scheduler
            .running()
            .iter()
            .copied()
            .filter(|&id| reqs[id as usize].2.is_some_and(|d| step >= d))
            .collect();
        expired.sort_unstable();
        for id in expired {
            let st = running.remove(&(id as usize)).unwrap();
            scheduler.remove(id);
            fins[id as usize] = Some(Fin::DeadlineExceeded { steps_done: st.steps_done });
        }
        for waiting in [&mut stash, &mut queue] {
            waiting.retain(|&i| {
                if reqs[i].2.is_some_and(|d| step >= d) {
                    fins[i] = Some(Fin::DeadlineExceeded { steps_done: 0 });
                    false
                } else {
                    true
                }
            });
        }

        let candidate = stash.front().or_else(|| queue.front()).copied();
        let pressure = PoolPressure {
            free_blocks: mgr.pool().free_blocks(),
            admit_blocks: candidate
                .map(|_| entry.head_blocks_for_prompt(PROMPT, BT) * LAYERS * KVH),
            step_blocks: scheduler
                .running()
                .iter()
                .map(|id| running[&(*id as usize)].cache.step_blocks())
                .sum(),
        };
        match scheduler.plan(&pressure) {
            StepPlan::Prefill => {
                let i = stash.pop_front().or_else(|| queue.pop_front()).unwrap();
                let content = reqs[i].0;
                let ctx = BuildCtx {
                    dim: DIM,
                    n_layers: LAYERS,
                    kv_heads: KVH,
                    gqa_ratio: R,
                    budget_hint: PROMPT,
                    mgr: &mgr,
                    selfindex: &si,
                    overlay: &overlay,
                    prompt_hash: u128::from(content + 1),
                };
                // prefill containment: a panic (injected alloc fault, real
                // exhaustion) drops the partial cache — blocks released —
                // and charges one eviction
                let built = catch_unwind(AssertUnwindSafe(|| {
                    let mut cache = entry.build_seq(&ctx);
                    let (keys, vals) = prompt_kv(content, PROMPT);
                    for l in 0..LAYERS {
                        cache.prefill_layer(l, &keys, &vals, &[]);
                    }
                    cache
                }));
                match built {
                    Ok(cache) => {
                        running.insert(
                            i,
                            Running { cache, steps_done: 0, out: vec![0.0; KVH * R * DIM] },
                        );
                        scheduler.add_running(i as u64);
                        if evict_count[i] >= preempt_budget {
                            scheduler.pin(i as u64);
                        }
                    }
                    Err(_) => {
                        evictions += 1;
                        evict_count[i] += 1;
                        if evict_count[i] > 2 * preempt_budget {
                            fins[i] = Some(Fin::Thrashing);
                        } else {
                            stash.push_back(i);
                        }
                    }
                }
            }
            StepPlan::Decode(ids) => {
                for id in ids {
                    let i = id as usize;
                    let st = running.get_mut(&i).unwrap();
                    let (k, v, q) = step_rows(reqs[i].0, st.steps_done);
                    let mut step_failed = false;
                    let mut step_panicked = false;
                    for l in 0..LAYERS {
                        let plan = DecodePlan {
                            layer: l,
                            dim: DIM,
                            kv_heads: KVH,
                            gqa_ratio: R,
                            budget: BUDGET,
                            k_rows: &k,
                            v_rows: &v,
                            queries: &q,
                        };
                        st.out.fill(0.0);
                        let mut tasks: Vec<HeadTask> = Vec::new();
                        st.cache.push_tasks(&plan, &mut st.out, &mut tasks);
                        for t in tasks.iter_mut() {
                            t.run_isolated(&faults);
                        }
                        step_failed |= tasks.iter().any(|t| t.failed);
                        step_panicked |= tasks.iter().any(|t| t.panicked);
                    }
                    if step_panicked {
                        // worker panic: the sequence's state is suspect —
                        // fail it, release its blocks, keep the batch
                        running.remove(&i);
                        scheduler.remove(id);
                        fins[i] = Some(Fin::WorkerPanic);
                    } else if step_failed {
                        // mid-step exhaustion: eviction + budget charge
                        running.remove(&i);
                        scheduler.remove(id);
                        evictions += 1;
                        evict_count[i] += 1;
                        if evict_count[i] > 2 * preempt_budget {
                            fins[i] = Some(Fin::Thrashing);
                        } else {
                            stash.push_back(i);
                        }
                    } else {
                        st.steps_done += 1;
                        if st.steps_done == reqs[i].1 {
                            let st = running.remove(&i).unwrap();
                            scheduler.remove(id);
                            fins[i] = Some(Fin::Completed(st.out));
                        }
                    }
                }
            }
            StepPlan::Preempt(id) => {
                let i = id as usize;
                let st = running.remove(&i).unwrap();
                scheduler.remove(id);
                drop(st); // the cache's Drop releases its pool blocks
                evictions += 1;
                evict_count[i] += 1;
                if evict_count[i] > 2 * preempt_budget {
                    fins[i] = Some(Fin::Thrashing);
                } else {
                    stash.push_back(i);
                }
            }
            StepPlan::Shed(id) => {
                // all running pinned and the step cannot fit: fail the
                // youngest structurally instead of livelocking
                let i = id as usize;
                running.remove(&i);
                scheduler.remove(id);
                fins[i] = Some(Fin::Thrashing);
            }
            StepPlan::Idle => {}
        }
    }
    panic!("chaos trace did not converge (livelock in the hardened policy)");
}

fn chaos_seed() -> u64 {
    std::env::var("SIKV_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

fn scenario_json(run: &ChaosRun) -> Json {
    let completed = run.count(|f| matches!(f, Fin::Completed(_)));
    let thrashing = run.count(|f| matches!(f, Fin::Thrashing));
    let panicked = run.count(|f| matches!(f, Fin::WorkerPanic));
    let expired = run.count(|f| matches!(f, Fin::DeadlineExceeded { .. }));
    let mut m = BTreeMap::new();
    m.insert("completed".to_string(), Json::Num(completed as f64));
    m.insert("thrashing".to_string(), Json::Num(thrashing as f64));
    m.insert("worker_panic".to_string(), Json::Num(panicked as f64));
    m.insert("deadline_exceeded".to_string(), Json::Num(expired as f64));
    m.insert("evictions".to_string(), Json::Num(run.evictions as f64));
    let integrity = run.integrity_failures as f64;
    m.insert("integrity_failures".to_string(), Json::Num(integrity));
    m.insert("drained".to_string(), Json::Bool(run.drained));
    Json::Obj(m)
}

#[test]
fn chaos_suite() {
    let seed = chaos_seed();
    let mut summary = BTreeMap::new();
    summary.insert("seed".to_string(), Json::Num(seed as f64));
    let work: Vec<Spec> = vec![(0, 20, None), (1, 20, None), (2, 20, None)];

    // -- baseline: disarmed injector is the bit-exactness reference -----
    let baseline = run_chaos("", 0, 64, 4, 3, &work);
    assert_eq!(baseline.count(|f| matches!(f, Fin::Completed(_))), 3);
    assert_eq!(baseline.evictions, 0, "64 blocks never evict this mix");
    assert!(baseline.drained, "pool must drain leak-free");
    summary.insert("baseline".to_string(), scenario_json(&baseline));

    // -- injected allocation failures: evict + recompute, never corrupt -
    let alloc = run_chaos("pool.alloc=prob:0.1", seed, 64, 16, 3, &work);
    for i in 0..work.len() {
        assert_eq!(
            alloc.completed(i),
            baseline.completed(i),
            "request {i}: eviction-and-recompute must be bit-identical"
        );
    }
    assert!(alloc.drained, "every injected alloc failure must leak nothing");
    summary.insert("alloc_faults".to_string(), scenario_json(&alloc));

    // -- one injected worker panic: fails exactly one request ----------
    let panic_run = run_chaos("worker.panic=nth:40", 0, 64, 4, 3, &work);
    assert_eq!(
        panic_run.count(|f| matches!(f, Fin::WorkerPanic)),
        1,
        "an nth schedule panics exactly one (sequence, head) task"
    );
    assert_eq!(panic_run.count(|f| matches!(f, Fin::Completed(_))), 2);
    for i in 0..work.len() {
        if let Fin::Completed(out) = &panic_run.fins[i] {
            assert_eq!(
                out.as_slice(),
                baseline.completed(i),
                "request {i} untouched by the panic must be bit-identical"
            );
        }
    }
    assert!(panic_run.drained, "the failed request's blocks are released");
    summary.insert("worker_panic".to_string(), scenario_json(&panic_run));

    // -- injected block corruption: checksum at adoption, fallback ------
    let shared: Vec<Spec> = vec![(7, 12, None), (7, 12, None)];
    let solo = run_chaos("", 0, 64, 4, 1, &[(7, 12, None)]);
    let clean = run_chaos("", 0, 64, 4, 2, &shared);
    assert_eq!(clean.completed(0), solo.completed(0));
    assert_eq!(clean.completed(1), solo.completed(0), "sharing is bit-exact");
    let corrupt = run_chaos("block.corrupt=nth:1", 0, 64, 4, 2, &shared);
    assert!(
        corrupt.integrity_failures >= 1,
        "the adopter must detect the flipped bit at adoption"
    );
    assert!(
        corrupt.prefix_hits >= 1,
        "uncorrupted prefix blocks still adopt"
    );
    assert_eq!(
        corrupt.completed(1),
        solo.completed(0),
        "adoption of a corrupted block falls back to a fresh encode — \
         never silent corruption"
    );
    assert!(matches!(corrupt.fins[0], Fin::Completed(_)));
    assert!(corrupt.drained);
    summary.insert("block_corrupt".to_string(), scenario_json(&corrupt));

    // -- thrashing cutoff: a working set the pool can never hold -------
    // 128-token prompt + 80 decode steps wants 4 blocks; 3 exist. Each
    // retry charges the budget (1): evictions 1, 2, then 3 > 2×budget.
    let thrash = run_chaos("", 0, 3, 1, 2, &[(9, 80, None)]);
    assert_eq!(thrash.fins[0], Fin::Thrashing, "structured, not a livelock");
    assert_eq!(thrash.evictions, 3, "pin → retry → 2N cutoff");
    assert!(thrash.drained);
    summary.insert("thrash".to_string(), scenario_json(&thrash));

    // -- injected CacheFull on append: one eviction, bit-exact finish --
    let append = run_chaos("append.cache_full=nth:2", 0, 64, 4, 3, &work);
    assert!(append.evictions >= 1, "the injected CacheFull must evict");
    for i in 0..work.len() {
        assert_eq!(append.completed(i), baseline.completed(i));
    }
    assert!(append.drained);
    summary.insert("append_full".to_string(), scenario_json(&append));

    // -- deadlines: partial output for running, empty for queued -------
    let dl = run_chaos("", 0, 64, 4, 1, &[(0, 40, Some(10)), (1, 40, Some(5))]);
    match dl.fins[0] {
        Fin::DeadlineExceeded { steps_done } => {
            assert!(steps_done > 0, "the running request keeps partial output");
            assert!(steps_done < 40, "it expired before completing");
        }
        ref other => panic!("request 0 expected DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(
        dl.fins[1],
        Fin::DeadlineExceeded { steps_done: 0 },
        "a request that never left the queue expires with no output"
    );
    assert!(dl.drained);
    summary.insert("deadline".to_string(), scenario_json(&dl));

    // -- seeded sweep: alloc + append + panic armed at once ------------
    // No bit-exactness claim — the invariants are: the process never
    // panics, every request reaches a structured terminal state, and the
    // pool drains regardless of which faults fired.
    let sweep_work: Vec<Spec> = (0..5).map(|c| (c, 16, None)).collect();
    let sweep = run_chaos(
        "pool.alloc=prob:0.05,append.cache_full=prob:0.05,worker.panic=prob:0.02",
        seed,
        16,
        4,
        3,
        &sweep_work,
    );
    assert_eq!(sweep.fins.len(), sweep_work.len(), "every request terminal");
    assert!(sweep.drained, "no fault mix may leak blocks");
    summary.insert("sweep".to_string(), scenario_json(&sweep));

    std::fs::write(
        "CHAOS_summary.json",
        format!("{}\n", Json::Obj(summary)),
    )
    .expect("write CHAOS_summary.json");
}
