//! Chaos suite: the hardened serving lifecycle under deterministic,
//! seeded fault injection (`substrate::faults`).
//!
//! The suite drives the shipped [`ServingEngine`] over the PJRT-free
//! [`NativeExecutor`] — prefill containment charging the preemption
//! budget, decode fan-out through `HeadTask::run_isolated`, pin-after-N
//! aging, the 2N thrashing cutoff, wall-clock deadlines on a virtual
//! clock (one step = one millisecond, so scenarios stay deterministic),
//! and `StepPlan::Shed`.
//!
//! Invariants asserted across every scenario:
//! * no fault schedule panics the process — every request ends in a
//!   structured [`Fin`];
//! * the pool drains leak-free (`free_blocks == capacity_blocks`);
//! * requests untouched by a fault finish **bit-identical** to the
//!   fault-free baseline (greedy recomputation is deterministic).
//!
//! `SIKV_CHAOS_SEED` (default 1) seeds the probabilistic scenarios; CI
//! runs the suite across a seed matrix and uploads the
//! `CHAOS_summary.json` written at the end.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Duration;

use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{NativeExecutor, Outcome, RequestResult, ServingEngine};
use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::kvcache::RecordLayout;
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::faults::FaultInjector;
use selfindex_kv::substrate::json::Json;

const DIM: usize = 64;
const LAYERS: usize = 1;
const KVH: usize = 1;
const R: usize = 1;
const BT: usize = 64;
const BUDGET: usize = 32;
const PROMPT: usize = 128;

/// Deterministic prompt bytes per content key. [`NativeExecutor`] derives
/// every synthetic K/V stream from prompt CONTENT, so two requests with
/// equal content are byte-identical submissions (identical streams, and
/// they share prefix blocks); recomputation after eviction replays the
/// identical stream, making outputs bit-exact.
fn prompt_bytes(content: u64) -> Vec<u8> {
    prompt_bytes_n(content, PROMPT)
}

/// [`prompt_bytes`] with an explicit length — the tiered-swap scenarios
/// need prompts off the block boundary (a prompt at an exact multiple of
/// `BT` wants its growth block on the very first decode, which makes two
/// symmetric sequences contend forever instead of transiently).
fn prompt_bytes_n(content: u64, len: usize) -> Vec<u8> {
    (0..len)
        .map(|t| (content as u8).wrapping_mul(37) ^ (t as u8).wrapping_mul(31))
        .collect()
}

/// `(content, max_new, deadline_ms)` — content keys the deterministic
/// prompt bytes; `deadline_ms` is a wall-clock SLO on the virtual clock
/// (one engine step = 1 ms), so `Some(10)` expires at step 10 exactly.
type Spec = (u64, usize, Option<u64>);

/// A fully spelled-out request: `(prompt, max_new, deadline_ms)`.
type ReqSpec = (Vec<u8>, usize, Option<u64>);

/// Structured terminal state — the harness's `Outcome` mirror.
#[derive(Clone, Debug, PartialEq)]
enum Fin {
    /// last decode step's attention output
    Completed(Vec<f32>),
    Thrashing,
    WorkerPanic,
    /// `tokens_done` = streamed tokens at expiry (0 = never left the queue)
    DeadlineExceeded { tokens_done: usize },
}

struct ChaosRun {
    /// terminal state per request, same order as the spec slice
    fins: Vec<Fin>,
    evictions: usize,
    integrity_failures: u64,
    prefix_hits: u64,
    drained: bool,
    swap_outs: u64,
    swap_ins: u64,
    swap_fallbacks: u64,
}

impl ChaosRun {
    fn completed(&self, i: usize) -> &[f32] {
        match &self.fins[i] {
            Fin::Completed(out) => out,
            other => panic!("request {i} expected Completed, got {other:?}"),
        }
    }

    fn count(&self, pred: fn(&Fin) -> bool) -> usize {
        self.fins.iter().filter(|&f| pred(f)).count()
    }
}

/// Run one chaos scenario through the shipped serving loop: build a
/// fault-armed pool, submit every spec (deadlines as wall-clock SLOs on
/// the 1 ms virtual clock), pump [`ServingEngine::step`] until drained,
/// and fold the structured [`RequestResult`]s back into [`Fin`]s in spec
/// order.
fn run_chaos(
    faults_spec: &str,
    fault_seed: u64,
    capacity_blocks: usize,
    preempt_budget: u32,
    max_batch: usize,
    reqs: &[Spec],
) -> ChaosRun {
    let reqs: Vec<ReqSpec> = reqs
        .iter()
        .map(|&(content, max_new, dl)| (prompt_bytes(content), max_new, dl))
        .collect();
    run_chaos_with(false, faults_spec, fault_seed, capacity_blocks, preempt_budget, max_batch, &reqs)
}

/// [`run_chaos`] with the tiered-storage swap policy enabled: preemption
/// victims spill to the host tier instead of dropping, and the scenario
/// can arm the `swap.out` / `swap.in` / `tier.corrupt` fault points.
/// Takes fully spelled-out requests so scenarios control prompt length.
fn run_chaos_swap(
    faults_spec: &str,
    fault_seed: u64,
    capacity_blocks: usize,
    preempt_budget: u32,
    max_batch: usize,
    reqs: &[ReqSpec],
) -> ChaosRun {
    run_chaos_with(true, faults_spec, fault_seed, capacity_blocks, preempt_budget, max_batch, reqs)
}

fn run_chaos_with(
    swap: bool,
    faults_spec: &str,
    fault_seed: u64,
    capacity_blocks: usize,
    preempt_budget: u32,
    max_batch: usize,
    reqs: &[ReqSpec],
) -> ChaosRun {
    let si = SelfIndexConfig::default();
    let faults = Arc::new(FaultInjector::parse(faults_spec, fault_seed).unwrap());
    let mgr = Arc::new(KvManager::with_faults(
        RecordLayout::new(DIM, &si),
        BT,
        capacity_blocks,
        Arc::clone(&faults),
    ));
    let exec = NativeExecutor::new(DIM, LAYERS, KVH, R, BUDGET, si, Arc::clone(&mgr));
    let mut cfg = EngineConfig {
        max_batch,
        block_tokens: BT,
        preempt_budget,
        ..EngineConfig::default()
    };
    cfg.swap.enabled = swap;
    let mut eng = ServingEngine::new(cfg, exec)
        .expect("valid config")
        .with_virtual_clock(Duration::from_millis(1));

    let mut ids = Vec::with_capacity(reqs.len());
    for (prompt, max_new, deadline_ms) in reqs {
        let h = match deadline_ms {
            Some(d) => eng
                .submit_with_deadline(prompt.clone(), *max_new, Duration::from_millis(*d))
                .expect("queue admits the scenario"),
            None => eng
                .submit(prompt.clone(), *max_new)
                .expect("queue admits the scenario"),
        };
        ids.push(h.id);
    }

    for _ in 0..200_000 {
        if eng.is_drained() {
            let mut by_id: HashMap<_, RequestResult> =
                eng.take_results().into_iter().map(|r| (r.id, r)).collect();
            let fins = ids
                .iter()
                .map(|id| {
                    let r = by_id.remove(id).expect("every submission reaches a result");
                    match r.outcome {
                        Outcome::Completed => {
                            Fin::Completed(eng.executor().finals()[id].clone())
                        }
                        Outcome::Thrashing => Fin::Thrashing,
                        Outcome::WorkerPanic => Fin::WorkerPanic,
                        Outcome::DeadlineExceeded => {
                            Fin::DeadlineExceeded { tokens_done: r.generated.len() }
                        }
                        Outcome::Failed => {
                            panic!("no fault in this suite maps to Outcome::Failed")
                        }
                    }
                })
                .collect();
            return ChaosRun {
                fins,
                evictions: eng.metrics.counter("engine.preemptions").get() as usize,
                integrity_failures: mgr.integrity_failures(),
                prefix_hits: mgr.prefix_hits(),
                drained: mgr.pool().free_blocks() == mgr.pool().capacity_blocks()
                    && mgr.tier().entries() == 0,
                swap_outs: eng.metrics.counter("engine.swap_outs").get(),
                swap_ins: eng.metrics.counter("engine.swap_ins").get(),
                swap_fallbacks: eng.metrics.counter("engine.swap_fallbacks").get(),
            };
        }
        eng.step().expect("no state drift");
    }
    panic!("chaos trace did not converge (livelock in the hardened policy)");
}

fn chaos_seed() -> u64 {
    std::env::var("SIKV_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

fn scenario_json(run: &ChaosRun) -> Json {
    let completed = run.count(|f| matches!(f, Fin::Completed(_)));
    let thrashing = run.count(|f| matches!(f, Fin::Thrashing));
    let panicked = run.count(|f| matches!(f, Fin::WorkerPanic));
    let expired = run.count(|f| matches!(f, Fin::DeadlineExceeded { .. }));
    let mut m = BTreeMap::new();
    m.insert("completed".to_string(), Json::Num(completed as f64));
    m.insert("thrashing".to_string(), Json::Num(thrashing as f64));
    m.insert("worker_panic".to_string(), Json::Num(panicked as f64));
    m.insert("deadline_exceeded".to_string(), Json::Num(expired as f64));
    m.insert("evictions".to_string(), Json::Num(run.evictions as f64));
    let integrity = run.integrity_failures as f64;
    m.insert("integrity_failures".to_string(), Json::Num(integrity));
    m.insert("swap_outs".to_string(), Json::Num(run.swap_outs as f64));
    m.insert("swap_ins".to_string(), Json::Num(run.swap_ins as f64));
    m.insert(
        "swap_fallbacks".to_string(),
        Json::Num(run.swap_fallbacks as f64),
    );
    m.insert("drained".to_string(), Json::Bool(run.drained));
    Json::Obj(m)
}

#[test]
fn chaos_suite() {
    let seed = chaos_seed();
    let mut summary = BTreeMap::new();
    summary.insert("seed".to_string(), Json::Num(seed as f64));
    let work: Vec<Spec> = vec![(0, 20, None), (1, 20, None), (2, 20, None)];

    // -- baseline: disarmed injector is the bit-exactness reference -----
    let baseline = run_chaos("", 0, 64, 4, 3, &work);
    assert_eq!(baseline.count(|f| matches!(f, Fin::Completed(_))), 3);
    assert_eq!(baseline.evictions, 0, "64 blocks never evict this mix");
    assert!(baseline.drained, "pool must drain leak-free");
    summary.insert("baseline".to_string(), scenario_json(&baseline));

    // -- injected allocation failures: evict + recompute, never corrupt -
    let alloc = run_chaos("pool.alloc=prob:0.1", seed, 64, 16, 3, &work);
    for i in 0..work.len() {
        assert_eq!(
            alloc.completed(i),
            baseline.completed(i),
            "request {i}: eviction-and-recompute must be bit-identical"
        );
    }
    assert!(alloc.drained, "every injected alloc failure must leak nothing");
    summary.insert("alloc_faults".to_string(), scenario_json(&alloc));

    // -- one injected worker panic: fails exactly one request ----------
    let panic_run = run_chaos("worker.panic=nth:40", 0, 64, 4, 3, &work);
    assert_eq!(
        panic_run.count(|f| matches!(f, Fin::WorkerPanic)),
        1,
        "an nth schedule panics exactly one (sequence, head) task"
    );
    assert_eq!(panic_run.count(|f| matches!(f, Fin::Completed(_))), 2);
    for i in 0..work.len() {
        if let Fin::Completed(out) = &panic_run.fins[i] {
            assert_eq!(
                out.as_slice(),
                baseline.completed(i),
                "request {i} untouched by the panic must be bit-identical"
            );
        }
    }
    assert!(panic_run.drained, "the failed request's blocks are released");
    summary.insert("worker_panic".to_string(), scenario_json(&panic_run));

    // -- injected block corruption: checksum at adoption, fallback ------
    let shared: Vec<Spec> = vec![(7, 12, None), (7, 12, None)];
    let solo = run_chaos("", 0, 64, 4, 1, &[(7, 12, None)]);
    let clean = run_chaos("", 0, 64, 4, 2, &shared);
    assert_eq!(clean.completed(0), solo.completed(0));
    assert_eq!(clean.completed(1), solo.completed(0), "sharing is bit-exact");
    let corrupt = run_chaos("block.corrupt=nth:1", 0, 64, 4, 2, &shared);
    assert!(
        corrupt.integrity_failures >= 1,
        "the adopter must detect the flipped bit at adoption"
    );
    assert!(
        corrupt.prefix_hits >= 1,
        "uncorrupted prefix blocks still adopt"
    );
    assert_eq!(
        corrupt.completed(1),
        solo.completed(0),
        "adoption of a corrupted block falls back to a fresh encode — \
         never silent corruption"
    );
    assert!(matches!(corrupt.fins[0], Fin::Completed(_)));
    assert!(corrupt.drained);
    summary.insert("block_corrupt".to_string(), scenario_json(&corrupt));

    // -- thrashing cutoff: a working set the pool can never hold -------
    // a 128-token prompt growing to 80 generated tokens (207 cache rows)
    // wants 4 blocks; 3 exist. Each retry charges the budget (1):
    // evictions 1, 2, then 3 > 2×budget.
    let thrash = run_chaos("", 0, 3, 1, 2, &[(9, 80, None)]);
    assert_eq!(thrash.fins[0], Fin::Thrashing, "structured, not a livelock");
    assert_eq!(thrash.evictions, 3, "pin → retry → 2N cutoff");
    assert!(thrash.drained);
    summary.insert("thrash".to_string(), scenario_json(&thrash));

    // -- injected CacheFull on append: one eviction, bit-exact finish --
    let append = run_chaos("append.cache_full=nth:2", 0, 64, 4, 3, &work);
    assert!(append.evictions >= 1, "the injected CacheFull must evict");
    for i in 0..work.len() {
        assert_eq!(append.completed(i), baseline.completed(i));
    }
    assert!(append.drained);
    summary.insert("append_full".to_string(), scenario_json(&append));

    // -- deadlines: partial output for running, empty for queued -------
    // wall-clock SLOs on the 1 ms virtual clock: 10 ms ≈ 10 engine steps
    let dl = run_chaos("", 0, 64, 4, 1, &[(0, 40, Some(10)), (1, 40, Some(5))]);
    match dl.fins[0] {
        Fin::DeadlineExceeded { tokens_done } => {
            assert!(tokens_done > 0, "the running request keeps partial output");
            assert!(tokens_done < 40, "it expired before completing");
        }
        ref other => panic!("request 0 expected DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(
        dl.fins[1],
        Fin::DeadlineExceeded { tokens_done: 0 },
        "a request that never left the queue expires with no output"
    );
    assert!(dl.drained);
    summary.insert("deadline".to_string(), scenario_json(&dl));

    // -- tiered swap: a 4-block pool forces the victim to the host tier -
    // Geometry (BT = 64, 4 blocks): a 126-token survivor that grows past
    // the 128-row boundary (2 → 3 blocks) plus a 120-token victim that
    // never grows (120 + 7 rows < 128, 2 blocks for life). Both admit
    // (2 + 2 = 4); the survivor's boundary decode finds `free 0 <
    // step 1`, so the youngest swaps out. Resume then stays blocked
    // (free − step < 2) until the survivor completes and releases —
    // a transient squeeze with one clean swap cycle, not a livelock.
    let swap_work: Vec<ReqSpec> = vec![
        (prompt_bytes_n(20, 126), 30, None),
        (prompt_bytes_n(21, 120), 8, None),
    ];
    // uncontended reference: 64 blocks never pressure, so never swap
    let swap_base = run_chaos_swap("", 0, 64, 4, 2, &swap_work);
    assert_eq!(swap_base.count(|f| matches!(f, Fin::Completed(_))), 2);
    assert_eq!(swap_base.swap_outs, 0, "no pressure, no swap");
    assert!(swap_base.drained);
    summary.insert("swap_base".to_string(), scenario_json(&swap_base));

    let swap_clean = run_chaos_swap("", 0, 4, 4, 2, &swap_work);
    assert!(swap_clean.swap_outs >= 1, "the tight pool must swap out");
    assert!(swap_clean.swap_ins >= 1, "the swapped victim must resume");
    assert_eq!(swap_clean.swap_fallbacks, 0, "clean tier never falls back");
    for i in 0..swap_work.len() {
        assert_eq!(
            swap_clean.completed(i),
            swap_base.completed(i),
            "request {i}: swap + resume must be bit-identical to never \
             having been evicted"
        );
    }
    assert!(swap_clean.drained, "swap round-trip must leak nothing");
    summary.insert("swap_clean".to_string(), scenario_json(&swap_clean));

    // -- swap-in corruption: detected at re-admission, bit-exact fallback
    let swap_corrupt = run_chaos_swap("tier.corrupt=nth:1", 0, 4, 4, 2, &swap_work);
    assert!(
        swap_corrupt.integrity_failures >= 1,
        "the flipped host byte must fail checksum verification"
    );
    assert!(
        swap_corrupt.swap_fallbacks >= 1,
        "a corrupt host copy must fall back to re-prefill"
    );
    for i in 0..swap_work.len() {
        assert_eq!(
            swap_corrupt.completed(i),
            swap_base.completed(i),
            "request {i}: corruption fallback recomputes bit-identically — \
             never silent corruption"
        );
    }
    assert!(swap_corrupt.drained, "corrupt fallback must leak nothing");
    summary.insert("swap_corrupt".to_string(), scenario_json(&swap_corrupt));

    // -- swap faults mid-flight: abort cleanly on either side, no leaks -
    let swap_out_fault = run_chaos_swap("swap.out=nth:1", 0, 4, 4, 2, &swap_work);
    assert_eq!(
        swap_out_fault.swap_outs, 0,
        "the faulted swap-out must fall back to a plain eviction"
    );
    assert!(swap_out_fault.evictions >= 1);
    for i in 0..swap_work.len() {
        assert_eq!(swap_out_fault.completed(i), swap_base.completed(i));
    }
    assert!(swap_out_fault.drained, "swap-out fault must leak nothing");
    summary.insert("swap_fault_out".to_string(), scenario_json(&swap_out_fault));

    let swap_in_fault = run_chaos_swap("swap.in=nth:1", 0, 4, 4, 2, &swap_work);
    assert!(swap_in_fault.swap_outs >= 1, "swap-out side is clean here");
    assert_eq!(swap_in_fault.swap_ins, 0, "the faulted swap-in never lands");
    assert!(
        swap_in_fault.swap_fallbacks >= 1,
        "a faulted swap-in must fall back to re-prefill"
    );
    for i in 0..swap_work.len() {
        assert_eq!(swap_in_fault.completed(i), swap_base.completed(i));
    }
    assert!(swap_in_fault.drained, "swap-in fault must leak nothing");
    summary.insert("swap_fault_in".to_string(), scenario_json(&swap_in_fault));

    // -- seeded sweep: alloc + append + panic armed at once ------------
    // No bit-exactness claim — the invariants are: the process never
    // panics, every request reaches a structured terminal state, and the
    // pool drains regardless of which faults fired.
    let sweep_work: Vec<Spec> = (0..5).map(|c| (c, 16, None)).collect();
    let sweep = run_chaos(
        "pool.alloc=prob:0.05,append.cache_full=prob:0.05,worker.panic=prob:0.02",
        seed,
        16,
        4,
        3,
        &sweep_work,
    );
    assert_eq!(sweep.fins.len(), sweep_work.len(), "every request terminal");
    assert!(sweep.drained, "no fault mix may leak blocks");
    summary.insert("sweep".to_string(), scenario_json(&sweep));

    std::fs::write(
        "CHAOS_summary.json",
        format!("{}\n", Json::Obj(summary)),
    )
    .expect("write CHAOS_summary.json");
}
