//! Golden-vector parity: the Rust self-indexing pipeline must reproduce
//! the Python reference (`python/compile/kernels/ref.py`) on the
//! deterministic tensors exported by `python -m compile.golden`.
//!
//! codes/top-k compare bit-exact; floats within tolerance (the Rust path
//! stores quant params in fp16, the Python oracle in f32 — quantized
//! *values* still match because both round the same way; dequantized
//! floats get a tolerance).
//!
//! Requires `make artifacts` (skips with a notice otherwise).

use std::collections::HashMap;
use std::path::Path;

use selfindex_kv::selfindex::codebook::CodebookBuilder;
use selfindex_kv::selfindex::codes::encode_token;
use selfindex_kv::selfindex::lut::Lut;
use selfindex_kv::selfindex::score::{score_tokens, ByteLut};
use selfindex_kv::selfindex::topk::top_k_indices;

const L: usize = 256;
const D: usize = 64;
const G: usize = 16;
const K_SEL: usize = 32;

struct Golden(HashMap<String, (Vec<usize>, Vec<f32>)>);

impl Golden {
    fn load() -> Option<Self> {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden.bin");
        if !path.exists() {
            eprintln!("golden.bin missing — run `make artifacts`; skipping");
            return None;
        }
        // same container as weights.bin
        let store = selfindex_kv::model::WeightStore::load(&path).unwrap();
        let mut map = HashMap::new();
        for name in store.names() {
            let (s, d) = store.get(name).unwrap();
            map.insert(name.clone(), (s.to_vec(), d.to_vec()));
        }
        Some(Self(map))
    }

    fn get(&self, name: &str) -> &[f32] {
        &self.0.get(name).unwrap_or_else(|| panic!("missing {name}")).1
    }
}

#[test]
fn golden_pipeline_parity() {
    let Some(g) = Golden::load() else { return };

    let k = g.get("k");
    let kn_ref = g.get("kn");
    let mu_ref = g.get("mu");

    // --- normalization
    let mu: Vec<f32> = (0..D)
        .map(|j| k.iter().skip(j).step_by(D).sum::<f32>() / L as f32)
        .collect();
    for j in 0..D {
        assert!((mu[j] - mu_ref[j]).abs() < 1e-4, "mu[{j}]");
    }
    let kn: Vec<f32> = k
        .iter()
        .enumerate()
        .map(|(i, &v)| v - mu[i % D])
        .collect();
    for i in 0..kn.len() {
        assert!((kn[i] - kn_ref[i]).abs() < 1e-4, "kn[{i}]");
    }

    // --- sign codes: bit-exact
    let codes_ref = g.get("codes");
    for t in 0..L {
        let codes = encode_token(&kn[t * D..(t + 1) * D]);
        for gi in 0..G {
            assert_eq!(
                codes[gi] as f32, codes_ref[t * G + gi],
                "codes[{t},{gi}]"
            );
        }
    }

    // --- codebook
    let mut b = CodebookBuilder::new(G);
    b.accumulate(&kn);
    let cb = b.finalize();
    let cb_ref = g.get("codebook");
    for i in 0..cb.centroids.len() {
        assert!(
            (cb.centroids[i] - cb_ref[i]).abs() < 1e-4,
            "codebook[{i}]: {} vs {}",
            cb.centroids[i],
            cb_ref[i]
        );
    }

    // --- LUT + scores
    let q = g.get("q");
    let lut = Lut::build(q, &cb);
    let lut_ref = g.get("lut");
    for i in 0..lut.table.len() {
        assert!((lut.table[i] - lut_ref[i]).abs() < 1e-3, "lut[{i}]");
    }
    let packed = selfindex_kv::selfindex::codes::encode_tokens_packed(&kn, D);
    let mut scores = Vec::new();
    score_tokens(&lut, &packed, L, &mut scores);
    let scores_ref = g.get("scores");
    for t in 0..L {
        assert!(
            (scores[t] - scores_ref[t]).abs() < 1e-2,
            "scores[{t}]: {} vs {}",
            scores[t],
            scores_ref[t]
        );
    }
    // byte-LUT path identical
    let blut = ByteLut::from_lut(&lut);
    let mut s2 = Vec::new();
    selfindex_kv::selfindex::score::score_tokens_bytelut(&blut, &packed, L, &mut s2);
    for t in 0..L {
        assert!((scores[t] - s2[t]).abs() < 1e-4);
    }

    // --- top-k: bit-exact (same tie-break contract)
    let topk_ref: Vec<u32> = g.get("topk").iter().map(|&x| x as u32).collect();
    // use the reference scores so fp noise can't flip near-ties
    let topk = top_k_indices(scores_ref, K_SEL);
    assert_eq!(topk, topk_ref);

    // --- quantized payloads: values bit-exact vs the oracle
    let alpha_ref = g.get("alpha");
    let alpha: Vec<f32> = (0..D)
        .map(|j| {
            let m = kn.iter().skip(j).step_by(D).fold(0.0f32, |a, &v| a.max(v.abs()));
            if m > 0.0 {
                m
            } else {
                1.0
            }
        })
        .collect();
    for j in 0..D {
        assert!((alpha[j] - alpha_ref[j]).abs() < 1e-4, "alpha[{j}]");
    }
    let khat: Vec<f32> = kn
        .iter()
        .enumerate()
        .map(|(i, &v)| v.abs() / alpha[i % D])
        .collect();
    let kq = selfindex_kv::quant::quantize_tokens(&khat, D, 32, 2);
    let kq_ref = g.get("k_q");
    let mut mismatches = 0;
    for i in 0..kq.values.len() {
        if kq.values[i] as f32 != kq_ref[i] {
            mismatches += 1;
        }
    }
    // fp16 param rounding can flip values sitting exactly on a rounding
    // boundary; allow a tiny fraction
    assert!(
        mismatches * 1000 < kq.values.len(),
        "{mismatches}/{} k_q mismatches",
        kq.values.len()
    );

    // --- dense attention vs oracle
    let v = g.get("v");
    let dense_ref = g.get("dense_out");
    let mut out = vec![0.0f32; D];
    selfindex_kv::attention::dense::attend_dense(q, &kn, v, L, &mut out);
    for j in 0..D {
        assert!(
            (out[j] - dense_ref[j]).abs() < 1e-3,
            "dense_out[{j}]: {} vs {}",
            out[j],
            dense_ref[j]
        );
    }
}
