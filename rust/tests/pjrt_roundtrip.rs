//! PJRT ↔ Rust numerics: load real artifacts, execute them, and check
//! cross-program consistency and parity with the Rust-native kernels.
//!
//! Requires `make artifacts` (tests skip with a notice otherwise).

use std::path::Path;

use selfindex_kv::runtime::{HostTensor, PjrtRuntime};
use selfindex_kv::substrate::rng::Rng;

fn runtime() -> Option<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts`; skipping");
        return None;
    }
    Some(PjrtRuntime::load(&dir).expect("runtime load"))
}

#[test]
fn quantize_block_matches_native() {
    let Some(mut rt) = runtime() else { return };
    let hd = rt.manifest.model.head_dim;
    let t = 256usize;
    let mut r = Rng::new(1);
    let k: Vec<f32> = (0..t * hd).map(|_| r.normal_f32()).collect();
    let v: Vec<f32> = (0..t * hd).map(|_| r.normal_f32()).collect();
    let mu: Vec<f32> = (0..hd)
        .map(|j| k.iter().skip(j).step_by(hd).sum::<f32>() / t as f32)
        .collect();
    let centered: Vec<f32> = k
        .iter()
        .enumerate()
        .map(|(i, &x)| x - mu[i % hd])
        .collect();
    let alpha: Vec<f32> = (0..hd)
        .map(|j| {
            centered
                .iter()
                .skip(j)
                .step_by(hd)
                .fold(0.0f32, |a, &x| a.max(x.abs()))
                .max(1e-9)
        })
        .collect();

    let outs = rt
        .run(
            "quantize_t256",
            None,
            &[
                HostTensor::F32(k.clone(), vec![t, hd]),
                HostTensor::F32(v.clone(), vec![t, hd]),
                HostTensor::F32(mu.clone(), vec![hd]),
                HostTensor::F32(alpha.clone(), vec![hd]),
            ],
        )
        .expect("quantize_t256");
    // outputs: codes, sums, counts, k_q, k_qs, k_zp, v_q, v_qs, v_zp
    let codes = outs[0].as_i32();
    let g = hd / 4;
    for t_i in 0..t {
        let native =
            selfindex_kv::selfindex::codes::encode_token(&centered[t_i * hd..(t_i + 1) * hd]);
        for gi in 0..g {
            assert_eq!(
                codes[t_i * g + gi], native[gi] as i32,
                "codes[{t_i},{gi}]"
            );
        }
    }
    // value quantization parity (values u8 exactly; params f32 close)
    let vq_native = selfindex_kv::quant::quantize_tokens(&v, hd, 32, 2);
    let v_q = match &outs[6] {
        HostTensor::U8(d, _) => d.clone(),
        _ => panic!("v_q dtype"),
    };
    let mut mismatch = 0;
    for i in 0..v_q.len() {
        if v_q[i] != vq_native.values[i] {
            mismatch += 1;
        }
    }
    assert!(
        mismatch * 500 < v_q.len(),
        "v_q mismatches {mismatch}/{}",
        v_q.len()
    );
}

#[test]
fn dense_attn_matches_native() {
    let Some(mut rt) = runtime() else { return };
    let m = rt.manifest.model.clone();
    let (h, kvh, hd) = (m.n_heads, m.n_kv_heads, m.head_dim);
    let r_ratio = m.gqa_ratio();
    let l = 256usize;
    let mut r = Rng::new(2);
    let q: Vec<f32> = (0..h * hd).map(|_| r.normal_f32()).collect();
    let k: Vec<f32> = (0..l * kvh * hd).map(|_| r.normal_f32()).collect();
    let v: Vec<f32> = (0..l * kvh * hd).map(|_| r.normal_f32()).collect();
    let n = 100usize; // true cache length

    let outs = rt
        .run(
            "dense_attn_b1_l256",
            None,
            &[
                HostTensor::F32(q.clone(), vec![1, h, hd]),
                HostTensor::F32(k.clone(), vec![1, l, kvh, hd]),
                HostTensor::F32(v.clone(), vec![1, l, kvh, hd]),
                HostTensor::I32(vec![n as i32], vec![1]),
            ],
        )
        .expect("dense_attn");
    let o = outs[0].as_f32(); // (1, h, hd)

    // native reference: per q-head attention over its kv head's rows
    for qh in 0..h {
        let kvh_idx = qh / r_ratio;
        let mut keys = vec![0.0f32; n * hd];
        let mut vals = vec![0.0f32; n * hd];
        for t in 0..n {
            let src = (t * kvh + kvh_idx) * hd;
            keys[t * hd..(t + 1) * hd].copy_from_slice(&k[src..src + hd]);
            vals[t * hd..(t + 1) * hd].copy_from_slice(&v[src..src + hd]);
        }
        let mut expect = vec![0.0f32; hd];
        selfindex_kv::attention::dense::attend_dense(
            &q[qh * hd..(qh + 1) * hd],
            &keys,
            &vals,
            n,
            &mut expect,
        );
        for j in 0..hd {
            assert!(
                (o[qh * hd + j] - expect[j]).abs() < 1e-4,
                "head {qh} j {j}: {} vs {}",
                o[qh * hd + j],
                expect[j]
            );
        }
    }
}

#[test]
fn decode_qkv_consistent_with_prefill_cache() {
    // RoPE/cache coherence across programs: prefill's K row at position p
    // must equal decode_qkv's k for the same input activations.
    let Some(mut rt) = runtime() else { return };
    let m = rt.manifest.model.clone();
    let t = 48usize;
    let mut r = Rng::new(3);
    let mut tokens = vec![0i32; 256];
    for tok in tokens.iter_mut().take(t) {
        *tok = r.below(m.vocab_size as u64) as i32;
    }
    let outs = rt
        .run(
            "prefill_l256",
            None,
            &[
                HostTensor::I32(tokens.clone(), vec![1, 256]),
                HostTensor::scalar_i32(t as i32),
            ],
        )
        .expect("prefill");
    let k_cache = outs[0].as_f32(); // (layers, 256, kvh, hd)
    let q_window = outs[3].as_f32(); // (layers, W, h, hd)
    let w = rt.manifest.artifact("prefill_l256").unwrap().outputs[3].shape[1];

    // embed token at position t-1, run decode_qkv layer 0, compare k
    let last_tok = tokens[t - 1];
    let x = rt
        .run("embed_b1", None, &[HostTensor::I32(vec![last_tok], vec![1])])
        .expect("embed")
        .remove(0);
    let qkv = rt
        .run(
            "decode_qkv_b1",
            Some(0),
            &[x, HostTensor::I32(vec![(t - 1) as i32], vec![1])],
        )
        .expect("decode_qkv");
    let k_dec = qkv[1].as_f32(); // (1, kvh, hd)
    let (kvh, hd, h) = (m.n_kv_heads, m.head_dim, m.n_heads);
    for head in 0..kvh {
        for j in 0..hd {
            let cache_val = k_cache[((t - 1) * kvh + head) * hd + j]; // layer 0
            let dec_val = k_dec[head * hd + j];
            assert!(
                (cache_val - dec_val).abs() < 1e-3,
                "k mismatch head {head} j {j}: {cache_val} vs {dec_val}"
            );
        }
    }
    // q_window's last row equals decode q at position t-1
    let q_dec = qkv[0].as_f32(); // (1, h, hd)
    for qh in 0..h {
        for j in 0..hd {
            let win_val = q_window[((w - 1) * h + qh) * hd + j]; // layer 0, last w
            let dec_val = q_dec[qh * hd + j];
            assert!(
                (win_val - dec_val).abs() < 1e-3,
                "q mismatch head {qh} j {j}: {win_val} vs {dec_val}"
            );
        }
    }
}

#[test]
fn sparse_attn_program_matches_native_fused() {
    // The PJRT fused sparse-attention program and the Rust-native fused
    // kernel must agree on identical gathered inputs.
    let Some(mut rt) = runtime() else { return };
    let m = rt.manifest.model.clone();
    let (h, kvh, hd) = (m.n_heads, m.n_kv_heads, m.head_dim);
    let r_ratio = m.gqa_ratio();
    let spec = rt.manifest.artifact("sparse_attn_b1").unwrap().clone();
    let s = spec.inputs[1].shape[2]; // slots
    let t_sink = spec.inputs[9].shape[2];
    let g = hd / 4;
    let ng = hd / 32;

    let mut r = Rng::new(4);
    let q: Vec<f32> = (0..h * hd).map(|_| r.normal_f32()).collect();
    let codes: Vec<i32> = (0..kvh * s * g).map(|_| r.below(16) as i32).collect();
    let k_q: Vec<u8> = (0..kvh * s * hd).map(|_| r.below(4) as u8).collect();
    let v_q: Vec<u8> = (0..kvh * s * hd).map(|_| r.below(4) as u8).collect();
    let k_qs: Vec<f32> = (0..kvh * s * ng).map(|_| r.uniform(0.1, 0.3)).collect();
    let k_zp: Vec<f32> = (0..kvh * s * ng).map(|_| r.uniform(0.0, 0.1)).collect();
    let v_qs: Vec<f32> = (0..kvh * s * ng).map(|_| r.uniform(0.1, 0.3)).collect();
    let v_zp: Vec<f32> = (0..kvh * s * ng).map(|_| r.uniform(-0.4, 0.0)).collect();
    let alpha: Vec<f32> = (0..kvh * hd).map(|_| r.uniform(0.5, 2.0)).collect();
    let k_sink: Vec<f32> = (0..kvh * t_sink * hd).map(|_| r.normal_f32()).collect();
    let v_sink: Vec<f32> = (0..kvh * t_sink * hd).map(|_| r.normal_f32()).collect();

    let outs = rt
        .run(
            "sparse_attn_b1",
            None,
            &[
                HostTensor::F32(q.clone(), vec![1, h, hd]),
                HostTensor::I32(codes.clone(), vec![1, kvh, s, g]),
                HostTensor::U8(k_q.clone(), vec![1, kvh, s, hd]),
                HostTensor::F32(k_qs.clone(), vec![1, kvh, s, ng]),
                HostTensor::F32(k_zp.clone(), vec![1, kvh, s, ng]),
                HostTensor::U8(v_q.clone(), vec![1, kvh, s, hd]),
                HostTensor::F32(v_qs.clone(), vec![1, kvh, s, ng]),
                HostTensor::F32(v_zp.clone(), vec![1, kvh, s, ng]),
                HostTensor::F32(alpha.clone(), vec![1, kvh, hd]),
                HostTensor::F32(k_sink.clone(), vec![1, kvh, t_sink, hd]),
                HostTensor::F32(v_sink.clone(), vec![1, kvh, t_sink, hd]),
                HostTensor::F32(vec![0.0; kvh * s], vec![1, kvh, s]),
                HostTensor::F32(vec![0.0; kvh * t_sink], vec![1, kvh, t_sink]),
            ],
        )
        .expect("sparse_attn");
    let o = outs[0].as_f32(); // (1, h, hd)

    // native reference: dequantize, then dense attention over sinks+sel
    let scale_bits = 2u32;
    for qh in 0..h {
        let head = qh / r_ratio;
        let mut keys = Vec::with_capacity((t_sink + s) * hd);
        let mut vals = Vec::with_capacity((t_sink + s) * hd);
        for t in 0..t_sink {
            let base = (head * t_sink + t) * hd;
            keys.extend_from_slice(&k_sink[base..base + hd]);
            vals.extend_from_slice(&v_sink[base..base + hd]);
        }
        for t in 0..s {
            for j in 0..hd {
                let pq = k_qs[(head * s + t) * ng + j / 32];
                let pz = k_zp[(head * s + t) * ng + j / 32];
                let mag = (pq * k_q[(head * s + t) * hd + j] as f32 + pz)
                    * alpha[head * hd + j];
                let code = codes[(head * s + t) * g + j / 4];
                let bit = (code >> (3 - (j % 4))) & 1;
                let sign = if bit == 1 { 1.0 } else { -1.0 };
                keys.push(sign * mag);
                let vq_ = v_qs[(head * s + t) * ng + j / 32];
                let vz = v_zp[(head * s + t) * ng + j / 32];
                vals.push(vq_ * v_q[(head * s + t) * hd + j] as f32 + vz);
            }
        }
        let mut expect = vec![0.0f32; hd];
        selfindex_kv::attention::dense::attend_dense(
            &q[qh * hd..(qh + 1) * hd],
            &keys,
            &vals,
            t_sink + s,
            &mut expect,
        );
        for j in 0..hd {
            assert!(
                (o[qh * hd + j] - expect[j]).abs() < 1e-3,
                "qh {qh} j {j}: {} vs {}",
                o[qh * hd + j],
                expect[j]
            );
        }
    }
    let _ = scale_bits;
}
