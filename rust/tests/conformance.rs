//! Trait-conformance suite over all seven methods through the
//! sequence-level `SequenceCache` API (the shared checks live in
//! `method::conformance`): registry-built caches are bit-exact with
//! hand-driven per-head leaves (serial AND work-queue fan-out), memory is
//! monotone under appends, budget ≥ len matches dense attention, and
//! append ≡ longer prefill where that is the method's contract.

use selfindex_kv::method::conformance::run_named;

#[test]
fn conformance_selfindex() {
    run_named("selfindex");
}

#[test]
fn conformance_full() {
    run_named("full");
}

#[test]
fn conformance_kivi() {
    run_named("kivi");
}

#[test]
fn conformance_snapkv() {
    run_named("snapkv");
}

#[test]
fn conformance_quest() {
    run_named("quest");
}

#[test]
fn conformance_doublesparse() {
    run_named("doublesparse");
}

#[test]
fn conformance_kmeans() {
    run_named("kmeans");
}

#[test]
fn suite_covers_every_registry_entry() {
    for entry in selfindex_kv::method::entries() {
        assert!(
            selfindex_kv::method::conformance::SUITE
                .iter()
                .any(|c| c.method == entry.name()),
            "no conformance case for registry method '{}'",
            entry.name()
        );
    }
}
