//! The engine decode fan-out allocates **zero bytes** at steady state —
//! process-wide, across every worker thread — asserted under the counting
//! global allocator.
//!
//! This drives the exact machinery `Engine::decode_batch` runs per layer
//! (registry-built `SequenceCache` → `DecodePlan` → `push_tasks` →
//! `DecodeWorkQueue::dispatch` over `ThreadPool::for_each_task`) against
//! prebuilt staging buffers, engine-shaped: B sequences × layers × kv
//! heads, GQA-grouped, self-indexing method. The PJRT projection calls
//! that surround the fan-out in the real engine are host-runtime staging
//! and out of scope here.
//!
//! Kept as the only test in this binary: the global counter sees every
//! thread, so a concurrently running unrelated test would pollute it.

use std::sync::Arc;

use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::method::registry::{lookup, BuildCtx};
use selfindex_kv::method::{DecodePlan, DecodeWorkQueue, SequenceCache};
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::exec::ThreadPool;
use selfindex_kv::substrate::metrics::{global_allocations, CountingAllocator};
use selfindex_kv::substrate::rng::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

const DIM: usize = 64;
const LAYERS: usize = 2;
const KVH: usize = 2;
const R: usize = 2;
const B: usize = 2;
const T: usize = 1024;
const BUDGET: usize = 96;

#[test]
fn engine_fanout_is_allocation_free_at_steady_state() {
    let si = SelfIndexConfig::default();
    let overlay = vec![];
    // ONE shared pool for all B × LAYERS × KVH heads — engine-shaped
    let mgr = Arc::new(KvManager::for_head(
        DIM,
        &si,
        64,
        B * LAYERS * KVH * (2 * T) / 64,
    ));
    let ctx = BuildCtx {
        dim: DIM,
        n_layers: LAYERS,
        kv_heads: KVH,
        gqa_ratio: R,
        budget_hint: T,
        mgr: &mgr,
        selfindex: &si,
        overlay: &overlay,
        prompt_hash: 0,
    };
    let entry = lookup("selfindex").unwrap();

    // B sequences, prefilled per layer (engine-shaped admission)
    let mut rng = Rng::new(99);
    let mut seqs: Vec<Box<dyn SequenceCache>> = Vec::new();
    for _ in 0..B {
        let mut cache = entry.build_seq(&ctx);
        for layer in 0..LAYERS {
            let keys: Vec<f32> = (0..KVH * T * DIM).map(|_| rng.normal_f32()).collect();
            let vals: Vec<f32> = (0..KVH * T * DIM).map(|_| rng.normal_f32()).collect();
            cache.prefill_layer(layer, &keys, &vals, &[]);
        }
        seqs.push(cache);
    }

    // prebuilt staging buffers (the engine's per-layer qkv outputs and
    // the layer output buffer — PJRT-boundary state, reused here)
    let k_rows: Vec<f32> = (0..B * KVH * DIM).map(|_| rng.normal_f32()).collect();
    let v_rows: Vec<f32> = (0..B * KVH * DIM).map(|_| rng.normal_f32()).collect();
    let queries: Vec<f32> = (0..B * KVH * R * DIM).map(|_| rng.normal_f32()).collect();
    let mut o = vec![0.0f32; B * KVH * R * DIM];

    let pool = ThreadPool::new(4);
    let mut wq = DecodeWorkQueue::new();

    let step =
        |seqs: &mut [Box<dyn SequenceCache>], o: &mut [f32], wq: &mut DecodeWorkQueue| {
            for layer in 0..LAYERS {
                let mut tasks = wq.take();
                let mut o_chunks = o.chunks_mut(KVH * R * DIM);
                for (i, seq) in seqs.iter_mut().enumerate() {
                    let plan = DecodePlan {
                        layer,
                        dim: DIM,
                        kv_heads: KVH,
                        gqa_ratio: R,
                        budget: BUDGET,
                        k_rows: &k_rows[i * KVH * DIM..(i + 1) * KVH * DIM],
                        v_rows: &v_rows[i * KVH * DIM..(i + 1) * KVH * DIM],
                        queries: &queries[i * KVH * R * DIM..(i + 1) * KVH * R * DIM],
                    };
                    let oslice = o_chunks.next().unwrap();
                    seq.push_tasks(&plan, oslice, &mut tasks);
                }
                wq.dispatch(&pool, tasks);
            }
        };

    // warmup: size every scratch arena (selector heaps, LUTs, encode and
    // quantize buffers, the task arena) AND run the fp recent window past
    // its 64-row fold cap, landing between 64-token block-allocation
    // boundaries so the measured window crosses none
    for _ in 0..72 {
        step(&mut seqs, &mut o, &mut wq);
    }

    let before = global_allocations();
    for _ in 0..8 {
        step(&mut seqs, &mut o, &mut wq);
    }
    let delta = global_allocations() - before;
    assert_eq!(
        delta, 0,
        "decode fan-out allocated {delta} times at steady state \
         (per-job boxing or per-call temp vecs crept back in)"
    );
    assert!(o.iter().any(|&x| x != 0.0), "fan-out produced no output");
}
