//! CI kernel-parity matrix: every scorer implementation must produce
//! BIT-IDENTICAL sign-agreement scores on randomized codes — the
//! dispatched popcount kernel (AVX2 / hardware-popcnt / NEON, whatever
//! the host selects), the always-compiled scalar popcount, the nibble
//! reference scorer and the byte-combined LUT over `Lut::sign_agreement`,
//! and a from-first-principles integer oracle. Scores are integers in
//! [−dim, dim] and integer f32 addition is exact under any summation
//! order, so equality holds under ANY RUSTFLAGS — the workflow runs this
//! file twice (baseline and `-C target-cpu=native`) to pin exactly that.

use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::kvcache::store::HeadCache;
use selfindex_kv::quant::pack;
use selfindex_kv::selfindex::codes::{encode_tokens_packed, sign_code};
use selfindex_kv::selfindex::lut::Lut;
use selfindex_kv::selfindex::score::{
    page_bound, popcnt_kernel_name, score_block_bytelut, score_block_popcnt,
    score_block_popcnt_scalar, score_tokens, score_tokens_bytelut, BlockScorer, ByteLut,
};
use selfindex_kv::selfindex::topk::TopKStream;
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::rng::Rng;

/// The ground-truth oracle: unpack nibbles, count agreeing minus
/// disagreeing sign bits per group, sum in i32.
fn oracle(q_codes: &[u8], packed: &[u8], n_tokens: usize) -> Vec<f32> {
    let g = q_codes.len();
    let codes = pack::unpack_codes(packed, n_tokens * g);
    (0..n_tokens)
        .map(|t| {
            let mut acc = 0i32;
            for (gi, &qc) in q_codes.iter().enumerate() {
                acc += 4 - 2 * (qc ^ codes[t * g + gi]).count_ones() as i32;
            }
            acc as f32
        })
        .collect()
}

/// Run all five scorer paths on one (query, keys) workload and assert
/// bitwise equality of every score and of the block max.
fn assert_parity(q_codes: &[u8], packed: &[u8], n_tokens: usize, dim: usize, label: &str) {
    let cb = dim / 8;
    assert_eq!(packed.len(), n_tokens * cb, "{label}: workload shape");
    let expect = oracle(q_codes, packed, n_tokens);
    let emax = expect.iter().fold(f32::NEG_INFINITY, |m, &s| m.max(s));

    // popcount: dispatched kernel + scalar, over word-packed codes
    let words = pack::pack_signs_u64(packed, n_tokens, cb);
    let q_packed = pack::pack_codes(q_codes);
    let q_words = pack::pack_signs_u64(&q_packed, 1, cb);
    let mut pop = vec![f32::NAN; n_tokens];
    let mut pop_s = vec![f32::NAN; n_tokens];
    let m_pop = score_block_popcnt(&q_words, &words, n_tokens, dim, &mut pop);
    let m_pop_s = score_block_popcnt_scalar(&q_words, &words, n_tokens, dim, &mut pop_s);

    // byte-LUT conformance oracle + reference scorer over the
    // sign-agreement LUT (integer entries)
    let lut = Lut::sign_agreement(q_codes);
    let blut = ByteLut::from_lut(&lut);
    let mut refr = Vec::new();
    score_tokens(&lut, packed, n_tokens, &mut refr);
    let mut bl = Vec::new();
    score_tokens_bytelut(&blut, packed, n_tokens, &mut bl);
    let mut bl_block = vec![f32::NAN; n_tokens];
    let m_bl = score_block_bytelut(&blut, packed, n_tokens, &mut bl_block);

    // and through the BlockScorer dispatch enum the serving path uses
    let mut via_enum = vec![f32::NAN; n_tokens];
    let enum_scorer = BlockScorer::Popcnt { q_words: &q_words, dim };
    let m_enum = enum_scorer.score_block(&[], &words, n_tokens, &mut via_enum);

    for t in 0..n_tokens {
        let e = expect[t];
        for (name, got) in [
            ("popcnt", pop[t]),
            ("popcnt_scalar", pop_s[t]),
            ("reference", refr[t]),
            ("bytelut", bl[t]),
            ("bytelut_block", bl_block[t]),
            ("block_scorer_enum", via_enum[t]),
        ] {
            assert_eq!(
                got.to_bits(),
                e.to_bits(),
                "{label} token {t} {name}: {got} != oracle {e}"
            );
        }
    }
    if n_tokens > 0 {
        for (name, got) in [
            ("popcnt", m_pop),
            ("popcnt_scalar", m_pop_s),
            ("bytelut_block", m_bl),
            ("block_scorer_enum", m_enum),
        ] {
            assert_eq!(got.to_bits(), emax.to_bits(), "{label} block max {name}");
        }
    }
}

#[test]
fn parity_over_randomized_real_keys() {
    // gaussian keys through the real encoder: the production shape
    let mut r = Rng::new(0x5eed);
    for &dim in &[8usize, 32, 56, 64, 72, 96, 128] {
        for &tokens in &[0usize, 1, 7, 8, 33, 256, 511] {
            let keys: Vec<f32> = (0..tokens * dim).map(|_| r.normal_f32()).collect();
            let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
            let packed = encode_tokens_packed(&keys, dim);
            let q_codes: Vec<u8> = q.chunks_exact(4).map(sign_code).collect();
            assert_parity(&q_codes, &packed, tokens, dim, &format!("keys d{dim} n{tokens}"));
        }
    }
}

#[test]
fn parity_over_raw_random_nibbles() {
    // adversarial: arbitrary packed bytes, not reachable from any real
    // key — the kernels must agree on ALL code patterns, not just the
    // encoder's image
    let mut r = Rng::new(0xfeed);
    for &dim in &[16usize, 40, 64, 104, 128] {
        for &tokens in &[1usize, 13, 64, 200] {
            let cb = dim / 8;
            let packed: Vec<u8> = (0..tokens * cb).map(|_| r.below(256) as u8).collect();
            let q_codes: Vec<u8> = (0..dim / 4).map(|_| r.below(16) as u8).collect();
            assert_parity(&q_codes, &packed, tokens, dim, &format!("raw d{dim} n{tokens}"));
        }
    }
}

#[test]
fn parity_at_extremes() {
    // all-zero and all-ones codes bracket the score range
    for &dim in &[64usize, 128] {
        let cb = dim / 8;
        let zeros = vec![0u8; 3 * cb];
        let ones = vec![0xffu8; 3 * cb];
        let q_zero = vec![0u8; dim / 4];
        let q_ones = vec![0xfu8; dim / 4];
        for (q, keys, label) in [
            (&q_zero, &zeros, "zz"),
            (&q_zero, &ones, "zo"),
            (&q_ones, &zeros, "oz"),
            (&q_ones, &ones, "oo"),
        ] {
            assert_parity(q, keys, 3, dim, &format!("extreme {label} d{dim}"));
        }
    }
}

#[test]
fn parity_page_bound_dominates_block_scores() {
    // the hierarchical page bound (DESIGN.md §Perf iteration 9) is pure
    // integer arithmetic: under any RUSTFLAGS it must stay a sound upper
    // bound on every kernel's token scores, bit-for-bit
    let mut r = Rng::new(0xbead);
    for &dim in &[8usize, 40, 64, 104, 128] {
        for &tokens in &[1usize, 13, 64, 200] {
            let cb = dim / 8;
            let packed: Vec<u8> = (0..tokens * cb).map(|_| r.below(256) as u8).collect();
            let q_codes: Vec<u8> = (0..dim / 4).map(|_| r.below(16) as u8).collect();
            let words = pack::pack_signs_u64(&packed, tokens, cb);
            let q_packed = pack::pack_codes(&q_codes);
            let q_words = pack::pack_signs_u64(&q_packed, 1, cb);
            let wpt = pack::words_per_token(cb);
            let m = pack::majority_sketch(&words, wpt);
            let rad = pack::hamming_radius(&words, &m);
            let bound = page_bound(&q_words, &m, rad, dim);
            let mut scores = vec![f32::NAN; tokens];
            let best = score_block_popcnt(&q_words, &words, tokens, dim, &mut scores);
            let mut scores_s = vec![f32::NAN; tokens];
            let best_s = score_block_popcnt_scalar(&q_words, &words, tokens, dim, &mut scores_s);
            assert_eq!(best.to_bits(), best_s.to_bits(), "d{dim} n{tokens} kernel max");
            assert!(
                best <= bound,
                "d{dim} n{tokens}: best {best} beats page bound {bound} (r {rad})"
            );
            // a sketch self-query at radius zero is exactly +dim
            assert_eq!(
                page_bound(&m, &m, 0, dim).to_bits(),
                (dim as f32).to_bits(),
                "d{dim} self-query"
            );
        }
    }
}

#[test]
fn parity_paged_stream_select_is_bit_identical_to_flat() {
    // end-to-end through the public cache API: sketch-bounded page
    // skipping must return the SAME (index, score) selection as the flat
    // sweep under every RUSTFLAGS configuration the matrix pins
    const DIM: usize = 64;
    const BT: usize = 16;
    const TOKENS: usize = 900;
    let mut r = Rng::new(0xcafe);
    let keys: Vec<f32> = (0..TOKENS * DIM).map(|_| r.normal_f32()).collect();
    let vals: Vec<f32> = (0..TOKENS * DIM).map(|_| r.normal_f32()).collect();
    let build = |page_blocks: usize| {
        let cfg = SelfIndexConfig { page_blocks, ..Default::default() };
        let mgr = KvManager::for_head(DIM, &cfg, BT, 128);
        let mut hc = HeadCache::new(DIM, cfg);
        let prefill = 768 * DIM; // block-aligned prompt, decode tail after
        hc.ingest_prefill(&mgr, &keys[..prefill], &vals[..prefill], 0).unwrap();
        for t in 768..TOKENS {
            hc.append(mgr.pool(), &keys[t * DIM..(t + 1) * DIM], &vals[t * DIM..(t + 1) * DIM])
                .unwrap();
        }
        (mgr, hc)
    };
    let (mgr_f, flat) = build(0);
    let (mgr_p, paged) = build(4); // 64-token pages: 14 closed + open tail
    assert_eq!(flat.pages(), 0, "page_blocks 0 keeps the flat sweep");
    assert_eq!(paged.pages(), TOKENS / (4 * BT), "closed full pages");

    let sinks: [&[u32]; 3] = [&[], &[0, 5, 100, 899], &[0, 1, 2, 3]];
    let mut scores = Vec::new();
    let mut sel = TopKStream::new(0);
    let mut out_f = Vec::new();
    let mut out_p = Vec::new();
    for qi in 0..8u64 {
        let mut qr = Rng::new(0x9000 + qi);
        let q_codes: Vec<u8> = (0..DIM / 4).map(|_| qr.below(16) as u8).collect();
        let q_packed = pack::pack_codes(&q_codes);
        let q_words = pack::pack_signs_u64(&q_packed, 1, DIM / 8);
        let scorer = BlockScorer::Popcnt { q_words: &q_words, dim: DIM };
        for &k in &[0usize, 1, 17, 96] {
            for &end in &[TOKENS, 641, 64, 1] {
                for sink_ids in sinks {
                    flat.stream_select(
                        mgr_f.pool(),
                        &scorer,
                        end,
                        sink_ids,
                        k,
                        &mut scores,
                        &mut sel,
                        &mut out_f,
                    );
                    paged.stream_select(
                        mgr_p.pool(),
                        &scorer,
                        end,
                        sink_ids,
                        k,
                        &mut scores,
                        &mut sel,
                        &mut out_p,
                    );
                    assert_eq!(out_f, out_p, "q{qi} k{k} end{end} sinks{sink_ids:?}");
                }
            }
        }
    }
    let (scanned, skipped) = paged.page_stats();
    assert!(scanned > 0, "paged path must have engaged");
    assert!(skipped <= scanned);
}

#[test]
fn report_selected_kernel() {
    // not an assertion — makes the dispatched kernel visible in CI logs
    // (`cargo test -- --nocapture` in the parity matrix job) so a run
    // that silently fell back to scalar is diagnosable
    for wpt in [1usize, 2, 3] {
        println!("popcnt kernel (wpt={wpt}): {}", popcnt_kernel_name(wpt));
    }
}
