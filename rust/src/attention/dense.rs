//! Dense single-query attention over f32 K/V (online softmax, one pass).

/// out = softmax(K·q / √d) · V over `len` tokens.
/// `keys`/`vals`: (len × dim) row-major; `out`: dim.
pub fn attend_dense(
    query: &[f32],
    keys: &[f32],
    vals: &[f32],
    len: usize,
    out: &mut [f32],
) {
    let dim = query.len();
    assert!(keys.len() >= len * dim && vals.len() >= len * dim);
    assert_eq!(out.len(), dim);
    let scale = 1.0 / (dim as f32).sqrt();

    let mut m = f32::NEG_INFINITY; // running max
    let mut l = 0.0f32; // running denom
    out.fill(0.0);

    for t in 0..len {
        let k = &keys[t * dim..(t + 1) * dim];
        let s = crate::tensor::dot(query, k) * scale;
        let v = &vals[t * dim..(t + 1) * dim];
        if s <= m {
            let w = (s - m).exp();
            l += w;
            crate::tensor::axpy(w, v, out);
        } else {
            // rescale accumulated state to the new max
            let c = (m - s).exp();
            let c = if c.is_finite() { c } else { 0.0 };
            l = l * c + 1.0;
            for (o, &vi) in out.iter_mut().zip(v) {
                *o = *o * c + vi;
            }
            m = s;
        }
    }
    if l > 0.0 {
        let inv = 1.0 / l;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

/// Two-pass reference (max, then exp-sum) for tests.
pub fn attend_dense_twopass(
    query: &[f32],
    keys: &[f32],
    vals: &[f32],
    len: usize,
    out: &mut [f32],
) {
    let dim = query.len();
    let scale = 1.0 / (dim as f32).sqrt();
    let scores: Vec<f32> = (0..len)
        .map(|t| crate::tensor::dot(query, &keys[t * dim..(t + 1) * dim]) * scale)
        .collect();
    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let ws: Vec<f32> = scores.iter().map(|&s| (s - m).exp()).collect();
    let denom: f32 = ws.iter().sum();
    out.fill(0.0);
    for t in 0..len {
        crate::tensor::axpy(ws[t] / denom, &vals[t * dim..(t + 1) * dim], out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    #[test]
    fn online_matches_twopass() {
        let mut r = Rng::new(1);
        for &(len, dim) in &[(1usize, 8usize), (7, 16), (128, 64), (1000, 32)] {
            let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
            let k: Vec<f32> = (0..len * dim).map(|_| r.normal_f32()).collect();
            let v: Vec<f32> = (0..len * dim).map(|_| r.normal_f32()).collect();
            let mut a = vec![0.0; dim];
            let mut b = vec![0.0; dim];
            attend_dense(&q, &k, &v, len, &mut a);
            attend_dense_twopass(&q, &k, &v, len, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn attends_to_dominant_token() {
        let dim = 16;
        let mut r = Rng::new(2);
        let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let mut k = vec![0.0f32; 10 * dim];
        // token 3 = strongly aligned with q
        for j in 0..dim {
            k[3 * dim + j] = q[j] * 10.0;
        }
        let mut v: Vec<f32> = (0..10 * dim).map(|_| r.normal_f32()).collect();
        for j in 0..dim {
            v[3 * dim + j] = 7.0;
        }
        let mut out = vec![0.0; dim];
        attend_dense(&q, &k, &v, 10, &mut out);
        for &o in &out {
            assert!((o - 7.0).abs() < 0.5, "{o}");
        }
    }

    #[test]
    fn extreme_logits_stable() {
        let dim = 8;
        let q = vec![100.0f32; dim];
        let k = vec![100.0f32; 3 * dim];
        let v = vec![1.0f32; 3 * dim];
        let mut out = vec![0.0; dim];
        attend_dense(&q, &k, &v, 3, &mut out);
        assert!(out.iter().all(|o| (o - 1.0).abs() < 1e-5), "{out:?}");
    }

    #[test]
    fn zero_len_outputs_zero() {
        let q = vec![1.0f32; 4];
        let mut out = vec![9.0; 4];
        attend_dense(&q, &[], &[], 0, &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }
}
