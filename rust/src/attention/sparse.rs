//! Fused dequantization + sparse attention — the paper's decode kernel on
//! the native backend.
//!
//! Attends over [sink rows (fp16→f32) ++ selected compressed tokens ++
//! recent fp rows], dequantizing each selected token *inside* the softmax
//! loop (single pass over compressed memory — the design that beats
//! KIVI's decompress-then-compute in Fig. 5).

use crate::kvcache::pool::BlockPool;
use crate::kvcache::sink::SinkStore;
use crate::kvcache::store::HeadCache;

/// Streaming softmax accumulator (the FlashAttention recurrence).
pub struct OnlineSoftmax {
    pub m: f32,
    pub l: f32,
    pub acc: Vec<f32>,
}

impl OnlineSoftmax {
    pub fn new(dim: usize) -> Self {
        Self { m: f32::NEG_INFINITY, l: 0.0, acc: vec![0.0; dim] }
    }

    pub fn reset(&mut self) {
        self.m = f32::NEG_INFINITY;
        self.l = 0.0;
        self.acc.fill(0.0);
    }

    #[inline]
    pub fn push(&mut self, score: f32, value: &[f32]) {
        // -inf score = zero weight. Without this guard the first pushed
        // -inf hits `score - self.m` = `-inf - -inf` = NaN and poisons
        // `l` (and `acc` via axpy) for every later push.
        if score == f32::NEG_INFINITY {
            return;
        }
        if score <= self.m {
            let w = (score - self.m).exp();
            self.l += w;
            crate::tensor::axpy(w, value, &mut self.acc);
        } else {
            let c = (self.m - score).exp();
            let c = if c.is_finite() { c } else { 0.0 };
            self.l = self.l * c + 1.0;
            for (a, &v) in self.acc.iter_mut().zip(value) {
                *a = *a * c + v;
            }
            self.m = score;
        }
    }

    /// Fold a score whose value contribution is negligible (weight ~ 0)
    /// into the denominator only.
    #[inline]
    pub fn push_score_only(&mut self, score: f32) {
        // same NaN edge as `push`: exp(-inf - -inf) when nothing finite
        // has been pushed yet
        if score == f32::NEG_INFINITY {
            return;
        }
        if score <= self.m {
            self.l += (score - self.m).exp();
        } else {
            let c = (self.m - score).exp();
            let c = if c.is_finite() { c } else { 0.0 };
            self.l = self.l * c + 1.0;
            for a in self.acc.iter_mut() {
                *a *= c;
            }
            self.m = score;
        }
    }

    pub fn finish(&self, out: &mut [f32]) {
        if self.l > 0.0 {
            let inv = 1.0 / self.l;
            for (o, &a) in out.iter_mut().zip(&self.acc) {
                *o = a * inv;
            }
        } else {
            out.fill(0.0);
        }
    }
}

/// Scratch buffers reused across calls (zero allocation per decode step).
pub struct SparseAttnScratch {
    k_row: Vec<f32>,
    v_row: Vec<f32>,
    q_alpha: Vec<f32>,
    scores: Vec<f32>,
    softmax: OnlineSoftmax,
}

impl SparseAttnScratch {
    pub fn new(dim: usize) -> Self {
        Self {
            k_row: vec![0.0; dim],
            v_row: vec![0.0; dim],
            q_alpha: vec![0.0; dim],
            scores: vec![],
            softmax: OnlineSoftmax::new(dim),
        }
    }
}

/// Fused sparse attention for one (query, head).
///
/// * `query` — rotated query, dim = head_dim (NOT centered; Eq. 7 makes
///   centering the keys sufficient).
/// * `selected` — dynamic top-k token indices into `cache`.
/// * `sinks` — full-precision sink rows (already centered keys).
/// * `recent` — (len × 2 × dim) interleaved [k_row, v_row] fp32 recent
///   decode tokens that always attend (paper: decode tokens included by
///   default).
pub fn attend_sparse_fused(
    query: &[f32],
    cache: &HeadCache,
    pool: &BlockPool,
    selected: &[u32],
    sinks: &SinkStore,
    recent: &[f32],
    scratch: &mut SparseAttnScratch,
    out: &mut [f32],
) {
    let dim = query.len();
    let scale = 1.0 / (dim as f32).sqrt();
    scratch.softmax.reset();

    // sink tokens (fp16 rows)
    for i in 0..sinks.len() {
        sinks.row(i, &mut scratch.k_row, &mut scratch.v_row);
        let s = crate::tensor::dot(query, &scratch.k_row) * scale;
        scratch.softmax.push(s, &scratch.v_row);
    }

    // selected compressed tokens — two-pass fused path (2-bit sign-plane):
    //   pass 1: fused dequant+dot scores only (key rows never materialize)
    //   pass 2: dequantize V only for tokens whose softmax weight is
    //           non-negligible (exp(s - max) >= SKIP_EPS) — exact within
    //           fp tolerance, and most tokens of a peaked distribution skip.
    const SKIP_LOG_EPS: f32 = -18.0; // exp(-18) ≈ 1.5e-8
    if cache.cfg.quant_bits == 2 && cache.cfg.sign_plane_quant {
        let alpha = cache.alpha();
        for j in 0..dim {
            scratch.q_alpha[j] = query[j] * alpha[j];
        }
        scratch.scores.clear();
        let mut smax = scratch.softmax.m; // include sink max in the bar
        for &idx in selected {
            let s = cache.dequant_dot_k(pool, idx as usize, &scratch.q_alpha) * scale;
            smax = smax.max(s);
            scratch.scores.push(s);
        }
        for (i, &idx) in selected.iter().enumerate() {
            let s = scratch.scores[i];
            if s - smax >= SKIP_LOG_EPS {
                cache.dequant_v(pool, idx as usize, &mut scratch.v_row);
                scratch.softmax.push(s, &scratch.v_row);
            } else {
                // weight ≈ 0: still fold into the denominator for exactness
                scratch.softmax.push_score_only(s);
            }
        }
    } else {
        for &idx in selected {
            cache.dequant_token(
                pool, idx as usize, &mut scratch.k_row, &mut scratch.v_row,
            );
            let s = crate::tensor::dot(query, &scratch.k_row) * scale;
            scratch.softmax.push(s, &scratch.v_row);
        }
    }

    // recent fp rows
    assert_eq!(recent.len() % (2 * dim), 0);
    for pair in recent.chunks_exact(2 * dim) {
        let (k, v) = pair.split_at(dim);
        let s = crate::tensor::dot(query, k) * scale;
        scratch.softmax.push(s, v);
    }

    scratch.softmax.finish(out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::KvManager;
    use crate::selfindex::SelfIndexConfig;
    use crate::substrate::rng::Rng;

    fn setup(
        tokens: usize,
    ) -> (HeadCache, KvManager, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut r = Rng::new(7);
        let cfg = SelfIndexConfig::default();
        let mgr = KvManager::for_head(64, &cfg, 16, 128);
        let mut hc = HeadCache::new(64, cfg);
        let keys: Vec<f32> = (0..tokens * 64).map(|_| r.normal_f32()).collect();
        let vals: Vec<f32> = (0..tokens * 64).map(|_| r.normal_f32()).collect();
        hc.ingest_prefill(&mgr, &keys, &vals, 0).unwrap();
        let q: Vec<f32> = (0..64).map(|_| r.normal_f32()).collect();
        (hc, mgr, keys, vals, q)
    }

    #[test]
    fn neg_inf_first_score_does_not_poison_softmax() {
        let dim = 4;
        let mut sm = OnlineSoftmax::new(dim);
        // the NaN edge: first score is -inf while m is still -inf
        sm.push(f32::NEG_INFINITY, &[1.0; 4]);
        assert!(sm.l.is_finite(), "l poisoned: {}", sm.l);
        sm.push_score_only(f32::NEG_INFINITY);
        assert!(sm.l.is_finite());
        // a real score afterwards behaves as if the -inf never happened
        sm.push(2.0, &[3.0, 1.0, 0.0, -1.0]);
        let mut out = vec![0.0; dim];
        sm.finish(&mut out);
        assert_eq!(out, vec![3.0, 1.0, 0.0, -1.0]);
        // only -inf pushes → empty distribution → zeros
        let mut sm2 = OnlineSoftmax::new(dim);
        sm2.push(f32::NEG_INFINITY, &[5.0; 4]);
        sm2.finish(&mut out);
        assert_eq!(out, vec![0.0; dim]);
    }

    #[test]
    fn fused_matches_dequant_then_dense() {
        let (hc, mgr, _, _, q) = setup(64);
        let pool = mgr.pool();
        let sel: Vec<u32> = vec![3, 17, 40, 63, 9];
        // reference: materialize dequantized rows, run dense attention
        let dim = 64;
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        let mut kr = vec![0.0; dim];
        let mut vr = vec![0.0; dim];
        for &i in &sel {
            hc.dequant_token(pool, i as usize, &mut kr, &mut vr);
            ks.extend_from_slice(&kr);
            vs.extend_from_slice(&vr);
        }
        let mut expect = vec![0.0; dim];
        crate::attention::dense::attend_dense(&q, &ks, &vs, sel.len(), &mut expect);

        let sinks = SinkStore::default();
        let mut scratch = SparseAttnScratch::new(dim);
        let mut out = vec![0.0; dim];
        attend_sparse_fused(&q, &hc, pool, &sel, &sinks, &[], &mut scratch, &mut out);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn sinks_and_recent_participate() {
        let (hc, mgr, keys, vals, q) = setup(32);
        let pool = mgr.pool();
        let dim = 64;
        // centered keys for the sink store
        let mu = hc.mu().to_vec();
        let centered: Vec<f32> = keys
            .iter()
            .enumerate()
            .map(|(i, &v)| v - mu[i % dim])
            .collect();
        let sinks = SinkStore::build(dim, &[0, 5], &centered, &vals);
        let recent: Vec<f32> = (0..2 * dim).map(|i| (i % 7) as f32 * 0.1).collect();

        let mut scratch = SparseAttnScratch::new(dim);
        let mut with = vec![0.0; dim];
        attend_sparse_fused(&q, &hc, pool, &[10, 20], &sinks, &recent,
                            &mut scratch, &mut with);
        let mut without = vec![0.0; dim];
        attend_sparse_fused(&q, &hc, pool, &[10, 20], &SinkStore::default(),
                            &[], &mut scratch, &mut without);
        let diff: f32 = with.iter().zip(&without).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "sinks/recent must change the output");
    }

    #[test]
    fn empty_selection_with_sinks_only() {
        let (hc, mgr, keys, vals, q) = setup(16);
        let pool = mgr.pool();
        let dim = 64;
        let mu = hc.mu().to_vec();
        let centered: Vec<f32> = keys
            .iter()
            .enumerate()
            .map(|(i, &v)| v - mu[i % dim])
            .collect();
        let sinks = SinkStore::build(dim, &[1], &centered, &vals);
        let mut scratch = SparseAttnScratch::new(dim);
        let mut out = vec![0.0; dim];
        attend_sparse_fused(&q, &hc, pool, &[], &sinks, &[], &mut scratch, &mut out);
        // attention over a single token == that token's value (fp16 slop)
        for j in 0..dim {
            assert!((out[j] - vals[dim + j]).abs() < 2e-3);
        }
    }
}
