//! Rust-native attention kernels (the `ComputeBackend::Native` path).
//!
//! * [`dense`]  — single-query full-cache attention (the FlashAttention-2
//!   baseline role in every efficiency table), online-softmax, one pass.
//! * [`sparse`] — the paper's fused kernel, CPU edition: iterate the
//!   selected tokens' *compressed* records, dequantize each row into a
//!   register-resident scratch, and fold it into the online softmax —
//!   one pass over compressed memory, no decompressed KV materialization.
//! * [`gather`] — staging of gathered quantized fields for the PJRT path.
//!
//! Both backends are numerically cross-checked in `rust/tests/`.

pub mod dense;
pub mod gather;
pub mod sparse;

pub use dense::attend_dense;
pub use sparse::{attend_sparse_fused, OnlineSoftmax, SparseAttnScratch};
