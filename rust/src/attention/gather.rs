//! Gather staging for the PJRT sparse-attention executable.
//!
//! The `sparse_attn_b{B}` program takes statically-shaped inputs
//! (B × KVH × S slots); real selections can be shorter (short prompts),
//! so this module pads the gathered fields and produces the matching
//! `sel_mask`/`sink_mask` (-inf on padded slots) that the masked AOT
//! program consumes.

use crate::kvcache::pool::BlockPool;
use crate::kvcache::sink::SinkStore;
use crate::kvcache::store::{GatheredQuant, HeadCache};

pub const NEG_INF: f32 = f32::NEG_INFINITY;

/// Gathered + padded fields of one (seq, kv-head) for slot count `s_slots`.
#[derive(Clone, Debug, Default)]
pub struct PaddedGather {
    pub quant: GatheredQuant,
    pub sel_mask: Vec<f32>,
    pub k_sink: Vec<f32>,
    pub v_sink: Vec<f32>,
    pub sink_mask: Vec<f32>,
}

/// Pad `selected` to exactly `s_slots` entries. Padded slots replicate
/// token 0's record (any valid record works — the mask removes it).
pub fn gather_padded(
    cache: &HeadCache,
    pool: &BlockPool,
    selected: &[u32],
    s_slots: usize,
    sinks: &SinkStore,
    sink_slots: usize,
    out: &mut PaddedGather,
) {
    assert!(selected.len() <= s_slots);
    assert!(sinks.len() <= sink_slots);
    assert!(cache.len() > 0, "gather from empty cache");
    let dim = cache.dim;

    let mut idx: Vec<u32> = selected.to_vec();
    idx.resize(s_slots, 0); // replicate token 0 on padded slots
    cache.gather_quant(pool, &idx, &mut out.quant);

    out.sel_mask.clear();
    out.sel_mask.resize(s_slots, 0.0);
    for slot in selected.len()..s_slots {
        out.sel_mask[slot] = NEG_INF;
    }

    let (ks, vs) = sinks.rows_f32();
    out.k_sink.clear();
    out.k_sink.extend_from_slice(&ks);
    out.k_sink.resize(sink_slots * dim, 0.0);
    out.v_sink.clear();
    out.v_sink.extend_from_slice(&vs);
    out.v_sink.resize(sink_slots * dim, 0.0);
    out.sink_mask.clear();
    out.sink_mask.resize(sink_slots, 0.0);
    for slot in sinks.len()..sink_slots {
        out.sink_mask[slot] = NEG_INF;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::KvManager;
    use crate::selfindex::SelfIndexConfig;
    use crate::substrate::rng::Rng;

    #[test]
    fn pads_and_masks() {
        let mut r = Rng::new(1);
        let cfg = SelfIndexConfig::default();
        let mgr = KvManager::for_head(64, &cfg, 16, 32);
        let mut hc = HeadCache::new(64, cfg);
        let keys: Vec<f32> = (0..20 * 64).map(|_| r.normal_f32()).collect();
        let vals: Vec<f32> = (0..20 * 64).map(|_| r.normal_f32()).collect();
        hc.ingest_prefill(&mgr, &keys, &vals, 0).unwrap();
        let sinks = SinkStore::build(64, &[0, 3], &keys, &vals);

        let mut pg = PaddedGather::default();
        gather_padded(&hc, mgr.pool(), &[5, 7, 9], 8, &sinks, 4, &mut pg);
        assert_eq!(pg.quant.codes_i32.len(), 8 * 16);
        assert_eq!(pg.sel_mask[..3], [0.0, 0.0, 0.0]);
        assert!(pg.sel_mask[3..].iter().all(|&m| m == NEG_INF));
        assert_eq!(pg.k_sink.len(), 4 * 64);
        assert_eq!(pg.sink_mask[..2], [0.0, 0.0]);
        assert!(pg.sink_mask[2..].iter().all(|&m| m == NEG_INF));
    }
}
