//! The artifact manifest (written by aot.py): model config, selfindex
//! constants, parameter order, and per-artifact input/output specs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::ModelConfig;
use crate::substrate::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: ModelConfig,
    pub sink_tokens: usize,
    pub sparse_k: usize,
    pub param_order: Vec<String>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("read manifest: {e}"))?;
        let j = Json::parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<Self, String> {
        let model = ModelConfig::from_json(
            j.get("model").ok_or("manifest: no model")?,
        )?;
        let si = j.get("selfindex").ok_or("manifest: no selfindex")?;
        let sink_tokens = si
            .get("sink_tokens")
            .and_then(Json::as_usize)
            .ok_or("selfindex.sink_tokens")?;
        let sparse_k = si
            .get("sparse_k")
            .and_then(Json::as_usize)
            .ok_or("selfindex.sparse_k")?;

        let param_order = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or("manifest: params")?
            .iter()
            .map(|p| {
                p.get("name")
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| "param name".to_string())
            })
            .collect::<Result<Vec<_>, _>>()?;

        let mut artifacts = BTreeMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or("manifest: artifacts")?
        {
            let parse_io = |key: &str| -> Result<Vec<IoSpec>, String> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| format!("{name}.{key}"))?
                    .iter()
                    .map(|io| {
                        Ok(IoSpec {
                            name: io
                                .get("name")
                                .and_then(Json::as_str)
                                .ok_or("io name")?
                                .to_string(),
                            dtype: io
                                .get("dtype")
                                .and_then(Json::as_str)
                                .ok_or("io dtype")?
                                .to_string(),
                            shape: io
                                .get("shape")
                                .and_then(Json::usize_list)
                                .ok_or("io shape")?,
                        })
                    })
                    .collect()
            };
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{name}.file"))?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs: parse_io("inputs")?,
                    outputs: parse_io("outputs")?,
                },
            );
        }
        Ok(Self {
            model,
            sink_tokens,
            sparse_k,
            param_order,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec, String> {
        self.artifacts
            .get(name)
            .ok_or_else(|| format!("artifact '{name}' not in manifest"))
    }

    /// Largest prefill bucket ≥ len, e.g. `prefill_l1024` for len 700.
    pub fn prefill_bucket(&self, len: usize) -> Option<&ArtifactSpec> {
        let mut best: Option<(usize, &ArtifactSpec)> = None;
        for (name, spec) in &self.artifacts {
            if let Some(l) = name.strip_prefix("prefill_l").and_then(|s| s.parse().ok())
            {
                let l: usize = l;
                if l >= len && best.map(|(b, _)| l < b).unwrap_or(true) {
                    best = Some((l, spec));
                }
            }
        }
        best.map(|(_, s)| s)
    }

    /// Smallest decode batch bucket ≥ b for a given prefix
    /// (e.g. "decode_qkv_b").
    pub fn batch_bucket(&self, prefix: &str, b: usize) -> Option<&ArtifactSpec> {
        let mut best: Option<(usize, &ArtifactSpec)> = None;
        for (name, spec) in &self.artifacts {
            if let Some(n) = name.strip_prefix(prefix).and_then(|s| s.parse().ok()) {
                let n: usize = n;
                if n >= b && best.map(|(x, _)| n < x).unwrap_or(true) {
                    best = Some((n, spec));
                }
            }
        }
        best.map(|(_, s)| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Json {
        Json::parse(
            r#"{
          "model": {"vocab_size":256,"d_model":256,"n_layers":4,"n_heads":4,
                    "n_kv_heads":2,"head_dim":64,"d_ff":512,"max_seq":8192,
                    "rope_theta":10000.0},
          "selfindex": {"vq_group":4,"vq_clusters":16,"quant_bits":2,
                        "quant_group":32,"sink_tokens":64,"sparse_k":96},
          "params": [{"name":"emb","shape":[256,256]},
                     {"name":"l0.ln1","shape":[256]}],
          "artifacts": {
            "prefill_l256": {"file":"prefill_l256.hlo.txt",
              "inputs":[{"name":"tokens","dtype":"int32","shape":[1,256]}],
              "outputs":[{"name":"k_cache","dtype":"float32","shape":[4,256,2,64]}]},
            "prefill_l1024": {"file":"prefill_l1024.hlo.txt",
              "inputs":[],"outputs":[]},
            "decode_qkv_b1": {"file":"decode_qkv_b1.hlo.txt",
              "inputs":[],"outputs":[]},
            "decode_qkv_b4": {"file":"decode_qkv_b4.hlo.txt",
              "inputs":[],"outputs":[]}
          }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_fixture() {
        let m = Manifest::from_json(&fixture(), Path::new("/tmp/a")).unwrap();
        assert_eq!(m.model.n_layers, 4);
        assert_eq!(m.sparse_k, 96);
        assert_eq!(m.param_order[0], "emb");
        let a = m.artifact("prefill_l256").unwrap();
        assert_eq!(a.inputs[0].shape, vec![1, 256]);
        assert_eq!(a.outputs[0].elems(), 4 * 256 * 2 * 64);
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::from_json(&fixture(), Path::new("/tmp/a")).unwrap();
        assert_eq!(m.prefill_bucket(100).unwrap().name, "prefill_l256");
        assert_eq!(m.prefill_bucket(256).unwrap().name, "prefill_l256");
        assert_eq!(m.prefill_bucket(257).unwrap().name, "prefill_l1024");
        assert!(m.prefill_bucket(5000).is_none());
        assert_eq!(
            m.batch_bucket("decode_qkv_b", 2).unwrap().name,
            "decode_qkv_b4"
        );
        assert_eq!(
            m.batch_bucket("decode_qkv_b", 1).unwrap().name,
            "decode_qkv_b1"
        );
    }
}
