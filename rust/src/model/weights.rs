//! weights.bin loader.
//!
//! Format (little-endian; writer: python/compile/train.py::save_weights):
//! ```text
//! magic  u32 = 0x53494B56 ("SIKV")
//! version u32 = 1
//! count  u32
//! repeat count times:
//!   name_len u32 | name bytes | dtype u8 (0 = f32) | ndim u8 |
//!   dims u32 × ndim | data f32-LE × prod(dims)
//! ```

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

pub const MAGIC: u32 = 0x53494B56;

#[derive(Debug)]
pub enum WeightsError {
    Io(std::io::Error),
    BadHeader(u32, u32),
    Malformed(String),
}

impl From<std::io::Error> for WeightsError {
    fn from(e: std::io::Error) -> Self {
        WeightsError::Io(e)
    }
}

impl std::fmt::Display for WeightsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WeightsError::Io(e) => write!(f, "io: {e}"),
            WeightsError::BadHeader(m, v) => write!(f, "bad magic/version: {m:#x} v{v}"),
            WeightsError::Malformed(m) => write!(f, "malformed tensor entry: {m}"),
        }
    }
}

impl std::error::Error for WeightsError {}

/// Named f32 tensors in insertion order.
pub struct WeightStore {
    tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    order: Vec<String>,
}

impl WeightStore {
    pub fn load(path: &Path) -> Result<Self, WeightsError> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut hdr = [0u8; 12];
        f.read_exact(&mut hdr)?;
        let magic = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
        let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        let count = u32::from_le_bytes(hdr[8..12].try_into().unwrap());
        if magic != MAGIC || version != 1 {
            return Err(WeightsError::BadHeader(magic, version));
        }
        let mut tensors = BTreeMap::new();
        let mut order = Vec::new();
        for _ in 0..count {
            let mut len4 = [0u8; 4];
            f.read_exact(&mut len4)?;
            let nlen = u32::from_le_bytes(len4) as usize;
            if nlen > 4096 {
                return Err(WeightsError::Malformed(format!("name len {nlen}")));
            }
            let mut name = vec![0u8; nlen];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name)
                .map_err(|e| WeightsError::Malformed(e.to_string()))?;
            let mut meta = [0u8; 2];
            f.read_exact(&mut meta)?;
            let (dtype, ndim) = (meta[0], meta[1] as usize);
            if dtype != 0 {
                return Err(WeightsError::Malformed(format!(
                    "{name}: unsupported dtype {dtype}"
                )));
            }
            let mut dims = vec![0usize; ndim];
            for d in dims.iter_mut() {
                let mut b = [0u8; 4];
                f.read_exact(&mut b)?;
                *d = u32::from_le_bytes(b) as usize;
            }
            let n: usize = dims.iter().product();
            if n > (1 << 28) {
                return Err(WeightsError::Malformed(format!("{name}: {n} elems")));
            }
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            order.push(name.clone());
            tensors.insert(name, (dims, data));
        }
        Ok(Self { tensors, order })
    }

    pub fn get(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.tensors
            .get(name)
            .map(|(s, d)| (s.as_slice(), d.as_slice()))
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|(_, d)| d.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_fixture(path: &Path, tensors: &[(&str, Vec<usize>, Vec<f32>)]) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(&MAGIC.to_le_bytes()).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&(tensors.len() as u32).to_le_bytes()).unwrap();
        for (name, dims, data) in tensors {
            f.write_all(&(name.len() as u32).to_le_bytes()).unwrap();
            f.write_all(name.as_bytes()).unwrap();
            f.write_all(&[0u8, dims.len() as u8]).unwrap();
            for &d in dims {
                f.write_all(&(d as u32).to_le_bytes()).unwrap();
            }
            for &x in data {
                f.write_all(&x.to_le_bytes()).unwrap();
            }
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sikv_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_fixture(
            &p,
            &[
                ("emb", vec![4, 2], (0..8).map(|x| x as f32).collect()),
                ("l0.wq", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            ],
        );
        let w = WeightStore::load(&p).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w.names(), &["emb".to_string(), "l0.wq".to_string()]);
        let (shape, data) = w.get("emb").unwrap();
        assert_eq!(shape, &[4, 2]);
        assert_eq!(data[7], 7.0);
        assert_eq!(w.total_params(), 12);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("sikv_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [0u8; 32]).unwrap();
        assert!(matches!(
            WeightStore::load(&p),
            Err(WeightsError::BadHeader(..))
        ));
    }
}
