//! Model metadata + weights I/O: the manifest written by
//! `python/compile/aot.py` and the `weights.bin` tensor container
//! (contract: python/compile/train.py::save_weights).

pub mod manifest;
pub mod weights;

pub use manifest::{ArtifactSpec, IoSpec, Manifest};
#[allow(unused_imports)]
pub use weights::WeightsError;
pub use weights::WeightStore;
