//! Minimal JSON: a recursive-descent parser and a serializer.
//!
//! Used for the config system and the artifact manifest written by
//! `python/compile/aot.py`. Supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bools, null); numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `a.b.c` path lookup.
    pub fn path(&self, path: &str) -> Option<&Json> {
        path.split('.').try_fold(self, |v, k| v.get(k))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_list(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len()
                        && (self.b[self.pos] & 0xC0) == 0x80
                    {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders for hand-assembled documents.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": {"d": "x\ny"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"m": {"n": 3}, "l": [1, 2, 3]}"#).unwrap();
        assert_eq!(v.path("m.n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("l").unwrap().usize_list().unwrap(), vec![1, 2, 3]);
        assert!(v.path("m.missing").is_none());
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""tab\t newline\n quote\" unicodeA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "tab\t newline\n quote\" unicodeA");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"abc", "{} x"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn parses_negative_and_exponent() {
        assert_eq!(Json::parse("-0.25").unwrap().as_f64(), Some(-0.25));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
    }
}
