//! Minimal error type (offline substitute for `anyhow`).
//!
//! The build environment resolves no external crates, so the crate carries
//! its own catch-all error: a message string with `From` conversions for
//! every `std::error::Error`. Files that used `anyhow` alias this module
//! (`use crate::substrate::error as anyhow;`) — call sites are unchanged.

use std::fmt;

/// Catch-all error: an owned message, convertible from any std error,
/// optionally tagged with a static machine-readable code so callers can
/// branch on failure class without string-matching the message.
pub struct Error {
    msg: String,
    code: Option<&'static str>,
}

impl Error {
    /// Build from anything displayable (the `anyhow::Error::msg` shape).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string(), code: None }
    }

    /// Build with a machine-readable code (e.g. `"state_drift"`).
    pub fn coded<M: fmt::Display>(code: &'static str, m: M) -> Self {
        Self { msg: m.to_string(), code: Some(code) }
    }

    pub fn message(&self) -> &str {
        &self.msg
    }

    /// Machine-readable failure class, if the construction site set one.
    pub fn code(&self) -> Option<&'static str> {
        self.code
    }

    /// Prefix with context, keeping the original message and code.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Self { msg: format!("{c}: {}", self.msg), code: self.code }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// keeps this blanket conversion coherent (no overlap with `From<T> for T`).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error { msg: s, code: None }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error { msg: s.to_string(), code: None }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!`-shaped constructor macro (re-exported below as `anyhow`).
#[macro_export]
macro_rules! sikv_anyhow {
    ($($t:tt)*) => {
        $crate::substrate::error::Error::msg(format!($($t)*))
    };
}

/// `bail!`-shaped early return (re-exported below as `bail`).
#[macro_export]
macro_rules! sikv_bail {
    ($($t:tt)*) => {
        return Err($crate::substrate::error::Error::msg(format!($($t)*)).into())
    };
}

pub use crate::sikv_anyhow as anyhow;
pub use crate::sikv_bail as bail;

#[cfg(test)]
mod tests {
    use crate::substrate::error as anyhow;

    fn fails() -> anyhow::Result<()> {
        anyhow::bail!("broke at {}", 42)
    }

    fn io_propagates() -> anyhow::Result<Vec<u8>> {
        let data = std::fs::read("/definitely/not/a/real/path/sikv")?;
        Ok(data)
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow::anyhow!("bad {}", "state");
        assert_eq!(e.to_string(), "bad state");
        assert_eq!(format!("{e:?}"), "bad state");
        assert_eq!(fails().unwrap_err().message(), "broke at 42");
    }

    #[test]
    fn std_errors_convert() {
        let e = io_propagates().unwrap_err();
        assert!(!e.message().is_empty());
        let e2: super::Error = "plain".into();
        assert_eq!(e2.context("ctx").message(), "ctx: plain");
    }

    #[test]
    fn coded_errors_carry_class_through_context() {
        let e = super::Error::coded("state_drift", "scheduler saw ghost seq 7");
        assert_eq!(e.code(), Some("state_drift"));
        let e = e.context("step 12");
        assert_eq!(e.code(), Some("state_drift"), "context keeps the code");
        assert_eq!(e.message(), "step 12: scheduler saw ghost seq 7");
        assert_eq!(anyhow::anyhow!("plain").code(), None);
    }
}
