//! Deterministic PRNGs: SplitMix64 (seeding) and Xoshiro256** (workhorse),
//! plus the distribution helpers the workloads and tests need.
//!
//! Algorithms follow Blackman & Vigna's reference implementations; all
//! streams are fully reproducible from a `u64` seed, which the benches rely
//! on to regenerate identical workloads across runs.

/// SplitMix64 — used to expand a seed into Xoshiro state (and fine as a
/// standalone generator for non-critical uses).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller normal
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n), k <= n.
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher-Yates over an index map (sparse for small k)
        let mut map = std::collections::HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            let vj = *map.get(&j).unwrap_or(&j);
            let vi = *map.get(&i).unwrap_or(&i);
            map.insert(j, vi);
            out.push(vj);
        }
        out
    }

    /// Zipf-ish rank sampler over [0, n) with exponent ~1 (workload skew).
    pub fn zipf(&mut self, n: usize) -> usize {
        let u = self.f64();
        let h = (n as f64).ln();
        ((u * h).exp() - 1.0).min(n as f64 - 1.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.below(17);
            assert!(x < 17);
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng::new(9);
        let mean: f64 = (0..50_000).map(|_| r.f64()).sum::<f64>() / 50_000.0;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            let k = r.below(50) as usize;
            let v = r.choose_distinct(64, k);
            let set: std::collections::HashSet<_> = v.iter().collect();
            assert_eq!(set.len(), v.len());
            assert!(v.iter().all(|&i| i < 64));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
