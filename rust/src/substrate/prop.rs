//! Mini property-based testing framework (offline substitute for proptest).
//!
//! `Gen`-style generators over a seeded [`rng::Rng`](super::rng::Rng), a
//! runner that executes N cases, and greedy input shrinking on failure
//! (halving vectors / bisecting scalars). Used across the crate for the
//! invariants DESIGN.md §7 lists (pack round-trips, allocator conservation,
//! batcher budgets, top-k correctness...).

use super::rng::Rng;

/// A generator of values of type `T` from a PRNG.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Rng) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new<F: Fn(&mut Rng) -> T + 'static>(f: F) -> Self {
        Self { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.f)(rng)
    }

    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |r| g(self.sample(r)))
    }
}

pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(hi >= lo);
    Gen::new(move |r| lo + r.below((hi - lo + 1) as u64) as usize)
}

pub fn f32_in(lo: f32, hi: f32) -> Gen<f32> {
    Gen::new(move |r| r.uniform(lo, hi))
}

pub fn f32_normal(scale: f32) -> Gen<f32> {
    Gen::new(move |r| r.normal_f32() * scale)
}

pub fn vec_of<T: 'static>(elem: Gen<T>, len: Gen<usize>) -> Gen<Vec<T>> {
    Gen::new(move |r| {
        let n = len.sample(r);
        (0..n).map(|_| elem.sample(r)).collect()
    })
}

pub fn pairs<A: 'static, B: 'static>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
    Gen::new(move |r| (a.sample(r), b.sample(r)))
}

/// Outcome of a property check over one input.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` random inputs; on failure, attempt shrinking via
/// the caller-provided `shrink` (return smaller candidates to retry) and
/// panic with the minimal failing input's debug string.
pub fn check_with_shrink<T, G, P, S>(
    seed: u64,
    cases: usize,
    gen: G,
    shrink: S,
    prop: P,
) where
    T: Clone + std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // greedy shrink (bounded: a candidate identical to the current
            // input must not loop forever)
            let mut best = input;
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 1000 {
                rounds += 1;
                improved = false;
                for cand in shrink(&best) {
                    if format!("{cand:?}") == format!("{best:?}") {
                        continue; // no progress — skip
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {seed}): {best_msg}\n\
                 minimal input: {best:?}"
            );
        }
    }
}

/// Run without shrinking.
pub fn check<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    check_with_shrink(seed, cases, gen, |_| vec![], prop);
}

/// Standard shrinker for Vec<T>: drop halves, then single elements.
/// Every candidate is strictly shorter than the input (termination).
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    if n >= 2 {
        out.push(v[..n / 2].to_vec());
        out.push(v[n / 2..].to_vec());
    }
    if n <= 16 {
        for i in 0..n {
            let mut w = v.to_vec();
            w.remove(i);
            out.push(w);
        }
    }
    out
}

/// Assert helper producing PropResult.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, |r| r.below(1000) as i64, |&x| {
            if x >= 0 {
                Ok(())
            } else {
                Err("negative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(2, 200, |r| r.below(1000), |&x| {
            if x < 900 {
                Ok(())
            } else {
                Err(format!("{x} too big"))
            }
        });
    }

    #[test]
    fn shrinking_finds_small_input() {
        // property: no vector contains a 7. Shrinker should reduce the
        // failing vector to a single-element [7]-ish case.
        let result = std::panic::catch_unwind(|| {
            check_with_shrink(
                3,
                500,
                |r| {
                    (0..(r.below(20) + 1))
                        .map(|_| r.below(10) as u8)
                        .collect::<Vec<u8>>()
                },
                |v| shrink_vec(v),
                |v| {
                    if v.contains(&7) {
                        Err("contains 7".into())
                    } else {
                        Ok(())
                    }
                },
            )
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("[7]"), "shrunk output should be [7]: {msg}");
    }

    #[test]
    fn generators_compose() {
        let mut rng = Rng::new(4);
        let g = vec_of(usize_in(0, 9), usize_in(1, 5));
        for _ in 0..50 {
            let v = g.sample(&mut rng);
            assert!((1..=5).contains(&v.len()));
            assert!(v.iter().all(|&x| x < 10));
        }
    }
}
