//! Mini property-based testing framework (offline substitute for proptest).
//!
//! `Gen`-style generators over a seeded [`rng::Rng`](super::rng::Rng), a
//! runner that executes N cases, and greedy input shrinking on failure
//! (halving vectors / bisecting scalars). Used across the crate for the
//! invariants DESIGN.md §7 lists (pack round-trips, allocator conservation,
//! batcher budgets, top-k correctness...).

use super::rng::Rng;

/// A generator of values of type `T` from a PRNG.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Rng) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new<F: Fn(&mut Rng) -> T + 'static>(f: F) -> Self {
        Self { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.f)(rng)
    }

    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |r| g(self.sample(r)))
    }
}

pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(hi >= lo);
    Gen::new(move |r| lo + r.below((hi - lo + 1) as u64) as usize)
}

pub fn f32_in(lo: f32, hi: f32) -> Gen<f32> {
    Gen::new(move |r| r.uniform(lo, hi))
}

pub fn f32_normal(scale: f32) -> Gen<f32> {
    Gen::new(move |r| r.normal_f32() * scale)
}

pub fn vec_of<T: 'static>(elem: Gen<T>, len: Gen<usize>) -> Gen<Vec<T>> {
    Gen::new(move |r| {
        let n = len.sample(r);
        (0..n).map(|_| elem.sample(r)).collect()
    })
}

pub fn pairs<A: 'static, B: 'static>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
    Gen::new(move |r| (a.sample(r), b.sample(r)))
}

/// Outcome of a property check over one input.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` random inputs; on failure, attempt shrinking via
/// the caller-provided `shrink` (return smaller candidates to retry) and
/// panic with the minimal failing input's debug string.
pub fn check_with_shrink<T, G, P, S>(
    seed: u64,
    cases: usize,
    gen: G,
    shrink: S,
    prop: P,
) where
    T: Clone + std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // greedy shrink (bounded: a candidate identical to the current
            // input must not loop forever)
            let mut best = input;
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 1000 {
                rounds += 1;
                improved = false;
                for cand in shrink(&best) {
                    if format!("{cand:?}") == format!("{best:?}") {
                        continue; // no progress — skip
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {seed}): {best_msg}\n\
                 minimal input: {best:?}"
            );
        }
    }
}

/// Run without shrinking.
pub fn check<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    check_with_shrink(seed, cases, gen, |_| vec![], prop);
}

/// Standard shrinker for Vec<T>: drop halves, then single elements.
/// Every candidate is strictly shorter than the input (termination).
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    if n >= 2 {
        out.push(v[..n / 2].to_vec());
        out.push(v[n / 2..].to_vec());
    }
    if n <= 16 {
        for i in 0..n {
            let mut w = v.to_vec();
            w.remove(i);
            out.push(w);
        }
    }
    out
}

/// Assert helper producing PropResult.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, |r| r.below(1000) as i64, |&x| {
            if x >= 0 {
                Ok(())
            } else {
                Err("negative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(2, 200, |r| r.below(1000), |&x| {
            if x < 900 {
                Ok(())
            } else {
                Err(format!("{x} too big"))
            }
        });
    }

    #[test]
    fn shrinking_finds_small_input() {
        // property: no vector contains a 7. Shrinker should reduce the
        // failing vector to a single-element [7]-ish case.
        let result = std::panic::catch_unwind(|| {
            check_with_shrink(
                3,
                500,
                |r| {
                    (0..(r.below(20) + 1))
                        .map(|_| r.below(10) as u8)
                        .collect::<Vec<u8>>()
                },
                |v| shrink_vec(v),
                |v| {
                    if v.contains(&7) {
                        Err("contains 7".into())
                    } else {
                        Ok(())
                    }
                },
            )
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("[7]"), "shrunk output should be [7]: {msg}");
    }

    #[test]
    fn generators_compose() {
        let mut rng = Rng::new(4);
        let g = vec_of(usize_in(0, 9), usize_in(1, 5));
        for _ in 0..50 {
            let v = g.sample(&mut rng);
            assert!((1..=5).contains(&v.len()));
            assert!(v.iter().all(|&x| x < 10));
        }
    }

    /// Swap-out → (recompress → rehydrate) → swap-in round-trip over a
    /// random block set: per-block checksums are stable across the host
    /// round-trip (cold recompression included), host byte accounting is
    /// exact (a chilled block saves precisely its `codes_w` mirror), and
    /// a non-multiple-of-block tail (`used < block_tokens`) survives.
    #[test]
    fn prop_tier_roundtrip_checksum_bytes_and_tail() {
        use crate::kvcache::tier::{HostTier, SwapIn};
        use crate::kvcache::{BlockId, BlockPool, RecordLayout};
        use crate::quant::pack;
        use crate::selfindex::SelfIndexConfig;
        const BT: usize = 16;
        // deterministic payload upholding the `codes_w == pack(codes)`
        // lockstep invariant `push_record` maintains on real blocks
        fn fill(p: &BlockPool, id: BlockId, salt: u8, used: usize) {
            let cb = p.layout.codes_bytes;
            // SAFETY: test-owned block, refcount 1.
            let b = unsafe { p.block_mut(id) };
            for (i, x) in b.codes.iter_mut().enumerate() {
                *x = (i as u8).wrapping_mul(29).wrapping_add(salt);
            }
            let w = pack::pack_signs_u64(&b.codes, BT, cb);
            b.codes_w.copy_from_slice(&w);
            for (i, x) in b.k_mag.iter_mut().enumerate() {
                *x = (i as u8).wrapping_add(salt).wrapping_mul(11);
            }
            for (i, x) in b.v_val.iter_mut().enumerate() {
                *x = (i as u8).wrapping_mul(17) ^ salt;
            }
            for (i, q) in b.k_prm.iter_mut().enumerate() {
                q.scale = i as u16 ^ (salt as u16) << 3;
                q.zero = 5 * i as u16;
            }
            b.used = used;
        }
        check(
            13,
            60,
            |r| {
                let n = 1 + r.below(4) as usize;
                let tail = 1 + r.below(BT as u64) as usize;
                let salts: Vec<u8> = (0..n).map(|_| r.below(256) as u8).collect();
                let chill = r.below(2) == 1;
                (n, tail, salts, chill)
            },
            |(n, tail, salts, chill)| {
                let layout = RecordLayout::new(64, &SelfIndexConfig::default());
                let pool = BlockPool::new(layout, BT, *n);
                let tier = HostTier::new();
                let ids: Vec<BlockId> = (0..*n).map(|_| pool.alloc().unwrap()).collect();
                for (i, &id) in ids.iter().enumerate() {
                    let used = if i + 1 == *n { *tail } else { BT };
                    fill(&pool, id, salts[i], used);
                }
                let sums: Vec<u64> = ids.iter().map(|&id| pool.get(id).checksum()).collect();
                let warm: usize = ids.iter().map(|&id| pool.get(id).bytes()).sum();
                let mirror: usize =
                    ids.iter().map(|&id| pool.get(id).codes_w.len() * 8).sum();
                if tier.swap_out(1, &pool, &ids).is_err() {
                    return Err("swap-out faulted with no injector armed".into());
                }
                for &id in &ids {
                    pool.release(id);
                }
                prop_assert!(pool.free_blocks() == *n, "device side fully released");
                prop_assert!(
                    tier.bytes() == warm,
                    "warm host bytes {} != device accounting {warm}",
                    tier.bytes()
                );
                prop_assert!(tier.cold_bytes() == 0, "nothing cold before the sweep");
                if *chill {
                    let chilled = tier.sweep(1);
                    prop_assert!(chilled == *n, "every block chills: {chilled} != {n}");
                    prop_assert!(
                        tier.bytes() == warm - mirror,
                        "recompression must save exactly the codes_w mirror: \
                         {} != {warm} - {mirror}",
                        tier.bytes()
                    );
                    prop_assert!(
                        tier.cold_bytes() == tier.bytes(),
                        "all-cold entry: cold bytes track total bytes"
                    );
                }
                let SwapIn::Restored(back) = tier.swap_in(1, &pool) else {
                    return Err("clean swap-in must restore".into());
                };
                for (i, (&id, &sum)) in back.iter().zip(&sums).enumerate() {
                    prop_assert!(
                        pool.get(id).checksum() == sum,
                        "block {i} checksum drifted across the round-trip \
                         (chill={chill})"
                    );
                }
                prop_assert!(
                    pool.get(back[*n - 1]).used == *tail,
                    "tail occupancy must survive: {} != {tail}",
                    pool.get(back[*n - 1]).used
                );
                for id in back {
                    pool.release(id);
                }
                prop_assert!(
                    tier.entries() == 0 && tier.bytes() == 0,
                    "consumed entry must free its host bytes"
                );
                Ok(())
            },
        );
    }

    /// Random (dim, tokens) sign-code workload: raw key rows, their nibble
    /// codes, and a query's codes — the shared generator for the
    /// pack→score round-trip properties below.
    fn sign_workload(r: &mut Rng) -> (usize, usize, Vec<u8>, Vec<u8>) {
        // dims cover sub-word (non-multiple-of-64-bit) tails: 8..=136
        let dim = 8 * (1 + r.below(17) as usize);
        let tokens = r.below(70) as usize;
        let key_codes: Vec<u8> =
            (0..tokens * dim / 4).map(|_| r.below(16) as u8).collect();
        let q_codes: Vec<u8> = (0..dim / 4).map(|_| r.below(16) as u8).collect();
        (dim, tokens, key_codes, q_codes)
    }

    #[test]
    fn prop_sign_word_packing_roundtrips_and_pads_tail() {
        use crate::quant::pack;
        check(11, 300, sign_workload, |(dim, tokens, key_codes, _)| {
            let cb = dim / 8;
            let packed = pack::pack_codes(key_codes);
            let words = pack::pack_signs_u64(&packed, *tokens, cb);
            let wpt = pack::words_per_token(cb);
            prop_assert!(words.len() == tokens * wpt, "len {}", words.len());
            for t in 0..*tokens {
                let row = &packed[t * cb..(t + 1) * cb];
                for (w, &word) in words[t * wpt..(t + 1) * wpt].iter().enumerate() {
                    let bytes = word.to_le_bytes();
                    for (i, &b) in bytes.iter().enumerate() {
                        let want = row.get(w * 8 + i).copied().unwrap_or(0);
                        prop_assert!(
                            b == want,
                            "token {t} word {w} byte {i}: {b} != {want} \
                             (tail bytes must be zero-padded)"
                        );
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_popcount_score_equals_naive_and_sign_lut() {
        use crate::quant::pack;
        use crate::selfindex::lut::Lut;
        use crate::selfindex::score::{
            score_block_popcnt, score_block_popcnt_scalar, score_tokens, ByteLut,
        };
        check(12, 200, sign_workload, |(dim, tokens, key_codes, q_codes)| {
            let cb = dim / 8;
            let packed = pack::pack_codes(key_codes);
            let words = pack::pack_signs_u64(&packed, *tokens, cb);
            let q_packed = pack::pack_codes(q_codes);
            let q_words = pack::pack_signs_u64(&q_packed, 1, cb);
            // naive oracle: per-nibble sign agreement, summed in i32
            let naive: Vec<f32> = (0..*tokens)
                .map(|t| {
                    let mut acc = 0i32;
                    for g in 0..dim / 4 {
                        let kc = key_codes[t * (dim / 4) + g];
                        acc += 4 - 2 * (q_codes[g] ^ kc).count_ones() as i32;
                    }
                    acc as f32
                })
                .collect();
            let mut pop = vec![0.0f32; *tokens];
            let mut sc = vec![0.0f32; *tokens];
            let bmax = score_block_popcnt(&q_words, &words, *tokens, *dim, &mut pop);
            let smax =
                score_block_popcnt_scalar(&q_words, &words, *tokens, *dim, &mut sc);
            let lut = Lut::sign_agreement(q_codes);
            let blut = ByteLut::from_lut(&lut);
            let mut via_lut = Vec::new();
            score_tokens(&lut, &packed, *tokens, &mut via_lut);
            let mut via_blut = Vec::new();
            crate::selfindex::score::score_tokens_bytelut(
                &blut, &packed, *tokens, &mut via_blut,
            );
            prop_assert!(bmax.to_bits() == smax.to_bits(), "{bmax} vs {smax}");
            for t in 0..*tokens {
                for (name, got) in [
                    ("popcnt", pop[t]),
                    ("popcnt_scalar", sc[t]),
                    ("sign_lut", via_lut[t]),
                    ("sign_bytelut", via_blut[t]),
                ] {
                    prop_assert!(
                        got.to_bits() == naive[t].to_bits(),
                        "token {t} {name}: {got} != naive {}",
                        naive[t]
                    );
                }
                prop_assert!(
                    (-(*dim as f32)..=*dim as f32).contains(&pop[t]),
                    "token {t} out of [-dim, dim]: {}",
                    pop[t]
                );
            }
            Ok(())
        });
    }

    /// The page sketch bound of DESIGN.md §Perf iteration 9 must dominate
    /// every token score it covers: `dim - 2*(popcount(q^m) - r)` is an
    /// upper bound on `dim - 2*popcount(q^t)` for every row `t` inside the
    /// sketched set. Exercised across sub-word tail dims (8..=136) and on
    /// row subsets, which model the end-clamped partial page a truncated
    /// `stream_select` descends: the radius then covers a superset of the
    /// scored rows, so the bound only loosens and stays sound.
    #[test]
    fn prop_page_bound_is_sound_for_full_and_partial_pages() {
        use crate::quant::pack;
        use crate::selfindex::score::{page_bound, score_block_popcnt};
        check(13, 300, sign_workload, |(dim, tokens, key_codes, q_codes)| {
            if *tokens == 0 {
                return Ok(());
            }
            let cb = dim / 8;
            let packed = pack::pack_codes(key_codes);
            let words = pack::pack_signs_u64(&packed, *tokens, cb);
            let q_packed = pack::pack_codes(q_codes);
            let q_words = pack::pack_signs_u64(&q_packed, 1, cb);
            let wpt = pack::words_per_token(cb);
            let m = pack::majority_sketch(&words, wpt);
            let r = pack::hamming_radius(&words, &m);
            let bound = page_bound(&q_words, &m, r, *dim);
            let mut scores = vec![0.0f32; *tokens];
            let best = score_block_popcnt(&q_words, &words, *tokens, *dim, &mut scores);
            prop_assert!(best <= bound, "best {best} beats page bound {bound} (r {r})");
            // any prefix of the sketched rows must also be dominated
            let sub = 1 + (*tokens - 1) / 2;
            let mut sub_scores = vec![0.0f32; sub];
            let sub_best =
                score_block_popcnt(&q_words, &words[..sub * wpt], sub, *dim, &mut sub_scores);
            prop_assert!(
                sub_best <= bound,
                "prefix best {sub_best} beats page bound {bound} (r {r})"
            );
            Ok(())
        });
    }
}
