//! Deterministic, seeded fault injection for the serving engine.
//!
//! Chaos testing a serving engine needs faults that are (a) *named* — each
//! failure mode has one injection point with one spelling, shared between
//! config, env, tests, and docs — and (b) *reproducible* — a failing CI
//! seed replays locally, byte for byte. This module provides both: a
//! [`FaultInjector`] armed from a spec string like
//! `pool.alloc=nth:5,block.corrupt=prob:0.125`, with per-point schedules
//! that fire on exact call counts (`nth:`/`every:`) or with a seeded
//! probability (`prob:`, SplitMix64-mixed so two injectors with the same
//! seed make identical decisions at identical arrival counts).
//!
//! Zero-cost when disarmed: every probe goes through
//! [`FaultInjector::should_fire`], which is a single branch on a plain
//! bool before any atomics are touched — a production engine carries the
//! probes at the price of one predictable branch per injection point.
//!
//! The injector is plain shared state (`Arc`-able, all interior
//! mutability via relaxed atomics), **not** a process-global: `cargo test`
//! runs many engines in one process, and faults armed for one must never
//! leak into another.
//!
//! DESIGN.md §Robustness holds the fault-point matrix (injection point →
//! expected degradation → test).

use std::sync::atomic::{AtomicU64, Ordering};

/// A named injection point inside the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// `pool.alloc` — [`BlockPool::alloc`] returns `None` as if the pool
    /// were exhausted (admission backpressure / preemption paths).
    ///
    /// [`BlockPool::alloc`]: crate::kvcache::pool::BlockPool::alloc
    PoolAlloc,
    /// `append.cache_full` — a decode-time `HeadCache::append` fails with
    /// `CacheFull` before touching the pool (mid-step exhaustion paths).
    AppendCacheFull,
    /// `worker.panic` — a decode `HeadTask` panics at the start of its
    /// run (worker-poisoning containment paths).
    WorkerPanic,
    /// `block.corrupt` — one bit of a block's payload is flipped right
    /// after prefix registration (integrity-check paths).
    BlockCorrupt,
    /// `swap.out` — a tier swap-out aborts mid-copy; the engine must fall
    /// back to the plain drop-and-re-prefill preemption path with no
    /// blocks leaked on either tier.
    SwapOut,
    /// `swap.in` — a tier swap-in fails before any payload is restored;
    /// the sequence falls back to re-prefill from its prompt.
    SwapIn,
    /// `tier.corrupt` — one byte of a host-tier payload copy is flipped
    /// while it rests in host memory, so the checksum verification at
    /// swap-in must detect it and fall back to re-prefill.
    TierCorrupt,
}

impl FaultPoint {
    pub const ALL: [FaultPoint; 7] = [
        FaultPoint::PoolAlloc,
        FaultPoint::AppendCacheFull,
        FaultPoint::WorkerPanic,
        FaultPoint::BlockCorrupt,
        FaultPoint::SwapOut,
        FaultPoint::SwapIn,
        FaultPoint::TierCorrupt,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::PoolAlloc => "pool.alloc",
            FaultPoint::AppendCacheFull => "append.cache_full",
            FaultPoint::WorkerPanic => "worker.panic",
            FaultPoint::BlockCorrupt => "block.corrupt",
            FaultPoint::SwapOut => "swap.out",
            FaultPoint::SwapIn => "swap.in",
            FaultPoint::TierCorrupt => "tier.corrupt",
        }
    }

    fn parse(s: &str) -> Option<FaultPoint> {
        Self::ALL.into_iter().find(|p| p.name() == s)
    }

    fn index(self) -> usize {
        match self {
            FaultPoint::PoolAlloc => 0,
            FaultPoint::AppendCacheFull => 1,
            FaultPoint::WorkerPanic => 2,
            FaultPoint::BlockCorrupt => 3,
            FaultPoint::SwapOut => 4,
            FaultPoint::SwapIn => 5,
            FaultPoint::TierCorrupt => 6,
        }
    }
}

/// When an armed point fires, relative to its own arrival counter.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    /// Fire exactly once, on the n-th arrival (1-based). The workhorse of
    /// bit-exactness chaos assertions: one deterministic fault, everything
    /// else untouched.
    Nth(u64),
    /// Fire on every n-th arrival (n, 2n, 3n, ...).
    Every(u64),
    /// Fire with probability `p` per arrival, drawn from a per-point
    /// seeded counter-mode PRNG — deterministic in (seed, arrival index),
    /// lock-free under concurrent probes. Use for no-panic / no-leak
    /// sweeps, not bit-exactness (thread interleaving permutes which
    /// arrival lands where).
    Prob(f64),
}

struct PointState {
    schedule: Schedule,
    arrivals: AtomicU64,
    fired: AtomicU64,
    /// per-point seed for `Prob` draws (counter-mode: the draw for
    /// arrival `i` is `mix64(seed + i·GOLDEN)`, so concurrent arrivals
    /// need no shared RNG state beyond the arrival counter)
    seed: u64,
}

/// SplitMix64 finalizer (also the mixer in `substrate::rng`): bijective,
/// avalanching — consecutive counters map to decorrelated draws.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

const GOLDEN: u64 = 0x9e3779b97f4a7c15;

/// Deterministic seeded fault-injection state for one engine.
pub struct FaultInjector {
    /// checked before anything else on every probe — a disarmed injector
    /// costs one predictable branch
    armed: bool,
    points: [Option<PointState>; 7],
}

impl Default for FaultInjector {
    fn default() -> Self {
        Self::disarmed()
    }
}

impl FaultInjector {
    /// No faults; every probe is a single cold branch.
    pub fn disarmed() -> Self {
        Self { armed: false, points: [None, None, None, None, None, None, None] }
    }

    /// Parse a spec like `pool.alloc=nth:5,block.corrupt=prob:0.125`.
    /// Entries are comma-separated `point=kind:arg`; an empty spec is the
    /// disarmed injector. `seed` feeds the `prob:` draws (each point's
    /// stream is further decorrelated by its own name hash).
    pub fn parse(spec: &str, seed: u64) -> Result<Self, String> {
        let mut inj = Self::disarmed();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (name, sched) = entry
                .split_once('=')
                .ok_or_else(|| format!("fault entry '{entry}' is not point=kind:arg"))?;
            let point = FaultPoint::parse(name.trim()).ok_or_else(|| {
                format!(
                    "unknown fault point '{}' (known: {})",
                    name.trim(),
                    FaultPoint::ALL.map(FaultPoint::name).join(", ")
                )
            })?;
            let (kind, arg) = sched
                .split_once(':')
                .ok_or_else(|| format!("fault schedule '{sched}' is not kind:arg"))?;
            let schedule = match kind.trim() {
                "nth" => {
                    let n: u64 = arg.trim().parse().map_err(|_| {
                        format!("nth argument '{arg}' is not an integer")
                    })?;
                    if n == 0 {
                        return Err("nth:0 — arrivals are 1-based".into());
                    }
                    Schedule::Nth(n)
                }
                "every" => {
                    let n: u64 = arg.trim().parse().map_err(|_| {
                        format!("every argument '{arg}' is not an integer")
                    })?;
                    if n == 0 {
                        return Err("every:0 — period must be positive".into());
                    }
                    Schedule::Every(n)
                }
                "prob" => {
                    let p: f64 = arg.trim().parse().map_err(|_| {
                        format!("prob argument '{arg}' is not a number")
                    })?;
                    if !(p > 0.0 && p <= 1.0) {
                        return Err(format!("prob {p} outside (0, 1]"));
                    }
                    Schedule::Prob(p)
                }
                other => {
                    return Err(format!(
                        "unknown fault schedule kind '{other}' (nth, every, prob)"
                    ))
                }
            };
            let idx = point.index();
            if inj.points[idx].is_some() {
                return Err(format!("fault point '{}' armed twice", point.name()));
            }
            // decorrelate the per-point prob streams: same injector seed,
            // different points, different draws
            let mut pseed = seed ^ GOLDEN;
            for b in point.name().bytes() {
                pseed = mix64(pseed ^ b as u64);
            }
            inj.points[idx] = Some(PointState {
                schedule,
                arrivals: AtomicU64::new(0),
                fired: AtomicU64::new(0),
                seed: pseed,
            });
            inj.armed = true;
        }
        Ok(inj)
    }

    /// Build from config, falling back to the `SIKV_FAULTS` /
    /// `SIKV_FAULT_SEED` environment when the config spec is empty — the
    /// CI chaos matrix arms the engine without touching config files.
    pub fn from_config(spec: &str, seed: u64) -> Result<Self, String> {
        if !spec.is_empty() {
            return Self::parse(spec, seed);
        }
        match std::env::var("SIKV_FAULTS") {
            Ok(env_spec) if !env_spec.is_empty() => {
                let env_seed = std::env::var("SIKV_FAULT_SEED")
                    .ok()
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(seed);
                Self::parse(&env_spec, env_seed)
            }
            _ => Ok(Self::disarmed()),
        }
    }

    /// Is any point armed?
    pub fn armed(&self) -> bool {
        self.armed
    }

    /// Probe the injection point: returns `true` when the armed schedule
    /// says this arrival faults. Disarmed injectors return `false` after
    /// a single branch; unarmed points after two.
    #[inline]
    pub fn should_fire(&self, point: FaultPoint) -> bool {
        if !self.armed {
            return false;
        }
        self.probe_armed(point)
    }

    #[cold]
    fn probe_armed(&self, point: FaultPoint) -> bool {
        let Some(st) = &self.points[point.index()] else {
            return false;
        };
        let arrival = st.arrivals.fetch_add(1, Ordering::Relaxed) + 1;
        let fire = match st.schedule {
            Schedule::Nth(n) => arrival == n,
            Schedule::Every(n) => arrival.is_multiple_of(n),
            Schedule::Prob(p) => {
                let z = mix64(st.seed.wrapping_add(arrival.wrapping_mul(GOLDEN)));
                // 53 uniform mantissa bits in [0, 1)
                ((z >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < p
            }
        };
        if fire {
            st.fired.fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// Times `point` has fired so far.
    pub fn fired(&self, point: FaultPoint) -> u64 {
        self.points[point.index()]
            .as_ref()
            .map_or(0, |st| st.fired.load(Ordering::Relaxed))
    }

    /// Times `point` has been probed while armed.
    pub fn arrivals(&self, point: FaultPoint) -> u64 {
        self.points[point.index()]
            .as_ref()
            .map_or(0, |st| st.arrivals.load(Ordering::Relaxed))
    }

    /// Total fires across all points (the chaos summaries' headline).
    pub fn total_fired(&self) -> u64 {
        FaultPoint::ALL.into_iter().map(|p| self.fired(p)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_never_fires() {
        let inj = FaultInjector::disarmed();
        assert!(!inj.armed());
        for _ in 0..100 {
            assert!(!inj.should_fire(FaultPoint::PoolAlloc));
        }
        assert_eq!(inj.fired(FaultPoint::PoolAlloc), 0);
        assert_eq!(inj.arrivals(FaultPoint::PoolAlloc), 0, "disarmed probes are free");
    }

    #[test]
    fn nth_fires_exactly_once_at_n() {
        let inj = FaultInjector::parse("pool.alloc=nth:5", 0).unwrap();
        let fires: Vec<bool> =
            (0..10).map(|_| inj.should_fire(FaultPoint::PoolAlloc)).collect();
        assert_eq!(fires.iter().filter(|&&f| f).count(), 1);
        assert!(fires[4], "1-based: the 5th arrival fires");
        assert_eq!(inj.fired(FaultPoint::PoolAlloc), 1);
        assert_eq!(inj.arrivals(FaultPoint::PoolAlloc), 10);
    }

    #[test]
    fn every_fires_periodically() {
        let inj = FaultInjector::parse("append.cache_full=every:3", 0).unwrap();
        let fires: Vec<bool> = (0..9)
            .map(|_| inj.should_fire(FaultPoint::AppendCacheFull))
            .collect();
        assert_eq!(fires, [false, false, true, false, false, true, false, false, true]);
    }

    #[test]
    fn prob_is_seed_deterministic_and_calibrated() {
        let a = FaultInjector::parse("worker.panic=prob:0.25", 42).unwrap();
        let b = FaultInjector::parse("worker.panic=prob:0.25", 42).unwrap();
        let da: Vec<bool> = (0..2000).map(|_| a.should_fire(FaultPoint::WorkerPanic)).collect();
        let db: Vec<bool> = (0..2000).map(|_| b.should_fire(FaultPoint::WorkerPanic)).collect();
        assert_eq!(da, db, "same seed, same arrival index, same decision");
        let rate = a.fired(FaultPoint::WorkerPanic) as f64 / 2000.0;
        assert!((rate - 0.25).abs() < 0.05, "empirical rate {rate} far from 0.25");
        let c = FaultInjector::parse("worker.panic=prob:0.25", 43).unwrap();
        let dc: Vec<bool> = (0..2000).map(|_| c.should_fire(FaultPoint::WorkerPanic)).collect();
        assert_ne!(da, dc, "different seed, different stream");
    }

    #[test]
    fn multi_point_specs_parse_and_stay_independent() {
        let inj =
            FaultInjector::parse(" pool.alloc=nth:1 , block.corrupt=every:2 ", 7).unwrap();
        assert!(inj.should_fire(FaultPoint::PoolAlloc));
        assert!(!inj.should_fire(FaultPoint::PoolAlloc));
        assert!(!inj.should_fire(FaultPoint::BlockCorrupt));
        assert!(inj.should_fire(FaultPoint::BlockCorrupt));
        assert!(!inj.should_fire(FaultPoint::WorkerPanic), "unarmed point never fires");
        assert_eq!(inj.total_fired(), 2);
    }

    #[test]
    fn tier_points_parse_and_fire_independently() {
        let inj = FaultInjector::parse(
            "swap.out=nth:1,swap.in=nth:2,tier.corrupt=every:2",
            9,
        )
        .unwrap();
        assert!(inj.should_fire(FaultPoint::SwapOut));
        assert!(!inj.should_fire(FaultPoint::SwapOut), "nth fires once");
        assert!(!inj.should_fire(FaultPoint::SwapIn));
        assert!(inj.should_fire(FaultPoint::SwapIn));
        assert!(!inj.should_fire(FaultPoint::TierCorrupt));
        assert!(inj.should_fire(FaultPoint::TierCorrupt));
        assert_eq!(inj.total_fired(), 3);
        assert!(!inj.should_fire(FaultPoint::PoolAlloc), "unarmed point untouched");
    }

    #[test]
    fn bad_specs_are_rejected() {
        for bad in [
            "pool.malloc=nth:1",   // unknown point
            "pool.alloc",          // no schedule
            "pool.alloc=nth",      // no argument
            "pool.alloc=nth:0",    // 1-based arrivals
            "pool.alloc=every:0",  // zero period
            "pool.alloc=prob:0.0", // never fires: spec bug, say so
            "pool.alloc=prob:1.5", // not a probability
            "pool.alloc=often:2",  // unknown kind
            "pool.alloc=nth:1,pool.alloc=nth:2", // armed twice
        ] {
            assert!(FaultInjector::parse(bad, 0).is_err(), "{bad} must be rejected");
        }
        assert!(!FaultInjector::parse("", 0).unwrap().armed(), "empty spec = disarmed");
    }
}
