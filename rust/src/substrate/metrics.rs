//! Counters / gauges / histograms for the serving engine.
//!
//! Cheap enough for the hot path (relaxed atomics), with a registry that
//! snapshots everything for the `/stats`-style dump the CLI prints.
//!
//! Naming convention: dotted `subsystem.metric` — e.g. the engine's
//! `engine.preemptions` / `engine.swap_outs` / `engine.swap_ins` /
//! `engine.swap_fallbacks` counters, the pool's `pool.free_blocks` /
//! `pool.integrity_failures` gauges, and the host tier's
//! `tier.host_blocks` / `tier.host_bytes` / `tier.cold_bytes` gauges
//! (set once per engine step while the swap policy is enabled).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Heap-allocation-counting wrapper around the system allocator,
/// installed as the global allocator in the crate's own test builds
/// (`lib.rs`). One thread-local increment per alloc/realloc; it makes
/// "this hot path allocates nothing" a *testable* invariant (see
/// `baselines::ours::tests::decode_step_is_allocation_free`) instead of a
/// comment. Outside test builds [`thread_allocations`] reads a counter
/// nothing bumps (always 0) and the allocator is not installed.
pub struct CountingAllocator;

thread_local! {
    // const-init + no Drop: safe to touch from inside the allocator
    // (no lazy initialization, no TLS destructor recursion)
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Process-wide allocation count (all threads). Lets tests assert that a
/// multi-threaded hot path — e.g. the engine's decode fan-out across the
/// worker pool — allocates nowhere, not just on the driving thread.
static GLOBAL_ALLOCS: AtomicU64 = AtomicU64::new(0);

#[inline]
fn bump() {
    TL_ALLOCS.with(|c| c.set(c.get() + 1));
    GLOBAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
}

/// Allocations made by the *current thread* since it started.
pub fn thread_allocations() -> u64 {
    TL_ALLOCS.with(|c| c.get())
}

/// Allocations made by *any* thread since process start (0 unless the
/// [`CountingAllocator`] is installed as the global allocator).
pub fn global_allocations() -> u64 {
    GLOBAL_ALLOCS.load(Ordering::Relaxed)
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram: 60 buckets, ~100ns .. ~100s.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

const HIST_BUCKETS: usize = 60;

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    fn bucket_of(ns: u64) -> usize {
        // ~3 buckets per decade starting at 100ns
        if ns < 100 {
            return 0;
        }
        let log = (ns as f64 / 100.0).log10();
        ((log * 6.0) as usize).min(HIST_BUCKETS - 1)
    }

    /// Upper bound (ns) of bucket i.
    fn bucket_hi(i: usize) -> u64 {
        (100.0 * 10f64.powf((i + 1) as f64 / 6.0)) as u64
    }

    pub fn observe(&self, d: Duration) {
        let ns = d.as_nanos().min(u128::from(u64::MAX)) as u64;
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(Self::bucket_hi(i));
            }
        }
        Duration::from_nanos(Self::bucket_hi(HIST_BUCKETS - 1))
    }
}

/// Named metric registry shared across the engine.
#[derive(Default, Clone)]
pub struct Registry {
    inner: Arc<Mutex<RegistryInner>>,
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            self.inner
                .lock()
                .unwrap()
                .counters
                .entry(name.to_string())
                .or_default(),
        )
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(
            self.inner
                .lock()
                .unwrap()
                .gauges
                .entry(name.to_string())
                .or_default(),
        )
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            self.inner
                .lock()
                .unwrap()
                .histograms
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Render a sorted text snapshot.
    pub fn snapshot(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, c) in &g.counters {
            out.push_str(&format!("counter {k} = {}\n", c.get()));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("gauge   {k} = {}\n", v.get()));
        }
        for (k, h) in &g.histograms {
            out.push_str(&format!(
                "hist    {k}: n={} mean={:?} p50={:?} p99={:?}\n",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::default();
        r.counter("reqs").add(5);
        r.counter("reqs").inc();
        assert_eq!(r.counter("reqs").get(), 6);
        r.gauge("q").set(42);
        r.gauge("q").add(-2);
        assert_eq!(r.gauge("q").get(), 40);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.observe(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        // p50 of 1..1000µs should land around 500µs (log-bucketed => loose)
        assert!(p50 >= Duration::from_micros(200));
        assert!(p50 <= Duration::from_micros(1200));
    }

    #[test]
    fn registry_snapshot_contains_names() {
        let r = Registry::default();
        r.counter("a").inc();
        r.histogram("lat").observe(Duration::from_millis(1));
        let snap = r.snapshot();
        assert!(snap.contains("counter a = 1"));
        assert!(snap.contains("hist    lat"));
    }

    #[test]
    fn allocation_counter_counts_this_thread() {
        let before = thread_allocations();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = thread_allocations();
        assert!(after > before, "Vec::with_capacity must be counted");
        drop(v);
        // pure arithmetic does not allocate
        let base = thread_allocations();
        let mut acc = 0u64;
        for i in 0..1000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        assert_eq!(thread_allocations(), base);
    }

    #[test]
    fn same_name_same_instance() {
        let r = Registry::default();
        let c1 = r.counter("x");
        let c2 = r.counter("x");
        c1.inc();
        assert_eq!(c2.get(), 1);
    }
}
