//! Declarative CLI argument parser (offline substitute for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, typed accessors with defaults, and auto-generated help.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid(String, String),
    MissingRequired(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(o) => write!(f, "unknown option '{o}' (see --help)"),
            CliError::MissingValue(o) => write!(f, "option '--{o}' expects a value"),
            CliError::Invalid(o, v) => write!(f, "invalid value for '--{o}': {v}"),
            CliError::MissingRequired(o) => write!(f, "missing required option '--{o}'"),
        }
    }
}

impl std::error::Error for CliError {}

#[derive(Clone)]
struct OptSpec {
    name: String,
    help: String,
    takes_value: bool,
    required: bool,
    default: Option<String>,
}

/// Builder-style command definition.
pub struct Command {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>, // (name, help)
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Self {
            name: name.to_string(),
            about: about.to_string(),
            opts: vec![],
            positionals: vec![],
        }
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: false,
            required: false,
            default: None,
        });
        self
    }

    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            required: false,
            default: Some(default.into()),
        });
        self
    }

    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            required: true,
            default: None,
        });
        self
    }

    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.into(), help.into()));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let def = match &o.default {
                Some(d) => format!(" [default: {d}]"),
                None if o.required => " [required]".to_string(),
                None => String::new(),
            };
            s.push_str(&format!("  --{}{val}\n      {}{def}\n", o.name, o.help));
        }
        if !self.positionals.is_empty() {
            s.push_str("\nPositional:\n");
            for (n, h) in &self.positionals {
                s.push_str(&format!("  <{n}>  {h}\n"));
            }
        }
        s
    }

    /// Parse an argv slice (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut pos = Vec::new();
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
        }

        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(key.clone()))?,
                    };
                    values.insert(key, v);
                } else {
                    flags.insert(key, true);
                }
            } else {
                pos.push(a.clone());
            }
        }

        for o in &self.opts {
            if o.required && !values.contains_key(&o.name) {
                return Err(CliError::MissingRequired(o.name.clone()));
            }
        }
        Ok(Matches { values, flags, pos })
    }
}

/// Parse results with typed accessors.
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub pos: Vec<String>,
}

impl Matches {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .unwrap_or_else(|| panic!("option '{name}' not declared/provided"))
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError::Invalid(name.into(), self.str(name).into()))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.parse_as(name)
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.parse_as(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    fn cmd() -> Command {
        Command::new("serve", "run the engine")
            .opt("batch", "8", "max batch size")
            .opt("sparsity", "0.075", "token keep ratio")
            .flag("verbose", "log more")
            .req("model", "artifact dir")
            .positional("trace", "workload trace file")
    }

    #[test]
    fn parses_mixed_styles() {
        let m = cmd()
            .parse(&argv("--model artifacts --batch=4 --verbose tracefile"))
            .unwrap();
        assert_eq!(m.str("model"), "artifacts");
        assert_eq!(m.usize("batch").unwrap(), 4);
        assert!((m.f64("sparsity").unwrap() - 0.075).abs() < 1e-12);
        assert!(m.flag("verbose"));
        assert_eq!(m.pos, vec!["tracefile"]);
    }

    #[test]
    fn defaults_apply() {
        let m = cmd().parse(&argv("--model a")).unwrap();
        assert_eq!(m.usize("batch").unwrap(), 8);
        assert!(!m.flag("verbose"));
    }

    #[test]
    fn missing_required_rejected() {
        assert!(matches!(
            cmd().parse(&argv("--batch 4")),
            Err(CliError::MissingRequired(_))
        ));
    }

    #[test]
    fn unknown_rejected() {
        assert!(matches!(
            cmd().parse(&argv("--model a --bogus 1")),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn bad_number_rejected() {
        let m = cmd().parse(&argv("--model a --batch nope")).unwrap();
        assert!(m.usize("batch").is_err());
    }

    #[test]
    fn help_mentions_options() {
        let h = cmd().help_text();
        assert!(h.contains("--batch") && h.contains("default: 8"));
        assert!(h.contains("--model") && h.contains("[required]"));
    }
}
