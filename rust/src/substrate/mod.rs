//! From-scratch infrastructure substrates.
//!
//! This build environment is fully offline: only the crates vendored for
//! the PJRT bridge are resolvable (no tokio / clap / serde / criterion /
//! proptest / rand). Per the reproduction mandate — *build every substrate
//! the system depends on* — this module provides the equivalents:
//!
//! * [`error`]    — catch-all error + `anyhow!`/`bail!` macros
//! * [`rng`]      — SplitMix64 / Xoshiro256** PRNGs + distributions
//! * [`json`]     — JSON parser/serializer (configs, manifest)
//! * [`cli`]      — declarative argument parser
//! * [`exec`]     — thread-pool executor + scoped parallelism
//! * [`faults`]   — deterministic seeded fault injection (chaos testing)
//! * [`prop`]     — property-based testing (generate / shrink / run)
//! * [`benchkit`] — measurement harness (warmup, percentiles, throughput)
//! * [`metrics`]  — counters / gauges / histograms registry

pub mod benchkit;
pub mod cli;
pub mod error;
pub mod exec;
pub mod faults;
pub mod json;
pub mod metrics;
pub mod prop;
pub mod rng;
