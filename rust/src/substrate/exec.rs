//! Thread-pool executor + channels (offline substitute for tokio).
//!
//! The coordinator is an event loop, not an async reactor: requests arrive
//! on an mpsc channel, the scheduler forms batches, and the engine drives
//! PJRT executions synchronously (PJRT CPU calls are blocking anyway).
//! What we need from a runtime is (a) a worker pool for parallelizable
//! work (per-head scoring, workload generation), (b) graceful shutdown,
//! (c) scoped joins, and (d) an allocation-free fan-out primitive for the
//! decode hot loop ([`ThreadPool::for_each_task`]: an atomic cursor over a
//! pre-built task slice — no per-job closure boxing). This module provides
//! exactly that on std primitives.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Type-erased view of one `for_each_task` batch, published to the
/// workers through the shared queue state. Every pointer targets the
/// *caller's* stack frame; the caller blocks until `remaining` reaches
/// zero before returning, so the frame outlives all worker accesses
/// (the same safety argument `scoped` makes, without per-job boxing).
#[derive(Clone, Copy)]
struct Batch {
    /// `&mut [T]` data pointer; workers index it through the cursor, so
    /// each element is handed out exactly once (disjoint `&mut T`).
    tasks: *mut (),
    len: usize,
    /// `&F`, the shared `Fn(&mut T)`
    ctx: *const (),
    run: unsafe fn(*mut (), usize, *const ()),
    cursor: *const AtomicUsize,
    remaining: *const AtomicUsize,
    panic_slot: *const Mutex<Option<Box<dyn Any + Send>>>,
}

// SAFETY: the raw pointers are only dereferenced while the publishing
// `for_each_task` frame is alive (it waits for `remaining == 0`), and the
// referenced task/context types are constrained `T: Send` / `F: Sync` at
// the only construction site.
unsafe impl Send for Batch {}

struct State {
    jobs: VecDeque<Job>,
    batch: Option<Batch>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// workers wait here for jobs / batches
    work_cv: Condvar,
    /// `for_each_task` callers wait here for batch completion
    done_cv: Condvar,
}

enum Work {
    Task(Batch, usize),
    Job(Job),
}

/// Run one claimed batch task, recording the first panic and signalling
/// completion (the final decrement wakes the waiting caller under the
/// state lock so the wakeup cannot be missed).
fn run_batch_task(shared: &Shared, b: Batch, i: usize) {
    let result = panic::catch_unwind(AssertUnwindSafe(|| unsafe { (b.run)(b.tasks, i, b.ctx) }));
    if let Err(p) = result {
        // SAFETY: the slot lives on the caller's frame, which is pinned
        // until `remaining` (decremented below) reaches zero.
        let slot = unsafe { &*b.panic_slot };
        let mut s = slot.lock().unwrap();
        if s.is_none() {
            *s = Some(p);
        }
    }
    // SAFETY: as above — the counter outlives the batch.
    let prev = unsafe { (*b.remaining).fetch_sub(1, Ordering::Release) };
    if prev == 1 {
        let _guard = shared.state.lock().unwrap();
        shared.done_cv.notify_all();
    }
}

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                jobs: VecDeque::new(),
                batch: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let in_flight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                let inf = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("sikv-worker-{i}"))
                    .spawn(move || loop {
                        let work = {
                            let mut st = shared.state.lock().unwrap();
                            loop {
                                if let Some(b) = st.batch {
                                    // SAFETY: a published batch's caller
                                    // frame is alive (see `Batch`).
                                    let i = unsafe {
                                        (*b.cursor).fetch_add(1, Ordering::Relaxed)
                                    };
                                    if i < b.len {
                                        break Some(Work::Task(b, i));
                                    }
                                    // cursor exhausted: retire the batch
                                    // so idle workers stop re-checking it
                                    st.batch = None;
                                    continue;
                                }
                                if let Some(j) = st.jobs.pop_front() {
                                    break Some(Work::Job(j));
                                }
                                if st.shutdown {
                                    break None;
                                }
                                st = shared.work_cv.wait(st).unwrap();
                            }
                        };
                        match work {
                            None => break,
                            Some(Work::Task(b, i)) => run_batch_task(&shared, b, i),
                            Some(Work::Job(job)) => {
                                // swallow panics so one bad job doesn't
                                // poison the pool; surfaced via JoinSet.
                                let _ = panic::catch_unwind(AssertUnwindSafe(job));
                                let (lock, cv) = &*inf;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                cv.notify_all();
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            shared,
            workers,
            in_flight,
        }
    }

    /// Pool sized to the machine (min 1).
    pub fn default_size() -> Self {
        Self::new(thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.in_flight;
        *lock.lock().unwrap() += 1;
        let mut st = self.shared.state.lock().unwrap();
        assert!(!st.shutdown, "pool shut down");
        st.jobs.push_back(Box::new(f));
        drop(st);
        self.shared.work_cv.notify_one();
    }

    /// Block until every spawned job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.in_flight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Run `f` over every element of `tasks`, fanned out across the pool
    /// via an atomic cursor over the pre-built slice — the engine's
    /// decode work-queue primitive.
    ///
    /// Unlike [`ThreadPool::scoped`] there is **no per-job boxing and no
    /// allocation at all**: the batch descriptor, cursor, and completion
    /// counter live on this call's stack, and workers claim indices with
    /// one `fetch_add` each. The caller participates in draining the
    /// cursor, then blocks until every claimed task has finished, so the
    /// borrowed slice and closure never outlive the call. If any task
    /// panicked, the first panic payload is re-raised here.
    ///
    /// Each index is claimed exactly once, so tasks receive disjoint
    /// `&mut T`. **Do not call from inside a pool job** (same nesting
    /// caveat as `scoped`); concurrent calls from *different* threads are
    /// safe — the loser of the publish race simply drains its own batch
    /// inline.
    pub fn for_each_task<T, F>(&self, tasks: &mut [T], f: F)
    where
        T: Send,
        F: Fn(&mut T) + Sync,
    {
        if tasks.is_empty() {
            return;
        }

        /// SAFETY (caller): `tasks` is the data pointer of a live
        /// `&mut [T]` with `i` in bounds and claimed exactly once, and
        /// `ctx` points to a live `F`.
        unsafe fn run_one<T, F: Fn(&mut T)>(tasks: *mut (), i: usize, ctx: *const ()) {
            let f: &F = &*(ctx as *const F);
            f(&mut *(tasks as *mut T).add(i))
        }

        let len = tasks.len();
        let cursor = AtomicUsize::new(0);
        let remaining = AtomicUsize::new(len);
        let panic_slot: Mutex<Option<Box<dyn Any + Send>>> = Mutex::new(None);
        let batch = Batch {
            tasks: tasks.as_mut_ptr() as *mut (),
            len,
            ctx: &f as *const F as *const (),
            run: run_one::<T, F>,
            cursor: &cursor,
            remaining: &remaining,
            panic_slot: &panic_slot,
        };
        // publish (one active batch at a time; a contended second caller
        // just drains its whole batch inline below)
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.batch.is_none() {
                st.batch = Some(batch);
                self.shared.work_cv.notify_all();
            }
        }
        // the caller drains the cursor alongside the workers
        loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= len {
                break;
            }
            run_batch_task(&self.shared, batch, i);
        }
        // retire the batch if still published, then wait out any tasks
        // other workers claimed but have not finished
        {
            let mut st = self.shared.state.lock().unwrap();
            if let Some(b) = st.batch {
                if std::ptr::eq(b.cursor, &cursor) {
                    st.batch = None;
                }
            }
            while remaining.load(Ordering::Acquire) != 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
        }
        if let Some(p) = panic_slot.lock().unwrap().take() {
            panic::resume_unwind(p);
        }
    }

    /// Run a batch of borrowing jobs to completion on the pool (a scoped
    /// join: jobs may capture references into the caller's stack frame).
    /// Returns only after every job has finished; if a job panicked, the
    /// first panic payload is re-raised in the caller (no partial results
    /// are silently accepted).
    ///
    /// Boxes one closure per job — prefer [`ThreadPool::for_each_task`]
    /// on hot paths where the jobs share one shape over a task slice.
    ///
    /// **Do not call from inside a job running on the same pool**: the
    /// caller blocks a worker while its child jobs queue behind it —
    /// with enough concurrent nested calls (or a 1-worker pool) that is
    /// a permanent deadlock. Fan out at one level only, or use a second
    /// pool for nested parallelism.
    pub fn scoped<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        if jobs.is_empty() {
            return;
        }
        type Payload = Option<Box<dyn Any + Send>>;

        /// Join guard: blocks until every enqueued job has reported —
        /// on the normal path below AND in Drop during an unwind — so a
        /// panic between enqueue and join can never let a detached job
        /// outlive the caller's borrowed frame. Owns the original sender
        /// and drops it before receiving, so the receive loop always
        /// terminates (a job dropped unrun just drops its own sender).
        struct Join {
            tx: Option<mpsc::Sender<Payload>>,
            rx: mpsc::Receiver<Payload>,
            pending: usize,
            first_panic: Payload,
        }

        impl Join {
            fn join(&mut self) {
                self.tx.take(); // job senders are now the only ones left
                while self.pending > 0 {
                    match self.rx.recv() {
                        Ok(p) => {
                            self.pending -= 1;
                            if self.first_panic.is_none() {
                                self.first_panic = p;
                            }
                        }
                        // all senders gone: remaining jobs were dropped
                        // unrun (pool shutdown) — nothing left to wait for
                        Err(_) => break,
                    }
                }
            }
        }

        impl Drop for Join {
            fn drop(&mut self) {
                self.join();
            }
        }

        let (tx, rx) = mpsc::channel::<Payload>();
        let mut join = Join {
            tx: Some(tx),
            rx,
            pending: 0,
            first_panic: None,
        };
        for job in jobs {
            // SAFETY: `join` blocks until every enqueued job has sent its
            // receipt (the job's own catch_unwind guarantees a send after
            // it ran or unwound; a job dropped unrun drops its sender).
            // That join happens before this frame is torn down even when
            // this loop unwinds (Join::drop), so no job outlives 'env.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            let tx = join.tx.as_ref().expect("sender live while enqueuing").clone();
            join.pending += 1;
            self.spawn(move || {
                let result = panic::catch_unwind(AssertUnwindSafe(job));
                let _ = tx.send(result.err());
            });
        }
        join.join();
        if let Some(payload) = join.first_panic.take() {
            panic::resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Collects results of a group of spawned tasks (order = spawn order).
pub struct JoinSet<T> {
    rx: mpsc::Receiver<(usize, T)>,
    tx: mpsc::Sender<(usize, T)>,
    spawned: usize,
}

impl<T: Send + 'static> JoinSet<T> {
    pub fn new() -> Self {
        let (tx, rx) = mpsc::channel();
        Self { rx, tx, spawned: 0 }
    }

    pub fn spawn_on<F>(&mut self, pool: &ThreadPool, f: F)
    where
        F: FnOnce() -> T + Send + 'static,
    {
        let idx = self.spawned;
        self.spawned += 1;
        let tx = self.tx.clone();
        pool.spawn(move || {
            let _ = tx.send((idx, f()));
        });
    }

    /// Wait for all results; panics if a task panicked (its slot missing).
    pub fn join_all(self) -> Vec<T> {
        let JoinSet { rx, tx, spawned } = self;
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..spawned).map(|_| None).collect();
        for (idx, v) in rx.iter() {
            slots[idx] = Some(v);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.unwrap_or_else(|| panic!("task {i} panicked")))
            .collect()
    }
}

impl<T: Send + 'static> Default for JoinSet<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Map `f` over items on the pool, preserving order.
pub fn par_map<T, U, F>(pool: &ThreadPool, items: Vec<T>, f: F) -> Vec<U>
where
    T: Send + 'static,
    U: Send + 'static,
    F: Fn(T) -> U + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let mut set = JoinSet::new();
    for item in items {
        let f = Arc::clone(&f);
        set.spawn_on(pool, move || f(item));
    }
    set.join_all()
}

/// Monotonic id generator (request ids, sequence ids).
#[derive(Default)]
pub struct IdGen(AtomicUsize);

impl IdGen {
    pub fn next(&self) -> usize {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = par_map(&pool, (0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn join_set_collects_in_spawn_order() {
        let pool = ThreadPool::new(2);
        let mut set = JoinSet::new();
        for i in 0..10usize {
            set.spawn_on(&pool, move || i * 2);
        }
        assert_eq!(set.join_all(), (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(1);
        pool.spawn(|| panic!("boom"));
        pool.wait_idle();
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.spawn(move || {
            d.store(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scoped_jobs_borrow_stack_data() {
        let pool = ThreadPool::new(4);
        let mut slots = vec![0u64; 64];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .iter_mut()
            .enumerate()
            .map(|(i, s)| {
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || *s = (i * i) as u64);
                job
            })
            .collect();
        pool.scoped(jobs);
        for (i, &s) in slots.iter().enumerate() {
            assert_eq!(s, (i * i) as u64);
        }
        // empty batch is a no-op
        pool.scoped(vec![]);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn scoped_propagates_panics() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() + Send + 'static>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("boom")),
            Box::new(|| {}),
        ];
        pool.scoped(jobs);
    }

    #[test]
    fn for_each_task_runs_every_task_with_disjoint_mut() {
        let pool = ThreadPool::new(4);
        let mut tasks: Vec<(usize, u64)> = (0..257).map(|i| (i, 0)).collect();
        pool.for_each_task(&mut tasks, |t| t.1 = (t.0 * t.0) as u64);
        for (i, v) in &tasks {
            assert_eq!(*v, (i * i) as u64);
        }
        // empty slice is a no-op
        pool.for_each_task(&mut Vec::<u64>::new(), |_| {});
    }

    #[test]
    fn for_each_task_works_on_one_worker_pool() {
        // the caller participates, so even a saturated 1-worker pool
        // makes progress
        let pool = ThreadPool::new(1);
        let mut tasks = vec![0u64; 100];
        pool.for_each_task(&mut tasks, |t| *t += 7);
        assert!(tasks.iter().all(|&t| t == 7));
    }

    #[test]
    fn for_each_task_borrows_stack_context() {
        let pool = ThreadPool::new(3);
        let bias = 11u64;
        let mut tasks = vec![0u64; 64];
        pool.for_each_task(&mut tasks, |t| *t = bias);
        assert!(tasks.iter().all(|&t| t == bias));
    }

    #[test]
    #[should_panic(expected = "task boom")]
    fn for_each_task_propagates_panics() {
        let pool = ThreadPool::new(2);
        let mut tasks: Vec<usize> = (0..16).collect();
        pool.for_each_task(&mut tasks, |t| {
            if *t == 9 {
                panic!("task boom");
            }
        });
    }

    #[test]
    fn for_each_task_then_spawn_interleave() {
        // batches and boxed jobs share the queue without starving each
        // other across repeated rounds
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
            let mut tasks = vec![1u64; 32];
            pool.for_each_task(&mut tasks, |t| *t *= 3);
            assert!(tasks.iter().all(|&t| t == 3));
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn idgen_monotonic() {
        let g = IdGen::default();
        let a = g.next();
        let b = g.next();
        assert!(b > a);
    }
}
