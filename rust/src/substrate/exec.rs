//! Thread-pool executor + channels (offline substitute for tokio).
//!
//! The coordinator is an event loop, not an async reactor: requests arrive
//! on an mpsc channel, the scheduler forms batches, and the engine drives
//! PJRT executions synchronously (PJRT CPU calls are blocking anyway).
//! What we need from a runtime is (a) a worker pool for parallelizable
//! work (per-head scoring, workload generation), (b) graceful shutdown,
//! (c) scoped joins. This module provides exactly that on std primitives.

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inf = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("sikv-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // swallow panics so one bad job doesn't
                                // poison the pool; surfaced via JoinSet.
                                let _ = panic::catch_unwind(
                                    AssertUnwindSafe(job));
                                let (lock, cv) = &*inf;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                cv.notify_all();
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers, in_flight }
    }

    /// Pool sized to the machine (min 1).
    pub fn default_size() -> Self {
        Self::new(
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.in_flight;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker pool hung up");
    }

    /// Block until every spawned job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.in_flight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Run a batch of borrowing jobs to completion on the pool (a scoped
    /// join: jobs may capture references into the caller's stack frame).
    /// Returns only after every job has finished; if a job panicked, the
    /// first panic payload is re-raised in the caller (no partial results
    /// are silently accepted).
    ///
    /// This is the engine's decode fan-out primitive: one job per
    /// (sequence, kv-head group), each owning disjoint `&mut` state, all
    /// joined before the layer's output projection runs.
    ///
    /// **Do not call from inside a job running on the same pool**: the
    /// caller blocks a worker while its child jobs queue behind it —
    /// with enough concurrent nested calls (or a 1-worker pool) that is
    /// a permanent deadlock. Fan out at one level only, or use a second
    /// pool for nested parallelism.
    pub fn scoped<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        if jobs.is_empty() {
            return;
        }
        type Payload = Option<Box<dyn std::any::Any + Send>>;

        /// Join guard: blocks until every enqueued job has reported —
        /// on the normal path below AND in Drop during an unwind — so a
        /// panic between enqueue and join can never let a detached job
        /// outlive the caller's borrowed frame. Owns the original sender
        /// and drops it before receiving, so the receive loop always
        /// terminates (a job dropped unrun just drops its own sender).
        struct Join {
            tx: Option<mpsc::Sender<Payload>>,
            rx: mpsc::Receiver<Payload>,
            pending: usize,
            first_panic: Payload,
        }

        impl Join {
            fn join(&mut self) {
                self.tx.take(); // job senders are now the only ones left
                while self.pending > 0 {
                    match self.rx.recv() {
                        Ok(p) => {
                            self.pending -= 1;
                            if self.first_panic.is_none() {
                                self.first_panic = p;
                            }
                        }
                        // all senders gone: remaining jobs were dropped
                        // unrun (pool shutdown) — nothing left to wait for
                        Err(_) => break,
                    }
                }
            }
        }

        impl Drop for Join {
            fn drop(&mut self) {
                self.join();
            }
        }

        let (tx, rx) = mpsc::channel::<Payload>();
        let mut join = Join { tx: Some(tx), rx, pending: 0, first_panic: None };
        for job in jobs {
            // SAFETY: `join` blocks until every enqueued job has sent its
            // receipt (the job's own catch_unwind guarantees a send after
            // it ran or unwound; a job dropped unrun drops its sender).
            // That join happens before this frame is torn down even when
            // this loop unwinds (Join::drop), so no job outlives 'env.
            let job: Box<dyn FnOnce() + Send + 'static> =
                unsafe { std::mem::transmute(job) };
            let tx = join.tx.as_ref().expect("sender live while enqueuing").clone();
            join.pending += 1;
            self.spawn(move || {
                let result = panic::catch_unwind(AssertUnwindSafe(job));
                let _ = tx.send(result.err());
            });
        }
        join.join();
        if let Some(payload) = join.first_panic.take() {
            panic::resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel -> workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Collects results of a group of spawned tasks (order = spawn order).
pub struct JoinSet<T> {
    rx: mpsc::Receiver<(usize, T)>,
    tx: mpsc::Sender<(usize, T)>,
    spawned: usize,
}

impl<T: Send + 'static> JoinSet<T> {
    pub fn new() -> Self {
        let (tx, rx) = mpsc::channel();
        Self { rx, tx, spawned: 0 }
    }

    pub fn spawn_on<F>(&mut self, pool: &ThreadPool, f: F)
    where
        F: FnOnce() -> T + Send + 'static,
    {
        let idx = self.spawned;
        self.spawned += 1;
        let tx = self.tx.clone();
        pool.spawn(move || {
            let _ = tx.send((idx, f()));
        });
    }

    /// Wait for all results; panics if a task panicked (its slot missing).
    pub fn join_all(self) -> Vec<T> {
        let JoinSet { rx, tx, spawned } = self;
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..spawned).map(|_| None).collect();
        for (idx, v) in rx.iter() {
            slots[idx] = Some(v);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.unwrap_or_else(|| panic!("task {i} panicked")))
            .collect()
    }
}

impl<T: Send + 'static> Default for JoinSet<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Map `f` over items on the pool, preserving order.
pub fn par_map<T, U, F>(pool: &ThreadPool, items: Vec<T>, f: F) -> Vec<U>
where
    T: Send + 'static,
    U: Send + 'static,
    F: Fn(T) -> U + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let mut set = JoinSet::new();
    for item in items {
        let f = Arc::clone(&f);
        set.spawn_on(pool, move || f(item));
    }
    set.join_all()
}

/// Monotonic id generator (request ids, sequence ids).
#[derive(Default)]
pub struct IdGen(AtomicUsize);

impl IdGen {
    pub fn next(&self) -> usize {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = par_map(&pool, (0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn join_set_collects_in_spawn_order() {
        let pool = ThreadPool::new(2);
        let mut set = JoinSet::new();
        for i in 0..10usize {
            set.spawn_on(&pool, move || i * 2);
        }
        assert_eq!(set.join_all(), (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(1);
        pool.spawn(|| panic!("boom"));
        pool.wait_idle();
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.spawn(move || {
            d.store(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scoped_jobs_borrow_stack_data() {
        let pool = ThreadPool::new(4);
        let mut slots = vec![0u64; 64];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .iter_mut()
            .enumerate()
            .map(|(i, s)| {
                let job: Box<dyn FnOnce() + Send + '_> =
                    Box::new(move || *s = (i * i) as u64);
                job
            })
            .collect();
        pool.scoped(jobs);
        for (i, &s) in slots.iter().enumerate() {
            assert_eq!(s, (i * i) as u64);
        }
        // empty batch is a no-op
        pool.scoped(vec![]);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn scoped_propagates_panics() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() + Send + 'static>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("boom")),
            Box::new(|| {}),
        ];
        pool.scoped(jobs);
    }

    #[test]
    fn idgen_monotonic() {
        let g = IdGen::default();
        let a = g.next();
        let b = g.next();
        assert!(b > a);
    }
}
