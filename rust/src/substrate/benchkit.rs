//! Measurement harness (offline substitute for criterion): warmup, timed
//! iterations, robust statistics, and aligned table printing used by every
//! `benches/table*.rs` binary.

use std::time::{Duration, Instant};

/// Summary statistics over per-iteration wall-clock samples.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    pub std_dev: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        let pct = |p: f64| samples[(p * (n - 1) as f64).round() as usize];
        Self {
            iters: n,
            mean,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            min: samples[0],
            max: samples[n - 1],
            std_dev: Duration::from_secs_f64(var.sqrt()),
        }
    }

    /// Ops/sec given `ops` operations per iteration.
    pub fn throughput(&self, ops: f64) -> f64 {
        ops / self.mean.as_secs_f64()
    }
}

/// Benchmark runner: measures `f` with warmup and either a fixed iteration
/// count or a time budget, whichever the caller picks.
pub struct Bench {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 3,
            min_iters: 10,
            max_iters: 1000,
            budget: Duration::from_secs(2),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: 1,
            min_iters: 3,
            max_iters: 50,
            budget: Duration::from_millis(500),
        }
    }

    /// Honors SIKV_BENCH_FAST=1 to shrink budgets (CI / smoke runs).
    pub fn from_env() -> Self {
        if std::env::var("SIKV_BENCH_FAST").is_ok() {
            Self::quick()
        } else {
            Self::default()
        }
    }

    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < self.min_iters || start.elapsed() < self.budget)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        Stats::from_samples(samples)
    }
}

/// Prevents the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Per-stage wall-clock accumulator: names the phases of a pipeline
/// (score / select / attend) and reports each stage's mean over all the
/// iterations it was timed in. The per-stage rows of `table4_modules` and
/// the `BENCH_decode.json` trajectory come from this.
#[derive(Default)]
pub struct StageTimer {
    stages: Vec<(String, Duration, u64)>, // (name, total, count)
}

impl StageTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time one execution of `f` under `stage` (accumulates).
    pub fn time<T>(&mut self, stage: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(stage, t.elapsed());
        out
    }

    /// Accumulate an externally measured duration.
    pub fn add(&mut self, stage: &str, d: Duration) {
        if let Some(e) = self.stages.iter_mut().find(|(n, _, _)| n == stage) {
            e.1 += d;
            e.2 += 1;
        } else {
            self.stages.push((stage.to_string(), d, 1));
        }
    }

    /// Mean microseconds per timed call of `stage` (0.0 if never timed).
    pub fn mean_us(&self, stage: &str) -> f64 {
        self.stages
            .iter()
            .find(|(n, _, _)| n == stage)
            .map(|(_, total, count)| total.as_secs_f64() * 1e6 / *count as f64)
            .unwrap_or(0.0)
    }

    /// (name, mean) pairs in first-use order.
    pub fn means(&self) -> Vec<(String, Duration)> {
        self.stages
            .iter()
            .map(|(n, total, count)| (n.clone(), *total / (*count).max(1) as u32))
            .collect()
    }

    /// `{"stage_us": {name: mean_us, ...}}`-shaped JSON fragment.
    pub fn to_json(&self) -> crate::substrate::json::Json {
        use crate::substrate::json::{num, obj};
        obj(self
            .stages
            .iter()
            .map(|(n, _, _)| (n.as_str(), num(self.mean_us(n))))
            .collect())
    }
}

/// Write a machine-readable bench result next to the human-readable
/// table: `BENCH_<name>.json` in `SIKV_BENCH_OUT` (default: cwd). Every
/// bench that emits one gives future PRs a perf trajectory to compare
/// against. Returns the path written.
pub fn write_bench_json(
    name: &str,
    payload: crate::substrate::json::Json,
) -> std::io::Result<std::path::PathBuf> {
    let dir = std::env::var("SIKV_BENCH_OUT").unwrap_or_else(|_| ".".into());
    write_bench_json_in(std::path::Path::new(&dir), name, payload)
}

/// [`write_bench_json`] with an explicit directory (the env read happens
/// only in the wrapper — callers and tests stay free of process-global
/// state).
pub fn write_bench_json_in(
    dir: &std::path::Path,
    name: &str,
    payload: crate::substrate::json::Json,
) -> std::io::Result<std::path::PathBuf> {
    let path = dir.join(format!("BENCH_{name}.json"));
    std::fs::write(&path, format!("{payload}\n"))?;
    Ok(path)
}

pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

pub fn fmt_bytes(b: usize) -> String {
    if b < 1024 {
        format!("{b}B")
    } else if b < 1024 * 1024 {
        format!("{:.1}KiB", b as f64 / 1024.0)
    } else if b < 1024 * 1024 * 1024 {
        format!("{:.1}MiB", b as f64 / (1024.0 * 1024.0))
    } else {
        format!("{:.2}GiB", b as f64 / (1024.0 * 1024.0 * 1024.0))
    }
}

/// Aligned plain-text table (the benches print paper-shaped rows).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordered() {
        let samples: Vec<Duration> =
            (1..=100).map(|i| Duration::from_micros(i)).collect();
        let s = Stats::from_samples(samples);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99);
        assert!(s.p99 <= s.max);
        assert_eq!(s.iters, 100);
    }

    #[test]
    fn bench_runs_at_least_min_iters() {
        let b = Bench {
            warmup: 0,
            min_iters: 5,
            max_iters: 10,
            budget: Duration::ZERO,
        };
        let mut count = 0;
        let s = b.run(|| count += 1);
        assert!(s.iters >= 5);
        assert_eq!(count, s.iters);
    }

    #[test]
    fn throughput_math() {
        let s = Stats::from_samples(vec![Duration::from_millis(10); 4]);
        let tps = s.throughput(100.0);
        assert!((tps - 10_000.0).abs() < 1.0, "{tps}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "ms"]);
        t.row(vec!["ours".into(), "0.1".into()]);
        t.row(vec!["flashattention2".into(), "0.8".into()]);
        let out = t.render();
        assert!(out.contains("method"));
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn stage_timer_accumulates_means() {
        let mut st = StageTimer::new();
        for _ in 0..4 {
            st.add("score", Duration::from_micros(10));
        }
        st.add("select", Duration::from_micros(100));
        assert!((st.mean_us("score") - 10.0).abs() < 1e-6);
        assert!((st.mean_us("select") - 100.0).abs() < 1e-6);
        assert_eq!(st.mean_us("missing"), 0.0);
        let means = st.means();
        assert_eq!(means[0].0, "score"); // first-use order
        let j = st.to_json();
        assert!(j.get("score").and_then(|v| v.as_f64()).unwrap() > 9.0);
    }

    #[test]
    fn bench_json_round_trips() {
        use crate::substrate::json::{num, obj, s, Json};
        let dir = std::env::temp_dir().join("sikv_benchkit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let payload = obj(vec![
            ("bench", s("decode")),
            ("tokens_per_sec", num(1234.5)),
        ]);
        let path = write_bench_json_in(&dir, "unit_test", payload).unwrap();
        assert!(path.ends_with("BENCH_unit_test.json"));
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.path("tokens_per_sec").and_then(Json::as_f64), Some(1234.5));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert!(fmt_duration(Duration::from_micros(1500)).ends_with("ms"));
        assert_eq!(fmt_bytes(2048), "2.0KiB");
    }
}
