//! Typed configuration system: engine + model + selfindex knobs, loadable
//! from JSON (own parser) with full validation. Every paper setting is a
//! field with the paper's value as default; the CLI overlays overrides.

use crate::selfindex::SelfIndexConfig;
use crate::substrate::json::Json;

/// Model geometry (mirrors python/compile/config.py and the manifest).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
}

impl ModelConfig {
    pub fn gqa_ratio(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let u = |k: &str| {
            v.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("model.{k} missing/invalid"))
        };
        let cfg = Self {
            vocab_size: u("vocab_size")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            n_kv_heads: u("n_kv_heads")?,
            head_dim: u("head_dim")?,
            d_ff: u("d_ff")?,
            max_seq: u("max_seq")?,
            rope_theta: v
                .get("rope_theta")
                .and_then(Json::as_f64)
                .ok_or("model.rope_theta missing")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_heads % self.n_kv_heads != 0 {
            return Err(format!(
                "n_heads {} not divisible by n_kv_heads {}",
                self.n_heads, self.n_kv_heads
            ));
        }
        if self.head_dim % 8 != 0 {
            return Err(format!("head_dim {} must be divisible by 8", self.head_dim));
        }
        if self.vocab_size == 0 || self.n_layers == 0 {
            return Err("degenerate model".into());
        }
        Ok(())
    }
}

/// Tiered-storage policy: block-granular swap-to-host on preemption
/// (see `kvcache::tier`). Off by default — the plain drop-and-re-prefill
/// path stays the baseline behaviour.
#[derive(Clone, Debug, PartialEq)]
pub struct SwapConfig {
    /// master switch for the host tier
    pub enabled: bool,
    /// modelled cost of swapping one block out and back in (same
    /// arbitrary units as `recompute_cost`)
    pub swap_cost: f64,
    /// modelled cost of re-prefilling one prompt token
    pub recompute_cost: f64,
    /// host-tier sweeps (one per engine step) an entry rests before the
    /// cold sub-tier recompresses it (0 = cold tier off)
    pub cold_after_sweeps: u64,
    /// host bytes the tier may hold before LRU discard of cold entries
    /// kicks in (`HostTier::enforce_budget`; 0 = unbounded)
    pub max_host_bytes: usize,
}

impl Default for SwapConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            // one 64-token block swaps for the modelled price of 8
            // recomputed tokens: swapping wins for any full block, which
            // matches a host-memory copy being far cheaper than a
            // prefill forward pass
            swap_cost: 8.0,
            recompute_cost: 1.0,
            cold_after_sweeps: 0,
            max_host_bytes: 0,
        }
    }
}

impl SwapConfig {
    /// The resume-vs-recompute crossover: swap a preempted sequence out
    /// when restoring its `blocks` is modelled cheaper than
    /// re-prefilling its `prefill_tokens`-token prompt.
    pub fn favors_swap(&self, blocks: usize, prefill_tokens: usize) -> bool {
        self.enabled
            && (blocks as f64) * self.swap_cost
                < (prefill_tokens as f64) * self.recompute_cost
    }
}

/// Serving engine knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// max sequences per decode batch (must be an AOT bucket)
    pub max_batch: usize,
    /// dynamic sparsity: fraction of context retrieved per step
    /// (paper Fig. 4/5: 7.5%); fixed-k mode when `sparse_k` is Some
    pub sparsity: f64,
    pub sparse_k: Option<usize>,
    /// ENGINE-WIDE kv pool budget in tokens: one shared block pool backs
    /// every sequence, layer, and kv head (capacity_blocks =
    /// pool_tokens / block_tokens); admission and preemption run on its
    /// exact free-block accounting
    pub pool_tokens: usize,
    /// tokens per pool block (paged-allocation granularity; must be a
    /// multiple of 8 for the block scorer's unroll)
    pub block_tokens: usize,
    /// chunked-prefill slice size in tokens (0 = disabled: whole prompts
    /// prefill in one step). When set, the serving layer splits long
    /// prompts into slices of this many tokens and strictly alternates
    /// them with decode turns over the running set. Must be a multiple of
    /// `block_tokens` so every chunk boundary is a block boundary —
    /// prefix-block registration/adoption operates on whole blocks and
    /// the chunked ingest stays bit-identical to the one-shot path.
    pub prefill_chunk_tokens: usize,
    /// admission queue bound (backpressure)
    pub queue_limit: usize,
    /// max new tokens per request default
    pub max_new_tokens: usize,
    /// worker threads for the per-(sequence, kv-head) decode fan-out
    /// (0 = one per available core)
    pub decode_workers: usize,
    /// attention/cache method served, validated against the method
    /// registry (canonical name or alias, case-insensitive)
    pub method: String,
    /// per-method knob overlay `(knob, value)`, validated against the
    /// selected method's declared knobs (see `method::registry`)
    pub method_overlay: Vec<(String, Json)>,
    pub selfindex: SelfIndexConfig,
    /// fault-injection spec, e.g. `"pool.alloc=prob:0.05,worker.panic=nth:3"`
    /// (see `substrate::faults`); empty = consult `SIKV_FAULTS`, then
    /// disarmed. Production runs leave this empty: a disarmed injector
    /// costs one predicted branch per probe.
    pub faults: String,
    /// seed for probabilistic fault schedules (deterministic per seed)
    pub fault_seed: u64,
    /// evictions a request absorbs before aging kicks in: at `N` the
    /// scheduler pins it (never a victim again), past `2N` it fails with
    /// `Outcome::Thrashing` instead of re-stashing
    pub preempt_budget: u32,
    /// tiered-storage policy: swap preempted sequences' blocks to the
    /// host tier instead of dropping them (see `kvcache::tier`)
    pub swap: SwapConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            sparsity: 0.075,
            sparse_k: Some(96),
            pool_tokens: 1 << 20,
            block_tokens: 64,
            prefill_chunk_tokens: 0,
            queue_limit: 256,
            max_new_tokens: 32,
            decode_workers: 0,
            method: "selfindex".to_string(),
            method_overlay: vec![],
            selfindex: SelfIndexConfig::default(),
            faults: String::new(),
            fault_seed: 0,
            preempt_budget: 4,
            swap: SwapConfig::default(),
        }
    }
}

impl EngineConfig {
    /// Dynamic budget for a context of `len` tokens.
    pub fn budget_for(&self, len: usize) -> usize {
        match self.sparse_k {
            Some(k) => k,
            None => ((len as f64 * self.sparsity).ceil() as usize).max(1),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let mut cfg = Self::default();
        if let Some(x) = v.get("max_batch").and_then(Json::as_usize) {
            cfg.max_batch = x;
        }
        if let Some(x) = v.get("sparsity").and_then(Json::as_f64) {
            cfg.sparsity = x;
        }
        if let Some(x) = v.get("sparse_k") {
            cfg.sparse_k = x.as_usize();
        }
        if let Some(x) = v.get("pool_tokens").and_then(Json::as_usize) {
            cfg.pool_tokens = x;
        }
        if let Some(x) = v.get("block_tokens").and_then(Json::as_usize) {
            cfg.block_tokens = x;
        }
        if let Some(x) = v.get("prefill_chunk_tokens").and_then(Json::as_usize) {
            cfg.prefill_chunk_tokens = x;
        }
        if let Some(x) = v.get("queue_limit").and_then(Json::as_usize) {
            cfg.queue_limit = x;
        }
        if let Some(x) = v.get("max_new_tokens").and_then(Json::as_usize) {
            cfg.max_new_tokens = x;
        }
        if let Some(x) = v.get("decode_workers").and_then(Json::as_usize) {
            cfg.decode_workers = x;
        }
        if let Some(x) = v.get("method").and_then(Json::as_str) {
            // canonicalize through the registry so aliases and case
            // differences collapse to one name
            let entry = crate::method::lookup(x).map_err(|e| e.to_string())?;
            cfg.method = entry.name().to_string();
        }
        if let Some(x) = v.get("faults").and_then(Json::as_str) {
            cfg.faults = x.to_string();
        }
        if let Some(x) = v.get("fault_seed").and_then(Json::as_usize) {
            cfg.fault_seed = x as u64;
        }
        if let Some(x) = v.get("preempt_budget").and_then(Json::as_usize) {
            cfg.preempt_budget = x as u32;
        }
        if let Some(x) = v.path("swap.enabled").and_then(Json::as_bool) {
            cfg.swap.enabled = x;
        }
        if let Some(x) = v.path("swap.swap_cost").and_then(Json::as_f64) {
            cfg.swap.swap_cost = x;
        }
        if let Some(x) = v.path("swap.recompute_cost").and_then(Json::as_f64) {
            cfg.swap.recompute_cost = x;
        }
        if let Some(x) = v.path("swap.cold_after_sweeps").and_then(Json::as_usize) {
            cfg.swap.cold_after_sweeps = x as u64;
        }
        if let Some(x) = v.path("swap.max_host_bytes").and_then(Json::as_usize) {
            cfg.swap.max_host_bytes = x;
        }
        if let Some(x) = v.get("method_overlay") {
            let obj = x
                .as_obj()
                .ok_or_else(|| "method_overlay must be an object".to_string())?;
            cfg.method_overlay = obj
                .iter()
                .map(|(k, val)| (k.clone(), val.clone()))
                .collect();
        }
        let si = &mut cfg.selfindex;
        if let Some(x) = v.path("selfindex.sink_tokens").and_then(Json::as_usize) {
            si.sink_tokens = x;
        }
        if let Some(x) = v.path("selfindex.sparse_k").and_then(Json::as_usize) {
            si.sparse_k = x;
        }
        if let Some(x) = v.path("selfindex.quant_bits").and_then(Json::as_usize) {
            si.quant_bits = x as u32;
        }
        if let Some(x) = v.path("selfindex.use_sinks").and_then(Json::as_bool) {
            si.use_sinks = x;
        }
        if let Some(x) = v.path("selfindex.scorer").and_then(Json::as_str) {
            si.scorer = crate::selfindex::Scorer::parse(x).ok_or_else(|| {
                format!("selfindex.scorer '{x}' unknown (expects bytelut or popcnt)")
            })?;
        }
        if let Some(x) = v.path("selfindex.page_blocks").and_then(Json::as_usize) {
            si.page_blocks = x;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.sparsity) {
            return Err(format!("sparsity {} outside [0,1]", self.sparsity));
        }
        if self.max_batch == 0 {
            return Err("max_batch == 0".into());
        }
        if self.queue_limit == 0 {
            return Err("queue_limit == 0".into());
        }
        if self.block_tokens == 0 || self.block_tokens % 8 != 0 {
            return Err(format!(
                "block_tokens {} must be a positive multiple of 8",
                self.block_tokens
            ));
        }
        if self.pool_tokens < self.block_tokens {
            return Err(format!(
                "pool_tokens {} below one block ({})",
                self.pool_tokens, self.block_tokens
            ));
        }
        if self.prefill_chunk_tokens % self.block_tokens != 0 {
            return Err(format!(
                "prefill_chunk_tokens {} must be a multiple of block_tokens {} \
                 (chunk boundaries must be block boundaries for prefix \
                 registration and bit-exact chunked ingest)",
                self.prefill_chunk_tokens, self.block_tokens
            ));
        }
        if self.preempt_budget == 0 {
            return Err("preempt_budget must be >= 1 (0 would fail every \
                        first eviction as thrashing)"
                .into());
        }
        if !(self.swap.swap_cost.is_finite() && self.swap.swap_cost > 0.0) {
            return Err(format!(
                "swap.swap_cost {} must be positive and finite",
                self.swap.swap_cost
            ));
        }
        if !(self.swap.recompute_cost.is_finite() && self.swap.recompute_cost > 0.0) {
            return Err(format!(
                "swap.recompute_cost {} must be positive and finite",
                self.swap.recompute_cost
            ));
        }
        if !self.faults.is_empty() {
            crate::substrate::faults::FaultInjector::parse(&self.faults, self.fault_seed)
                .map_err(|e| format!("faults: {e}"))?;
        }
        crate::method::registry::validate_overlay(&self.method, &self.method_overlay)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_from_json() {
        let j = Json::parse(
            r#"{"vocab_size":256,"d_model":256,"n_layers":4,"n_heads":4,
                "n_kv_heads":2,"head_dim":64,"d_ff":512,"max_seq":8192,
                "rope_theta":10000.0}"#,
        )
        .unwrap();
        let m = ModelConfig::from_json(&j).unwrap();
        assert_eq!(m.gqa_ratio(), 2);
        assert_eq!(m.head_dim, 64);
    }

    #[test]
    fn model_validation_catches_bad_gqa() {
        let j = Json::parse(
            r#"{"vocab_size":256,"d_model":256,"n_layers":4,"n_heads":5,
                "n_kv_heads":2,"head_dim":64,"d_ff":512,"max_seq":8192,
                "rope_theta":10000.0}"#,
        )
        .unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }

    #[test]
    fn engine_defaults_are_paper_settings() {
        let e = EngineConfig::default();
        assert_eq!(e.budget_for(10_000), 96);
        assert!((e.sparsity - 0.075).abs() < 1e-9);
        assert_eq!(e.selfindex.sink_tokens, 64);
    }

    #[test]
    fn ratio_mode_budget() {
        let mut e = EngineConfig::default();
        e.sparse_k = None;
        assert_eq!(e.budget_for(1000), 75);
        assert_eq!(e.budget_for(4), 1);
    }

    #[test]
    fn engine_overlay_from_json() {
        let j = Json::parse(
            r#"{"max_batch":4,"sparsity":0.1,"sparse_k":null,
                "selfindex":{"sink_tokens":32,"use_sinks":false}}"#,
        )
        .unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.max_batch, 4);
        assert_eq!(e.sparse_k, None);
        assert_eq!(e.selfindex.sink_tokens, 32);
        assert!(!e.selfindex.use_sinks);
    }

    #[test]
    fn selfindex_scorer_parses_and_rejects_unknown() {
        use crate::selfindex::Scorer;
        let j = Json::parse(r#"{"selfindex":{"scorer":"popcnt"}}"#).unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.selfindex.scorer, Scorer::Popcnt);
        let j = Json::parse(r#"{"selfindex":{"scorer":"bytelut"}}"#).unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.selfindex.scorer, Scorer::ByteLut);
        assert_eq!(EngineConfig::default().selfindex.scorer, Scorer::ByteLut);
        let j = Json::parse(r#"{"selfindex":{"scorer":"gemv"}}"#).unwrap();
        let err = EngineConfig::from_json(&j).unwrap_err();
        assert!(err.contains("selfindex.scorer 'gemv'"), "{err}");
    }

    #[test]
    fn selfindex_page_blocks_parses_and_defaults_on() {
        assert_eq!(
            EngineConfig::default().selfindex.page_blocks,
            64,
            "hierarchical page tier on by default"
        );
        let j = Json::parse(r#"{"selfindex":{"page_blocks":0}}"#).unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.selfindex.page_blocks, 0, "0 = flat sweep");
        let j = Json::parse(r#"{"selfindex":{"page_blocks":32}}"#).unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.selfindex.page_blocks, 32);
    }

    #[test]
    fn block_tokens_is_validated() {
        let j = Json::parse(r#"{"block_tokens":60}"#).unwrap();
        let err = EngineConfig::from_json(&j).unwrap_err();
        assert!(err.contains("multiple of 8"), "{err}");
        let j = Json::parse(r#"{"block_tokens":32,"pool_tokens":16}"#).unwrap();
        let err = EngineConfig::from_json(&j).unwrap_err();
        assert!(err.contains("below one block"), "{err}");
        let j = Json::parse(r#"{"block_tokens":32,"pool_tokens":4096}"#).unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.block_tokens, 32);
        assert_eq!(e.pool_tokens, 4096);
    }

    #[test]
    fn prefill_chunk_tokens_is_validated() {
        assert_eq!(EngineConfig::default().prefill_chunk_tokens, 0, "off by default");
        let j = Json::parse(r#"{"prefill_chunk_tokens":96}"#).unwrap();
        let err = EngineConfig::from_json(&j).unwrap_err();
        assert!(err.contains("multiple of block_tokens"), "{err}");
        let j = Json::parse(r#"{"prefill_chunk_tokens":256}"#).unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.prefill_chunk_tokens, 256);
        let j = Json::parse(r#"{"block_tokens":32,"prefill_chunk_tokens":96}"#).unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.prefill_chunk_tokens, 96, "multiple of a non-default block");
    }

    #[test]
    fn method_string_is_validated_and_canonicalized() {
        let j = Json::parse(r#"{"method":"OURS"}"#).unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.method, "selfindex", "alias canonicalized");

        let j = Json::parse(r#"{"method":"h2o"}"#).unwrap();
        let err = EngineConfig::from_json(&j).unwrap_err();
        assert!(err.contains("unknown method 'h2o'"), "{err}");
        assert!(err.contains("selfindex"), "error must list known: {err}");
    }

    #[test]
    fn fault_and_budget_knobs_roundtrip_and_validate() {
        let e = EngineConfig::default();
        assert!(e.faults.is_empty(), "production default is disarmed");
        assert_eq!(e.preempt_budget, 4);

        let j = Json::parse(
            r#"{"faults":"pool.alloc=nth:3,worker.panic=prob:0.5",
                "fault_seed":7,"preempt_budget":2}"#,
        )
        .unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.faults, "pool.alloc=nth:3,worker.panic=prob:0.5");
        assert_eq!(e.fault_seed, 7);
        assert_eq!(e.preempt_budget, 2);

        let j = Json::parse(r#"{"faults":"pool.alloc=sometimes"}"#).unwrap();
        let err = EngineConfig::from_json(&j).unwrap_err();
        assert!(err.starts_with("faults:"), "{err}");

        let j = Json::parse(r#"{"preempt_budget":0}"#).unwrap();
        let err = EngineConfig::from_json(&j).unwrap_err();
        assert!(err.contains("preempt_budget"), "{err}");
    }

    #[test]
    fn swap_knobs_roundtrip_validate_and_model_the_crossover() {
        let e = EngineConfig::default();
        assert!(!e.swap.enabled, "swap is off by default");
        assert!(!e.swap.favors_swap(1, 10_000), "disabled policy never swaps");

        assert_eq!(e.swap.max_host_bytes, 0, "host tier unbounded by default");

        let j = Json::parse(
            r#"{"swap":{"enabled":true,"swap_cost":16.0,
                "recompute_cost":2.0,"cold_after_sweeps":3,
                "max_host_bytes":65536}}"#,
        )
        .unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert!(e.swap.enabled);
        assert_eq!(e.swap.swap_cost, 16.0);
        assert_eq!(e.swap.recompute_cost, 2.0);
        assert_eq!(e.swap.cold_after_sweeps, 3);
        assert_eq!(e.swap.max_host_bytes, 65536);
        // crossover: blocks*swap_cost vs tokens*recompute_cost
        assert!(e.swap.favors_swap(2, 17), "2*16 < 17*2");
        assert!(!e.swap.favors_swap(2, 16), "2*16 == 16*2: tie goes to recompute");
        assert!(!e.swap.favors_swap(64, 64), "short prompts recompute");

        let j = Json::parse(r#"{"swap":{"swap_cost":0.0}}"#).unwrap();
        let err = EngineConfig::from_json(&j).unwrap_err();
        assert!(err.contains("swap.swap_cost"), "{err}");
        let j = Json::parse(r#"{"swap":{"recompute_cost":-1.0}}"#).unwrap();
        let err = EngineConfig::from_json(&j).unwrap_err();
        assert!(err.contains("swap.recompute_cost"), "{err}");
    }

    #[test]
    fn method_overlay_is_validated_against_knobs() {
        let j = Json::parse(r#"{"method":"kivi","method_overlay":{"bits":4}}"#).unwrap();
        let e = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e.method, "kivi");
        assert_eq!(e.method_overlay.len(), 1);

        let j = Json::parse(r#"{"method":"kivi","method_overlay":{"pages":4}}"#).unwrap();
        let err = EngineConfig::from_json(&j).unwrap_err();
        assert!(err.contains("no knob 'pages'"), "{err}");
    }
}
