//! The serving coordinator (vLLM-router-like L3 layer).
//!
//! * [`request`]   — request/response types + lifecycle states.
//! * [`router`]    — admission control: bounded FIFO queue, rejection
//!   under backpressure, queue metrics.
//! * [`scheduler`] — step planning: continuous batching of decodes,
//!   prefill interleaving, pool-pressure awareness.
//! * [`engine`]    — the closed-batch serving loop: PJRT prefill →
//!   per-head compressed caches → per-step LUT-GEMV retrieval + sparse
//!   attention → PJRT decode projections → greedy sampling. Python never
//!   runs here.
//! * [`serving`]   — the continuous-batching front-end: async-style
//!   submission with per-request token streams, chunked prefill
//!   interleaved with decode turns, wall-clock SLOs, and the PJRT-free
//!   [`NativeExecutor`] backend for tests/benches/CI.

pub mod engine;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod serving;

pub use engine::{Engine, MethodKind};
pub use request::{Outcome, Request, RequestId, RequestResult, RequestState};
pub use router::Router;
pub use scheduler::{PoolPressure, Scheduler, StepPlan};
pub use serving::{
    DecodeOutcome, NativeExecutor, SeqExecutor, ServingEngine, StreamEvent, SubmitHandle,
};
