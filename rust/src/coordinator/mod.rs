//! The serving coordinator (vLLM-router-like L3 layer).
//!
//! * [`request`]   — request/response types + lifecycle states.
//! * [`router`]    — admission control: bounded FIFO queue, rejection
//!   under backpressure, queue metrics.
//! * [`scheduler`] — step planning: continuous batching of decodes,
//!   prefill interleaving, pool-pressure awareness.
//! * [`engine`]    — the serving loop: PJRT prefill → per-head compressed
//!   caches → per-step LUT-GEMV retrieval + sparse attention → PJRT
//!   decode projections → greedy sampling. Python never runs here.

pub mod engine;
pub mod request;
pub mod router;
pub mod scheduler;

pub use engine::{Engine, MethodKind};
pub use request::{Outcome, Request, RequestId, RequestResult, RequestState};
pub use router::Router;
pub use scheduler::{PoolPressure, Scheduler, StepPlan};
