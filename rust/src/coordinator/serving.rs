//! Continuous-batching serving front-end (DESIGN.md §Serving).
//!
//! [`ServingEngine`] wraps the coordinator's admission/scheduling stack
//! (router → scheduler → shared-pool accounting) behind an async-style
//! API: [`ServingEngine::submit`] returns a [`SubmitHandle`] immediately
//! and tokens stream to it as they are produced, while the caller (or a
//! driver loop) pumps [`ServingEngine::step`].
//!
//! Chunked prefill: with `EngineConfig::prefill_chunk_tokens > 0`, a long
//! prompt is ingested `chunk` tokens at a time, and the scheduler
//! alternates each chunk with a decode turn for the running batch
//! ([`StepPlan::PrefillChunk`]) — a 100K-token arrival can no longer
//! stall every in-flight decode for the whole prefill. Chunk boundaries
//! are block boundaries (validated in `EngineConfig::validate`), and
//! chunk 0 freezes per-head stats/codebooks over the FULL prompt
//! (`HeadCache::ingest_prefill_range`), so the chunked cache is
//! bit-identical to a one-shot prefill — served output equals closed
//! batch output by construction.
//!
//! Deadlines are wall-clock SLOs ([`ServingEngine::submit_with_deadline`]
//! stamps `now + slo`), checked at every step boundary AND at admission,
//! so an already-expired request never burns a long prefill. Tests pin
//! time with [`ServingEngine::with_virtual_clock`] (the clock advances a
//! fixed tick per step), keeping deadline scenarios deterministic —
//! submission stamps, TTFT, and end-to-end latency all read the same
//! clock, so a virtual-clock replay is a pure function of the schedule.
//!
//! Tiered KV storage (DESIGN.md §Tiered storage): with
//! `EngineConfig::swap` enabled, a preemption victim whose re-prefill
//! would cost more than a host round-trip (`blocks × swap_cost <
//! prompt_tokens × recompute_cost`) is swapped out instead of dropped —
//! its block payloads move to the [`crate::kvcache::HostTier`] and the
//! whole sequence state (generated tokens, frozen stats, codebooks)
//! stays live, so resume is a checksum-verified block restore rather
//! than a re-prefill + re-decode. A corrupt or faulted host copy is
//! detected at re-admission and falls back to bit-identical
//! recomputation (`engine.swap_fallbacks`); the stream's high-water
//! mark keeps re-produced tokens duplicate-free either way.
//!
//! The engine is generic over a [`SeqExecutor`] — the thing that actually
//! builds per-sequence caches and runs attention. [`NativeExecutor`]
//! runs the full self-indexing stack (shared [`KvManager`] pool, prefix
//! reuse, fault injection, [`HeadTask::run_isolated`] panic containment)
//! on synthetic deterministic K/V derived from prompt *content*, so the
//! complete serving lifecycle — preemption, thrashing, worker panics,
//! SLO expiry, chunked prefill — is exercised in tests, benches, and CI
//! without PJRT artifacts. The PJRT [`super::Engine`] keeps its own
//! closed-batch loop; both sit on the same router/scheduler/pool layers.

use crate::substrate::error as anyhow;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::request::{Outcome, Request, RequestId, RequestResult};
use super::router::{AdmitError, Router};
use super::scheduler::{PoolPressure, Scheduler, StepPlan};
use crate::baselines::{AttentionMethod, SelfIndexing};
use crate::config::EngineConfig;
use crate::kvcache::manager::KvManager;
use crate::kvcache::{tier, BlockId};
use crate::method::HeadTask;
use crate::selfindex::SelfIndexConfig;
use crate::substrate::faults::FaultInjector;
use crate::substrate::metrics::Registry;
use crate::substrate::rng::Rng;

/// What a decode step produced for one sequence.
pub struct DecodeOutcome {
    /// greedy-sampled token (meaningless when `failed`)
    pub token: u8,
    /// mid-step pool exhaustion: the engine preempts the sequence and the
    /// partial step is discarded (recomputation is bit-identical)
    pub failed: bool,
    /// a worker panicked on this sequence: its state is suspect, the
    /// engine fails the request with [`Outcome::WorkerPanic`]
    pub panicked: bool,
}

/// The compute + cache backend a [`ServingEngine`] drives. One instance
/// serves every sequence; per-sequence state lives in `Self::Seq`
/// (dropping a `Seq` must release every pool block it holds).
pub trait SeqExecutor {
    /// per-sequence cache state (layer × kv-head leaves)
    type Seq;

    /// Exact shared-pool blocks needed to admit a `prompt_len` prompt.
    fn admit_blocks(&self, prompt_len: usize) -> usize;
    /// Blocks this sequence will allocate on its next decode step.
    fn step_blocks(&self, seq: &Self::Seq) -> usize;
    /// Current free blocks in the shared pool.
    fn free_blocks(&self) -> usize;
    /// Total blocks in the shared pool.
    fn capacity_blocks(&self) -> usize;
    /// Longest admissible prompt (the router rejects beyond this).
    fn max_prompt(&self) -> usize;

    /// Ingest prompt tokens `[start, end)`. Builds `*seq` when
    /// `start == 0`; returns `Some(first_token)` once the final chunk
    /// lands (`end == prompt len`), `None` mid-prompt. Pool exhaustion
    /// must PANIC (the engine contains it and charges an eviction against
    /// the request's preemption budget); `Err` means an engine-side
    /// invariant broke — the request fails with [`Outcome::Failed`] and
    /// the engine keeps serving.
    fn prefill_chunk(
        &mut self,
        seq: &mut Option<Self::Seq>,
        req: &Request,
        start: usize,
        end: usize,
    ) -> anyhow::Result<Option<u8>>;

    /// One decode step for one sequence (`step` = tokens generated so
    /// far, first prefill token included).
    fn decode_step(&mut self, req: &Request, seq: &mut Self::Seq, step: usize) -> DecodeOutcome;

    /// Terminal hook: the request left the engine with `outcome`
    /// (`seq` is `None` when it never finished a prefill). Dropping the
    /// seq releases its pool blocks; implementations may capture final
    /// state first (e.g. [`NativeExecutor`] keeps the last attention
    /// output as a bit-exactness witness).
    fn retire(&mut self, _req: &Request, _seq: Option<Self::Seq>, _outcome: Outcome) {}

    // --- tiered KV storage hooks (DESIGN.md §Tiered storage) ---
    // Default implementations make swap unsupported: the engine then
    // behaves exactly as before (`swap_eligible` never set, evictions
    // drop + re-prefill). Executors with a `HostTier` override them all.

    /// Device pool blocks this sequence currently holds (the `blocks`
    /// side of the swap-vs-recompute cost model).
    fn held_blocks(&self, _seq: &Self::Seq) -> usize {
        0
    }

    /// Copy `seq`'s device blocks to the host tier under `key` and
    /// release the device copies; returns the block count. `None` means
    /// unsupported or the `swap.out` fault fired *before* anything was
    /// copied (device state untouched) — the engine falls back to the
    /// plain drop + re-prefill eviction.
    fn swap_out(&mut self, _key: RequestId, _seq: &mut Self::Seq) -> Option<usize> {
        None
    }

    /// Device blocks needed to swap `key` back in (its host-tier entry
    /// size) — the admission cost of a resume.
    fn swapped_blocks(&self, _key: RequestId) -> usize {
        0
    }

    /// Restore `key`'s blocks from the host tier into `seq`, verifying
    /// per-block checksums at re-admission.
    fn swap_in(&mut self, _key: RequestId, _seq: &mut Self::Seq) -> SeqSwapIn {
        SeqSwapIn::Failed
    }

    /// Drop `key`'s host-tier entry (the request went terminal while
    /// swapped out, or the engine gave up on the host copy).
    fn swap_discard(&mut self, _key: RequestId) {}

    /// Age the host tier by one sweep, recompressing entries idle for
    /// `cold_after` sweeps (PackKV-style cold sub-tier); returns how
    /// many blocks went cold this sweep.
    fn tier_sweep(&mut self, _cold_after: u64) -> usize {
        0
    }

    /// `(host_blocks, host_bytes, cold_bytes)` snapshot for the
    /// `tier.*` gauges.
    fn tier_stats(&self) -> (usize, usize, usize) {
        (0, 0, 0)
    }

    /// Bound the host tier to `swap.max_host_bytes` by LRU-discarding
    /// cold entries (`HostTier::enforce_budget`); returns how many
    /// entries were evicted (`tier.host_evictions`).
    fn tier_enforce_budget(&mut self, _max_bytes: usize) -> usize {
        0
    }
}

/// Outcome of a [`SeqExecutor::swap_in`] restore attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqSwapIn {
    /// blocks restored bit-exactly; the sequence can rejoin the batch
    Restored,
    /// the device pool cannot host the entry right now; the host copy is
    /// kept parked for a later retry
    NoCapacity,
    /// the host copy is gone (swap-in fault) or failed its checksum at
    /// re-admission — the engine must fall back to re-prefill
    Failed,
}

/// One streamed event on a [`SubmitHandle`]'s channel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamEvent {
    /// the next generated token (tokens already streamed are never
    /// re-sent, even across preemption + bit-identical recomputation)
    Token(u8),
    /// terminal: how the request's lifecycle ended
    Done(Outcome),
}

/// Returned by [`ServingEngine::submit`]: the assigned id plus the
/// receiving end of the request's token stream. Dropping the handle is
/// fine — the engine ignores send failures and the full result is still
/// available via [`ServingEngine::take_results`].
pub struct SubmitHandle {
    pub id: RequestId,
    pub tokens: Receiver<StreamEvent>,
}

/// A running (post-prefill) sequence.
struct Active<S> {
    req: Request,
    seq: S,
    generated: Vec<u8>,
    first_token_at: Option<Instant>,
    decode_steps: usize,
}

/// The one mid-flight chunked prefill (at most one at a time: chunk 0
/// freezes stats over the full prompt, so chunks of one request must land
/// in order, and serial chunks keep admission accounting exact).
struct Inflight<S> {
    req: Request,
    seq: Option<S>,
    /// prompt tokens ingested so far
    done: usize,
}

/// Continuous-batching serving loop over a [`SeqExecutor`]. See the
/// module docs for the full policy.
pub struct ServingEngine<X: SeqExecutor> {
    exec: X,
    pub cfg: EngineConfig,
    pub metrics: Registry,
    router: Router,
    scheduler: Scheduler,
    seqs: HashMap<RequestId, Active<X::Seq>>,
    /// preempted requests awaiting recomputation, FIFO, ahead of the queue
    stash: VecDeque<Request>,
    /// swapped-out sequences awaiting re-admission, FIFO, ahead of both
    /// the stash and the queue: their whole state (generated tokens,
    /// frozen stats, codebooks) stays live — only the block payloads sit
    /// in the host tier — so resume is a block restore, not a re-prefill
    swapped: VecDeque<Active<X::Seq>>,
    inflight: Option<Inflight<X::Seq>>,
    /// true iff the previous executed plan was a prefill chunk — the
    /// scheduler uses it to hand the running batch a decode turn between
    /// chunks (the interleave that bounds decode stalls to one chunk)
    chunk_last: bool,
    /// per-request token sinks: (sender, tokens streamed so far)
    sinks: HashMap<RequestId, (Sender<StreamEvent>, usize)>,
    done: Vec<RequestResult>,
    step_idx: u64,
    /// virtual clock support: `now()` = `origin + tick × step_idx` when a
    /// tick is pinned, else the real `Instant::now()`
    origin: Instant,
    tick: Option<Duration>,
}

impl<X: SeqExecutor> ServingEngine<X> {
    pub fn new(cfg: EngineConfig, exec: X) -> anyhow::Result<Self> {
        cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        let metrics = Registry::default();
        let max_prompt = exec.max_prompt();
        Ok(Self {
            router: Router::new(cfg.queue_limit, max_prompt, metrics.clone()),
            scheduler: Scheduler::new(cfg.max_batch),
            seqs: HashMap::new(),
            stash: VecDeque::new(),
            swapped: VecDeque::new(),
            inflight: None,
            chunk_last: false,
            sinks: HashMap::new(),
            done: vec![],
            step_idx: 0,
            origin: Instant::now(),
            tick: None,
            exec,
            cfg,
            metrics,
        })
    }

    /// Pin the SLO clock to `tick` per step: deadlines become a pure
    /// function of step count, making expiry scenarios deterministic
    /// under test regardless of host speed.
    pub fn with_virtual_clock(mut self, tick: Duration) -> Self {
        self.tick = Some(tick);
        self
    }

    /// The engine's notion of "now" for SLO accounting.
    fn now(&self) -> Instant {
        match self.tick {
            Some(t) => self.origin + t * (self.step_idx as u32),
            None => Instant::now(),
        }
    }

    pub fn submit(&mut self, prompt: Vec<u8>, max_new: usize) -> Result<SubmitHandle, AdmitError> {
        self.submit_opt(prompt, max_new, None)
    }

    /// [`Self::submit`] with a wall-clock SLO: the request expires `slo`
    /// after submission, completing with whatever it generated by then as
    /// [`Outcome::DeadlineExceeded`] (empty output if it never ran —
    /// expiry is checked at admission too, so a dead-on-arrival request
    /// skips its prefill entirely).
    pub fn submit_with_deadline(
        &mut self,
        prompt: Vec<u8>,
        max_new: usize,
        slo: Duration,
    ) -> Result<SubmitHandle, AdmitError> {
        self.submit_opt(prompt, max_new, Some(slo))
    }

    fn submit_opt(
        &mut self,
        prompt: Vec<u8>,
        max_new: usize,
        slo: Option<Duration>,
    ) -> Result<SubmitHandle, AdmitError> {
        let now = self.now();
        let deadline = slo.map(|s| now + s);
        // stamp submission off the engine clock: under a virtual clock,
        // TTFT and latency become pure functions of the step schedule
        let id = self.router.submit_at(prompt, max_new, deadline, now)?;
        let (tx, rx) = channel();
        self.sinks.insert(id, (tx, 0));
        Ok(SubmitHandle { id, tokens: rx })
    }

    /// No queued, stashed, swapped, in-flight, or running work remains.
    pub fn is_drained(&self) -> bool {
        self.router.is_empty()
            && self.seqs.is_empty()
            && self.stash.is_empty()
            && self.swapped.is_empty()
            && self.inflight.is_none()
    }

    pub fn running(&self) -> usize {
        self.scheduler.running().len()
    }

    pub fn step_index(&self) -> u64 {
        self.step_idx
    }

    pub fn executor(&self) -> &X {
        &self.exec
    }

    pub fn executor_mut(&mut self) -> &mut X {
        &mut self.exec
    }

    /// Results accumulated since the last call (requests finish inside
    /// [`Self::step`]; this drains them).
    pub fn take_results(&mut self) -> Vec<RequestResult> {
        std::mem::take(&mut self.done)
    }

    /// Stream any not-yet-sent tokens of `generated` to the request's
    /// sink. The per-request high-water mark survives preemption: greedy
    /// decode recomputes bit-identically, so re-produced tokens are
    /// skipped rather than duplicated.
    fn stream_new_tokens(&mut self, id: RequestId, generated: &[u8]) {
        if let Some((tx, sent)) = self.sinks.get_mut(&id) {
            while *sent < generated.len() {
                let _ = tx.send(StreamEvent::Token(generated[*sent]));
                *sent += 1;
            }
        }
    }

    /// Terminal path for a sequence that ran (possibly partially):
    /// stream the tail + `Done`, record TTFT/TPOT, hand the seq to the
    /// executor's retire hook (dropping it releases the pool blocks).
    fn finish(&mut self, st: Active<X::Seq>, outcome: Outcome) {
        let Active { req, seq, generated, first_token_at, decode_steps } = st;
        self.stream_new_tokens(req.id, &generated);
        if let Some((tx, _)) = self.sinks.remove(&req.id) {
            let _ = tx.send(StreamEvent::Done(outcome));
        }
        let ttft = first_token_at
            .map(|t| t.saturating_duration_since(req.submitted_at))
            .unwrap_or_default();
        let latency = self.now().saturating_duration_since(req.submitted_at);
        self.metrics.histogram("serving.ttft").observe(ttft);
        if decode_steps > 1 {
            // time-per-output-token over the decode phase (excludes prefill)
            let tpot = latency.saturating_sub(ttft) / (decode_steps - 1) as u32;
            self.metrics.histogram("serving.tpot").observe(tpot);
        }
        let res = RequestResult {
            id: req.id,
            prompt_len: req.prompt.len(),
            ttft,
            latency,
            decode_steps,
            generated,
            outcome,
        };
        self.exec.retire(&req, Some(seq), outcome);
        self.done.push(res);
    }

    /// Terminal path for a request that never finished a prefill.
    fn never_ran(&mut self, req: Request, outcome: Outcome) {
        if let Some((tx, _)) = self.sinks.remove(&req.id) {
            let _ = tx.send(StreamEvent::Done(outcome));
        }
        let res = RequestResult {
            id: req.id,
            generated: vec![],
            prompt_len: req.prompt.len(),
            ttft: Duration::default(),
            latency: self.now().saturating_duration_since(req.submitted_at),
            decode_steps: 0,
            outcome,
        };
        self.exec.retire(&req, None, outcome);
        self.done.push(res);
    }

    /// Expire every request whose wall-clock deadline is at or before
    /// `now`: running sequences finish with partial output, the in-flight
    /// prefill is abandoned (its partial cache drops, releasing blocks),
    /// stashed/queued requests finish empty.
    fn expire_deadlines(&mut self, now: Instant) {
        let mut n = 0u64;
        let mut expired_running: Vec<RequestId> = self
            .seqs
            .iter()
            .filter(|(_, st)| st.req.deadline.is_some_and(|d| now >= d))
            .map(|(&id, _)| id)
            .collect();
        expired_running.sort_unstable(); // map order is not deterministic
        for id in expired_running {
            let st = self.seqs.remove(&id).unwrap();
            self.scheduler.remove(id);
            self.finish(st, Outcome::DeadlineExceeded);
            n += 1;
        }
        if self
            .inflight
            .as_ref()
            .is_some_and(|fl| fl.req.deadline.is_some_and(|d| now >= d))
        {
            let Inflight { req, seq, .. } = self.inflight.take().unwrap();
            drop(seq); // partial cache → blocks back to the pool
            self.chunk_last = false;
            self.never_ran(req, Outcome::DeadlineExceeded);
            n += 1;
        }
        let mut kept = VecDeque::with_capacity(self.stash.len());
        for r in std::mem::take(&mut self.stash) {
            if r.deadline.is_some_and(|d| now >= d) {
                self.never_ran(r, Outcome::DeadlineExceeded);
                n += 1;
            } else {
                kept.push_back(r);
            }
        }
        self.stash = kept;
        let mut kept_swapped = VecDeque::with_capacity(self.swapped.len());
        for st in std::mem::take(&mut self.swapped) {
            if st.req.deadline.is_some_and(|d| now >= d) {
                // the host copy is dead weight once the request expires
                self.exec.swap_discard(st.req.id);
                self.finish(st, Outcome::DeadlineExceeded);
                n += 1;
            } else {
                kept_swapped.push_back(st);
            }
        }
        self.swapped = kept_swapped;
        for r in self.router.expire_before(now) {
            self.never_ran(r, Outcome::DeadlineExceeded);
            n += 1;
        }
        if n > 0 {
            self.metrics.counter("engine.deadline_expired").add(n);
        }
    }

    /// Blocks the running set will allocate on its next decode step.
    fn step_blocks(&self) -> usize {
        self.scheduler
            .running()
            .iter()
            .map(|id| self.exec.step_blocks(&self.seqs[id].seq))
            .sum()
    }

    /// Drive one scheduler step; returns the plan that was executed (the
    /// interleave tests assert on the plan sequence). Finished requests
    /// accumulate in [`Self::take_results`] and stream to their handles.
    pub fn step(&mut self) -> anyhow::Result<StepPlan> {
        self.step_idx += 1;
        let now = self.now();
        self.expire_deadlines(now);
        // re-admission of a swapped sequence comes ahead of the stash and
        // the queue (it blocks nothing behind it for long: a resume is a
        // block restore, not a prefill)
        let candidate = if let Some(st) = self.swapped.front() {
            Some(self.exec.swapped_blocks(st.req.id))
        } else {
            self.stash
                .front()
                .map(|r| r.prompt.len())
                .or_else(|| self.router.peek().map(|r| r.prompt.len()))
                .map(|len| self.exec.admit_blocks(len))
        };
        // swap policy verdict for the victim `plan` would pick: swap
        // pays when moving the blocks costs less than re-prefilling
        let swap_eligible = self.cfg.swap.enabled
            && self.scheduler.victim_candidate().is_some_and(|id| {
                let st = &self.seqs[&id];
                self.cfg
                    .swap
                    .favors_swap(self.exec.held_blocks(&st.seq), st.req.prompt.len())
            });
        let pressure = PoolPressure {
            free_blocks: self.exec.free_blocks(),
            // no new admissions while a chunked prefill is mid-flight
            admit_blocks: if self.inflight.is_some() { None } else { candidate },
            step_blocks: self.step_blocks(),
            inflight_prefill: self.inflight.is_some(),
            chunk_last: self.chunk_last,
            swap_eligible,
        };
        let plan = self.scheduler.plan(&pressure);
        match &plan {
            StepPlan::Prefill => self.start_prefill(now)?,
            StepPlan::PrefillChunk => self.continue_prefill()?,
            StepPlan::Preempt(id) => self.preempt(*id)?,
            StepPlan::SwapOut(id) => self.swap_out(*id)?,
            StepPlan::Shed(id) => {
                // every running sequence is pinned and the step cannot
                // fit: fail the youngest structurally, never livelock
                let id = *id;
                let st = self.seqs.remove(&id).ok_or_else(|| {
                    anyhow::Error::coded("state_drift", format!("shed of unknown sequence {id}"))
                })?;
                self.scheduler.remove(id);
                self.metrics.counter("engine.request_failures").inc();
                self.finish(st, Outcome::Thrashing);
            }
            StepPlan::Decode(ids) => {
                let ids = ids.clone();
                self.do_decode(&ids)?;
            }
            StepPlan::Idle => {}
        }
        if self.cfg.swap.enabled {
            if self.cfg.swap.cold_after_sweeps > 0 {
                self.exec.tier_sweep(self.cfg.swap.cold_after_sweeps);
            }
            if self.cfg.swap.max_host_bytes > 0 {
                // bound the host tier; an evicted entry's later swap-in
                // reports Failed and the request re-prefills (the
                // already-hardened fallback path)
                let evicted = self.exec.tier_enforce_budget(self.cfg.swap.max_host_bytes);
                if evicted > 0 {
                    self.metrics.counter("tier.host_evictions").add(evicted as u64);
                }
            }
            let (host_blocks, host_bytes, cold_bytes) = self.exec.tier_stats();
            self.metrics.gauge("tier.host_blocks").set(host_blocks as i64);
            self.metrics.gauge("tier.host_bytes").set(host_bytes as i64);
            self.metrics.gauge("tier.cold_bytes").set(cold_bytes as i64);
        }
        Ok(plan)
    }

    /// Pump [`Self::step`] until drained; returns every accumulated result.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<RequestResult>> {
        while !self.is_drained() {
            self.step()?;
        }
        Ok(self.take_results())
    }

    /// Admit the next request (swapped first, then stash, FIFO) and run
    /// its first prefill chunk. The admission-time deadline check lives
    /// here: an expired request finishes empty instead of burning a
    /// prefill.
    fn start_prefill(&mut self, now: Instant) -> anyhow::Result<()> {
        if !self.swapped.is_empty() {
            return self.resume_swapped(now);
        }
        let from_stash = !self.stash.is_empty();
        let req = self
            .stash
            .pop_front()
            .or_else(|| self.router.pop())
            .ok_or_else(|| anyhow::Error::coded("state_drift", "plan admitted an empty queue"))?;
        if from_stash {
            self.metrics.counter("engine.retries").inc();
        }
        if req.deadline.is_some_and(|d| now >= d) {
            self.metrics.counter("engine.deadline_expired").inc();
            self.never_ran(req, Outcome::DeadlineExceeded);
            return Ok(());
        }
        let need = self.exec.admit_blocks(req.prompt.len());
        if need > self.exec.capacity_blocks() {
            return Err(anyhow::anyhow!(
                "prompt needs {need} pool blocks but the pool holds {} — raise pool_tokens",
                self.exec.capacity_blocks()
            ));
        }
        self.metrics.counter("engine.prefills").inc();
        self.inflight = Some(Inflight { req, seq: None, done: 0 });
        self.continue_prefill()
    }

    /// Run the next prefill chunk of the in-flight request under panic
    /// containment: a panic (injected fault or pool exhaustion mid-chunk)
    /// drops the partial cache and charges an eviction against the
    /// preemption budget — re-stash or [`Outcome::Thrashing`]. An `Err`
    /// from the executor is an engine-side invariant breach: that request
    /// alone fails with [`Outcome::Failed`] and serving continues.
    fn continue_prefill(&mut self) -> anyhow::Result<()> {
        let mut fl = self.inflight.take().ok_or_else(|| {
            anyhow::Error::coded("state_drift", "prefill-chunk plan without an inflight prefill")
        })?;
        let total = fl.req.prompt.len();
        let chunk = if self.cfg.prefill_chunk_tokens == 0 {
            total
        } else {
            self.cfg.prefill_chunk_tokens
        };
        let start = fl.done;
        let end = (start + chunk).min(total);
        let exec = &mut self.exec;
        let ran = catch_unwind(AssertUnwindSafe(|| {
            exec.prefill_chunk(&mut fl.seq, &fl.req, start, end)
        }));
        match ran {
            Err(_) => {
                // the partial cache (however many chunks landed) drops
                // here, releasing its blocks; charge one eviction
                let Inflight { mut req, seq, .. } = fl;
                drop(seq);
                self.chunk_last = false;
                req.preempt_count += 1;
                self.metrics.counter("engine.preemptions").inc();
                if req.preempt_count > 2 * self.cfg.preempt_budget {
                    self.metrics.counter("engine.request_failures").inc();
                    self.never_ran(req, Outcome::Thrashing);
                } else {
                    self.stash.push_back(req);
                }
                Ok(())
            }
            Ok(Err(_e)) => {
                let Inflight { req, seq, .. } = fl;
                drop(seq);
                self.chunk_last = false;
                self.metrics.counter("engine.request_failures").inc();
                self.never_ran(req, Outcome::Failed);
                Ok(())
            }
            Ok(Ok(None)) => {
                // mid-prompt: keep the prefill in flight, give the
                // running batch the next turn
                fl.done = end;
                self.inflight = Some(fl);
                self.chunk_last = true;
                Ok(())
            }
            Ok(Ok(Some(first))) => {
                debug_assert_eq!(end, total, "first token before the final chunk");
                let id = fl.req.id;
                let pin = fl.req.preempt_count >= self.cfg.preempt_budget;
                let seq = fl.seq.take().ok_or_else(|| {
                    anyhow::Error::coded(
                        "state_drift",
                        "executor finished a prefill without building a sequence",
                    )
                })?;
                self.stream_new_tokens(id, &[first]);
                // the engine clock, not the host clock: under a virtual
                // clock TTFT is a pure function of the step schedule
                let first_token_at = Some(self.now());
                self.seqs.insert(
                    id,
                    Active {
                        req: fl.req,
                        seq,
                        generated: vec![first],
                        first_token_at,
                        decode_steps: 1,
                    },
                );
                self.scheduler.add_running(id);
                if pin {
                    // aging: at its budget the request is pinned — never
                    // a preemption victim again
                    self.scheduler.pin(id);
                }
                self.chunk_last = false;
                Ok(())
            }
        }
    }

    /// Re-admit the oldest swapped-out sequence: restore its blocks from
    /// the host tier (checksum-verified) and rejoin the running set with
    /// generated tokens and frozen per-head state intact — no re-prefill,
    /// no re-decode. A corrupt or faulted host copy falls back to
    /// bit-identical recomputation via the stash; the stream's per-request
    /// high-water mark keeps re-produced tokens duplicate-free.
    fn resume_swapped(&mut self, now: Instant) -> anyhow::Result<()> {
        let mut st = self.swapped.pop_front().ok_or_else(|| {
            anyhow::Error::coded("state_drift", "resume planned with nothing swapped")
        })?;
        if st.req.deadline.is_some_and(|d| now >= d) {
            // expire_deadlines runs every step; this guards the same-step
            // race where the deadline lands between the sweep and the plan
            self.exec.swap_discard(st.req.id);
            self.metrics.counter("engine.deadline_expired").inc();
            self.finish(st, Outcome::DeadlineExceeded);
            return Ok(());
        }
        match self.exec.swap_in(st.req.id, &mut st.seq) {
            SeqSwapIn::Restored => {
                self.metrics.counter("engine.swap_ins").inc();
                let id = st.req.id;
                let pin = st.req.preempt_count >= self.cfg.preempt_budget;
                self.seqs.insert(id, st);
                self.scheduler.add_running(id);
                if pin {
                    self.scheduler.pin(id);
                }
            }
            SeqSwapIn::NoCapacity if !self.scheduler.running().is_empty() => {
                // transient: the running set still holds the blocks; the
                // exact admission check retries once pressure eases
                self.swapped.push_front(st);
            }
            SeqSwapIn::NoCapacity => {
                // even an otherwise-idle pool cannot host the entry
                // (prefix retention can pin blocks): give up on the host
                // copy and recompute from the prompt instead of spinning
                self.exec.swap_discard(st.req.id);
                self.metrics.counter("engine.swap_fallbacks").inc();
                let Active { req, seq, .. } = st;
                drop(seq);
                self.stash.push_back(req);
            }
            SeqSwapIn::Failed => {
                // swap-in fault or checksum mismatch at re-admission: the
                // tier entry is already gone, recompute bit-identically
                self.metrics.counter("engine.swap_fallbacks").inc();
                let Active { req, seq, .. } = st;
                drop(seq);
                self.stash.push_back(req);
            }
        }
        Ok(())
    }

    /// Swap a running sequence's blocks to the host tier instead of
    /// dropping them: the eviction still charges the preemption budget
    /// (repeated swaps must age into pinning, then [`Outcome::Thrashing`],
    /// exactly like drops — the tier must never enable a livelock), but
    /// on success the sequence parks whole and resumes without a
    /// re-prefill. A swap-out fault falls back to the plain eviction.
    fn swap_out(&mut self, id: RequestId) -> anyhow::Result<()> {
        let mut st = self.seqs.remove(&id).ok_or_else(|| {
            anyhow::Error::coded("state_drift", format!("swap-out of unknown sequence {id}"))
        })?;
        self.scheduler.remove(id);
        st.req.preempt_count += 1;
        self.metrics.counter("engine.preemptions").inc();
        if st.req.preempt_count > 2 * self.cfg.preempt_budget {
            self.metrics.counter("engine.request_failures").inc();
            self.finish(st, Outcome::Thrashing);
            return Ok(());
        }
        match self.exec.swap_out(id, &mut st.seq) {
            Some(_blocks) => {
                self.metrics.counter("engine.swap_outs").inc();
                self.swapped.push_back(st);
            }
            None => {
                // fault before anything was copied: device state is
                // untouched, evict the classic way (drop + re-prefill)
                let Active { req, seq, .. } = st;
                drop(seq);
                self.stash.push_back(req);
            }
        }
        Ok(())
    }

    /// Evict a running sequence: drop its cache (blocks back to the
    /// pool), re-stash the request for bit-identical recomputation, or
    /// fail it with [`Outcome::Thrashing`] past twice its budget.
    fn preempt(&mut self, id: RequestId) -> anyhow::Result<()> {
        let mut st = self.seqs.remove(&id).ok_or_else(|| {
            anyhow::Error::coded("state_drift", format!("preempt of unknown sequence {id}"))
        })?;
        self.scheduler.remove(id);
        st.req.preempt_count += 1;
        self.metrics.counter("engine.preemptions").inc();
        if st.req.preempt_count > 2 * self.cfg.preempt_budget {
            self.metrics.counter("engine.request_failures").inc();
            self.finish(st, Outcome::Thrashing);
            return Ok(());
        }
        let Active { req, seq, .. } = st;
        drop(seq);
        self.stash.push_back(req);
        Ok(())
    }

    /// One decode step over the running set, in scheduler order (the
    /// order is deterministic, so served runs replay bit-identically).
    fn do_decode(&mut self, ids: &[RequestId]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        for &id in ids {
            let mut st = self.seqs.remove(&id).ok_or_else(|| {
                anyhow::Error::coded("state_drift", format!("decode of unknown sequence {id}"))
            })?;
            let out = self.exec.decode_step(&st.req, &mut st.seq, st.decode_steps);
            if out.panicked {
                self.scheduler.remove(id);
                self.metrics.counter("engine.request_failures").inc();
                self.finish(st, Outcome::WorkerPanic);
                continue;
            }
            if out.failed {
                // mid-step pool exhaustion: discard the partial step and
                // preempt (exact pre-step accounting normally prevents
                // this; chaos injection exercises it)
                self.scheduler.remove(id);
                st.req.preempt_count += 1;
                self.metrics.counter("engine.preemptions").inc();
                if st.req.preempt_count > 2 * self.cfg.preempt_budget {
                    self.metrics.counter("engine.request_failures").inc();
                    self.finish(st, Outcome::Thrashing);
                } else {
                    let Active { req, seq, .. } = st;
                    drop(seq);
                    self.stash.push_back(req);
                }
                continue;
            }
            st.generated.push(out.token);
            st.decode_steps += 1;
            self.stream_new_tokens(id, &st.generated);
            self.metrics.counter("engine.decoded_tokens").inc();
            if st.generated.len() >= st.req.max_new_tokens {
                self.scheduler.remove(id);
                self.finish(st, Outcome::Completed);
            } else {
                self.seqs.insert(id, st);
            }
        }
        self.metrics
            .histogram("engine.decode_step_latency")
            .observe(t0.elapsed());
        self.metrics.counter("engine.decode_steps").inc();
        self.chunk_last = false;
        Ok(())
    }
}

/// Per-(layer, kv-head) full-precision prompt rows, retained only while
/// the chunked prefill is in flight (dropped once the cache is built).
struct HeadRows {
    keys: Vec<f32>,
    vals: Vec<f32>,
    q_window: Vec<f32>,
}

/// [`NativeExecutor`]'s per-sequence state: one [`SelfIndexing`] leaf per
/// (layer, kv-head), layer-major — the same fan-out shape as the PJRT
/// engine's [`crate::method::SequenceCache`].
pub struct NativeSeq {
    heads: Vec<SelfIndexing>,
    rows: Vec<HeadRows>,
    /// last decode step's attention output, (kv_heads × gqa_ratio × dim)
    out: Vec<f32>,
    content_seed: u64,
}

/// Fixed-constant FNV-1a over the prompt bytes: the seed for a request's
/// synthetic K/V streams. Depends only on prompt CONTENT, so two engines
/// (or a preempted sequence's recomputation) derive identical tensors.
fn content_seed(prompt: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in prompt {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// PJRT-free [`SeqExecutor`]: runs the complete self-indexing cache stack
/// (compression, shared pool, prefix reuse, retrieval, sparse attention,
/// fault injection) on deterministic synthetic K/V derived from prompt
/// content. Model weights never enter the picture, so serving-layer
/// behavior — scheduling, chunking, SLOs, preemption, containment — is
/// testable and benchable in CI without artifacts, with bit-exact
/// cross-engine outputs.
pub struct NativeExecutor {
    mgr: Arc<KvManager>,
    faults: Arc<FaultInjector>,
    si: SelfIndexConfig,
    dim: usize,
    n_layers: usize,
    kv_heads: usize,
    gqa_ratio: usize,
    /// retrieval budget per decode step (tokens)
    budget: usize,
    /// SnapKV observation-window tokens for sink selection
    q_window_tokens: usize,
    /// final attention outputs of completed requests — the bit-exactness
    /// witness compared across serving modes
    finals: HashMap<RequestId, Vec<f32>>,
}

impl NativeExecutor {
    pub fn new(
        dim: usize,
        n_layers: usize,
        kv_heads: usize,
        gqa_ratio: usize,
        budget: usize,
        si: SelfIndexConfig,
        mgr: Arc<KvManager>,
    ) -> Self {
        let faults = Arc::clone(mgr.pool().faults());
        Self {
            mgr,
            faults,
            si,
            dim,
            n_layers,
            kv_heads,
            gqa_ratio,
            budget,
            q_window_tokens: 8,
            finals: HashMap::new(),
        }
    }

    pub fn mgr(&self) -> &Arc<KvManager> {
        &self.mgr
    }

    /// Final attention output per completed request id.
    pub fn finals(&self) -> &HashMap<RequestId, Vec<f32>> {
        &self.finals
    }

    fn build_seq(&self, req: &Request) -> NativeSeq {
        let seed = content_seed(&req.prompt);
        let total = req.prompt.len();
        let (d, r, w) = (self.dim, self.gqa_ratio, self.q_window_tokens.min(total));
        let n = self.n_layers * self.kv_heads;
        let mut heads = Vec::with_capacity(n);
        let mut rows = Vec::with_capacity(n);
        for l in 0..self.n_layers {
            for h in 0..self.kv_heads {
                let mut head = SelfIndexing::with_manager(d, self.si.clone(), Arc::clone(&self.mgr));
                head.set_prompt_hash(req.prompt_hash);
                heads.push(head);
                // stream seed mixes (layer, head) so leaves diverge, but
                // derives only from prompt content
                let mix = ((l as u64) << 32 | h as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let mut rng = Rng::new(seed ^ mix);
                rows.push(HeadRows {
                    keys: (0..total * d).map(|_| rng.f32() - 0.5).collect(),
                    vals: (0..total * d).map(|_| rng.f32() - 0.5).collect(),
                    q_window: (0..w * r * d).map(|_| rng.f32() - 0.5).collect(),
                });
            }
        }
        NativeSeq {
            heads,
            rows,
            out: vec![0.0; self.kv_heads * r * d],
            content_seed: seed,
        }
    }
}

impl SeqExecutor for NativeExecutor {
    type Seq = NativeSeq;

    fn admit_blocks(&self, prompt_len: usize) -> usize {
        prompt_len.div_ceil(self.mgr.pool().block_tokens) * self.n_layers * self.kv_heads
    }

    fn step_blocks(&self, seq: &NativeSeq) -> usize {
        seq.heads.iter().map(|h| h.blocks_for_append()).sum()
    }

    fn free_blocks(&self) -> usize {
        self.mgr.pool().free_blocks()
    }

    fn capacity_blocks(&self) -> usize {
        self.mgr.pool().capacity_blocks()
    }

    fn max_prompt(&self) -> usize {
        let heads = (self.n_layers * self.kv_heads).max(1);
        (self.capacity_blocks() / heads) * self.mgr.pool().block_tokens
    }

    fn prefill_chunk(
        &mut self,
        seq: &mut Option<NativeSeq>,
        req: &Request,
        start: usize,
        end: usize,
    ) -> anyhow::Result<Option<u8>> {
        let total = req.prompt.len();
        if start == 0 {
            *seq = Some(self.build_seq(req));
        }
        let s = seq.as_mut().ok_or_else(|| {
            anyhow::Error::coded("state_drift", "prefill chunk without a built sequence")
        })?;
        for (head, rows) in s.heads.iter_mut().zip(&s.rows) {
            // panics on pool exhaustion — contained by the engine
            head.prefill_chunk(&rows.keys, &rows.vals, &rows.q_window, self.gqa_ratio, start, end);
        }
        if end < total {
            return Ok(None);
        }
        // cache built: the retained fp rows are no longer needed
        s.rows = Vec::new();
        // deterministic "first token" from prompt content alone
        Ok(Some((content_seed(&req.prompt[..1]) ^ s.content_seed) as u8))
    }

    fn decode_step(&mut self, req: &Request, seq: &mut NativeSeq, step: usize) -> DecodeOutcome {
        let _ = req;
        let (d, r) = (self.dim, self.gqa_ratio);
        let mut failed = false;
        let mut panicked = false;
        for l in 0..self.n_layers {
            // per-(step, layer) synthetic projections, seeded by content:
            // replays after preemption regenerate the exact same rows
            let mix = 0xa076_1d64_78bd_642f_u64 ^ ((step as u64) << 20) ^ l as u64;
            let mut rng = Rng::new(seq.content_seed ^ mix);
            let k: Vec<f32> = (0..self.kv_heads * d).map(|_| rng.f32() - 0.5).collect();
            let v: Vec<f32> = (0..self.kv_heads * d).map(|_| rng.f32() - 0.5).collect();
            let q: Vec<f32> = (0..self.kv_heads * r * d).map(|_| rng.f32() - 0.5).collect();
            let mut chunks = seq.out.chunks_mut(r * d);
            for h in 0..self.kv_heads {
                let out = chunks.next().unwrap();
                let mut task = HeadTask {
                    method: &mut seq.heads[l * self.kv_heads + h],
                    k_row: &k[h * d..(h + 1) * d],
                    v_row: &v[h * d..(h + 1) * d],
                    queries: &q[h * r * d..(h + 1) * r * d],
                    dim: d,
                    budget: self.budget,
                    out,
                    failed: false,
                    panicked: false,
                };
                task.run_isolated(&self.faults);
                failed |= task.failed;
                panicked |= task.panicked;
            }
        }
        // greedy "sample": hash the last layer's attention output bits
        let mut h64 = 0xcbf2_9ce4_8422_2325u64;
        for &x in &seq.out {
            h64 ^= x.to_bits() as u64;
            h64 = h64.wrapping_mul(0x0000_0100_0000_01b3);
        }
        DecodeOutcome { token: (h64 >> 24) as u8, failed, panicked }
    }

    fn retire(&mut self, req: &Request, seq: Option<NativeSeq>, outcome: Outcome) {
        if let (Some(seq), Outcome::Completed) = (seq, outcome) {
            self.finals.insert(req.id, seq.out);
        }
        // dropping `seq` releases every pool block the sequence held
    }

    fn held_blocks(&self, seq: &NativeSeq) -> usize {
        seq.heads.iter().map(|h| h.cache().blocks().len()).sum()
    }

    fn swap_out(&mut self, key: RequestId, seq: &mut NativeSeq) -> Option<usize> {
        // head-major order; swap_in re-splits by each head's block count,
        // so the concatenation order must be reproducible from lengths
        let all: Vec<BlockId> = seq
            .heads
            .iter()
            .flat_map(|h| h.cache().blocks().iter().copied())
            .collect();
        match self.mgr.tier().swap_out(key, self.mgr.pool(), &all) {
            Ok(()) => {
                for h in seq.heads.iter_mut() {
                    h.detach_blocks();
                }
                Some(all.len())
            }
            Err(tier::SwapOutFault) => None,
        }
    }

    fn swapped_blocks(&self, key: RequestId) -> usize {
        self.mgr.tier().blocks_of(key)
    }

    fn swap_in(&mut self, key: RequestId, seq: &mut NativeSeq) -> SeqSwapIn {
        let pool = self.mgr.pool();
        let bt = pool.block_tokens;
        match self.mgr.tier().swap_in(key, pool) {
            tier::SwapIn::Restored(ids) => {
                let mut it = ids.into_iter();
                for h in seq.heads.iter_mut() {
                    let n = h.len().div_ceil(bt);
                    let part: Vec<BlockId> = it.by_ref().take(n).collect();
                    h.attach_blocks(part);
                }
                debug_assert!(it.next().is_none(), "swap-in split drift");
                SeqSwapIn::Restored
            }
            tier::SwapIn::NoCapacity => SeqSwapIn::NoCapacity,
            tier::SwapIn::Faulted => SeqSwapIn::Failed,
            tier::SwapIn::Corrupt => {
                // detected at re-admission: surfaces on the same counter
                // the store's epoch/checksum guards use
                self.mgr.note_integrity_failure();
                SeqSwapIn::Failed
            }
        }
    }

    fn swap_discard(&mut self, key: RequestId) {
        self.mgr.tier().discard(key);
    }

    fn tier_sweep(&mut self, cold_after: u64) -> usize {
        self.mgr.tier().sweep(cold_after)
    }

    fn tier_stats(&self) -> (usize, usize, usize) {
        let t = self.mgr.tier();
        (t.host_blocks(), t.bytes(), t.cold_bytes())
    }

    fn tier_enforce_budget(&mut self, max_bytes: usize) -> usize {
        self.mgr.tier().enforce_budget(max_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIM: usize = 64;
    const BT: usize = 16;

    fn si_cfg() -> SelfIndexConfig {
        SelfIndexConfig { sink_tokens: 4, sparse_k: 16, ..Default::default() }
    }

    fn native(capacity_blocks: usize) -> NativeExecutor {
        let mgr = Arc::new(KvManager::for_head(DIM, &si_cfg(), BT, capacity_blocks));
        NativeExecutor::new(DIM, 1, 1, 1, 24, si_cfg(), mgr)
    }

    fn cfg(chunk: usize) -> EngineConfig {
        EngineConfig {
            block_tokens: BT,
            pool_tokens: 1 << 12,
            prefill_chunk_tokens: chunk,
            max_batch: 4,
            preempt_budget: 2,
            ..Default::default()
        }
    }

    #[test]
    fn serves_and_streams_to_completion() {
        let mut eng = ServingEngine::new(cfg(0), native(256)).unwrap();
        let h = eng.submit(vec![7; 40], 4).unwrap();
        let mut results = eng.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        let r = results.pop().unwrap();
        assert_eq!(r.outcome, Outcome::Completed);
        assert_eq!(r.generated.len(), 4);
        assert_eq!(r.decode_steps, 4, "first token from prefill + 3 decodes");
        let mut streamed = vec![];
        loop {
            match h.tokens.try_recv().unwrap() {
                StreamEvent::Token(t) => streamed.push(t),
                StreamEvent::Done(o) => {
                    assert_eq!(o, Outcome::Completed);
                    break;
                }
            }
        }
        assert_eq!(streamed, r.generated, "stream carries exactly the output");
        assert_eq!(eng.executor().finals().len(), 1);
        assert_eq!(
            eng.executor().mgr().pool().used_blocks(),
            0,
            "drained engine leaks no blocks"
        );
    }

    #[test]
    fn chunked_prefill_serving_is_bit_identical_to_one_shot() {
        let prompts: Vec<Vec<u8>> = vec![vec![1; 40], vec![2; 33], vec![3; 64]];
        let run = |chunk: usize| {
            let mut eng = ServingEngine::new(cfg(chunk), native(256)).unwrap();
            for p in &prompts {
                eng.submit(p.clone(), 6).unwrap();
            }
            let mut res = eng.run_to_completion().unwrap();
            res.sort_by_key(|r| r.id);
            let finals: Vec<Vec<f32>> = res
                .iter()
                .map(|r| eng.executor().finals()[&r.id].clone())
                .collect();
            let toks: Vec<(Vec<u8>, Outcome)> =
                res.into_iter().map(|r| (r.generated, r.outcome)).collect();
            (toks, finals)
        };
        let one_shot = run(0);
        let chunked = run(BT); // prompts 40 and 33 take 3 chunks, 64 takes 4
        assert_eq!(one_shot, chunked);
    }

    #[test]
    fn expired_queued_request_skips_prefill_entirely() {
        let mut eng = ServingEngine::new(cfg(0), native(256))
            .unwrap()
            .with_virtual_clock(Duration::from_millis(1));
        let h = eng
            .submit_with_deadline(vec![9; 40], 8, Duration::from_millis(0))
            .unwrap();
        let res = eng.run_to_completion().unwrap();
        assert_eq!(res.len(), 1);
        assert_eq!(res[0].outcome, Outcome::DeadlineExceeded);
        assert!(res[0].generated.is_empty());
        assert_eq!(
            eng.metrics.counter("engine.prefills").get(),
            0,
            "a dead-on-arrival request must not burn its prefill"
        );
        assert_eq!(
            h.tokens.try_recv().unwrap(),
            StreamEvent::Done(Outcome::DeadlineExceeded)
        );
    }

    #[test]
    fn running_request_expires_with_partial_output() {
        let mut eng = ServingEngine::new(cfg(0), native(256))
            .unwrap()
            .with_virtual_clock(Duration::from_millis(1));
        eng.submit_with_deadline(vec![5; 40], 1000, Duration::from_millis(10))
            .unwrap();
        let res = eng.run_to_completion().unwrap();
        assert_eq!(res[0].outcome, Outcome::DeadlineExceeded);
        let n = res[0].generated.len();
        assert!(n > 0 && n < 1000, "partial output, got {n} tokens");
        assert_eq!(eng.executor().mgr().pool().used_blocks(), 0);
    }

    #[test]
    fn swap_resume_is_bit_exact_and_re_prefills_strictly_less() {
        let prompts: Vec<Vec<u8>> = vec![vec![11; 48], vec![13; 48]];
        // (generated, finals, swap_ins, retries) for one engine run
        let run = |swap: bool, blocks: usize| {
            let mut c = cfg(0);
            c.preempt_budget = 8; // same thrashing horizon in every mode
            c.swap.enabled = swap;
            c.swap.swap_cost = 0.1; // tight pool: always favor the tier
            c.swap.recompute_cost = 1.0;
            c.swap.cold_after_sweeps = 2; // exercise cold recompression too
            let mut eng = ServingEngine::new(c, native(blocks)).unwrap();
            for p in &prompts {
                eng.submit(p.clone(), 40).unwrap();
            }
            let mut res = eng.run_to_completion().unwrap();
            assert!(res.iter().all(|r| r.outcome == Outcome::Completed));
            res.sort_by_key(|r| r.id);
            let finals: Vec<Vec<f32>> = res
                .iter()
                .map(|r| eng.executor().finals()[&r.id].clone())
                .collect();
            let gen: Vec<Vec<u8>> = res.iter().map(|r| r.generated.clone()).collect();
            assert_eq!(
                eng.executor().mgr().pool().used_blocks(),
                0,
                "drained engine leaks no device blocks"
            );
            assert_eq!(
                eng.executor().mgr().tier().entries(),
                0,
                "drained engine leaks no host-tier entries"
            );
            (
                gen,
                finals,
                eng.metrics.counter("engine.swap_ins").get(),
                eng.metrics.counter("engine.retries").get(),
            )
        };
        let uncontended = run(false, 256);
        let evicting = run(false, 8);
        let swapping = run(true, 8);
        assert_eq!(
            uncontended.0, evicting.0,
            "drop + recompute must replay bit-identically"
        );
        assert_eq!(
            (&uncontended.0, &uncontended.1),
            (&swapping.0, &swapping.1),
            "swap + resume must be bit-exact vs never having been evicted"
        );
        assert!(swapping.2 > 0, "the tight pool must actually swap and resume");
        assert_eq!(evicting.2, 0, "swap disabled must never swap in");
        assert!(
            swapping.3 < evicting.3,
            "swap must re-prefill strictly less (swap {} vs evict {})",
            swapping.3,
            evicting.3
        );
    }

    #[test]
    fn host_tier_budget_evicts_and_evicted_entries_re_prefill() {
        let prompts: Vec<Vec<u8>> = vec![vec![11; 48], vec![13; 48]];
        // same tight-pool workload as the swap e2e test, with the host
        // tier bounded by swap.max_host_bytes
        let run = |max_host_bytes: usize| {
            let mut c = cfg(0);
            c.preempt_budget = 8;
            c.swap.enabled = true;
            c.swap.swap_cost = 0.1;
            c.swap.recompute_cost = 1.0;
            c.swap.max_host_bytes = max_host_bytes;
            let mut eng = ServingEngine::new(c, native(8)).unwrap();
            for p in &prompts {
                eng.submit(p.clone(), 40).unwrap();
            }
            let mut res = eng.run_to_completion().unwrap();
            assert!(res.iter().all(|r| r.outcome == Outcome::Completed));
            res.sort_by_key(|r| r.id);
            let gen: Vec<Vec<u8>> = res.iter().map(|r| r.generated.clone()).collect();
            assert_eq!(eng.executor().mgr().tier().entries(), 0, "tier drains");
            (
                gen,
                eng.metrics.counter("tier.host_evictions").get(),
                eng.executor().mgr().tier().host_evictions(),
            )
        };
        let unbounded = run(0);
        assert_eq!(unbounded.1, 0, "0 = unbounded: nothing evicted");
        // a 1-byte budget evicts every host entry the step it lands, so
        // each resume takes the failed-swap-in → re-prefill path
        let tight = run(1);
        assert!(tight.1 > 0, "tight budget must evict host entries");
        assert_eq!(tight.1, tight.2, "engine counter mirrors the tier's");
        assert_eq!(
            unbounded.0,
            tight.0,
            "evicted sequences must replay bit-identically via re-prefill"
        );
    }

    #[test]
    fn preemption_replay_never_duplicates_streamed_tokens() {
        // tight pool: two decoding sequences + pressure forces eviction;
        // the evicted one replays bit-identically and its stream must
        // carry each token exactly once
        let mut eng = ServingEngine::new(cfg(0), native(8)).unwrap();
        let ha = eng.submit(vec![11; 48], 40).unwrap();
        let hb = eng.submit(vec![13; 48], 40).unwrap();
        let res = eng.run_to_completion().unwrap();
        assert_eq!(res.len(), 2);
        assert!(res.iter().all(|r| r.outcome == Outcome::Completed));
        assert!(
            eng.metrics.counter("engine.preemptions").get() > 0,
            "the tight pool must force at least one eviction"
        );
        for (h, id) in [(&ha, ha.id), (&hb, hb.id)] {
            let want = &res.iter().find(|r| r.id == id).unwrap().generated;
            let mut got = vec![];
            loop {
                match h.tokens.try_recv().unwrap() {
                    StreamEvent::Token(t) => got.push(t),
                    StreamEvent::Done(o) => {
                        assert_eq!(o, Outcome::Completed);
                        break;
                    }
                }
            }
            assert_eq!(&got, want, "stream {id} must be duplicate-free");
        }
    }
}
