//! The serving engine: PJRT compute + compressed caches + retrieval.
//!
//! Per engine step ([`Engine::step`]): the scheduler either prefixes a
//! queued request (PJRT `prefill_l{N}` → per-layer
//! [`SequenceCache::prefill_layer`] with SnapKV windows) or decodes the
//! running batch (`embed` → per-layer `decode_qkv` → native GQA-grouped
//! attention through the sequence-level [`SequenceCache`] API →
//! `decode_out` → `logits` → greedy sample). The KV cache never crosses
//! the PJRT boundary.
//!
//! Decode fan-out: each layer builds one [`DecodePlan`] per sequence,
//! every sequence's cache expands it into [`HeadTask`]s
//! ([`SequenceCache::push_tasks`]), and the pre-built task slice runs
//! over `ThreadPool::for_each_task` — an atomic cursor, no per-job
//! closure boxing, and (the task arena being recycled by
//! [`DecodeWorkQueue`]) zero steady-state heap allocations in the engine
//! layer. Methods are built by the [`crate::method::registry`] rather
//! than a hardcoded match.
//!
//! Memory: ONE engine-wide [`KvManager`] (shared refcounted block pool +
//! prefix-block registry) backs every sequence, layer, and kv head.
//! Admission and preemption run on **exact** free-block accounting
//! ([`PoolPressure`] → `Scheduler::plan`): the head of the queue admits
//! only when its prompt fits on top of the running set's next step, and
//! when a decode step cannot fit the youngest unpinned running sequence
//! is preempted — blocks released, request re-stashed FIFO for
//! deterministic recomputation (DESIGN.md §Memory manager).
//!
//! Hardened lifecycle (DESIGN.md §Robustness): every terminal state is a
//! structured [`Outcome`] — a worker panic fails only its own request
//! ([`HeadTask::run_isolated`]), repeated eviction escalates through the
//! preemption budget (pin, then `Thrashing`), deadlines expire with
//! partial output, and internal invariant breaches surface as
//! `"state_drift"`-coded errors instead of process panics. The whole
//! path is exercised deterministically by the seeded
//! [`crate::substrate::faults`] layer (tests/chaos_engine.rs).
//!
//! [`HeadTask`]: crate::method::HeadTask
//! [`HeadTask::run_isolated`]: crate::method::HeadTask::run_isolated

use crate::substrate::error as anyhow;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::request::{Outcome, Request, RequestId, RequestResult};
use super::router::{AdmitError, Router};
use super::scheduler::{PoolPressure, Scheduler, StepPlan};
use crate::config::{EngineConfig, ModelConfig};
use crate::kvcache::layout::RecordLayout;
use crate::kvcache::manager::KvManager;
use crate::method::registry::{self, BuildCtx, CacheMethod};
use crate::method::{DecodePlan, DecodeWorkQueue, SequenceCache};
use crate::runtime::{HostTensor, PjrtRuntime};
use crate::substrate::exec::ThreadPool;
use crate::substrate::faults::FaultInjector;
use crate::substrate::metrics::Registry;

pub use crate::method::MethodKind;

struct SeqState {
    req: Request,
    /// the whole sequence's cache — every (layer, kv-head)'s state,
    /// layer-major, behind the sequence-level method API
    cache: Box<dyn SequenceCache>,
    /// prompt + generated tokens so far
    tokens: Vec<u8>,
    generated: Vec<u8>,
    first_token_at: Option<Instant>,
    decode_steps: usize,
}

pub struct Engine {
    pub rt: PjrtRuntime,
    pub model: ModelConfig,
    pub cfg: EngineConfig,
    pub method: MethodKind,
    pub metrics: Registry,
    /// the registry entry building each admitted sequence's cache
    builder: &'static dyn CacheMethod,
    /// the engine-wide memory manager: ONE shared block pool + the
    /// prefix-block registry, cloned into every pool-backed leaf — the
    /// ownership inversion that replaced per-head pools (DESIGN.md
    /// §Memory manager)
    mgr: Arc<KvManager>,
    /// seeded fault-injection points (disarmed in production: one branch
    /// per probe); shared with the pool/manager via `KvManager::with_faults`
    faults: Arc<FaultInjector>,
    router: Router,
    scheduler: Scheduler,
    seqs: HashMap<RequestId, SeqState>,
    /// preempted requests awaiting recomputation, FIFO (`pop_front`) and
    /// retried before the router queue
    stash: VecDeque<Request>,
    /// decode fan-out workers (one task per (sequence, kv head))
    workers: ThreadPool,
    /// recycled task arena for the per-layer decode fan-out
    decode_tasks: DecodeWorkQueue,
    /// cached PJRT staging per batch bucket: bucket-name strings + host
    /// tensor buffers reused across decode steps (no steady-state
    /// formatting or staging allocations)
    staging: Vec<DecodeStaging>,
    /// monotone step counter (scheduler progress metric)
    step_idx: u64,
}

/// Cached host-side staging for one `decode_batch` bucket size `bb`:
/// the four bucket-name strings and the token/position/output buffers
/// (with their shape vectors), reused across decode steps via
/// take-into-`HostTensor` / put-back cycles — steady-state decode stages
/// with zero heap allocations (asserted by the unit test below, since
/// PJRT itself cannot run in CI).
struct DecodeStaging {
    bb: usize,
    embed: String,
    qkv: String,
    out: String,
    logits: String,
    toks: Vec<i32>,
    toks_shape: Vec<usize>,
    pos: Vec<i32>,
    pos_shape: Vec<usize>,
    o: Vec<f32>,
    o_shape: Vec<usize>,
}

impl DecodeStaging {
    fn new(bb: usize, h: usize, hd: usize) -> Self {
        Self {
            bb,
            embed: format!("embed_b{bb}"),
            qkv: format!("decode_qkv_b{bb}"),
            out: format!("decode_out_b{bb}"),
            logits: format!("logits_b{bb}"),
            toks: vec![0; bb],
            toks_shape: vec![bb],
            pos: vec![0; bb],
            pos_shape: vec![bb],
            o: vec![0.0; bb * h * hd],
            o_shape: vec![bb, h, hd],
        }
    }

    fn take_toks(&mut self) -> HostTensor {
        HostTensor::I32(std::mem::take(&mut self.toks), std::mem::take(&mut self.toks_shape))
    }

    fn put_toks(&mut self, t: HostTensor) {
        if let HostTensor::I32(v, s) = t {
            self.toks = v;
            self.toks_shape = s;
        }
    }

    fn take_pos(&mut self) -> HostTensor {
        HostTensor::I32(std::mem::take(&mut self.pos), std::mem::take(&mut self.pos_shape))
    }

    fn put_pos(&mut self, t: HostTensor) {
        if let HostTensor::I32(v, s) = t {
            self.pos = v;
            self.pos_shape = s;
        }
    }

    fn take_o(&mut self) -> HostTensor {
        HostTensor::F32(std::mem::take(&mut self.o), std::mem::take(&mut self.o_shape))
    }

    fn put_o(&mut self, t: HostTensor) {
        if let HostTensor::F32(v, s) = t {
            self.o = v;
            self.o_shape = s;
        }
    }
}

/// Parse the numeric suffix of a PJRT bucket name (`prefill_l4096` →
/// 4096). A name that does not parse means the compiled manifest and the
/// engine have drifted — surfaced as a `"state_drift"`-coded error, never
/// an engine-crashing panic.
fn parse_bucket(name: &str, prefix: &str) -> anyhow::Result<usize> {
    name.strip_prefix(prefix)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            anyhow::Error::coded(
                "state_drift",
                format!("unparseable bucket name {name:?} (expected {prefix}<N>)"),
            )
        })
}

impl Engine {
    pub fn new(artifact_dir: &Path, cfg: EngineConfig, method: MethodKind) -> anyhow::Result<Self> {
        let mut cfg = cfg;
        cfg.method = method.name().to_string();
        registry::validate_overlay(&cfg.method, &cfg.method_overlay)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let builder = method.entry();
        let faults = Arc::new(
            FaultInjector::from_config(&cfg.faults, cfg.fault_seed)
                .map_err(|e| anyhow::anyhow!("fault spec: {e}"))?,
        );
        let rt = PjrtRuntime::load(artifact_dir)?;
        let model = rt.manifest.model.clone();
        let metrics = Registry::default();
        // one pool for the whole engine, sized in blocks from the token
        // budget; its record layout comes from the *resolved* selfindex
        // config (a quant_bits overlay changes record widths). Methods
        // that never store into the pool get a 1-block stub instead of
        // megabytes of untouched buffers.
        let si_eff = if method == MethodKind::SelfIndex {
            registry::selfindex_overlayed(&cfg.selfindex, &cfg.method_overlay)
        } else {
            cfg.selfindex.clone()
        };
        let uses_pool = builder.head_blocks_for_prompt(cfg.block_tokens, cfg.block_tokens) > 0;
        let capacity_blocks = if uses_pool {
            (cfg.pool_tokens / cfg.block_tokens).max(1)
        } else {
            1
        };
        let mgr = Arc::new(KvManager::with_faults(
            RecordLayout::new(model.head_dim, &si_eff),
            cfg.block_tokens,
            capacity_blocks,
            Arc::clone(&faults),
        ));
        // reject prompts the pool could never host at SUBMIT time (a
        // per-request AdmitError) instead of letting step() abort the
        // whole run after the request is already queued
        let max_prompt = if uses_pool {
            let heads = (model.n_layers * model.n_kv_heads).max(1);
            model.max_seq.min((capacity_blocks / heads) * cfg.block_tokens)
        } else {
            model.max_seq
        };
        Ok(Self {
            mgr,
            faults,
            router: Router::new(cfg.queue_limit, max_prompt, metrics.clone()),
            scheduler: Scheduler::new(cfg.max_batch),
            seqs: HashMap::new(),
            stash: VecDeque::new(),
            workers: if cfg.decode_workers == 0 {
                ThreadPool::default_size()
            } else {
                ThreadPool::new(cfg.decode_workers)
            },
            decode_tasks: DecodeWorkQueue::new(),
            staging: vec![],
            builder,
            rt,
            model,
            cfg,
            method,
            metrics,
            step_idx: 0,
        })
    }

    /// Build from the config's validated `method` string (the CLI path:
    /// `--method Quest` and `"method": "quest"` behave identically).
    pub fn from_config(artifact_dir: &Path, cfg: EngineConfig) -> anyhow::Result<Self> {
        let kind = MethodKind::parse(&cfg.method).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::new(artifact_dir, cfg, kind)
    }

    pub fn submit(&mut self, prompt: Vec<u8>, max_new: usize) -> Result<RequestId, AdmitError> {
        self.router.submit(prompt, max_new)
    }

    /// [`Self::submit`] with a wall-clock SLO: the request expires `slo`
    /// after submission, completing with whatever it generated by then as
    /// [`Outcome::DeadlineExceeded`] (empty output if it never ran —
    /// expiry is also checked at admission, so a dead-on-arrival request
    /// never burns its prefill).
    pub fn submit_with_deadline(
        &mut self,
        prompt: Vec<u8>,
        max_new: usize,
        slo: Duration,
    ) -> Result<RequestId, AdmitError> {
        self.router.submit_with(prompt, max_new, Some(Instant::now() + slo))
    }

    pub fn idle(&self) -> bool {
        self.router.is_empty() && self.seqs.is_empty() && self.stash.is_empty()
    }

    pub fn running(&self) -> usize {
        self.scheduler.running().len()
    }

    /// The engine-wide memory manager (shared pool + prefix registry).
    pub fn manager(&self) -> &Arc<KvManager> {
        &self.mgr
    }

    /// The engine's fault-injection layer (disarmed unless configured).
    pub fn faults(&self) -> &Arc<FaultInjector> {
        &self.faults
    }

    /// Steps executed so far.
    pub fn step_index(&self) -> u64 {
        self.step_idx
    }

    /// KV bytes currently held across sequences (Fig. 5 metric): the
    /// shared pool's allocated blocks — each counted **once**, however
    /// many sequences share it through the prefix registry — plus every
    /// sequence's off-pool state (sinks, recent windows, fixed overhead,
    /// and the storage of non-pool methods).
    pub fn cache_bytes(&self) -> usize {
        let off_pool: usize = self
            .seqs
            .values()
            .map(|s| s.cache.memory_bytes() - s.cache.pool_payload_bytes())
            .sum();
        self.mgr.pool().used_bytes() + off_pool
    }

    /// Exact shared-pool blocks needed to admit a `prompt_len` prompt.
    fn admit_blocks_for(&self, prompt_len: usize) -> usize {
        let heads = self.model.n_layers * self.model.n_kv_heads;
        self.builder
            .head_blocks_for_prompt(prompt_len, self.mgr.pool().block_tokens)
            * heads
    }

    /// Blocks the running set will allocate on its next decode step.
    fn step_blocks(&self) -> usize {
        self.scheduler
            .running()
            .iter()
            .map(|id| self.seqs[id].cache.step_blocks())
            .sum()
    }

    /// Terminal result for a sequence that ran (possibly partially).
    /// Consuming the state drops its cache, releasing every shared-pool
    /// block reference.
    fn finish(st: SeqState, outcome: Outcome) -> RequestResult {
        RequestResult {
            id: st.req.id,
            prompt_len: st.req.prompt.len(),
            ttft: st
                .first_token_at
                .map(|t| t - st.req.submitted_at)
                .unwrap_or_default(),
            latency: st.req.submitted_at.elapsed(),
            decode_steps: st.decode_steps,
            generated: st.generated,
            outcome,
        }
    }

    /// Terminal result for a request that never (re)entered prefill.
    fn never_ran(req: Request, outcome: Outcome) -> RequestResult {
        RequestResult {
            id: req.id,
            generated: vec![],
            prompt_len: req.prompt.len(),
            ttft: Duration::default(),
            latency: req.submitted_at.elapsed(),
            decode_steps: 0,
            outcome,
        }
    }

    /// Evict a running sequence: release its pool blocks (the cache's
    /// `Drop` returns every reference) and re-stash the request for
    /// recomputation. Greedy decode is deterministic, so the recomputed
    /// request finishes with bit-identical output. A request evicted more
    /// than twice its preemption budget is failed with
    /// [`Outcome::Thrashing`] instead (returned as `Some(result)`), so a
    /// pool that cannot hold its working set terminates the request
    /// structurally rather than looping forever.
    fn preempt(&mut self, id: RequestId) -> anyhow::Result<Option<RequestResult>> {
        let mut st = self.seqs.remove(&id).ok_or_else(|| {
            anyhow::Error::coded("state_drift", format!("preempt of unknown sequence {id}"))
        })?;
        self.scheduler.remove(id);
        st.req.preempt_count += 1;
        self.metrics.counter("engine.preemptions").inc();
        if st.req.preempt_count > 2 * self.cfg.preempt_budget {
            self.metrics.counter("engine.request_failures").inc();
            return Ok(Some(Self::finish(st, Outcome::Thrashing)));
        }
        let SeqState { req, cache, .. } = st;
        drop(cache); // releases shared-pool block references
        self.stash.push_back(req);
        Ok(None)
    }

    /// Expire every request whose wall-clock deadline has passed: running
    /// sequences complete with their partial output, stashed/queued ones
    /// with empty output — all as [`Outcome::DeadlineExceeded`].
    fn expire_deadlines(&mut self) -> Vec<RequestResult> {
        let now = Instant::now();
        let mut results = vec![];
        let mut expired_running: Vec<RequestId> = self
            .seqs
            .iter()
            .filter(|(_, st)| st.req.deadline.is_some_and(|d| now >= d))
            .map(|(&id, _)| id)
            .collect();
        expired_running.sort_unstable(); // map order is not deterministic
        for id in expired_running {
            let st = self.seqs.remove(&id).unwrap();
            self.scheduler.remove(id);
            results.push(Self::finish(st, Outcome::DeadlineExceeded));
        }
        let mut kept = VecDeque::with_capacity(self.stash.len());
        for r in self.stash.drain(..) {
            if r.deadline.is_some_and(|d| now >= d) {
                results.push(Self::never_ran(r, Outcome::DeadlineExceeded));
            } else {
                kept.push_back(r);
            }
        }
        self.stash = kept;
        for r in self.router.expire_before(now) {
            results.push(Self::never_ran(r, Outcome::DeadlineExceeded));
        }
        if !results.is_empty() {
            self.metrics
                .counter("engine.deadline_expired")
                .add(results.len() as u64);
        }
        results
    }

    fn refresh_pool_gauges(&self) {
        let pool = self.mgr.pool();
        self.metrics
            .gauge("pool.free_blocks")
            .set(pool.free_blocks() as i64);
        self.metrics
            .gauge("pool.prefix_hits")
            .set(self.mgr.prefix_hits() as i64);
        self.metrics
            .gauge("pool.integrity_failures")
            .set(self.mgr.integrity_failures() as i64);
    }

    /// Drive one scheduler step; returns requests completed in this step.
    ///
    /// Policy: prefill-prioritized continuous batching over exact pool
    /// occupancy — admit the head of the deferred/router queue while batch
    /// capacity and free blocks allow, preempt the youngest unpinned
    /// running sequence when the next decode step cannot fit, otherwise
    /// run one decode step over the whole running set. Preempted requests
    /// retry FIFO from the stash, ahead of the router queue. Deadlines
    /// are checked first, against the pre-step counter.
    pub fn step(&mut self) -> anyhow::Result<Vec<RequestResult>> {
        self.step_idx += 1;
        let mut results = self.expire_deadlines();
        let candidate = self
            .stash
            .front()
            .map(|r| r.prompt.len())
            .or_else(|| self.router.peek().map(|r| r.prompt.len()));
        let pressure = PoolPressure {
            free_blocks: self.mgr.pool().free_blocks(),
            admit_blocks: candidate.map(|len| self.admit_blocks_for(len)),
            step_blocks: self.step_blocks(),
            // this engine prefills whole prompts in one step; the chunked
            // path lives in `super::serving::ServingEngine`
            ..Default::default()
        };
        let plan = self.scheduler.plan(&pressure);
        // deferred = batch capacity existed but pool pressure refused the
        // admission (a batch-full engine decoding normally is not deferral)
        if candidate.is_some()
            && self.scheduler.has_capacity()
            && !matches!(plan, StepPlan::Prefill)
        {
            self.metrics.counter("engine.deferred_admissions").inc();
        }
        let out = match plan {
            StepPlan::Prefill => {
                let from_stash = !self.stash.is_empty();
                let req = self
                    .stash
                    .pop_front()
                    .or_else(|| self.router.pop())
                    .ok_or_else(|| {
                        anyhow::Error::coded("state_drift", "plan admitted an empty queue")
                    })?;
                if from_stash {
                    self.metrics.counter("engine.retries").inc();
                }
                let need = self.admit_blocks_for(req.prompt.len());
                if need > self.mgr.pool().capacity_blocks() {
                    return Err(anyhow::anyhow!(
                        "prompt needs {need} pool blocks but the pool holds {} — \
                         raise pool_tokens",
                        self.mgr.pool().capacity_blocks()
                    ));
                }
                self.do_prefill(req).map(|r| r.into_iter().collect())
            }
            StepPlan::Preempt(id) => self.preempt(id).map(|r| r.into_iter().collect()),
            StepPlan::Shed(id) => {
                // every running sequence is pinned and the step cannot
                // fit: aging has no victim left, so the youngest pinned
                // sequence fails structurally instead of livelocking
                let st = self.seqs.remove(&id).ok_or_else(|| {
                    anyhow::Error::coded("state_drift", format!("shed of unknown sequence {id}"))
                })?;
                self.scheduler.remove(id);
                self.metrics.counter("engine.request_failures").inc();
                Ok(vec![Self::finish(st, Outcome::Thrashing)])
            }
            StepPlan::Decode(ids) => self.do_decode(&ids),
            StepPlan::PrefillChunk => Err(anyhow::Error::coded(
                "state_drift",
                "scheduler planned a prefill chunk but this engine never starts one",
            )),
            // the closed-batch engine never sets `swap_eligible` — tiered
            // swap lives in `super::serving::ServingEngine`
            StepPlan::SwapOut(_) => Err(anyhow::Error::coded(
                "state_drift",
                "scheduler planned a swap-out but this engine never enables the swap policy",
            )),
            StepPlan::Idle => Ok(vec![]),
        };
        self.refresh_pool_gauges();
        results.extend(out?);
        Ok(results)
    }

    /// Run until all submitted work completes; returns all results.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<RequestResult>> {
        let mut out = vec![];
        while !self.idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// Prefill one request. The cache build (compression, pool
    /// allocation, prefix adoption) runs under `catch_unwind`: a panic
    /// there — injected or real — drops the partial cache (releasing its
    /// blocks) and counts as an eviction against the request's preemption
    /// budget, re-stashing it or failing it with [`Outcome::Thrashing`].
    /// PJRT execution stays outside the guard: a runtime fault is an
    /// engine error, not a per-request one.
    fn do_prefill(&mut self, req: Request) -> anyhow::Result<Option<RequestResult>> {
        let t0 = Instant::now();
        // admission-time SLO check: an already-expired request must not
        // burn a (possibly 100K-token) prefill only to be discarded at
        // the next step boundary
        if req.deadline.is_some_and(|d| t0 >= d) {
            self.metrics.counter("engine.deadline_expired").inc();
            return Ok(Some(Self::never_ran(req, Outcome::DeadlineExceeded)));
        }
        let prompt_len = req.prompt.len();
        let bucket = self
            .rt
            .manifest
            .prefill_bucket(prompt_len)
            .ok_or_else(|| anyhow::anyhow!("prompt {} exceeds buckets", prompt_len))?
            .name
            .clone();
        let padded: usize = match parse_bucket(&bucket, "prefill_l") {
            Ok(p) => p,
            Err(_) => {
                // manifest drift is contained per the robustness policy:
                // fail THIS request with a structured outcome and keep
                // the engine serving, instead of panicking the loop
                self.metrics.counter("engine.request_failures").inc();
                return Ok(Some(Self::never_ran(req, Outcome::Failed)));
            }
        };

        let mut tokens = vec![0i32; padded];
        for (i, &b) in req.prompt.iter().enumerate() {
            tokens[i] = b as i32;
        }
        let outs = self.rt.run(
            &bucket,
            None,
            &[
                HostTensor::I32(tokens, vec![1, padded]),
                HostTensor::scalar_i32(prompt_len as i32),
            ],
        )?;
        let (k_cache, v_cache, last_logits, q_window) = (&outs[0], &outs[1], &outs[2], &outs[3]);

        let m = &self.model;
        let (nl, kvh, hd, h) = (m.n_layers, m.n_kv_heads, m.head_dim, m.n_heads);
        let r = m.gqa_ratio();
        let w = q_window.shape()[1];
        let kc = k_cache.as_f32();
        let vc = v_cache.as_f32();
        let qw = q_window.as_f32();

        // build the sequence's cache via the registry, then feed it one
        // layer at a time (kv-head-major staging buffers)
        let budget_hint = self.cfg.budget_for(prompt_len) + self.cfg.selfindex.sink_tokens;
        let ctx = BuildCtx {
            dim: hd,
            n_layers: nl,
            kv_heads: kvh,
            gqa_ratio: r,
            budget_hint,
            mgr: &self.mgr,
            selfindex: &self.cfg.selfindex,
            overlay: &self.cfg.method_overlay,
            prompt_hash: req.prompt_hash,
        };
        let built = catch_unwind(AssertUnwindSafe(|| {
            let mut cache = self.builder.build_seq(&ctx);
            let mut keys_buf = vec![0.0f32; kvh * prompt_len * hd];
            let mut vals_buf = vec![0.0f32; kvh * prompt_len * hd];
            let mut qw_buf = vec![0.0f32; kvh * w * r * hd];
            for l in 0..nl {
                for head in 0..kvh {
                    // k_cache layout: (layers, padded, kvh, hd)
                    for t in 0..prompt_len {
                        let src = ((l * padded + t) * kvh + head) * hd;
                        let dst = (head * prompt_len + t) * hd;
                        keys_buf[dst..dst + hd].copy_from_slice(&kc[src..src + hd]);
                        vals_buf[dst..dst + hd].copy_from_slice(&vc[src..src + hd]);
                    }
                    // q_window layout: (layers, w, h, hd); group query heads
                    // under their kv head, head-major
                    for wi in 0..w {
                        for ri in 0..r {
                            let qh = head * r + ri;
                            let src = ((l * w + wi) * h + qh) * hd;
                            let dst = ((head * w + wi) * r + ri) * hd;
                            qw_buf[dst..dst + hd].copy_from_slice(&qw[src..src + hd]);
                        }
                    }
                }
                cache.prefill_layer(l, &keys_buf, &vals_buf, &qw_buf);
            }
            cache
        }));
        let cache = match built {
            Ok(cache) => cache,
            Err(_) => {
                // the unwinding closure dropped the partial cache, so its
                // blocks are already back in the pool; charge an eviction
                let mut req = req;
                req.preempt_count += 1;
                self.metrics.counter("engine.preemptions").inc();
                if req.preempt_count > 2 * self.cfg.preempt_budget {
                    self.metrics.counter("engine.request_failures").inc();
                    return Ok(Some(Self::never_ran(req, Outcome::Thrashing)));
                }
                self.stash.push_back(req);
                return Ok(None);
            }
        };

        // first token from prefill logits
        let first = argmax(last_logits.as_f32()) as u8;
        let mut tokens_all = req.prompt.clone();
        tokens_all.push(first);
        let id = req.id;
        // aging: a request at its budget is pinned — never a preemption
        // victim again — so repeat evictions cannot starve it forever
        let pin = req.preempt_count >= self.cfg.preempt_budget;
        let st = SeqState {
            req,
            cache,
            tokens: tokens_all,
            generated: vec![first],
            first_token_at: Some(Instant::now()),
            decode_steps: 1,
        };
        self.seqs.insert(id, st);
        self.scheduler.add_running(id);
        if pin {
            self.scheduler.pin(id);
        }
        self.metrics
            .histogram("engine.prefill_latency")
            .observe(t0.elapsed());
        self.metrics.counter("engine.prefills").inc();
        Ok(None)
    }

    /// One decode step over `states`: embed → per-layer qkv → parallel
    /// native attention (one [`crate::method::HeadTask`] per (sequence,
    /// kv-head), executed over the pool's atomic-cursor work queue; each
    /// task owns its leaf's scratch arenas and a disjoint slice of the
    /// output buffer) → output projection → logits → greedy sample.
    ///
    /// Tasks run through [`crate::method::HeadTask::run_isolated`], so a
    /// panicking worker marks only its own sequence. Returns
    /// `(failed, panicked)` indices: `failed` covers both mid-step pool
    /// exhaustion (normally none — the scheduler's exact pre-step
    /// accounting preempts first) and panics; `panicked ⊆ failed`. A
    /// failed sequence skips its remaining layers and its token sample;
    /// the caller preempts (exhaustion) or fails (panic) it, which
    /// discards the partial step entirely.
    #[allow(clippy::type_complexity)]
    fn decode_batch(
        &mut self,
        states: &mut [SeqState],
    ) -> anyhow::Result<(Vec<usize>, Vec<usize>)> {
        let b = states.len();
        let m = self.model.clone();
        let (nl, kvh, hd, h, d) = (m.n_layers, m.n_kv_heads, m.head_dim, m.n_heads, m.d_model);
        let r = m.gqa_ratio();
        let faults = Arc::clone(&self.faults);

        let bucket = self
            .rt
            .manifest
            .batch_bucket("embed_b", b)
            .ok_or_else(|| anyhow::anyhow!("batch {} exceeds buckets", b))?
            .name
            .clone();
        let bb: usize = parse_bucket(&bucket, "embed_b")?;
        // bucket-keyed staging cache: bucket-name strings + host buffers
        // reused across steps (a `?` return drops the entry; it is
        // rebuilt on the next step)
        let idx = match self.staging.iter().position(|s| s.bb == bb) {
            Some(i) => i,
            None => {
                self.staging.push(DecodeStaging::new(bb, h, hd));
                self.staging.len() - 1
            }
        };
        let mut stg = self.staging.swap_remove(idx);

        // stage last tokens + positions (padded to bucket)
        stg.toks.fill(0);
        stg.pos.fill(0);
        for (i, s) in states.iter().enumerate() {
            stg.toks[i] = *s.tokens.last().unwrap() as i32;
            stg.pos[i] = (s.tokens.len() - 1) as i32;
        }
        let args = [stg.take_toks()];
        let outs = self.rt.run(&stg.embed, None, &args)?;
        let [toks_t] = args;
        stg.put_toks(toks_t);
        let mut x = outs.into_iter().next().unwrap();

        let budgets: Vec<usize> = states
            .iter()
            .map(|s| self.cfg.budget_for(s.tokens.len()))
            .collect();
        let mut failed = vec![false; b];
        let mut panicked = vec![false; b];
        // (start, end) of each sequence's tasks in this layer's arena
        let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(b);

        for l in 0..nl {
            let args = [x, stg.take_pos()];
            let qkv = self.rt.run(&stg.qkv, Some(l), &args)?;
            let [x_back, pos_t] = args;
            x = x_back;
            stg.put_pos(pos_t);
            let (q, k, v) = (&qkv[0], &qkv[1], &qkv[2]);
            let qf = q.as_f32(); // (bb, h, hd)
            let kf = k.as_f32(); // (bb, kvh, hd)
            let vf = v.as_f32();

            // native attention per (seq, kv head), GQA-grouped, fanned
            // out over the slice-based work queue: every sequence's cache
            // expands its DecodePlan into HeadTasks (disjoint &mut leaf +
            // disjoint r·hd output chunk), and the pre-built task slice
            // runs under one atomic cursor — no per-job boxing
            stg.o.fill(0.0);
            {
                let mut tasks = self.decode_tasks.take();
                ranges.clear();
                let mut o_chunks = stg.o.chunks_mut(h * hd);
                for (i, seq) in states.iter_mut().enumerate() {
                    let oslice = o_chunks.next().unwrap();
                    let start = tasks.len();
                    // a sequence that failed at an earlier layer appends
                    // nothing further — it is resolved after this step
                    if !failed[i] {
                        let plan = DecodePlan {
                            layer: l,
                            dim: hd,
                            kv_heads: kvh,
                            gqa_ratio: r,
                            budget: budgets[i],
                            k_rows: &kf[i * kvh * hd..(i + 1) * kvh * hd],
                            v_rows: &vf[i * kvh * hd..(i + 1) * kvh * hd],
                            // group queries (r heads per kv head) are
                            // contiguous in the (h, hd) layout
                            queries: &qf[i * h * hd..(i + 1) * h * hd],
                        };
                        // chunk (i) is this sequence's (kvh × r × hd) output
                        seq.cache.push_tasks(&plan, oslice, &mut tasks);
                    }
                    ranges.push((start, tasks.len()));
                }
                self.workers.for_each_task(&mut tasks, |t| t.run_isolated(&faults));
                for (i, &(start, end)) in ranges.iter().enumerate() {
                    for t in &tasks[start..end] {
                        if t.failed {
                            failed[i] = true;
                        }
                        if t.panicked {
                            panicked[i] = true;
                        }
                    }
                }
                self.decode_tasks.bank(tasks);
            }

            let args = [stg.take_o(), x];
            let next = self.rt.run(&stg.out, Some(l), &args)?;
            let [o_t, _x_residual] = args;
            stg.put_o(o_t);
            x = next.into_iter().next().unwrap();
        }
        debug_assert_eq!(x.shape(), &[bb, d]);

        let args = [x];
        let logits = self
            .rt
            .run(&stg.logits, None, &args)?
            .into_iter()
            .next()
            .unwrap();
        self.staging.push(stg);
        let lf = logits.as_f32(); // (bb, vocab)
        let vocab = self.model.vocab_size;
        for (i, seq) in states.iter_mut().enumerate() {
            if failed[i] {
                continue; // partial step: discarded by preemption/failure
            }
            let tok = argmax(&lf[i * vocab..(i + 1) * vocab]) as u8;
            seq.tokens.push(tok);
            seq.generated.push(tok);
            seq.decode_steps += 1;
        }
        Ok((
            (0..b).filter(|&i| failed[i]).collect(),
            (0..b).filter(|&i| panicked[i]).collect(),
        ))
    }

    fn do_decode(&mut self, ids: &[RequestId]) -> anyhow::Result<Vec<RequestResult>> {
        let t0 = Instant::now();
        // Pull the batch's states out of the map once: the parallel
        // per-(sequence, kv-head) fan-out needs disjoint `&mut` access,
        // which a HashMap cannot hand out. States are always reinserted —
        // on success, on error, AND on a re-raised fan-out panic — so a
        // caller that catches the panic still sees a consistent map.
        let mut states: Vec<SeqState> = Vec::with_capacity(ids.len());
        for id in ids {
            match self.seqs.remove(id) {
                Some(st) => states.push(st),
                None => {
                    // put back what was already taken before reporting the
                    // scheduler bug — the map must never lose live states
                    for (id2, st) in ids.iter().zip(states.drain(..)) {
                        self.seqs.insert(*id2, st);
                    }
                    return Err(anyhow::Error::coded(
                        "state_drift",
                        format!("decode of unknown/duplicate seq {id}"),
                    ));
                }
            }
        }
        let step = catch_unwind(AssertUnwindSafe(|| self.decode_batch(&mut states)));
        for (id, st) in ids.iter().zip(states) {
            self.seqs.insert(*id, st);
        }
        let (failed_idx, panicked_idx) = match step {
            Ok(res) => res?,
            // worker panics are contained by run_isolated; anything that
            // still unwinds here (PJRT, staging) is an engine-level bug
            // and must keep unwinding once the map is consistent again
            Err(payload) => std::panic::resume_unwind(payload),
        };

        let mut results = vec![];
        // a panicked worker poisons its sequence's in-memory state:
        // fail the request, return the pre-step partial output, release
        // the blocks (via the finished state's cache Drop)
        for &i in &panicked_idx {
            let id = ids[i];
            let st = self.seqs.remove(&id).ok_or_else(|| {
                anyhow::Error::coded("state_drift", format!("panic on unknown seq {id}"))
            })?;
            self.scheduler.remove(id);
            self.metrics.counter("engine.request_failures").inc();
            results.push(Self::finish(st, Outcome::WorkerPanic));
        }
        // mid-step pool exhaustion (the reservation check normally makes
        // this unreachable): preempt the starved sequences so the freed
        // blocks let the survivors (and FIFO re-stash) make progress.
        // Even a sequence failing while running ALONE terminates: each
        // retry charges its preemption budget, so it either fits on a
        // later mix or exits with `Outcome::Thrashing`.
        for &i in &failed_idx {
            if panicked_idx.contains(&i) {
                continue;
            }
            if let Some(r) = self.preempt(ids[i])? {
                results.push(r);
            }
        }

        let mut done = vec![];
        for id in ids {
            // preempted/failed sequences left the map; stashed ones
            // recompute later
            let Some(seq) = self.seqs.get(id) else { continue };
            if seq.generated.len() >= seq.req.max_new_tokens {
                done.push(*id);
            }
        }

        self.metrics
            .histogram("engine.decode_step_latency")
            .observe(t0.elapsed());
        self.metrics.counter("engine.decode_steps").inc();
        self.metrics
            .counter("engine.decoded_tokens")
            .add((ids.len() - failed_idx.len()) as u64);

        for id in done {
            let seq = self.seqs.remove(&id).unwrap();
            self.scheduler.remove(id);
            results.push(Self::finish(seq, Outcome::Completed));
        }
        Ok(results)
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::metrics::thread_allocations;

    #[test]
    fn bucket_parse_is_fallible_not_panicking() {
        assert_eq!(parse_bucket("prefill_l4096", "prefill_l").unwrap(), 4096);
        assert_eq!(parse_bucket("embed_b8", "embed_b").unwrap(), 8);
        for bad in ["prefill_l", "prefill_lx", "decode_b8", ""] {
            let e = parse_bucket(bad, "prefill_l").unwrap_err();
            assert_eq!(e.code(), Some("state_drift"), "drift must be coded: {bad:?}");
        }
    }

    /// One full staging cycle exactly as `decode_batch` performs it:
    /// fill + take/put the token and position tensors, zero + take/put
    /// the per-layer output buffer. PJRT itself cannot run in CI, so the
    /// reuse contract is asserted directly on the staging struct under
    /// the counting allocator.
    fn staging_cycle(stg: &mut DecodeStaging, layers: usize) {
        stg.toks.fill(0);
        stg.pos.fill(0);
        stg.toks[0] = 7;
        stg.pos[0] = 42;
        let args = [stg.take_toks()];
        let [t] = args;
        stg.put_toks(t);
        for _ in 0..layers {
            let args = [stg.take_pos()];
            let [p] = args;
            stg.put_pos(p);
            stg.o.fill(0.0);
            let args = [stg.take_o()];
            let [o] = args;
            stg.put_o(o);
        }
    }

    #[test]
    fn decode_staging_reuse_is_allocation_free() {
        let mut stg = DecodeStaging::new(8, 4, 16);
        for _ in 0..4 {
            staging_cycle(&mut stg, 3); // warm-up
        }
        let before = thread_allocations();
        for _ in 0..8 {
            staging_cycle(&mut stg, 3);
        }
        assert_eq!(
            thread_allocations() - before,
            0,
            "steady-state decode staging must not allocate"
        );
        assert_eq!(stg.toks.len(), 8, "buffers survive the cycles");
        assert_eq!(stg.pos.len(), 8);
        assert_eq!(stg.o.len(), 8 * 4 * 16);
        assert_eq!(stg.toks_shape, [8]);
        assert_eq!(stg.o_shape, [8, 4, 16]);
    }
}
