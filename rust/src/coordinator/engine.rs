//! The serving engine: PJRT compute + compressed caches + retrieval.
//!
//! Per engine step ([`Engine::step`]): the scheduler either prefixes a
//! queued request (PJRT `prefill_l{N}` → per-layer
//! [`SequenceCache::prefill_layer`] with SnapKV windows) or decodes the
//! running batch (`embed` → per-layer `decode_qkv` → native GQA-grouped
//! attention through the sequence-level [`SequenceCache`] API →
//! `decode_out` → `logits` → greedy sample). The KV cache never crosses
//! the PJRT boundary.
//!
//! Decode fan-out: each layer builds one [`DecodePlan`] per sequence,
//! every sequence's cache expands it into [`HeadTask`]s
//! ([`SequenceCache::push_tasks`]), and the pre-built task slice runs
//! over `ThreadPool::for_each_task` — an atomic cursor, no per-job
//! closure boxing, and (the task arena being recycled by
//! [`DecodeWorkQueue`]) zero steady-state heap allocations in the engine
//! layer. Methods are built by the [`crate::method::registry`] rather
//! than a hardcoded match.
//!
//! Memory: ONE engine-wide [`KvManager`] (shared refcounted block pool +
//! prefix-block registry) backs every sequence, layer, and kv head.
//! Admission and preemption run on **exact** free-block accounting
//! ([`PoolPressure`] → `Scheduler::plan`): the head of the queue admits
//! only when its prompt fits on top of the running set's next step, and
//! when a decode step cannot fit the youngest running sequence is
//! preempted — blocks released, request re-stashed FIFO for deterministic
//! recomputation (DESIGN.md §Memory manager).
//!
//! [`HeadTask`]: crate::method::HeadTask

use crate::substrate::error as anyhow;
use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use super::request::{Request, RequestId, RequestResult};
use super::router::{AdmitError, Router};
use super::scheduler::{PoolPressure, Scheduler, StepPlan};
use crate::config::{EngineConfig, ModelConfig};
use crate::kvcache::layout::RecordLayout;
use crate::kvcache::manager::KvManager;
use crate::method::registry::{self, BuildCtx, CacheMethod};
use crate::method::{DecodePlan, DecodeWorkQueue, SequenceCache};
use crate::runtime::{HostTensor, PjrtRuntime};
use crate::substrate::exec::ThreadPool;
use crate::substrate::metrics::Registry;

pub use crate::method::MethodKind;

struct SeqState {
    req: Request,
    /// the whole sequence's cache — every (layer, kv-head)'s state,
    /// layer-major, behind the sequence-level method API
    cache: Box<dyn SequenceCache>,
    /// prompt + generated tokens so far
    tokens: Vec<u8>,
    generated: Vec<u8>,
    first_token_at: Option<Instant>,
    decode_steps: usize,
}

pub struct Engine {
    pub rt: PjrtRuntime,
    pub model: ModelConfig,
    pub cfg: EngineConfig,
    pub method: MethodKind,
    pub metrics: Registry,
    /// the registry entry building each admitted sequence's cache
    builder: &'static dyn CacheMethod,
    /// the engine-wide memory manager: ONE shared block pool + the
    /// prefix-block registry, cloned into every pool-backed leaf — the
    /// ownership inversion that replaced per-head pools (DESIGN.md
    /// §Memory manager)
    mgr: Arc<KvManager>,
    router: Router,
    scheduler: Scheduler,
    seqs: HashMap<RequestId, SeqState>,
    /// preempted requests awaiting recomputation, FIFO (`pop_front`) and
    /// retried before the router queue
    stash: VecDeque<Request>,
    /// decode fan-out workers (one task per (sequence, kv head))
    workers: ThreadPool,
    /// recycled task arena for the per-layer decode fan-out
    decode_tasks: DecodeWorkQueue,
}

impl Engine {
    pub fn new(artifact_dir: &Path, cfg: EngineConfig, method: MethodKind) -> anyhow::Result<Self> {
        let mut cfg = cfg;
        cfg.method = method.name().to_string();
        registry::validate_overlay(&cfg.method, &cfg.method_overlay)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let builder = method.entry();
        let rt = PjrtRuntime::load(artifact_dir)?;
        let model = rt.manifest.model.clone();
        let metrics = Registry::default();
        // one pool for the whole engine, sized in blocks from the token
        // budget; its record layout comes from the *resolved* selfindex
        // config (a quant_bits overlay changes record widths). Methods
        // that never store into the pool get a 1-block stub instead of
        // megabytes of untouched buffers.
        let si_eff = if method == MethodKind::SelfIndex {
            registry::selfindex_overlayed(&cfg.selfindex, &cfg.method_overlay)
        } else {
            cfg.selfindex.clone()
        };
        let uses_pool = builder.head_blocks_for_prompt(cfg.block_tokens, cfg.block_tokens) > 0;
        let capacity_blocks = if uses_pool {
            (cfg.pool_tokens / cfg.block_tokens).max(1)
        } else {
            1
        };
        let mgr = Arc::new(KvManager::new(
            RecordLayout::new(model.head_dim, &si_eff),
            cfg.block_tokens,
            capacity_blocks,
        ));
        // reject prompts the pool could never host at SUBMIT time (a
        // per-request AdmitError) instead of letting step() abort the
        // whole run after the request is already queued
        let max_prompt = if uses_pool {
            let heads = (model.n_layers * model.n_kv_heads).max(1);
            model.max_seq.min((capacity_blocks / heads) * cfg.block_tokens)
        } else {
            model.max_seq
        };
        Ok(Self {
            mgr,
            router: Router::new(cfg.queue_limit, max_prompt, metrics.clone()),
            scheduler: Scheduler::new(cfg.max_batch),
            seqs: HashMap::new(),
            stash: VecDeque::new(),
            workers: if cfg.decode_workers == 0 {
                ThreadPool::default_size()
            } else {
                ThreadPool::new(cfg.decode_workers)
            },
            decode_tasks: DecodeWorkQueue::new(),
            builder,
            rt,
            model,
            cfg,
            method,
            metrics,
        })
    }

    /// Build from the config's validated `method` string (the CLI path:
    /// `--method Quest` and `"method": "quest"` behave identically).
    pub fn from_config(artifact_dir: &Path, cfg: EngineConfig) -> anyhow::Result<Self> {
        let kind = MethodKind::parse(&cfg.method).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::new(artifact_dir, cfg, kind)
    }

    pub fn submit(&mut self, prompt: Vec<u8>, max_new: usize) -> Result<RequestId, AdmitError> {
        self.router.submit(prompt, max_new)
    }

    pub fn idle(&self) -> bool {
        self.router.is_empty() && self.seqs.is_empty() && self.stash.is_empty()
    }

    pub fn running(&self) -> usize {
        self.scheduler.running().len()
    }

    /// The engine-wide memory manager (shared pool + prefix registry).
    pub fn manager(&self) -> &Arc<KvManager> {
        &self.mgr
    }

    /// KV bytes currently held across sequences (Fig. 5 metric): the
    /// shared pool's allocated blocks — each counted **once**, however
    /// many sequences share it through the prefix registry — plus every
    /// sequence's off-pool state (sinks, recent windows, fixed overhead,
    /// and the storage of non-pool methods).
    pub fn cache_bytes(&self) -> usize {
        let off_pool: usize = self
            .seqs
            .values()
            .map(|s| s.cache.memory_bytes() - s.cache.pool_payload_bytes())
            .sum();
        self.mgr.pool().used_bytes() + off_pool
    }

    /// Exact shared-pool blocks needed to admit a `prompt_len` prompt.
    fn admit_blocks_for(&self, prompt_len: usize) -> usize {
        let heads = self.model.n_layers * self.model.n_kv_heads;
        self.builder
            .head_blocks_for_prompt(prompt_len, self.mgr.pool().block_tokens)
            * heads
    }

    /// Blocks the running set will allocate on its next decode step.
    fn step_blocks(&self) -> usize {
        self.scheduler
            .running()
            .iter()
            .map(|id| self.seqs[id].cache.step_blocks())
            .sum()
    }

    /// Evict a running sequence: release its pool blocks (the cache's
    /// `Drop` returns every reference) and re-stash the request for
    /// recomputation. Greedy decode is deterministic, so the recomputed
    /// request finishes with bit-identical output.
    fn preempt(&mut self, id: RequestId) {
        let st = self
            .seqs
            .remove(&id)
            .expect("preempt of unknown sequence");
        self.scheduler.remove(id);
        drop(st.cache); // releases shared-pool block references
        self.stash.push_back(st.req);
        self.metrics.counter("engine.preemptions").inc();
    }

    fn refresh_pool_gauges(&self) {
        let pool = self.mgr.pool();
        self.metrics
            .gauge("pool.free_blocks")
            .set(pool.free_blocks() as i64);
        self.metrics
            .gauge("pool.prefix_hits")
            .set(self.mgr.prefix_hits() as i64);
    }

    /// Drive one scheduler step; returns requests completed in this step.
    ///
    /// Policy: prefill-prioritized continuous batching over exact pool
    /// occupancy — admit the head of the deferred/router queue while batch
    /// capacity and free blocks allow, preempt the youngest running
    /// sequence when the next decode step cannot fit, otherwise run one
    /// decode step over the whole running set. Preempted requests retry
    /// FIFO from the stash, ahead of the router queue.
    pub fn step(&mut self) -> anyhow::Result<Vec<RequestResult>> {
        let candidate = self
            .stash
            .front()
            .map(|r| r.prompt.len())
            .or_else(|| self.router.peek().map(|r| r.prompt.len()));
        let pressure = PoolPressure {
            free_blocks: self.mgr.pool().free_blocks(),
            admit_blocks: candidate.map(|len| self.admit_blocks_for(len)),
            step_blocks: self.step_blocks(),
        };
        let plan = self.scheduler.plan(&pressure);
        // deferred = batch capacity existed but pool pressure refused the
        // admission (a batch-full engine decoding normally is not deferral)
        if candidate.is_some()
            && self.scheduler.has_capacity()
            && !matches!(plan, StepPlan::Prefill)
        {
            self.metrics.counter("engine.deferred_admissions").inc();
        }
        let out = match plan {
            StepPlan::Prefill => {
                let req = self
                    .stash
                    .pop_front()
                    .or_else(|| self.router.pop())
                    .expect("plan admitted an empty queue");
                let need = self.admit_blocks_for(req.prompt.len());
                if need > self.mgr.pool().capacity_blocks() {
                    return Err(anyhow::anyhow!(
                        "prompt needs {need} pool blocks but the pool holds {} — \
                         raise pool_tokens",
                        self.mgr.pool().capacity_blocks()
                    ));
                }
                self.do_prefill(req)?;
                Ok(vec![])
            }
            StepPlan::Preempt(id) => {
                self.preempt(id);
                Ok(vec![])
            }
            StepPlan::Decode(ids) => self.do_decode(&ids),
            StepPlan::Idle => Ok(vec![]),
        };
        self.refresh_pool_gauges();
        out
    }

    /// Run until all submitted work completes; returns all results.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<RequestResult>> {
        let mut out = vec![];
        while !self.idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    fn do_prefill(&mut self, req: Request) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let prompt_len = req.prompt.len();
        let bucket = self
            .rt
            .manifest
            .prefill_bucket(prompt_len)
            .ok_or_else(|| anyhow::anyhow!("prompt {} exceeds buckets", prompt_len))?
            .name
            .clone();
        let padded: usize = bucket.strip_prefix("prefill_l").unwrap().parse().unwrap();

        let mut tokens = vec![0i32; padded];
        for (i, &b) in req.prompt.iter().enumerate() {
            tokens[i] = b as i32;
        }
        let outs = self.rt.run(
            &bucket,
            None,
            &[
                HostTensor::I32(tokens, vec![1, padded]),
                HostTensor::scalar_i32(prompt_len as i32),
            ],
        )?;
        let (k_cache, v_cache, last_logits, q_window) = (&outs[0], &outs[1], &outs[2], &outs[3]);

        let m = &self.model;
        let (nl, kvh, hd, h) = (m.n_layers, m.n_kv_heads, m.head_dim, m.n_heads);
        let r = m.gqa_ratio();
        let w = q_window.shape()[1];
        let kc = k_cache.as_f32();
        let vc = v_cache.as_f32();
        let qw = q_window.as_f32();

        // build the sequence's cache via the registry, then feed it one
        // layer at a time (kv-head-major staging buffers)
        let budget_hint = self.cfg.budget_for(prompt_len) + self.cfg.selfindex.sink_tokens;
        let ctx = BuildCtx {
            dim: hd,
            n_layers: nl,
            kv_heads: kvh,
            gqa_ratio: r,
            budget_hint,
            mgr: &self.mgr,
            selfindex: &self.cfg.selfindex,
            overlay: &self.cfg.method_overlay,
        };
        let mut cache = self.builder.build_seq(&ctx);
        let mut keys_buf = vec![0.0f32; kvh * prompt_len * hd];
        let mut vals_buf = vec![0.0f32; kvh * prompt_len * hd];
        let mut qw_buf = vec![0.0f32; kvh * w * r * hd];
        for l in 0..nl {
            for head in 0..kvh {
                // k_cache layout: (layers, padded, kvh, hd)
                for t in 0..prompt_len {
                    let src = ((l * padded + t) * kvh + head) * hd;
                    let dst = (head * prompt_len + t) * hd;
                    keys_buf[dst..dst + hd].copy_from_slice(&kc[src..src + hd]);
                    vals_buf[dst..dst + hd].copy_from_slice(&vc[src..src + hd]);
                }
                // q_window layout: (layers, w, h, hd); group query heads
                // under their kv head, head-major
                for wi in 0..w {
                    for ri in 0..r {
                        let qh = head * r + ri;
                        let src = ((l * w + wi) * h + qh) * hd;
                        let dst = ((head * w + wi) * r + ri) * hd;
                        qw_buf[dst..dst + hd].copy_from_slice(&qw[src..src + hd]);
                    }
                }
            }
            cache.prefill_layer(l, &keys_buf, &vals_buf, &qw_buf);
        }

        // first token from prefill logits
        let first = argmax(last_logits.as_f32()) as u8;
        let mut tokens_all = req.prompt.clone();
        tokens_all.push(first);
        let id = req.id;
        let st = SeqState {
            req,
            cache,
            tokens: tokens_all,
            generated: vec![first],
            first_token_at: Some(Instant::now()),
            decode_steps: 1,
        };
        self.seqs.insert(id, st);
        self.scheduler.add_running(id);
        self.metrics
            .histogram("engine.prefill_latency")
            .observe(t0.elapsed());
        self.metrics.counter("engine.prefills").inc();
        Ok(())
    }

    /// One decode step over `states`: embed → per-layer qkv → parallel
    /// native attention (one [`crate::method::HeadTask`] per (sequence,
    /// kv-head), executed over the pool's atomic-cursor work queue; each
    /// task owns its leaf's scratch arenas and a disjoint slice of the
    /// output buffer) → output projection → logits → greedy sample.
    ///
    /// Returns the indices of sequences whose append hit pool exhaustion
    /// mid-step (normally none — the scheduler's exact pre-step accounting
    /// preempts first). A failed sequence skips its remaining layers and
    /// its token sample; the caller preempts it, which discards the
    /// partial step entirely (recompute-from-prompt semantics).
    fn decode_batch(&mut self, states: &mut [SeqState]) -> anyhow::Result<Vec<usize>> {
        let b = states.len();
        let m = self.model.clone();
        let (nl, kvh, hd, h, d) = (m.n_layers, m.n_kv_heads, m.head_dim, m.n_heads, m.d_model);
        let r = m.gqa_ratio();

        let bucket = self
            .rt
            .manifest
            .batch_bucket("embed_b", b)
            .ok_or_else(|| anyhow::anyhow!("batch {} exceeds buckets", b))?
            .name
            .clone();
        let bb: usize = bucket.strip_prefix("embed_b").unwrap().parse().unwrap();

        // stage last tokens + positions (padded to bucket)
        let mut toks = vec![0i32; bb];
        let mut pos = vec![0i32; bb];
        for (i, s) in states.iter().enumerate() {
            toks[i] = *s.tokens.last().unwrap() as i32;
            pos[i] = (s.tokens.len() - 1) as i32;
        }
        let outs = self
            .rt
            .run(&format!("embed_b{bb}"), None, &[HostTensor::I32(toks, vec![bb])])?;
        let mut x = outs.into_iter().next().unwrap();

        let budgets: Vec<usize> = states
            .iter()
            .map(|s| self.cfg.budget_for(s.tokens.len()))
            .collect();
        let mut failed = vec![false; b];
        // (start, end) of each sequence's tasks in this layer's arena
        let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(b);

        for l in 0..nl {
            let qkv = self.rt.run(
                &format!("decode_qkv_b{bb}"),
                Some(l),
                &[x.clone(), HostTensor::I32(pos.clone(), vec![bb])],
            )?;
            let (q, k, v) = (&qkv[0], &qkv[1], &qkv[2]);
            let qf = q.as_f32(); // (bb, h, hd)
            let kf = k.as_f32(); // (bb, kvh, hd)
            let vf = v.as_f32();

            // native attention per (seq, kv head), GQA-grouped, fanned
            // out over the slice-based work queue: every sequence's cache
            // expands its DecodePlan into HeadTasks (disjoint &mut leaf +
            // disjoint r·hd output chunk), and the pre-built task slice
            // runs under one atomic cursor — no per-job boxing
            let mut o = vec![0.0f32; bb * h * hd];
            {
                let mut tasks = self.decode_tasks.take();
                ranges.clear();
                let mut o_chunks = o.chunks_mut(h * hd);
                for (i, seq) in states.iter_mut().enumerate() {
                    let oslice = o_chunks.next().unwrap();
                    let start = tasks.len();
                    // a sequence that failed at an earlier layer appends
                    // nothing further — it is preempted after this step
                    if !failed[i] {
                        let plan = DecodePlan {
                            layer: l,
                            dim: hd,
                            kv_heads: kvh,
                            gqa_ratio: r,
                            budget: budgets[i],
                            k_rows: &kf[i * kvh * hd..(i + 1) * kvh * hd],
                            v_rows: &vf[i * kvh * hd..(i + 1) * kvh * hd],
                            // group queries (r heads per kv head) are
                            // contiguous in the (h, hd) layout
                            queries: &qf[i * h * hd..(i + 1) * h * hd],
                        };
                        // chunk (i) is this sequence's (kvh × r × hd) output
                        seq.cache.push_tasks(&plan, oslice, &mut tasks);
                    }
                    ranges.push((start, tasks.len()));
                }
                self.workers.for_each_task(&mut tasks, |t| t.run());
                for (i, &(start, end)) in ranges.iter().enumerate() {
                    if tasks[start..end].iter().any(|t| t.failed) {
                        failed[i] = true;
                    }
                }
                self.decode_tasks.bank(tasks);
            }

            let next = self.rt.run(
                &format!("decode_out_b{bb}"),
                Some(l),
                &[HostTensor::F32(o, vec![bb, h, hd]), x.clone()],
            )?;
            x = next.into_iter().next().unwrap();
        }
        debug_assert_eq!(x.shape(), &[bb, d]);

        let logits = self
            .rt
            .run(&format!("logits_b{bb}"), None, &[x])?
            .into_iter()
            .next()
            .unwrap();
        let lf = logits.as_f32(); // (bb, vocab)
        let vocab = self.model.vocab_size;
        for (i, seq) in states.iter_mut().enumerate() {
            if failed[i] {
                continue; // partial step: discarded by preemption
            }
            let tok = argmax(&lf[i * vocab..(i + 1) * vocab]) as u8;
            seq.tokens.push(tok);
            seq.generated.push(tok);
            seq.decode_steps += 1;
        }
        Ok((0..b).filter(|&i| failed[i]).collect())
    }

    fn do_decode(&mut self, ids: &[RequestId]) -> anyhow::Result<Vec<RequestResult>> {
        let t0 = Instant::now();
        // Pull the batch's states out of the map once: the parallel
        // per-(sequence, kv-head) fan-out needs disjoint `&mut` access,
        // which a HashMap cannot hand out. States are always reinserted —
        // on success, on error, AND on a re-raised fan-out panic — so a
        // caller that catches the panic still sees a consistent map.
        let mut states: Vec<SeqState> = Vec::with_capacity(ids.len());
        for id in ids {
            match self.seqs.remove(id) {
                Some(st) => states.push(st),
                None => {
                    // put back what was already taken before reporting the
                    // scheduler bug — the map must never lose live states
                    for (id2, st) in ids.iter().zip(states.drain(..)) {
                        self.seqs.insert(*id2, st);
                    }
                    panic!("decode of unknown/duplicate seq {id}");
                }
            }
        }
        let step = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.decode_batch(&mut states)
        }));
        for (id, st) in ids.iter().zip(states) {
            self.seqs.insert(*id, st);
        }
        let failed_idx = match step {
            Ok(res) => res?,
            Err(payload) => std::panic::resume_unwind(payload),
        };
        // mid-step pool exhaustion (the reservation check normally makes
        // this unreachable): preempt the starved sequences so the freed
        // blocks let the survivors (and FIFO re-stash) make progress. A
        // sequence that fails while running ALONE is fatal — the whole
        // pool was its to use, so eviction could not free anything and
        // retrying would loop forever. (`ids.len()`, not the post-preempt
        // running count: preempting several failures from one batch must
        // not be mistaken for that lone-runner dead end.)
        if !failed_idx.is_empty() && ids.len() == 1 {
            return Err(anyhow::anyhow!(
                "kv pool exhausted with a single running sequence — \
                 raise pool_tokens"
            ));
        }
        for &i in &failed_idx {
            self.preempt(ids[i]);
        }

        let mut done = vec![];
        for id in ids {
            // preempted sequences left the map; they recompute later
            let Some(seq) = self.seqs.get(id) else { continue };
            if seq.generated.len() >= seq.req.max_new_tokens {
                done.push(*id);
            }
        }

        self.metrics
            .histogram("engine.decode_step_latency")
            .observe(t0.elapsed());
        self.metrics.counter("engine.decode_steps").inc();
        self.metrics
            .counter("engine.decoded_tokens")
            .add((ids.len() - failed_idx.len()) as u64);

        let mut results = vec![];
        for id in done {
            let seq = self.seqs.remove(&id).unwrap();
            self.scheduler.remove(id);
            results.push(RequestResult {
                id,
                prompt_len: seq.req.prompt.len(),
                ttft: seq
                    .first_token_at
                    .map(|t| t - seq.req.submitted_at)
                    .unwrap_or_default(),
                latency: seq.req.submitted_at.elapsed(),
                decode_steps: seq.decode_steps,
                generated: seq.generated,
            });
        }
        Ok(results)
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}
