//! The serving engine: PJRT compute + compressed caches + retrieval.
//!
//! Per engine step ([`Engine::step`]): the scheduler either prefixes a
//! queued request (PJRT `prefill_l{N}` → per-layer
//! [`SequenceCache::prefill_layer`] with SnapKV windows) or decodes the
//! running batch (`embed` → per-layer `decode_qkv` → native GQA-grouped
//! attention through the sequence-level [`SequenceCache`] API →
//! `decode_out` → `logits` → greedy sample). The KV cache never crosses
//! the PJRT boundary.
//!
//! Decode fan-out: each layer builds one [`DecodePlan`] per sequence,
//! every sequence's cache expands it into [`HeadTask`]s
//! ([`SequenceCache::push_tasks`]), and the pre-built task slice runs
//! over `ThreadPool::for_each_task` — an atomic cursor, no per-job
//! closure boxing, and (the task arena being recycled by
//! [`DecodeWorkQueue`]) zero steady-state heap allocations in the engine
//! layer. Methods are built by the [`crate::method::registry`] rather
//! than a hardcoded match.
//!
//! [`HeadTask`]: crate::method::HeadTask

use crate::substrate::error as anyhow;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use super::request::{Request, RequestId, RequestResult};
use super::router::{AdmitError, Router};
use super::scheduler::{Scheduler, StepPlan};
use crate::config::{EngineConfig, ModelConfig};
use crate::method::registry::{self, BuildCtx, CacheMethod};
use crate::method::{DecodePlan, DecodeWorkQueue, SequenceCache};
use crate::runtime::{HostTensor, PjrtRuntime};
use crate::substrate::exec::ThreadPool;
use crate::substrate::metrics::Registry;

pub use crate::method::MethodKind;

struct SeqState {
    req: Request,
    /// the whole sequence's cache — every (layer, kv-head)'s state,
    /// layer-major, behind the sequence-level method API
    cache: Box<dyn SequenceCache>,
    /// prompt + generated tokens so far
    tokens: Vec<u8>,
    generated: Vec<u8>,
    first_token_at: Option<Instant>,
    decode_steps: usize,
}

pub struct Engine {
    pub rt: PjrtRuntime,
    pub model: ModelConfig,
    pub cfg: EngineConfig,
    pub method: MethodKind,
    pub metrics: Registry,
    /// the registry entry building each admitted sequence's cache
    builder: &'static dyn CacheMethod,
    router: Router,
    scheduler: Scheduler,
    seqs: HashMap<RequestId, SeqState>,
    /// requests deferred by pool pressure (retried before the queue)
    stash: Vec<Request>,
    /// total cached tokens across sequences (pool pressure heuristic)
    cached_tokens: usize,
    /// decode fan-out workers (one task per (sequence, kv head))
    workers: ThreadPool,
    /// recycled task arena for the per-layer decode fan-out
    decode_tasks: DecodeWorkQueue,
}

impl Engine {
    pub fn new(artifact_dir: &Path, cfg: EngineConfig, method: MethodKind) -> anyhow::Result<Self> {
        let mut cfg = cfg;
        cfg.method = method.name().to_string();
        registry::validate_overlay(&cfg.method, &cfg.method_overlay)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let builder = method.entry();
        let rt = PjrtRuntime::load(artifact_dir)?;
        let model = rt.manifest.model.clone();
        let metrics = Registry::default();
        let max_prompt = model.max_seq;
        Ok(Self {
            router: Router::new(cfg.queue_limit, max_prompt, metrics.clone()),
            scheduler: Scheduler::new(cfg.max_batch),
            seqs: HashMap::new(),
            stash: vec![],
            cached_tokens: 0,
            workers: if cfg.decode_workers == 0 {
                ThreadPool::default_size()
            } else {
                ThreadPool::new(cfg.decode_workers)
            },
            decode_tasks: DecodeWorkQueue::new(),
            builder,
            rt,
            model,
            cfg,
            method,
            metrics,
        })
    }

    /// Build from the config's validated `method` string (the CLI path:
    /// `--method Quest` and `"method": "quest"` behave identically).
    pub fn from_config(artifact_dir: &Path, cfg: EngineConfig) -> anyhow::Result<Self> {
        let kind = MethodKind::parse(&cfg.method).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::new(artifact_dir, cfg, kind)
    }

    pub fn submit(&mut self, prompt: Vec<u8>, max_new: usize) -> Result<RequestId, AdmitError> {
        self.router.submit(prompt, max_new)
    }

    pub fn idle(&self) -> bool {
        self.router.is_empty() && self.seqs.is_empty() && self.stash.is_empty()
    }

    pub fn running(&self) -> usize {
        self.scheduler.running().len()
    }

    /// KV bytes currently held across sequences (Fig. 5 metric).
    pub fn cache_bytes(&self) -> usize {
        self.seqs.values().map(|s| s.cache.memory_bytes()).sum()
    }

    fn pool_can_admit(&self, prompt_len: usize) -> bool {
        let per_head = prompt_len + self.cfg.max_new_tokens;
        let heads = self.model.n_layers * self.model.n_kv_heads;
        self.cached_tokens + per_head * heads <= self.cfg.pool_tokens * heads
    }

    /// Drive one scheduler step; returns requests completed in this step.
    ///
    /// Policy: prefill-prioritized continuous batching — admit one queued
    /// request per step while batch capacity and pool pressure allow,
    /// otherwise run one decode step over the whole running set.
    pub fn step(&mut self) -> anyhow::Result<Vec<RequestResult>> {
        if self.scheduler.has_capacity() {
            if let Some(req) = self.stash.pop().or_else(|| self.router.pop()) {
                // force-admit when nothing is running (deadlock guard)
                if self.pool_can_admit(req.prompt.len()) || self.seqs.is_empty() {
                    self.do_prefill(req)?;
                    return Ok(vec![]);
                }
                self.metrics.counter("engine.deferred_admissions").inc();
                self.stash.push(req);
            }
        }
        match self.scheduler.plan(None, false) {
            StepPlan::Decode(ids) => self.do_decode(&ids),
            _ => Ok(vec![]),
        }
    }

    /// Run until all submitted work completes; returns all results.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<RequestResult>> {
        let mut out = vec![];
        while !self.idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    fn do_prefill(&mut self, req: Request) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let prompt_len = req.prompt.len();
        let bucket = self
            .rt
            .manifest
            .prefill_bucket(prompt_len)
            .ok_or_else(|| anyhow::anyhow!("prompt {} exceeds buckets", prompt_len))?
            .name
            .clone();
        let padded: usize = bucket.strip_prefix("prefill_l").unwrap().parse().unwrap();

        let mut tokens = vec![0i32; padded];
        for (i, &b) in req.prompt.iter().enumerate() {
            tokens[i] = b as i32;
        }
        let outs = self.rt.run(
            &bucket,
            None,
            &[
                HostTensor::I32(tokens, vec![1, padded]),
                HostTensor::scalar_i32(prompt_len as i32),
            ],
        )?;
        let (k_cache, v_cache, last_logits, q_window) = (&outs[0], &outs[1], &outs[2], &outs[3]);

        let m = &self.model;
        let (nl, kvh, hd, h) = (m.n_layers, m.n_kv_heads, m.head_dim, m.n_heads);
        let r = m.gqa_ratio();
        let w = q_window.shape()[1];
        let kc = k_cache.as_f32();
        let vc = v_cache.as_f32();
        let qw = q_window.as_f32();

        // build the sequence's cache via the registry, then feed it one
        // layer at a time (kv-head-major staging buffers)
        let budget_hint = self.cfg.budget_for(prompt_len) + self.cfg.selfindex.sink_tokens;
        let ctx = BuildCtx {
            dim: hd,
            n_layers: nl,
            kv_heads: kvh,
            gqa_ratio: r,
            budget_hint,
            pool_tokens: self.cfg.pool_tokens,
            selfindex: &self.cfg.selfindex,
            overlay: &self.cfg.method_overlay,
        };
        let mut cache = self.builder.build_seq(&ctx);
        let mut keys_buf = vec![0.0f32; kvh * prompt_len * hd];
        let mut vals_buf = vec![0.0f32; kvh * prompt_len * hd];
        let mut qw_buf = vec![0.0f32; kvh * w * r * hd];
        for l in 0..nl {
            for head in 0..kvh {
                // k_cache layout: (layers, padded, kvh, hd)
                for t in 0..prompt_len {
                    let src = ((l * padded + t) * kvh + head) * hd;
                    let dst = (head * prompt_len + t) * hd;
                    keys_buf[dst..dst + hd].copy_from_slice(&kc[src..src + hd]);
                    vals_buf[dst..dst + hd].copy_from_slice(&vc[src..src + hd]);
                }
                // q_window layout: (layers, w, h, hd); group query heads
                // under their kv head, head-major
                for wi in 0..w {
                    for ri in 0..r {
                        let qh = head * r + ri;
                        let src = ((l * w + wi) * h + qh) * hd;
                        let dst = ((head * w + wi) * r + ri) * hd;
                        qw_buf[dst..dst + hd].copy_from_slice(&qw[src..src + hd]);
                    }
                }
            }
            cache.prefill_layer(l, &keys_buf, &vals_buf, &qw_buf);
        }
        self.cached_tokens += prompt_len * nl * kvh;

        // first token from prefill logits
        let first = argmax(last_logits.as_f32()) as u8;
        let mut tokens_all = req.prompt.clone();
        tokens_all.push(first);
        let id = req.id;
        let st = SeqState {
            req,
            cache,
            tokens: tokens_all,
            generated: vec![first],
            first_token_at: Some(Instant::now()),
            decode_steps: 1,
        };
        self.seqs.insert(id, st);
        self.scheduler.add_running(id);
        self.metrics
            .histogram("engine.prefill_latency")
            .observe(t0.elapsed());
        self.metrics.counter("engine.prefills").inc();
        Ok(())
    }

    /// One decode step over `states`: embed → per-layer qkv → parallel
    /// native attention (one [`crate::method::HeadTask`] per (sequence,
    /// kv-head), executed over the pool's atomic-cursor work queue; each
    /// task owns its leaf's scratch arenas and a disjoint slice of the
    /// output buffer) → output projection → logits → greedy sample.
    fn decode_batch(&mut self, states: &mut [SeqState]) -> anyhow::Result<()> {
        let b = states.len();
        let m = self.model.clone();
        let (nl, kvh, hd, h, d) = (m.n_layers, m.n_kv_heads, m.head_dim, m.n_heads, m.d_model);
        let r = m.gqa_ratio();

        let bucket = self
            .rt
            .manifest
            .batch_bucket("embed_b", b)
            .ok_or_else(|| anyhow::anyhow!("batch {} exceeds buckets", b))?
            .name
            .clone();
        let bb: usize = bucket.strip_prefix("embed_b").unwrap().parse().unwrap();

        // stage last tokens + positions (padded to bucket)
        let mut toks = vec![0i32; bb];
        let mut pos = vec![0i32; bb];
        for (i, s) in states.iter().enumerate() {
            toks[i] = *s.tokens.last().unwrap() as i32;
            pos[i] = (s.tokens.len() - 1) as i32;
        }
        let outs = self
            .rt
            .run(&format!("embed_b{bb}"), None, &[HostTensor::I32(toks, vec![bb])])?;
        let mut x = outs.into_iter().next().unwrap();

        let budgets: Vec<usize> = states
            .iter()
            .map(|s| self.cfg.budget_for(s.tokens.len()))
            .collect();

        for l in 0..nl {
            let qkv = self.rt.run(
                &format!("decode_qkv_b{bb}"),
                Some(l),
                &[x.clone(), HostTensor::I32(pos.clone(), vec![bb])],
            )?;
            let (q, k, v) = (&qkv[0], &qkv[1], &qkv[2]);
            let qf = q.as_f32(); // (bb, h, hd)
            let kf = k.as_f32(); // (bb, kvh, hd)
            let vf = v.as_f32();

            // native attention per (seq, kv head), GQA-grouped, fanned
            // out over the slice-based work queue: every sequence's cache
            // expands its DecodePlan into HeadTasks (disjoint &mut leaf +
            // disjoint r·hd output chunk), and the pre-built task slice
            // runs under one atomic cursor — no per-job boxing
            let mut o = vec![0.0f32; bb * h * hd];
            {
                let mut tasks = self.decode_tasks.take();
                let mut o_chunks = o.chunks_mut(h * hd);
                for (i, seq) in states.iter_mut().enumerate() {
                    let plan = DecodePlan {
                        layer: l,
                        dim: hd,
                        kv_heads: kvh,
                        gqa_ratio: r,
                        budget: budgets[i],
                        k_rows: &kf[i * kvh * hd..(i + 1) * kvh * hd],
                        v_rows: &vf[i * kvh * hd..(i + 1) * kvh * hd],
                        // group queries (r heads per kv head) are
                        // contiguous in the (h, hd) layout
                        queries: &qf[i * h * hd..(i + 1) * h * hd],
                    };
                    // chunk (i) is this sequence's (kvh × r × hd) output
                    let oslice = o_chunks.next().unwrap();
                    seq.cache.push_tasks(&plan, oslice, &mut tasks);
                }
                self.decode_tasks.dispatch(&self.workers, tasks);
            }
            self.cached_tokens += b * kvh;

            let next = self.rt.run(
                &format!("decode_out_b{bb}"),
                Some(l),
                &[HostTensor::F32(o, vec![bb, h, hd]), x.clone()],
            )?;
            x = next.into_iter().next().unwrap();
        }
        debug_assert_eq!(x.shape(), &[bb, d]);

        let logits = self
            .rt
            .run(&format!("logits_b{bb}"), None, &[x])?
            .into_iter()
            .next()
            .unwrap();
        let lf = logits.as_f32(); // (bb, vocab)
        let vocab = self.model.vocab_size;
        for (i, seq) in states.iter_mut().enumerate() {
            let tok = argmax(&lf[i * vocab..(i + 1) * vocab]) as u8;
            seq.tokens.push(tok);
            seq.generated.push(tok);
            seq.decode_steps += 1;
        }
        Ok(())
    }

    fn do_decode(&mut self, ids: &[RequestId]) -> anyhow::Result<Vec<RequestResult>> {
        let t0 = Instant::now();
        // Pull the batch's states out of the map once: the parallel
        // per-(sequence, kv-head) fan-out needs disjoint `&mut` access,
        // which a HashMap cannot hand out. States are always reinserted —
        // on success, on error, AND on a re-raised fan-out panic — so a
        // caller that catches the panic still sees a consistent map.
        let mut states: Vec<SeqState> = Vec::with_capacity(ids.len());
        for id in ids {
            match self.seqs.remove(id) {
                Some(st) => states.push(st),
                None => {
                    // put back what was already taken before reporting the
                    // scheduler bug — the map must never lose live states
                    for (id2, st) in ids.iter().zip(states.drain(..)) {
                        self.seqs.insert(*id2, st);
                    }
                    panic!("decode of unknown/duplicate seq {id}");
                }
            }
        }
        let step = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.decode_batch(&mut states)
        }));
        for (id, st) in ids.iter().zip(states) {
            self.seqs.insert(*id, st);
        }
        match step {
            Ok(res) => res?,
            Err(payload) => std::panic::resume_unwind(payload),
        }

        let nl = self.model.n_layers;
        let kvh = self.model.n_kv_heads;
        let mut done = vec![];
        for id in ids {
            let seq = &self.seqs[id];
            if seq.generated.len() >= seq.req.max_new_tokens {
                done.push(*id);
            }
        }

        self.metrics
            .histogram("engine.decode_step_latency")
            .observe(t0.elapsed());
        self.metrics.counter("engine.decode_steps").inc();
        self.metrics
            .counter("engine.decoded_tokens")
            .add(ids.len() as u64);

        let mut results = vec![];
        for id in done {
            let seq = self.seqs.remove(&id).unwrap();
            self.scheduler.remove(id);
            self.cached_tokens = self
                .cached_tokens
                .saturating_sub(seq.tokens.len() * nl * kvh);
            results.push(RequestResult {
                id,
                prompt_len: seq.req.prompt.len(),
                ttft: seq
                    .first_token_at
                    .map(|t| t - seq.req.submitted_at)
                    .unwrap_or_default(),
                latency: seq.req.submitted_at.elapsed(),
                decode_steps: seq.decode_steps,
                generated: seq.generated,
            });
        }
        Ok(results)
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}
