//! Request/response types and lifecycle.

use std::time::{Duration, Instant};

pub type RequestId = u64;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    /// byte-level prompt (vocab 256: token == byte)
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    pub submitted_at: Instant,
    /// router-interned content hash of `prompt` (computed once at submit;
    /// re-prefills after preemption reuse it instead of re-hashing)
    pub prompt_hash: u128,
    /// evictions suffered so far — drives the scheduler's pin-after-N
    /// aging and the 2N thrashing cutoff (see `EngineConfig::preempt_budget`)
    pub preempt_count: u32,
    /// wall-clock SLO: the instant at which the request expires
    /// (`submit_with_deadline` stamps `now + slo`); `None` = no deadline.
    /// Checked at step boundaries AND at admission, so an already-expired
    /// request never burns a long prefill.
    pub deadline: Option<Instant>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Prefilling,
    Decoding,
    Finished,
    Rejected,
}

/// How a request's lifecycle ended. Every terminal state is structured —
/// a hardened engine never reports failure by panicking or by silently
/// truncating output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// ran to `max_new_tokens` — `generated` is the full output
    Completed,
    /// deadline expired mid-flight — `generated` holds the partial output
    /// produced so far (possibly empty if it never left the queue)
    DeadlineExceeded,
    /// evicted more than twice its preemption budget: the pool cannot
    /// hold this request's working set alongside the running mix
    Thrashing,
    /// a decode worker panicked on this sequence; its in-memory state is
    /// suspect, so the partial output is returned and the blocks released
    WorkerPanic,
    /// an engine-side invariant broke while serving this request (e.g. a
    /// drifted PJRT bucket name) — contained per the robustness policy:
    /// the request fails with whatever it produced, the engine continues
    Failed,
}

#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: RequestId,
    pub generated: Vec<u8>,
    pub prompt_len: usize,
    /// queue admission -> first generated token
    pub ttft: Duration,
    /// queue admission -> completion
    pub latency: Duration,
    pub decode_steps: usize,
    /// how the lifecycle ended (partial outputs carry non-`Completed`)
    pub outcome: Outcome,
}

impl RequestResult {
    /// decode throughput in tokens/sec (excludes prefill)
    pub fn decode_tps(&self) -> f64 {
        let decode_time = self.latency.saturating_sub(self.ttft);
        if decode_time.is_zero() || self.decode_steps <= 1 {
            return 0.0;
        }
        (self.decode_steps - 1) as f64 / decode_time.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tps_math() {
        let r = RequestResult {
            id: 1,
            generated: vec![0; 11],
            prompt_len: 100,
            ttft: Duration::from_millis(100),
            latency: Duration::from_millis(1100),
            decode_steps: 11,
            outcome: Outcome::Completed,
        };
        assert!((r.decode_tps() - 10.0).abs() < 1e-9);
        assert_eq!(r.outcome, Outcome::Completed);
    }
}
