//! Request/response types and lifecycle.

use std::time::{Duration, Instant};

pub type RequestId = u64;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    /// byte-level prompt (vocab 256: token == byte)
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    pub submitted_at: Instant,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Prefilling,
    Decoding,
    Finished,
    Rejected,
}

#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: RequestId,
    pub generated: Vec<u8>,
    pub prompt_len: usize,
    /// queue admission -> first generated token
    pub ttft: Duration,
    /// queue admission -> completion
    pub latency: Duration,
    pub decode_steps: usize,
}

impl RequestResult {
    /// decode throughput in tokens/sec (excludes prefill)
    pub fn decode_tps(&self) -> f64 {
        let decode_time = self.latency.saturating_sub(self.ttft);
        if decode_time.is_zero() || self.decode_steps <= 1 {
            return 0.0;
        }
        (self.decode_steps - 1) as f64 / decode_time.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tps_math() {
        let r = RequestResult {
            id: 1,
            generated: vec![0; 11],
            prompt_len: 100,
            ttft: Duration::from_millis(100),
            latency: Duration::from_millis(1100),
            decode_steps: 11,
        };
        assert!((r.decode_tps() - 10.0).abs() < 1e-9);
    }
}
