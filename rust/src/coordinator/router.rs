//! Admission control: bounded FIFO with rejection under backpressure.

use std::collections::VecDeque;
use std::time::Instant;

use super::request::{Request, RequestId};
use crate::kvcache::{fnv128_bytes, random_seed128};
use crate::substrate::metrics::Registry;

#[derive(Debug)]
pub enum AdmitError {
    QueueFull(usize),
    PromptTooLong(usize, usize),
    EmptyPrompt,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull(n) => write!(f, "queue full ({n} waiting)"),
            AdmitError::PromptTooLong(got, max) => {
                write!(f, "prompt too long: {got} > {max}")
            }
            AdmitError::EmptyPrompt => write!(f, "empty prompt"),
        }
    }
}

impl std::error::Error for AdmitError {}

pub struct Router {
    queue: VecDeque<Request>,
    limit: usize,
    max_prompt: usize,
    next_id: RequestId,
    metrics: Registry,
    /// random key for interned prompt content hashes: computed once here
    /// at submit, carried on the `Request` through every re-stash, so a
    /// preempted request never re-hashes its full prompt on re-prefill
    hash_seed: u128,
}

impl Router {
    pub fn new(limit: usize, max_prompt: usize, metrics: Registry) -> Self {
        Self {
            queue: VecDeque::new(),
            limit,
            max_prompt,
            next_id: 1,
            metrics,
            hash_seed: random_seed128(),
        }
    }

    /// Validate + enqueue; returns the assigned id.
    pub fn submit(
        &mut self,
        prompt: Vec<u8>,
        max_new_tokens: usize,
    ) -> Result<RequestId, AdmitError> {
        self.submit_with(prompt, max_new_tokens, None)
    }

    /// [`Self::submit`] with an absolute wall-clock deadline (the serving
    /// layer stamps `now + slo`).
    pub fn submit_with(
        &mut self,
        prompt: Vec<u8>,
        max_new_tokens: usize,
        deadline: Option<Instant>,
    ) -> Result<RequestId, AdmitError> {
        self.submit_at(prompt, max_new_tokens, deadline, Instant::now())
    }

    /// [`Self::submit_with`] with an explicit submission stamp: the
    /// serving engine passes its own notion of "now", so under a virtual
    /// clock TTFT/latency are pure functions of the step schedule.
    pub fn submit_at(
        &mut self,
        prompt: Vec<u8>,
        max_new_tokens: usize,
        deadline: Option<Instant>,
        now: Instant,
    ) -> Result<RequestId, AdmitError> {
        if prompt.is_empty() {
            return Err(AdmitError::EmptyPrompt);
        }
        if prompt.len() > self.max_prompt {
            self.metrics.counter("router.rejected_len").inc();
            return Err(AdmitError::PromptTooLong(prompt.len(), self.max_prompt));
        }
        if self.queue.len() >= self.limit {
            self.metrics.counter("router.rejected_full").inc();
            return Err(AdmitError::QueueFull(self.queue.len()));
        }
        let id = self.next_id;
        self.next_id += 1;
        let prompt_hash = fnv128_bytes(self.hash_seed, &prompt);
        self.queue.push_back(Request {
            id,
            prompt,
            max_new_tokens,
            submitted_at: now,
            prompt_hash,
            preempt_count: 0,
            deadline,
        });
        self.metrics.counter("router.admitted").inc();
        self.metrics.gauge("router.queue_depth").set(self.queue.len() as i64);
        Ok(id)
    }

    /// Drain every queued request whose deadline is at or before `now` —
    /// the engine turns them into `Outcome::DeadlineExceeded` results with
    /// empty output (they never ran).
    pub fn expire_before(&mut self, now: Instant) -> Vec<Request> {
        let expired: Vec<Request> = {
            let mut kept = VecDeque::with_capacity(self.queue.len());
            let mut out = vec![];
            for r in self.queue.drain(..) {
                if r.deadline.is_some_and(|d| now >= d) {
                    out.push(r);
                } else {
                    kept.push_back(r);
                }
            }
            self.queue = kept;
            out
        };
        if !expired.is_empty() {
            self.metrics.gauge("router.queue_depth").set(self.queue.len() as i64);
        }
        expired
    }

    /// Head of the queue without dequeueing — the engine sizes its exact
    /// admission check (prompt blocks) against this before popping.
    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    /// Next request if the caller has capacity.
    pub fn pop(&mut self) -> Option<Request> {
        let r = self.queue.pop_front();
        self.metrics.gauge("router.queue_depth").set(self.queue.len() as i64);
        r
    }

    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(limit: usize) -> Router {
        Router::new(limit, 4096, Registry::default())
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut r = router(10);
        let a = r.submit(vec![1], 4).unwrap();
        let b = r.submit(vec![2], 4).unwrap();
        assert!(b > a);
        assert_eq!(r.pop().unwrap().id, a);
        assert_eq!(r.pop().unwrap().id, b);
        assert!(r.pop().is_none());
    }

    #[test]
    fn backpressure() {
        let mut r = router(2);
        r.submit(vec![1], 1).unwrap();
        r.submit(vec![2], 1).unwrap();
        assert!(matches!(
            r.submit(vec![3], 1),
            Err(AdmitError::QueueFull(2))
        ));
        r.pop();
        assert!(r.submit(vec![3], 1).is_ok());
    }

    #[test]
    fn validation() {
        let mut r = router(4);
        assert!(matches!(r.submit(vec![], 1), Err(AdmitError::EmptyPrompt)));
        assert!(matches!(
            r.submit(vec![0; 5000], 1),
            Err(AdmitError::PromptTooLong(5000, 4096))
        ));
    }

    #[test]
    fn prompt_hash_interned_once_per_content() {
        let mut r = router(8);
        r.submit(vec![1, 2, 3], 1).unwrap();
        r.submit(vec![1, 2, 3], 1).unwrap();
        r.submit(vec![1, 2, 4], 1).unwrap();
        let a = r.pop().unwrap();
        let b = r.pop().unwrap();
        let c = r.pop().unwrap();
        assert_ne!(a.prompt_hash, 0, "hash is computed at submit");
        assert_eq!(a.prompt_hash, b.prompt_hash, "same content, same hash");
        assert_ne!(a.prompt_hash, c.prompt_hash);
        // seed is per-router: the same prompt hashes differently elsewhere
        let mut r2 = router(8);
        r2.submit(vec![1, 2, 3], 1).unwrap();
        assert_ne!(r2.pop().unwrap().prompt_hash, a.prompt_hash);
    }

    #[test]
    fn expire_before_drains_only_overdue_deadlines() {
        use std::time::Duration;
        let t0 = Instant::now();
        let mut r = router(8);
        let a = r
            .submit_with(vec![1], 4, Some(t0 + Duration::from_millis(5)))
            .unwrap();
        let b = r
            .submit_with(vec![2], 4, Some(t0 + Duration::from_secs(100)))
            .unwrap();
        let c = r.submit(vec![3], 4).unwrap();
        let expired = r.expire_before(t0 + Duration::from_millis(5));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, a);
        assert_eq!(r.depth(), 2, "live deadline and no-deadline stay queued");
        assert_eq!(r.pop().unwrap().id, b);
        assert_eq!(r.pop().unwrap().id, c);
        assert!(r.expire_before(t0 + Duration::from_secs(1000)).is_empty());
    }
}
