//! Admission control: bounded FIFO with rejection under backpressure.

use std::collections::VecDeque;
use std::time::Instant;

use super::request::{Request, RequestId};
use crate::substrate::metrics::Registry;

#[derive(Debug)]
pub enum AdmitError {
    QueueFull(usize),
    PromptTooLong(usize, usize),
    EmptyPrompt,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull(n) => write!(f, "queue full ({n} waiting)"),
            AdmitError::PromptTooLong(got, max) => {
                write!(f, "prompt too long: {got} > {max}")
            }
            AdmitError::EmptyPrompt => write!(f, "empty prompt"),
        }
    }
}

impl std::error::Error for AdmitError {}

pub struct Router {
    queue: VecDeque<Request>,
    limit: usize,
    max_prompt: usize,
    next_id: RequestId,
    metrics: Registry,
}

impl Router {
    pub fn new(limit: usize, max_prompt: usize, metrics: Registry) -> Self {
        Self { queue: VecDeque::new(), limit, max_prompt, next_id: 1, metrics }
    }

    /// Validate + enqueue; returns the assigned id.
    pub fn submit(
        &mut self,
        prompt: Vec<u8>,
        max_new_tokens: usize,
    ) -> Result<RequestId, AdmitError> {
        if prompt.is_empty() {
            return Err(AdmitError::EmptyPrompt);
        }
        if prompt.len() > self.max_prompt {
            self.metrics.counter("router.rejected_len").inc();
            return Err(AdmitError::PromptTooLong(prompt.len(), self.max_prompt));
        }
        if self.queue.len() >= self.limit {
            self.metrics.counter("router.rejected_full").inc();
            return Err(AdmitError::QueueFull(self.queue.len()));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request {
            id,
            prompt,
            max_new_tokens,
            submitted_at: Instant::now(),
        });
        self.metrics.counter("router.admitted").inc();
        self.metrics.gauge("router.queue_depth").set(self.queue.len() as i64);
        Ok(id)
    }

    /// Head of the queue without dequeueing — the engine sizes its exact
    /// admission check (prompt blocks) against this before popping.
    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    /// Next request if the caller has capacity.
    pub fn pop(&mut self) -> Option<Request> {
        let r = self.queue.pop_front();
        self.metrics.gauge("router.queue_depth").set(self.queue.len() as i64);
        r
    }

    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(limit: usize) -> Router {
        Router::new(limit, 4096, Registry::default())
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut r = router(10);
        let a = r.submit(vec![1], 4).unwrap();
        let b = r.submit(vec![2], 4).unwrap();
        assert!(b > a);
        assert_eq!(r.pop().unwrap().id, a);
        assert_eq!(r.pop().unwrap().id, b);
        assert!(r.pop().is_none());
    }

    #[test]
    fn backpressure() {
        let mut r = router(2);
        r.submit(vec![1], 1).unwrap();
        r.submit(vec![2], 1).unwrap();
        assert!(matches!(
            r.submit(vec![3], 1),
            Err(AdmitError::QueueFull(2))
        ));
        r.pop();
        assert!(r.submit(vec![3], 1).is_ok());
    }

    #[test]
    fn validation() {
        let mut r = router(4);
        assert!(matches!(r.submit(vec![], 1), Err(AdmitError::EmptyPrompt)));
        assert!(matches!(
            r.submit(vec![0; 5000], 1),
            Err(AdmitError::PromptTooLong(5000, 4096))
        ));
    }
}
