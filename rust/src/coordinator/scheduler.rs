//! Step planning: continuous batching with prefill/decode interleaving
//! and pool-pressure preemption.
//!
//! Policy (vLLM-flavored, prefill-prioritized): if a request waits at the
//! head of the queue and the running set is below `max_batch` — and the
//! shared block pool has room for its prompt *plus* the running set's
//! next decode step — the next step admits it; otherwise decode the whole
//! running set. When even the decode step cannot fit (`free_blocks <
//! step_blocks`), the plan preempts the **youngest** running sequence:
//! the engine releases its blocks and re-stashes the request for
//! recomputation (greedy decode is deterministic, so a preempted request
//! finishes with bit-identical output, just later).
//!
//! All pool inputs arrive as **exact block counts** ([`PoolPressure`]) —
//! the engine measures them from the shared pool and the sequence caches,
//! so the admission decision that used to be a token-counting guess is
//! one testable code path here.

use super::request::RequestId;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StepPlan {
    /// admit the request at the head of the deferred/router queue
    /// (the engine pops it and runs its prefill — or, when chunked
    /// prefill is configured, its first `prefill_chunk_tokens` slice)
    Prefill,
    /// continue the mid-flight chunked prefill with its next token slice
    /// (see [`PoolPressure::inflight_prefill`])
    PrefillChunk,
    /// one decode step over these running sequences
    Decode(Vec<RequestId>),
    /// evict this (youngest unpinned) running sequence: release its
    /// blocks and re-stash its request, then re-plan
    Preempt(RequestId),
    /// evict this (youngest unpinned) running sequence by swapping its
    /// blocks to the host tier instead of dropping them — emitted in
    /// place of [`StepPlan::Preempt`] when the engine marked the victim
    /// [`PoolPressure::swap_eligible`] (swap policy on AND the
    /// resume-vs-recompute cost model favors restoring over re-prefill)
    SwapOut(RequestId),
    /// every running sequence is pinned and the step still cannot fit:
    /// fail this (youngest) one with `Outcome::Thrashing` — the pool is
    /// too small for the pinned working set, and shedding beats livelock
    Shed(RequestId),
    /// nothing to do
    Idle,
}

/// Exact shared-pool occupancy inputs for one planning decision.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolPressure {
    /// free blocks in the engine's shared pool right now
    pub free_blocks: usize,
    /// blocks the head-of-queue prompt needs to admit (`None` = nothing
    /// queued); prefix reuse can only lower the real cost, so this is a
    /// safe upper bound
    pub admit_blocks: Option<usize>,
    /// blocks the running set will allocate on its next decode step
    pub step_blocks: usize,
    /// a chunked prefill is mid-flight: new admissions pause until its
    /// final slice lands, and its remaining chunks alternate with decode
    /// steps over the running set
    pub inflight_prefill: bool,
    /// the previous plan ran a prefill chunk — with anything running, the
    /// next plan is a decode turn (strict alternation: a 100K-token
    /// prompt can never stall an in-flight decode for more than one
    /// chunk's worth of work)
    pub chunk_last: bool,
    /// the engine's swap policy verdict for the current preemption victim
    /// candidate (the youngest unpinned running sequence): when true, a
    /// preemption is planned as [`StepPlan::SwapOut`] instead of
    /// [`StepPlan::Preempt`]. Default `false` — the policy knob is off
    /// and preemption behaves exactly as before.
    pub swap_eligible: bool,
}

pub struct Scheduler {
    pub max_batch: usize,
    running: Vec<RequestId>,
    /// sequences aged past their preemption budget: never chosen as a
    /// preemption victim again (the anti-starvation half of the budget;
    /// the engine fails requests that *keep* thrashing past 2× budget)
    pinned: Vec<RequestId>,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Self {
        Self { max_batch, running: vec![], pinned: vec![] }
    }

    pub fn running(&self) -> &[RequestId] {
        &self.running
    }

    pub fn has_capacity(&self) -> bool {
        self.running.len() < self.max_batch
    }

    /// Called by the engine after a successful prefill.
    pub fn add_running(&mut self, id: RequestId) {
        assert!(self.has_capacity(), "over-admitted");
        assert!(!self.running.contains(&id), "duplicate running id");
        self.running.push(id);
    }

    /// Shield `id` from future preemption (aged past its budget).
    pub fn pin(&mut self, id: RequestId) {
        if !self.pinned.contains(&id) {
            self.pinned.push(id);
        }
    }

    pub fn is_pinned(&self, id: RequestId) -> bool {
        self.pinned.contains(&id)
    }

    /// The sequence [`Scheduler::plan`] would evict if the next step does
    /// not fit: the youngest unpinned running sequence. `None` when fewer
    /// than two sequences are running (the last one is never evicted) or
    /// when every candidate is pinned (the plan degrades to
    /// [`StepPlan::Shed`]).
    ///
    /// The engine prices its swap-vs-recompute cost model against this
    /// candidate *before* building [`PoolPressure`]: `swap_eligible` must
    /// describe the same victim `plan` will pick.
    pub fn victim_candidate(&self) -> Option<RequestId> {
        if self.running.len() < 2 {
            return None;
        }
        self.running.iter().rev().find(|&&id| !self.is_pinned(id)).copied()
    }

    /// Called when a sequence finishes (or is preempted / shed / failed).
    pub fn remove(&mut self, id: RequestId) {
        self.running.retain(|&r| r != id);
        self.pinned.retain(|&r| r != id);
    }

    /// Plan the next step from exact pool pressure.
    ///
    /// * A mid-flight chunked prefill ([`PoolPressure::inflight_prefill`])
    ///   takes priority over new admissions and strictly alternates with
    ///   decode turns: after a chunk (`chunk_last`), anything running gets
    ///   a decode step (or a preemption if that step cannot fit) before
    ///   the next chunk; with nothing running, chunks run back-to-back.
    /// * Admission requires batch capacity AND enough free blocks for the
    ///   prompt *on top of* the running set's next step — admitting must
    ///   never trigger an immediate preemption. When nothing is running
    ///   the head request is force-admitted (deadlock guard; a prompt
    ///   larger than the whole pool is rejected by the engine instead).
    /// * Preemption picks the youngest (most recently admitted) running
    ///   sequence that is not pinned — it has the least sunk decode work
    ///   to recompute, and pinned sequences already paid their eviction
    ///   budget. The last running sequence is never preempted: with the
    ///   pool entirely its own, eviction could not free anything another
    ///   step needs. When *every* candidate is pinned the plan degrades to
    ///   [`StepPlan::Shed`] — the engine fails that request with a
    ///   structured `Thrashing` outcome rather than spinning forever.
    pub fn plan(&self, pressure: &PoolPressure) -> StepPlan {
        if pressure.inflight_prefill {
            if self.running.is_empty() || !pressure.chunk_last {
                return StepPlan::PrefillChunk;
            }
            // chunk_last with a live running set: fall through to the
            // decode/preempt logic below — the running set's turn
        } else if let Some(need) = pressure.admit_blocks {
            let fits = pressure
                .free_blocks
                .checked_sub(pressure.step_blocks)
                .is_some_and(|headroom| headroom >= need);
            if self.has_capacity() && (self.running.is_empty() || fits) {
                return StepPlan::Prefill;
            }
        }
        if self.running.is_empty() {
            return StepPlan::Idle;
        }
        if pressure.free_blocks < pressure.step_blocks && self.running.len() > 1 {
            return match self.running.iter().rev().find(|&&id| !self.is_pinned(id)) {
                Some(&victim) if pressure.swap_eligible => StepPlan::SwapOut(victim),
                Some(&victim) => StepPlan::Preempt(victim),
                None => StepPlan::Shed(*self.running.last().unwrap()),
            };
        }
        StepPlan::Decode(self.running.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pressure(
        free_blocks: usize,
        admit_blocks: Option<usize>,
        step_blocks: usize,
    ) -> PoolPressure {
        PoolPressure { free_blocks, admit_blocks, step_blocks, ..Default::default() }
    }

    #[test]
    fn prefill_prioritized_under_capacity() {
        let mut s = Scheduler::new(2);
        assert_eq!(s.plan(&pressure(100, Some(4), 0)), StepPlan::Prefill);
        s.add_running(1);
        assert_eq!(s.plan(&pressure(100, Some(4), 1)), StepPlan::Prefill);
        s.add_running(2);
        // batch full: decode
        assert_eq!(
            s.plan(&pressure(100, Some(4), 2)),
            StepPlan::Decode(vec![1, 2])
        );
    }

    #[test]
    fn pool_pressure_blocks_admission() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        // 5 free, step needs 2 → only 3 of the 4 admit blocks remain
        assert_eq!(
            s.plan(&pressure(5, Some(4), 2)),
            StepPlan::Decode(vec![1])
        );
        // exactly enough on top of the step: admit
        assert_eq!(s.plan(&pressure(6, Some(4), 2)), StepPlan::Prefill);
    }

    #[test]
    fn force_admit_when_nothing_running() {
        let s = Scheduler::new(2);
        // deadlock guard: an empty engine admits regardless of the guess
        assert_eq!(s.plan(&pressure(0, Some(64), 0)), StepPlan::Prefill);
    }

    #[test]
    fn preempts_youngest_when_step_cannot_fit() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        s.add_running(2);
        s.add_running(3);
        assert_eq!(s.plan(&pressure(1, None, 3)), StepPlan::Preempt(3));
        s.remove(3);
        // after eviction frees blocks, the survivors decode
        assert_eq!(s.plan(&pressure(9, None, 2)), StepPlan::Decode(vec![1, 2]));
    }

    #[test]
    fn swap_eligible_pressure_plans_swap_out() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        s.add_running(2);
        s.add_running(3);
        let p = PoolPressure {
            free_blocks: 1,
            step_blocks: 3,
            swap_eligible: true,
            ..Default::default()
        };
        // same victim selection as Preempt, different disposition
        assert_eq!(s.victim_candidate(), Some(3));
        assert_eq!(s.plan(&p), StepPlan::SwapOut(3));
        // pinning the youngest shifts both the candidate and the plan
        s.pin(3);
        assert_eq!(s.victim_candidate(), Some(2));
        assert_eq!(s.plan(&p), StepPlan::SwapOut(2));
        // all pinned: swap eligibility cannot rescue a thrashing set
        s.pin(2);
        s.pin(1);
        assert_eq!(s.victim_candidate(), None);
        assert_eq!(s.plan(&p), StepPlan::Shed(3));
        // a lone sequence is never a victim candidate
        let mut lone = Scheduler::new(4);
        lone.add_running(9);
        assert_eq!(lone.victim_candidate(), None);
    }

    #[test]
    fn lone_sequence_is_never_preempted() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        // nothing to evict that would help — decode and let the engine
        // surface exhaustion as an error if it truly cannot proceed
        assert_eq!(s.plan(&pressure(0, None, 1)), StepPlan::Decode(vec![1]));
    }

    #[test]
    fn idle_when_nothing() {
        let s = Scheduler::new(2);
        assert_eq!(s.plan(&pressure(100, None, 0)), StepPlan::Idle);
    }

    #[test]
    fn remove_frees_capacity() {
        let mut s = Scheduler::new(1);
        s.add_running(7);
        assert!(!s.has_capacity());
        s.remove(7);
        assert!(s.has_capacity());
        assert_eq!(s.plan(&pressure(10, None, 0)), StepPlan::Idle);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_running_panics() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        s.add_running(1);
    }

    #[test]
    fn chunked_prefill_alternates_with_decode_turns() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        let chunk_turn = PoolPressure {
            free_blocks: 100,
            step_blocks: 1,
            inflight_prefill: true,
            ..Default::default()
        };
        assert_eq!(s.plan(&chunk_turn), StepPlan::PrefillChunk);
        // the chunk ran: the running set gets its decode turn next
        let decode_turn = PoolPressure { chunk_last: true, ..chunk_turn };
        assert_eq!(s.plan(&decode_turn), StepPlan::Decode(vec![1]));
        // nothing running: chunks run back-to-back
        s.remove(1);
        assert_eq!(s.plan(&decode_turn), StepPlan::PrefillChunk);
    }

    #[test]
    fn inflight_prefill_pauses_admission() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        // a queued prompt that would otherwise admit must wait for the
        // mid-flight chunked prefill to land its final slice
        let p = PoolPressure {
            free_blocks: 100,
            admit_blocks: Some(2),
            step_blocks: 1,
            inflight_prefill: true,
            ..Default::default()
        };
        assert_eq!(s.plan(&p), StepPlan::PrefillChunk);
        assert_eq!(
            s.plan(&PoolPressure { chunk_last: true, ..p }),
            StepPlan::Decode(vec![1])
        );
    }

    #[test]
    fn inflight_decode_turn_still_preempts_under_pressure() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        s.add_running(2);
        let p = PoolPressure {
            free_blocks: 1,
            step_blocks: 3,
            inflight_prefill: true,
            chunk_last: true,
            ..Default::default()
        };
        assert_eq!(s.plan(&p), StepPlan::Preempt(2));
    }

    #[test]
    fn pinned_sequences_are_skipped_as_victims() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        s.add_running(2);
        s.add_running(3);
        s.pin(3);
        // youngest is pinned: the next-youngest unpinned is evicted
        assert_eq!(s.plan(&pressure(1, None, 3)), StepPlan::Preempt(2));
        s.pin(2);
        assert_eq!(s.plan(&pressure(1, None, 3)), StepPlan::Preempt(1));
        s.pin(1);
        // all pinned: shed the youngest instead of livelocking
        assert_eq!(s.plan(&pressure(1, None, 3)), StepPlan::Shed(3));
        s.remove(3);
        assert!(!s.is_pinned(3), "remove clears the pin");
        s.add_running(3);
        assert_eq!(s.plan(&pressure(1, None, 3)), StepPlan::Preempt(3));
    }

    // ---- property tests (substrate::prop) ---------------------------------

    use crate::substrate::prop::check;
    use crate::substrate::rng::Rng;

    /// Admission never triggers an immediate preemption: whenever `plan`
    /// says `Prefill`, simulating that admission (prompt blocks allocated,
    /// sequence added to the running set, same measured step cost — a
    /// fresh prefill's ragged tail appends in place) must yield a
    /// non-`Preempt`, non-`Shed` next plan. This is the scheduler's core
    /// headroom invariant — `free - step >= need` — checked against
    /// arbitrary pressure rather than the hand-picked unit cases above.
    #[test]
    fn prop_admission_never_preempts_immediately() {
        check(
            0xadc1,
            300,
            |r| {
                let running = r.below(6) as usize;
                (
                    running,
                    2 + r.below(6) as usize,       // max_batch
                    r.below(64) as usize,          // free
                    r.below(16) as usize,          // admit need
                    running + r.below(8) as usize, // step blocks
                )
            },
            |&(running, max_batch, free, need, step)| {
                let mut s = Scheduler::new(max_batch.max(running + 1));
                for id in 0..running as RequestId {
                    s.add_running(id);
                }
                let p = PoolPressure {
                    free_blocks: free,
                    admit_blocks: Some(need),
                    step_blocks: step,
                    ..Default::default()
                };
                if s.plan(&p) != StepPlan::Prefill {
                    return Ok(()); // vacuous: nothing admitted
                }
                // force-admit of a too-big prompt into an empty engine is
                // the engine's prompt-size rejection to veto, not ours
                if running == 0 && free < need {
                    return Ok(());
                }
                s.add_running(999);
                let after = PoolPressure {
                    free_blocks: free - need,
                    admit_blocks: None,
                    step_blocks: step,
                    ..Default::default()
                };
                match s.plan(&after) {
                    StepPlan::Preempt(_) | StepPlan::SwapOut(_) | StepPlan::Shed(_) => {
                        Err(format!(
                            "admit at free={free} need={need} step={step} \
                             preempted immediately"
                        ))
                    }
                    _ => Ok(()),
                }
            },
        );
    }

    /// Liveness under draining pressure: a closed-loop model — sequences
    /// hold blocks, each decode step allocates one more per sequence,
    /// completion releases, preemption re-queues (counting against a
    /// budget that pins, then sheds) — always terminates with every
    /// request finished or shed, and never plans `Idle` while work
    /// remains. This is the anti-livelock guarantee: two large sequences
    /// cannot evict each other forever.
    #[test]
    fn prop_draining_pressure_always_makes_progress() {
        check(
            0x11fe,
            120,
            |r| {
                (
                    1 + r.below(4) as usize,        // max_batch
                    4 + r.below(28) as usize,       // pool capacity (blocks)
                    1 + r.below(6) as usize,        // requests
                    1 + r.below(4) as usize,        // prompt blocks each
                    1 + r.below(12) as usize,       // decode steps to finish
                    1 + r.below(3),                 // preempt budget
                )
            },
            |&(max_batch, cap, n_req, prompt_blocks, steps_needed, budget)| {
                // a request that cannot fit alone can never finish; keep
                // the generated workload inside the pool's ability
                let prompt_blocks = prompt_blocks.min(cap);
                let mut s = Scheduler::new(max_batch);
                let mut queue: Vec<RequestId> = (0..n_req as RequestId).collect();
                let mut held = vec![0usize; n_req]; // blocks per request
                let mut steps = vec![0usize; n_req];
                let mut evictions = vec![0u64; n_req];
                let mut free = cap;
                let mut done = 0usize;
                let mut shed = 0usize;
                for iter in 0.. {
                    if iter > 10_000 {
                        return Err("no termination in 10k iterations".into());
                    }
                    if done + shed == n_req {
                        break;
                    }
                    let admit = queue.first().map(|_| prompt_blocks);
                    let step_blocks = s.running().len();
                    let p = PoolPressure {
                        free_blocks: free,
                        admit_blocks: admit,
                        step_blocks,
                        ..Default::default()
                    };
                    let plan = s.plan(&p);
                    let is_shed = matches!(plan, StepPlan::Shed(_));
                    match plan {
                        StepPlan::Prefill => {
                            let id = queue.remove(0);
                            if prompt_blocks > free {
                                // engine-level rejection of an oversize
                                // force-admit; count it as shed
                                shed += 1;
                                continue;
                            }
                            free -= prompt_blocks;
                            held[id as usize] = prompt_blocks;
                            s.add_running(id);
                            if evictions[id as usize] >= budget {
                                s.pin(id);
                            }
                        }
                        StepPlan::Decode(ids) => {
                            if free < ids.len() {
                                // mirrors the engine: the plan only
                                // decodes when the step fits OR there is
                                // one lone sequence; a lone sequence that
                                // cannot step gets preempted by the
                                // engine's failed-task path
                                let id = *ids.last().unwrap();
                                evictions[id as usize] += 1;
                                if evictions[id as usize] > 2 * budget {
                                    shed += 1;
                                } else {
                                    queue.push(id);
                                }
                                free += held[id as usize];
                                held[id as usize] = 0;
                                s.remove(id);
                                continue;
                            }
                            for id in ids {
                                free -= 1;
                                held[id as usize] += 1;
                                steps[id as usize] += 1;
                                if steps[id as usize] >= steps_needed {
                                    free += held[id as usize];
                                    held[id as usize] = 0;
                                    s.remove(id);
                                    done += 1;
                                }
                            }
                        }
                        StepPlan::Preempt(id) | StepPlan::Shed(id) => {
                            evictions[id as usize] += 1;
                            if is_shed || evictions[id as usize] > 2 * budget {
                                shed += 1;
                            } else {
                                steps[id as usize] = 0;
                                queue.push(id);
                            }
                            free += held[id as usize];
                            held[id as usize] = 0;
                            s.remove(id);
                        }
                        StepPlan::PrefillChunk => {
                            return Err(
                                "PrefillChunk planned with inflight_prefill unset".into()
                            );
                        }
                        StepPlan::SwapOut(_) => {
                            return Err(
                                "SwapOut planned with swap_eligible unset".into()
                            );
                        }
                        StepPlan::Idle => {
                            if done + shed < n_req {
                                return Err(format!(
                                    "Idle with work left: done={done} \
                                     shed={shed} of {n_req}"
                                ));
                            }
                        }
                    }
                }
                if free != cap {
                    return Err(format!("leak: free {free} != cap {cap}"));
                }
                Ok(())
            },
        );
    }
}
