//! Step planning: continuous batching with prefill/decode interleaving.
//!
//! Policy (vLLM-flavored, prefill-prioritized): if a queued request exists
//! and the running set is below `max_batch` (and the kv pool heuristic
//! admits it), the next step is that request's prefill; otherwise decode
//! the whole running set. Decode batches are padded up to the nearest AOT
//! batch bucket by the engine.

use super::request::RequestId;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StepPlan {
    /// run one prompt's prefill (then it joins the running set)
    Prefill(RequestId),
    /// one decode step over these running sequences
    Decode(Vec<RequestId>),
    /// nothing to do
    Idle,
}

pub struct Scheduler {
    pub max_batch: usize,
    running: Vec<RequestId>,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Self {
        Self { max_batch, running: vec![] }
    }

    pub fn running(&self) -> &[RequestId] {
        &self.running
    }

    pub fn has_capacity(&self) -> bool {
        self.running.len() < self.max_batch
    }

    /// Called by the engine after a successful prefill.
    pub fn add_running(&mut self, id: RequestId) {
        assert!(self.has_capacity(), "over-admitted");
        assert!(!self.running.contains(&id), "duplicate running id");
        self.running.push(id);
    }

    /// Called when a sequence finishes (or is evicted).
    pub fn remove(&mut self, id: RequestId) {
        self.running.retain(|&r| r != id);
    }

    /// Plan the next step. `queued_head` = next queued request (if any),
    /// `pool_can_admit` = kv-pool pressure heuristic from the engine.
    pub fn plan(&self, queued_head: Option<RequestId>, pool_can_admit: bool) -> StepPlan {
        if let Some(id) = queued_head {
            if self.has_capacity() && pool_can_admit {
                return StepPlan::Prefill(id);
            }
        }
        if self.running.is_empty() {
            StepPlan::Idle
        } else {
            StepPlan::Decode(self.running.clone())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_prioritized_under_capacity() {
        let mut s = Scheduler::new(2);
        assert_eq!(s.plan(Some(1), true), StepPlan::Prefill(1));
        s.add_running(1);
        assert_eq!(s.plan(Some(2), true), StepPlan::Prefill(2));
        s.add_running(2);
        // full: decode
        assert_eq!(s.plan(Some(3), true), StepPlan::Decode(vec![1, 2]));
    }

    #[test]
    fn pool_pressure_blocks_admission() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        assert_eq!(s.plan(Some(2), false), StepPlan::Decode(vec![1]));
    }

    #[test]
    fn idle_when_nothing() {
        let s = Scheduler::new(2);
        assert_eq!(s.plan(None, true), StepPlan::Idle);
    }

    #[test]
    fn remove_frees_capacity() {
        let mut s = Scheduler::new(1);
        s.add_running(7);
        assert!(!s.has_capacity());
        s.remove(7);
        assert!(s.has_capacity());
        assert_eq!(s.plan(None, true), StepPlan::Idle);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_running_panics() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        s.add_running(1);
    }
}
