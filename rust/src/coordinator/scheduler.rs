//! Step planning: continuous batching with prefill/decode interleaving
//! and pool-pressure preemption.
//!
//! Policy (vLLM-flavored, prefill-prioritized): if a request waits at the
//! head of the queue and the running set is below `max_batch` — and the
//! shared block pool has room for its prompt *plus* the running set's
//! next decode step — the next step admits it; otherwise decode the whole
//! running set. When even the decode step cannot fit (`free_blocks <
//! step_blocks`), the plan preempts the **youngest** running sequence:
//! the engine releases its blocks and re-stashes the request for
//! recomputation (greedy decode is deterministic, so a preempted request
//! finishes with bit-identical output, just later).
//!
//! All pool inputs arrive as **exact block counts** ([`PoolPressure`]) —
//! the engine measures them from the shared pool and the sequence caches,
//! so the admission decision that used to be a token-counting guess is
//! one testable code path here.

use super::request::RequestId;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StepPlan {
    /// admit the request at the head of the deferred/router queue
    /// (the engine pops it and runs its prefill)
    Prefill,
    /// one decode step over these running sequences
    Decode(Vec<RequestId>),
    /// evict this (youngest) running sequence: release its blocks and
    /// re-stash its request, then re-plan
    Preempt(RequestId),
    /// nothing to do
    Idle,
}

/// Exact shared-pool occupancy inputs for one planning decision.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolPressure {
    /// free blocks in the engine's shared pool right now
    pub free_blocks: usize,
    /// blocks the head-of-queue prompt needs to admit (`None` = nothing
    /// queued); prefix reuse can only lower the real cost, so this is a
    /// safe upper bound
    pub admit_blocks: Option<usize>,
    /// blocks the running set will allocate on its next decode step
    pub step_blocks: usize,
}

pub struct Scheduler {
    pub max_batch: usize,
    running: Vec<RequestId>,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Self {
        Self { max_batch, running: vec![] }
    }

    pub fn running(&self) -> &[RequestId] {
        &self.running
    }

    pub fn has_capacity(&self) -> bool {
        self.running.len() < self.max_batch
    }

    /// Called by the engine after a successful prefill.
    pub fn add_running(&mut self, id: RequestId) {
        assert!(self.has_capacity(), "over-admitted");
        assert!(!self.running.contains(&id), "duplicate running id");
        self.running.push(id);
    }

    /// Called when a sequence finishes (or is preempted).
    pub fn remove(&mut self, id: RequestId) {
        self.running.retain(|&r| r != id);
    }

    /// Plan the next step from exact pool pressure.
    ///
    /// * Admission requires batch capacity AND enough free blocks for the
    ///   prompt *on top of* the running set's next step — admitting must
    ///   never trigger an immediate preemption. When nothing is running
    ///   the head request is force-admitted (deadlock guard; a prompt
    ///   larger than the whole pool is rejected by the engine instead).
    /// * Preemption picks the youngest (most recently admitted) running
    ///   sequence — it has the least sunk decode work to recompute. The
    ///   last running sequence is never preempted: with the pool entirely
    ///   its own, eviction could not free anything another step needs.
    pub fn plan(&self, pressure: &PoolPressure) -> StepPlan {
        if let Some(need) = pressure.admit_blocks {
            let fits = pressure
                .free_blocks
                .checked_sub(pressure.step_blocks)
                .is_some_and(|headroom| headroom >= need);
            if self.has_capacity() && (self.running.is_empty() || fits) {
                return StepPlan::Prefill;
            }
        }
        if self.running.is_empty() {
            return StepPlan::Idle;
        }
        if pressure.free_blocks < pressure.step_blocks && self.running.len() > 1 {
            return StepPlan::Preempt(*self.running.last().unwrap());
        }
        StepPlan::Decode(self.running.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pressure(
        free_blocks: usize,
        admit_blocks: Option<usize>,
        step_blocks: usize,
    ) -> PoolPressure {
        PoolPressure { free_blocks, admit_blocks, step_blocks }
    }

    #[test]
    fn prefill_prioritized_under_capacity() {
        let mut s = Scheduler::new(2);
        assert_eq!(s.plan(&pressure(100, Some(4), 0)), StepPlan::Prefill);
        s.add_running(1);
        assert_eq!(s.plan(&pressure(100, Some(4), 1)), StepPlan::Prefill);
        s.add_running(2);
        // batch full: decode
        assert_eq!(
            s.plan(&pressure(100, Some(4), 2)),
            StepPlan::Decode(vec![1, 2])
        );
    }

    #[test]
    fn pool_pressure_blocks_admission() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        // 5 free, step needs 2 → only 3 of the 4 admit blocks remain
        assert_eq!(
            s.plan(&pressure(5, Some(4), 2)),
            StepPlan::Decode(vec![1])
        );
        // exactly enough on top of the step: admit
        assert_eq!(s.plan(&pressure(6, Some(4), 2)), StepPlan::Prefill);
    }

    #[test]
    fn force_admit_when_nothing_running() {
        let s = Scheduler::new(2);
        // deadlock guard: an empty engine admits regardless of the guess
        assert_eq!(s.plan(&pressure(0, Some(64), 0)), StepPlan::Prefill);
    }

    #[test]
    fn preempts_youngest_when_step_cannot_fit() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        s.add_running(2);
        s.add_running(3);
        assert_eq!(s.plan(&pressure(1, None, 3)), StepPlan::Preempt(3));
        s.remove(3);
        // after eviction frees blocks, the survivors decode
        assert_eq!(s.plan(&pressure(9, None, 2)), StepPlan::Decode(vec![1, 2]));
    }

    #[test]
    fn lone_sequence_is_never_preempted() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        // nothing to evict that would help — decode and let the engine
        // surface exhaustion as an error if it truly cannot proceed
        assert_eq!(s.plan(&pressure(0, None, 1)), StepPlan::Decode(vec![1]));
    }

    #[test]
    fn idle_when_nothing() {
        let s = Scheduler::new(2);
        assert_eq!(s.plan(&pressure(100, None, 0)), StepPlan::Idle);
    }

    #[test]
    fn remove_frees_capacity() {
        let mut s = Scheduler::new(1);
        s.add_running(7);
        assert!(!s.has_capacity());
        s.remove(7);
        assert!(s.has_capacity());
        assert_eq!(s.plan(&pressure(10, None, 0)), StepPlan::Idle);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_running_panics() {
        let mut s = Scheduler::new(4);
        s.add_running(1);
        s.add_running(1);
    }
}
