//! **Self-Indexing KVCache** — the paper's contribution as a library.
//!
//! The compressed key representation *is* the retrieval index:
//!
//! 1. [`normalize`] — entropy-aware channel-mean normalization (Eq. 5-7):
//!    subtracting the per-channel mean balances sign bits (max entropy)
//!    without changing softmax outputs.
//! 2. [`codes`] — each 4-channel subvector of a key maps to the 4-bit
//!    integer formed by its sign bits (Eq. 2-3). These nibbles are both
//!    the VQ cluster ids *and* the exact sign plane of the key.
//! 3. [`codebook`] — one-pass clustering (Eq. 4): centroid = mean of the
//!    subvectors sharing a sign pattern. No k-means iterations.
//! 4. [`lut`] + [`score`] — compressed-domain retrieval (Eq. 8, Fig. 3):
//!    per query, dot the G subvectors with 16 centroids each (a tiny
//!    GEMV), then score every cached token with G table lookups over its
//!    packed codes. This is the decode hot path (see DESIGN.md §Perf).
//! 5. [`topk`] — partial selection of the k highest scores.
//!
//! [`SelfIndexConfig`] carries every paper knob (+ ablation switches used
//! by `benches/table5_ablation.rs`).

pub mod codebook;
pub mod codes;
pub mod lut;
pub mod normalize;
pub mod score;
pub mod topk;

pub use codebook::{Codebook, CodebookBuilder};
pub use codes::{encode_token, encode_tokens_packed, sign_code};
pub use lut::Lut;
pub use normalize::ChannelStats;
pub use score::{
    page_bound, popcnt_kernel_name, score_block_bytelut, score_block_popcnt,
    score_block_popcnt_scalar, score_tokens, score_tokens_bytelut, BlockScorer, ByteLut,
};
pub use topk::{top_k_indices, TopKStream};

/// Which kernel scores packed codes during decode retrieval (the method
/// registry's `scorer` knob; DESIGN.md §Perf iteration 8).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Scorer {
    /// byte-combined LUT over the magnitude-centroid table — the general
    /// scorer and the conformance oracle (default).
    #[default]
    ByteLut,
    /// XOR + popcount over word-packed sign codes: sign-agreement
    /// scoring, the paper's "retrieval is a bit operation" claim made
    /// literal. Ignores centroid magnitudes (like the sign-only
    /// ablation), trading a little retrieval fidelity for a much
    /// cheaper score stage.
    Popcnt,
}

impl Scorer {
    /// Parse a knob/config string (`"bytelut"` / `"popcnt"`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "bytelut" | "byte_lut" | "lut" => Some(Scorer::ByteLut),
            "popcnt" | "popcount" => Some(Scorer::Popcnt),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Scorer::ByteLut => "bytelut",
            Scorer::Popcnt => "popcnt",
        }
    }
}

/// Paper hyper-parameters + ablation switches.
#[derive(Clone, Debug)]
pub struct SelfIndexConfig {
    /// channels per sign-VQ group (paper: 4 → 16 clusters).
    pub vq_group: usize,
    /// bits per quantized magnitude/value element (paper: 2).
    pub quant_bits: u32,
    /// channels per quant parameter group (paper: 32).
    pub quant_group: usize,
    /// full-precision sink tokens kept from prefill (paper: 64).
    pub sink_tokens: usize,
    /// dynamically selected tokens per decode step (paper: 96 at the
    /// LongBench budget; RULER uses a ratio instead).
    pub sparse_k: usize,
    /// ablation: retrieve with centroid magnitudes (true) or sign-only
    /// ±1 codebook (false) — Table 5 "sign-only retrieval".
    pub magnitude_centroids: bool,
    /// ablation: keep the sign plane exact during quantization (true) or
    /// quantize signed values directly — Table 5 "w/o sign in quant".
    pub sign_plane_quant: bool,
    /// ablation: disable sink tokens — Table 5 "w/o sink tokens".
    pub use_sinks: bool,
    /// decode-retrieval score kernel (byte-LUT oracle vs popcount).
    pub scorer: Scorer,
    /// blocks per retrieval page for the hierarchical popcount tier
    /// (DESIGN.md §Perf iteration 9): each closed page of this many full
    /// blocks gets a bit-majority sketch + Hamming radius, and
    /// `stream_select` skips pages whose sound score bound cannot beat
    /// the running top-k threshold. 0 disables paging (flat sweep). Only
    /// the [`Scorer::Popcnt`] path consults pages; selection stays
    /// bit-identical to the flat sweep either way.
    pub page_blocks: usize,
}

impl Default for SelfIndexConfig {
    fn default() -> Self {
        Self {
            vq_group: 4,
            quant_bits: 2,
            quant_group: 32,
            sink_tokens: 64,
            sparse_k: 96,
            magnitude_centroids: true,
            sign_plane_quant: true,
            use_sinks: true,
            scorer: Scorer::ByteLut,
            page_blocks: 64,
        }
    }
}

impl SelfIndexConfig {
    pub fn clusters(&self) -> usize {
        1 << self.vq_group
    }

    pub fn groups(&self, head_dim: usize) -> usize {
        assert_eq!(head_dim % self.vq_group, 0);
        head_dim / self.vq_group
    }

    pub fn validate(&self, head_dim: usize) -> Result<(), String> {
        if self.vq_group != 4 {
            // packing + LUT layouts assume nibble codes
            return Err(format!("vq_group must be 4, got {}", self.vq_group));
        }
        if head_dim % self.quant_group != 0 {
            return Err(format!(
                "head_dim {head_dim} not divisible by quant_group {}",
                self.quant_group
            ));
        }
        if !(1..=8).contains(&self.quant_bits) {
            return Err(format!("quant_bits out of range: {}", self.quant_bits));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_settings() {
        let c = SelfIndexConfig::default();
        assert_eq!(c.vq_group, 4);
        assert_eq!(c.clusters(), 16);
        assert_eq!(c.quant_bits, 2);
        assert_eq!(c.quant_group, 32);
        assert_eq!(c.sink_tokens, 64);
        assert_eq!(c.sparse_k, 96);
        assert_eq!(c.scorer, Scorer::ByteLut, "byte-LUT stays the oracle default");
        assert_eq!(c.page_blocks, 64, "hierarchical page tier on by default");
        assert!(c.validate(64).is_ok());
        assert!(c.validate(128).is_ok());
    }

    #[test]
    fn scorer_parse_and_name_roundtrip() {
        for sc in [Scorer::ByteLut, Scorer::Popcnt] {
            assert_eq!(Scorer::parse(sc.name()), Some(sc));
        }
        assert_eq!(Scorer::parse(" POPCOUNT "), Some(Scorer::Popcnt));
        assert_eq!(Scorer::parse("lut"), Some(Scorer::ByteLut));
        assert_eq!(Scorer::parse("gemv"), None);
    }

    #[test]
    fn validate_rejects_bad_dims() {
        let c = SelfIndexConfig::default();
        assert!(c.validate(48).is_err()); // not divisible by 32
        let mut c2 = c.clone();
        c2.vq_group = 8;
        assert!(c2.validate(64).is_err());
    }
}
