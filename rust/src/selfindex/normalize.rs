//! Entropy-aware normalization (paper Eq. 5-7): per-channel statistics of
//! the key stream — the mean `mu` subtracted before sign extraction and
//! the magnitude normalizer `alpha = max |K'[:,j]|` (Eq. 12).
//!
//! Streaming: prefill may arrive in chunks and decode appends one token at
//! a time, so stats accumulate incrementally. Following the paper, `mu`
//! and `alpha` are *frozen* at the end of prefill (they are baked into the
//! codebook and quantized magnitudes); later tokens reuse them — softmax
//! shift-invariance (Eq. 7) makes a slightly-stale `mu` harmless, and the
//! engine tracks post-freeze drift via `metrics`.

/// Running per-channel statistics over keys.
#[derive(Clone, Debug)]
pub struct ChannelStats {
    pub dim: usize,
    sum: Vec<f64>,
    max_abs_centered: Vec<f32>,
    count: usize,
    frozen: Option<Frozen>,
}

#[derive(Clone, Debug)]
pub struct Frozen {
    pub mu: Vec<f32>,
    pub alpha: Vec<f32>,
}

impl ChannelStats {
    pub fn new(dim: usize) -> Self {
        Self {
            dim,
            sum: vec![0.0; dim],
            max_abs_centered: vec![0.0; dim],
            count: 0,
            frozen: None,
        }
    }

    /// Accumulate a block of tokens ((tokens × dim) row-major).
    /// Must be called before `freeze`.
    pub fn accumulate(&mut self, keys: &[f32]) {
        assert!(self.frozen.is_none(), "stats already frozen");
        assert_eq!(keys.len() % self.dim, 0);
        for row in keys.chunks_exact(self.dim) {
            for (j, &v) in row.iter().enumerate() {
                self.sum[j] += v as f64;
            }
            self.count += 1;
        }
    }

    pub fn tokens_seen(&self) -> usize {
        self.count
    }

    /// Current mean estimate (valid pre- or post-freeze).
    pub fn mu(&self) -> Vec<f32> {
        if let Some(f) = &self.frozen {
            return f.mu.clone();
        }
        let n = self.count.max(1) as f64;
        self.sum.iter().map(|&s| (s / n) as f32).collect()
    }

    /// Freeze `mu` from accumulated sums, then compute
    /// `alpha_j = max_i |K[i,j] - mu_j|` over the provided prefill keys.
    /// (Two passes over prefill — cheap vector ops, matching the paper's
    /// prefill-side normalization.)
    pub fn freeze(&mut self, prefill_keys: &[f32]) -> &Frozen {
        assert!(self.frozen.is_none(), "freeze called twice");
        let mu = self.mu();
        for row in prefill_keys.chunks_exact(self.dim) {
            for (j, &v) in row.iter().enumerate() {
                let a = (v - mu[j]).abs();
                if a > self.max_abs_centered[j] {
                    self.max_abs_centered[j] = a;
                }
            }
        }
        let alpha = self
            .max_abs_centered
            .iter()
            .map(|&a| if a > 0.0 { a } else { 1.0 })
            .collect();
        self.frozen = Some(Frozen { mu, alpha });
        self.frozen.as_ref().unwrap()
    }

    pub fn frozen(&self) -> Option<&Frozen> {
        self.frozen.as_ref()
    }

    /// Subtract mu in-place from a block of tokens.
    pub fn center(&self, keys: &mut [f32]) {
        let f = self.frozen.as_ref().expect("center() needs frozen stats");
        for row in keys.chunks_exact_mut(self.dim) {
            for (j, v) in row.iter_mut().enumerate() {
                *v -= f.mu[j];
            }
        }
    }
}

/// Sign balance of a centered key block: fraction of non-negative entries.
/// Eq. 6: maximal code entropy at 0.5. Exposed for tests + metrics.
pub fn sign_balance(centered: &[f32]) -> f32 {
    if centered.is_empty() {
        return 0.5;
    }
    centered.iter().filter(|&&v| v >= 0.0).count() as f32 / centered.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    fn biased_keys(seed: u64, tokens: usize, dim: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let offsets: Vec<f32> = (0..dim).map(|_| r.uniform(-3.0, 3.0)).collect();
        (0..tokens)
            .flat_map(|_| {
                let r = &mut r;
                offsets
                    .iter()
                    .map(|&o| o + r.normal_f32())
                    .collect::<Vec<_>>()
            })
            .collect()
    }

    #[test]
    fn mean_converges() {
        let dim = 8;
        let keys = biased_keys(1, 4096, dim);
        let mut st = ChannelStats::new(dim);
        st.accumulate(&keys);
        let mu = st.mu();
        // recompute directly
        for j in 0..dim {
            let direct: f32 = keys.iter().skip(j).step_by(dim).sum::<f32>()
                / 4096.0;
            assert!((mu[j] - direct).abs() < 1e-3, "{} vs {}", mu[j], direct);
        }
    }

    #[test]
    fn centering_balances_signs() {
        // balance must hold PER CHANNEL (Eq. 6 is about each sign bit);
        // aggregate balance can average out even with skewed channels.
        let dim = 16;
        let n = 2048;
        let keys = biased_keys(2, n, dim);
        let mut st = ChannelStats::new(dim);
        st.accumulate(&keys);
        st.freeze(&keys);
        let mut centered = keys.clone();
        st.center(&mut centered);
        let chan_balance = |data: &[f32], j: usize| {
            data.iter().skip(j).step_by(dim).filter(|&&v| v >= 0.0).count()
                as f32
                / n as f32
        };
        let mut max_raw_dev = 0.0f32;
        for j in 0..dim {
            let c = chan_balance(&centered, j);
            assert!((c - 0.5).abs() < 0.06, "channel {j} balance {c}");
            max_raw_dev = max_raw_dev.max((chan_balance(&keys, j) - 0.5).abs());
        }
        // sanity: at least one raw channel WAS badly unbalanced
        assert!(max_raw_dev > 0.2, "raw max deviation {max_raw_dev}");
    }

    #[test]
    fn alpha_covers_all_magnitudes() {
        let dim = 8;
        let keys = biased_keys(3, 512, dim);
        let mut st = ChannelStats::new(dim);
        st.accumulate(&keys);
        let f = st.freeze(&keys).clone();
        let mut centered = keys.clone();
        st.center(&mut centered);
        for row in centered.chunks_exact(dim) {
            for (j, &v) in row.iter().enumerate() {
                assert!(v.abs() <= f.alpha[j] + 1e-6);
            }
        }
        assert!(f.alpha.iter().all(|&a| a > 0.0));
    }

    #[test]
    fn accumulate_in_chunks_equals_one_shot() {
        let dim = 8;
        let keys = biased_keys(4, 300, dim);
        let mut a = ChannelStats::new(dim);
        a.accumulate(&keys);
        let mut b = ChannelStats::new(dim);
        for chunk in keys.chunks(7 * dim) {
            b.accumulate(chunk);
        }
        assert_eq!(a.tokens_seen(), b.tokens_seen());
        let (ma, mb) = (a.mu(), b.mu());
        for j in 0..dim {
            assert!((ma[j] - mb[j]).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "frozen")]
    fn accumulate_after_freeze_panics() {
        let mut st = ChannelStats::new(4);
        st.accumulate(&[1.0, 2.0, 3.0, 4.0]);
        st.freeze(&[1.0, 2.0, 3.0, 4.0]);
        st.accumulate(&[1.0, 2.0, 3.0, 4.0]);
    }
}
