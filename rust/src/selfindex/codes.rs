//! Sign-pattern codes (paper Eq. 2-3).
//!
//! `Code(k) = Σ_i ((1+sign(k_i))/2) · 2^(4-i)` — channel 0 of each
//! 4-channel group is the most-significant bit; `x >= 0` encodes as 1
//! (matching `ref.sign_codes`, pinned by golden vectors).

/// 4-bit sign code of one 4-channel subvector.
#[inline(always)]
pub fn sign_code(sub: &[f32]) -> u8 {
    debug_assert_eq!(sub.len(), 4);
    (((sub[0] >= 0.0) as u8) << 3)
        | (((sub[1] >= 0.0) as u8) << 2)
        | (((sub[2] >= 0.0) as u8) << 1)
        | ((sub[3] >= 0.0) as u8)
}

/// All G codes of one normalized key vector (head_dim = 4·G).
pub fn encode_token(key: &[f32]) -> Vec<u8> {
    assert_eq!(key.len() % 4, 0);
    key.chunks_exact(4).map(sign_code).collect()
}

/// Encode a block of tokens directly into packed nibbles
/// (token-major: token t occupies bytes [t·G/2, (t+1)·G/2)).
pub fn encode_tokens_packed(keys: &[f32], head_dim: usize) -> Vec<u8> {
    assert_eq!(head_dim % 8, 0, "packed layout needs even group count");
    assert_eq!(keys.len() % head_dim, 0);
    let g = head_dim / 4;
    let tokens = keys.len() / head_dim;
    let mut out = vec![0u8; tokens * g / 2];
    for t in 0..tokens {
        let row = &keys[t * head_dim..(t + 1) * head_dim];
        let dst = &mut out[t * g / 2..(t + 1) * g / 2];
        for (j, pair) in row.chunks_exact(8).enumerate() {
            let lo = sign_code(&pair[0..4]);
            let hi = sign_code(&pair[4..8]);
            dst[j] = lo | (hi << 4);
        }
    }
    out
}

/// Expand a 4-bit code back to ±1 signs (MSB-first), for reconstruction.
#[inline(always)]
pub fn code_signs(code: u8) -> [f32; 4] {
    [
        if code & 0b1000 != 0 { 1.0 } else { -1.0 },
        if code & 0b0100 != 0 { 1.0 } else { -1.0 },
        if code & 0b0010 != 0 { 1.0 } else { -1.0 },
        if code & 0b0001 != 0 { 1.0 } else { -1.0 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::get_code;
    use crate::substrate::prop::check;
    use crate::substrate::rng::Rng;

    #[test]
    fn bit_order_msb_first() {
        assert_eq!(sign_code(&[1.0, -1.0, -1.0, -1.0]), 0b1000);
        assert_eq!(sign_code(&[-1.0, -1.0, -1.0, 1.0]), 0b0001);
        assert_eq!(sign_code(&[1.0, 1.0, 1.0, 1.0]), 0b1111);
        assert_eq!(sign_code(&[-1.0, -1.0, -1.0, -1.0]), 0);
        // zero counts as non-negative (post-normalization measure-zero)
        assert_eq!(sign_code(&[0.0, -1.0, 0.0, -1.0]), 0b1010);
    }

    #[test]
    fn signs_roundtrip() {
        for c in 0u8..16 {
            let s = code_signs(c);
            assert_eq!(sign_code(&s), c);
        }
    }

    #[test]
    fn packed_encoding_matches_per_token() {
        let mut r = Rng::new(3);
        let hd = 64;
        let keys: Vec<f32> = (0..hd * 10).map(|_| r.normal_f32()).collect();
        let packed = encode_tokens_packed(&keys, hd);
        let g = hd / 4;
        for t in 0..10 {
            let codes = encode_token(&keys[t * hd..(t + 1) * hd]);
            for (gi, &c) in codes.iter().enumerate() {
                assert_eq!(get_code(&packed[t * g / 2..], gi), c);
            }
        }
    }

    #[test]
    fn prop_sign_consistency() {
        // flipping one channel's sign flips exactly the matching code bit
        check(
            7,
            200,
            |r| {
                let v: Vec<f32> = (0..4)
                    .map(|_| {
                        let x = r.normal_f32();
                        if x == 0.0 {
                            1.0
                        } else {
                            x
                        }
                    })
                    .collect();
                let ch = r.below(4) as usize;
                (v, ch)
            },
            |(v, ch)| {
                let before = sign_code(v);
                let mut w = v.clone();
                w[*ch] = -w[*ch];
                let after = sign_code(&w);
                let expect = before ^ (1 << (3 - ch));
                if after == expect {
                    Ok(())
                } else {
                    Err(format!("{before:04b} ^ ch{ch} -> {after:04b}"))
                }
            },
        );
    }
}
