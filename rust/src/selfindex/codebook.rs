//! One-pass sign-based codebook (paper Eq. 4).
//!
//! For every (group g, sign pattern c) the centroid is the mean of all
//! subvectors of group g whose sign pattern is c. Built in a single pass
//! over the prefill keys (the paper's 20×+ win over iterative k-means —
//! measured head-to-head in `benches/table4_modules.rs` against
//! [`crate::baselines::kmeans`]).
//!
//! Layout: centroids flat `[g][c][4]` (g-major) for LUT-build locality.

use super::codes::{code_signs, sign_code};

/// Streaming builder: accumulate blocks, finalize once.
#[derive(Clone, Debug)]
pub struct CodebookBuilder {
    pub groups: usize,
    sums: Vec<f64>,   // groups × 16 × 4
    counts: Vec<u32>, // groups × 16
}

impl CodebookBuilder {
    pub fn new(groups: usize) -> Self {
        Self {
            groups,
            sums: vec![0.0; groups * 16 * 4],
            counts: vec![0; groups * 16],
        }
    }

    /// Accumulate centered keys ((tokens × 4·groups) row-major).
    pub fn accumulate(&mut self, centered_keys: &[f32]) {
        let dim = self.groups * 4;
        assert_eq!(centered_keys.len() % dim, 0);
        for row in centered_keys.chunks_exact(dim) {
            for (g, sub) in row.chunks_exact(4).enumerate() {
                let c = sign_code(sub) as usize;
                let base = (g * 16 + c) * 4;
                for i in 0..4 {
                    self.sums[base + i] += sub[i] as f64;
                }
                self.counts[g * 16 + c] += 1;
            }
        }
    }

    /// Merge sums/counts produced elsewhere (e.g. the Pallas
    /// `quantize_block` program returns raw sums/counts per chunk).
    pub fn merge_raw(&mut self, sums: &[f32], counts: &[f32]) {
        assert_eq!(sums.len(), self.sums.len());
        assert_eq!(counts.len(), self.counts.len());
        for (a, &b) in self.sums.iter_mut().zip(sums) {
            *a += b as f64;
        }
        for (a, &b) in self.counts.iter_mut().zip(counts) {
            *a += b as u32;
        }
    }

    /// Finalize: empty clusters get the zero centroid (never looked up for
    /// the keys that built the codebook; harmless for later arrivals —
    /// matches `ref.build_codebook`).
    pub fn finalize(&self) -> Codebook {
        let mut centroids = vec![0.0f32; self.groups * 16 * 4];
        for g in 0..self.groups {
            for c in 0..16 {
                let n = self.counts[g * 16 + c];
                if n > 0 {
                    let base = (g * 16 + c) * 4;
                    for i in 0..4 {
                        centroids[base + i] =
                            (self.sums[base + i] / n as f64) as f32;
                    }
                }
            }
        }
        Codebook { groups: self.groups, centroids }
    }
}

/// Finalized codebook: `groups × 16` centroids of dim 4.
#[derive(Clone, Debug)]
pub struct Codebook {
    pub groups: usize,
    /// flat [g][c][4]
    pub centroids: Vec<f32>,
}

impl Codebook {
    pub fn centroid(&self, g: usize, c: usize) -> &[f32] {
        let base = (g * 16 + c) * 4;
        &self.centroids[base..base + 4]
    }

    /// Sign-only codebook for the Table-5 "sign-only retrieval" ablation:
    /// centroid = the ±1 pattern itself (no magnitudes).
    pub fn sign_only(groups: usize) -> Self {
        let mut centroids = vec![0.0f32; groups * 16 * 4];
        for g in 0..groups {
            for c in 0..16 {
                let signs = code_signs(c as u8);
                centroids[(g * 16 + c) * 4..(g * 16 + c) * 4 + 4]
                    .copy_from_slice(&signs);
            }
        }
        Self { groups, centroids }
    }

    /// Memory footprint in bytes (f32 centroids) — fixed overhead in the
    /// paper's accounting, O(1) in context length.
    pub fn bytes(&self) -> usize {
        self.centroids.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    fn keys(seed: u64, tokens: usize, dim: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..tokens * dim).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn centroids_live_in_their_orthant() {
        let dim = 32;
        let k = keys(1, 1024, dim);
        let mut b = CodebookBuilder::new(dim / 4);
        b.accumulate(&k);
        let cb = b.finalize();
        for g in 0..cb.groups {
            for c in 0..16 {
                let cent = cb.centroid(g, c);
                if cent.iter().all(|&x| x == 0.0) {
                    continue; // empty cluster
                }
                assert_eq!(sign_code(cent), c as u8, "g{g} c{c} {cent:?}");
            }
        }
    }

    #[test]
    fn chunked_equals_one_shot() {
        let dim = 16;
        let k = keys(2, 500, dim);
        let mut a = CodebookBuilder::new(dim / 4);
        a.accumulate(&k);
        let mut b = CodebookBuilder::new(dim / 4);
        for chunk in k.chunks(13 * dim) {
            b.accumulate(chunk);
        }
        let (ca, cb) = (a.finalize(), b.finalize());
        for (x, y) in ca.centroids.iter().zip(&cb.centroids) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn merge_raw_equals_accumulate() {
        let dim = 16;
        let groups = dim / 4;
        let k = keys(3, 200, dim);
        let mut direct = CodebookBuilder::new(groups);
        direct.accumulate(&k);
        // build raw sums/counts separately (f32, like the pallas outputs)
        let mut sums = vec![0.0f32; groups * 16 * 4];
        let mut counts = vec![0.0f32; groups * 16];
        for row in k.chunks_exact(dim) {
            for (g, sub) in row.chunks_exact(4).enumerate() {
                let c = sign_code(sub) as usize;
                for i in 0..4 {
                    sums[(g * 16 + c) * 4 + i] += sub[i];
                }
                counts[g * 16 + c] += 1.0;
            }
        }
        let mut merged = CodebookBuilder::new(groups);
        merged.merge_raw(&sums, &counts);
        for (x, y) in direct.finalize().centroids.iter()
            .zip(&merged.finalize().centroids)
        {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn sign_only_centroids_are_unit_signs() {
        let cb = Codebook::sign_only(4);
        assert_eq!(cb.centroid(0, 0b1010), &[1.0, -1.0, 1.0, -1.0]);
        assert_eq!(cb.centroid(3, 0b1111), &[1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn single_cluster_centroid_is_mean() {
        // all tokens identical -> their cluster's centroid is the token
        let dim = 8;
        let row: Vec<f32> = vec![0.5, -0.25, 1.0, -2.0, 0.1, 0.2, -0.3, 0.4];
        let mut b = CodebookBuilder::new(dim / 4);
        let many: Vec<f32> = row.iter().cycle().take(dim * 10).copied().collect();
        b.accumulate(&many);
        let cb = b.finalize();
        let c0 = sign_code(&row[0..4]) as usize;
        for i in 0..4 {
            assert!((cb.centroid(0, c0)[i] - row[i]).abs() < 1e-6);
        }
    }
}
