//! LUT-GEMV scoring over packed codes — **the decode hot path** (Eq. 8).
//!
//! `score(token) = Σ_g lut[g][code_g(token)]` where the codes are nibbles
//! packed two-per-byte, token-major. Two implementations:
//!
//! * [`score_tokens`] — straightforward nibble loop (reference).
//! * [`score_tokens_bytelut`] — byte-combined LUT: for each byte position
//!   (two adjacent groups) precompute a 256-entry table
//!   `byte_lut[j][b] = lut[2j][b & 0xF] + lut[2j+1][b >> 4]`, halving the
//!   lookups per token to G/2. This is the shared-memory LUT trick of the
//!   paper's CUDA kernel, restated for CPU caches: at G=16 the combined
//!   table is 8·256·4 B = 8 KiB — L1-resident. (§Perf iteration 1.)

use super::lut::Lut;

/// Reference scorer: G nibble lookups per token.
/// `packed`: token-major nibbles, `bpt` = bytes per token = G/2.
pub fn score_tokens(lut: &Lut, packed: &[u8], n_tokens: usize, out: &mut Vec<f32>) {
    let g = lut.groups;
    let bpt = g / 2;
    assert!(packed.len() >= n_tokens * bpt);
    out.clear();
    out.reserve(n_tokens);
    for t in 0..n_tokens {
        let row = &packed[t * bpt..(t + 1) * bpt];
        let mut acc = 0.0f32;
        for (j, &b) in row.iter().enumerate() {
            acc += lut.get(2 * j, (b & 0x0f) as usize);
            acc += lut.get(2 * j + 1, (b >> 4) as usize);
        }
        out.push(acc);
    }
}

/// Byte-combined LUT: 256 entries per byte position.
pub struct ByteLut {
    pub bytes_per_token: usize,
    /// flat [byte_pos][256]
    pub table: Vec<f32>,
}

impl ByteLut {
    /// Empty table — a reusable arena for [`ByteLut::rebuild`].
    pub fn empty() -> Self {
        Self { bytes_per_token: 0, table: vec![] }
    }

    pub fn from_lut(lut: &Lut) -> Self {
        let mut blut = Self::empty();
        blut.rebuild(lut);
        blut
    }

    /// Rebuild in place (decode hot path: no per-step allocation once the
    /// table has its capacity, and no redundant zero-fill — the loop
    /// below overwrites every slot).
    pub fn rebuild(&mut self, lut: &Lut) {
        let bpt = lut.groups / 2;
        self.bytes_per_token = bpt;
        let needed = bpt * 256;
        if self.table.len() != needed {
            self.table.clear();
            self.table.resize(needed, 0.0);
        }
        for j in 0..bpt {
            let lo = &lut.table[(2 * j) * 16..(2 * j) * 16 + 16];
            let hi = &lut.table[(2 * j + 1) * 16..(2 * j + 1) * 16 + 16];
            let dst = &mut self.table[j * 256..(j + 1) * 256];
            for b in 0..256 {
                dst[b] = lo[b & 0x0f] + hi[b >> 4];
            }
        }
    }
}

/// Optimized scorer: G/2 byte lookups per token, 4-token unrolled.
pub fn score_tokens_bytelut(
    blut: &ByteLut,
    packed: &[u8],
    n_tokens: usize,
    out: &mut Vec<f32>,
) {
    let bpt = blut.bytes_per_token;
    assert!(packed.len() >= n_tokens * bpt);
    out.clear();
    out.resize(n_tokens, 0.0);
    let table = &blut.table;

    let chunks = n_tokens / 4;
    for c in 0..chunks {
        let t0 = c * 4;
        let base = t0 * bpt;
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for j in 0..bpt {
            let tj = &table[j * 256..(j + 1) * 256];
            a0 += tj[packed[base + j] as usize];
            a1 += tj[packed[base + bpt + j] as usize];
            a2 += tj[packed[base + 2 * bpt + j] as usize];
            a3 += tj[packed[base + 3 * bpt + j] as usize];
        }
        out[t0] = a0;
        out[t0 + 1] = a1;
        out[t0 + 2] = a2;
        out[t0 + 3] = a3;
    }
    for t in chunks * 4..n_tokens {
        let row = &packed[t * bpt..(t + 1) * bpt];
        let mut acc = 0.0f32;
        for j in 0..bpt {
            acc += table[j * 256 + row[j] as usize];
        }
        out[t] = acc;
    }
}

/// Block scorer for the fused streaming pipeline (§Perf iteration 5):
/// scores `n_tokens` packed codes straight out of one cache block into a
/// caller-owned slice (no allocation, no Vec bookkeeping) and returns the
/// block maximum so the streaming selector can reject whole blocks below
/// its running k-th threshold. 8-token unroll: blocks are block-major
/// contiguous, so eight rows span 8·bpt consecutive bytes — enough
/// independent accumulator chains to hide the L1 load latency of the
/// table lookups.
pub fn score_block_bytelut(
    blut: &ByteLut,
    packed: &[u8],
    n_tokens: usize,
    out: &mut [f32],
) -> f32 {
    let bpt = blut.bytes_per_token;
    assert!(packed.len() >= n_tokens * bpt);
    assert!(out.len() >= n_tokens);
    let table = &blut.table;
    let mut bmax = f32::NEG_INFINITY;

    let chunks = n_tokens / 8;
    for c in 0..chunks {
        let t0 = c * 8;
        let base = t0 * bpt;
        let mut acc = [0.0f32; 8];
        for j in 0..bpt {
            let tj = &table[j * 256..(j + 1) * 256];
            for (u, a) in acc.iter_mut().enumerate() {
                *a += tj[packed[base + u * bpt + j] as usize];
            }
        }
        for (u, &a) in acc.iter().enumerate() {
            out[t0 + u] = a;
            bmax = bmax.max(a);
        }
    }
    for t in chunks * 8..n_tokens {
        let row = &packed[t * bpt..(t + 1) * bpt];
        let mut a = 0.0f32;
        for j in 0..bpt {
            a += table[j * 256 + row[j] as usize];
        }
        out[t] = a;
        bmax = bmax.max(a);
    }
    bmax
}

/// Full-precision scores q·K'ᵀ — the baseline LUT-GEMV replaces
/// (paper Table 4 "Full K·qᵀ" row).
pub fn exact_scores(query: &[f32], keys: &[f32], dim: usize, out: &mut Vec<f32>) {
    assert_eq!(keys.len() % dim, 0);
    out.clear();
    for row in keys.chunks_exact(dim) {
        out.push(crate::tensor::dot(query, row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selfindex::codebook::CodebookBuilder;
    use crate::selfindex::codes::encode_tokens_packed;
    use crate::substrate::rng::Rng;

    fn setup(seed: u64, tokens: usize, dim: usize) -> (Lut, Vec<u8>, Vec<f32>, Vec<f32>) {
        let mut r = Rng::new(seed);
        let keys: Vec<f32> = (0..tokens * dim).map(|_| r.normal_f32()).collect();
        let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let mut b = CodebookBuilder::new(dim / 4);
        b.accumulate(&keys);
        let cb = b.finalize();
        let packed = encode_tokens_packed(&keys, dim);
        (Lut::build(&q, &cb), packed, keys, q)
    }

    #[test]
    fn bytelut_matches_reference() {
        for (seed, tokens, dim) in [(1, 127, 64), (2, 4, 64), (3, 1000, 32), (4, 3, 8)] {
            let (lut, packed, _, _) = setup(seed, tokens, dim);
            let mut a = Vec::new();
            let mut b = Vec::new();
            score_tokens(&lut, &packed, tokens, &mut a);
            let blut = ByteLut::from_lut(&lut);
            score_tokens_bytelut(&blut, &packed, tokens, &mut b);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn block_scorer_matches_reference_and_max() {
        // covers: multiple-of-8, ragged tails, tiny blocks
        let cases = [(1, 128, 64), (2, 7, 64), (3, 1000, 32), (4, 8, 8), (9, 1, 64)];
        for (seed, tokens, dim) in cases {
            let (lut, packed, _, _) = setup(seed, tokens, dim);
            let mut expect = Vec::new();
            score_tokens(&lut, &packed, tokens, &mut expect);
            let blut = ByteLut::from_lut(&lut);
            let mut out = vec![0.0f32; tokens];
            let bmax = score_block_bytelut(&blut, &packed, tokens, &mut out);
            let mut emax = f32::NEG_INFINITY;
            for (x, y) in expect.iter().zip(&out) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
                emax = emax.max(*y);
            }
            assert_eq!(bmax, emax);
        }
        // n == 0: max is -inf, nothing written
        let (lut, packed, _, _) = setup(5, 8, 64);
        let blut = ByteLut::from_lut(&lut);
        let mut out = [0.0f32; 0];
        assert_eq!(
            score_block_bytelut(&blut, &packed, 0, &mut out),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn bytelut_rebuild_reuses_capacity() {
        let (lut, packed, _, _) = setup(6, 64, 64);
        let mut blut = ByteLut::from_lut(&lut);
        let cap = blut.table.capacity();
        blut.rebuild(&lut);
        assert_eq!(blut.table.capacity(), cap, "rebuild must not reallocate");
        let mut a = Vec::new();
        score_tokens(&lut, &packed, 64, &mut a);
        let mut b = vec![0.0f32; 64];
        score_block_bytelut(&blut, &packed, 64, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn scores_approximate_exact_dot() {
        // correlation between LUT scores and exact q·k must be strong
        let (lut, packed, keys, q) = setup(5, 2048, 64);
        let mut approx = Vec::new();
        score_tokens(&lut, &packed, 2048, &mut approx);
        let mut exact = Vec::new();
        exact_scores(&q, &keys, 64, &mut exact);
        let n = approx.len() as f32;
        let (ma, me) = (
            approx.iter().sum::<f32>() / n,
            exact.iter().sum::<f32>() / n,
        );
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut ve = 0.0;
        for i in 0..approx.len() {
            let (da, de) = (approx[i] - ma, exact[i] - me);
            cov += da * de;
            va += da * da;
            ve += de * de;
        }
        let corr = cov / (va.sqrt() * ve.sqrt());
        assert!(corr > 0.65, "correlation {corr}");
    }

    #[test]
    fn score_is_sum_of_lut_entries() {
        let (lut, packed, _, _) = setup(6, 16, 16);
        let g = lut.groups;
        let mut scores = Vec::new();
        score_tokens(&lut, &packed, 16, &mut scores);
        // recompute via unpacked codes
        let codes = crate::quant::pack::unpack_codes(&packed, 16 * g);
        for t in 0..16 {
            let expect: f32 = (0..g)
                .map(|gi| lut.get(gi, codes[t * g + gi] as usize))
                .sum();
            assert!((scores[t] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let (lut, packed, _, _) = setup(7, 8, 64);
        let mut out = Vec::new();
        score_tokens(&lut, &packed, 0, &mut out);
        assert!(out.is_empty());
        let blut = ByteLut::from_lut(&lut);
        score_tokens_bytelut(&blut, &packed, 1, &mut out);
        assert_eq!(out.len(), 1);
    }
}
