//! LUT-GEMV scoring over packed codes — **the decode hot path** (Eq. 8).
//!
//! `score(token) = Σ_g lut[g][code_g(token)]` where the codes are nibbles
//! packed two-per-byte, token-major. Two implementations:
//!
//! * [`score_tokens`] — straightforward nibble loop (reference).
//! * [`score_tokens_bytelut`] — byte-combined LUT: for each byte position
//!   (two adjacent groups) precompute a 256-entry table
//!   `byte_lut[j][b] = lut[2j][b & 0xF] + lut[2j+1][b >> 4]`, halving the
//!   lookups per token to G/2. This is the shared-memory LUT trick of the
//!   paper's CUDA kernel, restated for CPU caches: at G=16 the combined
//!   table is 8·256·4 B = 8 KiB — L1-resident. (§Perf iteration 1.)

use super::lut::Lut;

/// Reference scorer: G nibble lookups per token.
/// `packed`: token-major nibbles, `bpt` = bytes per token = G/2.
pub fn score_tokens(lut: &Lut, packed: &[u8], n_tokens: usize, out: &mut Vec<f32>) {
    let g = lut.groups;
    let bpt = g / 2;
    debug_assert_eq!(
        packed.len(),
        n_tokens * bpt,
        "packed length must be exactly n_tokens × bytes_per_token"
    );
    assert!(packed.len() >= n_tokens * bpt);
    out.clear();
    out.resize(n_tokens, 0.0);
    for t in 0..n_tokens {
        let row = &packed[t * bpt..(t + 1) * bpt];
        let mut acc = 0.0f32;
        for (j, &b) in row.iter().enumerate() {
            acc += lut.get(2 * j, (b & 0x0f) as usize);
            acc += lut.get(2 * j + 1, (b >> 4) as usize);
        }
        out[t] = acc;
    }
}

/// Byte-combined LUT: 256 entries per byte position.
pub struct ByteLut {
    pub bytes_per_token: usize,
    /// flat [byte_pos][256]
    pub table: Vec<f32>,
}

impl ByteLut {
    /// Empty table — a reusable arena for [`ByteLut::rebuild`].
    pub fn empty() -> Self {
        Self { bytes_per_token: 0, table: vec![] }
    }

    pub fn from_lut(lut: &Lut) -> Self {
        let mut blut = Self::empty();
        blut.rebuild(lut);
        blut
    }

    /// Rebuild in place (decode hot path: no per-step allocation once the
    /// table has its capacity, and no redundant zero-fill — the loop
    /// below overwrites every slot).
    pub fn rebuild(&mut self, lut: &Lut) {
        let bpt = lut.groups / 2;
        self.bytes_per_token = bpt;
        let needed = bpt * 256;
        if self.table.len() != needed {
            self.table.clear();
            self.table.resize(needed, 0.0);
        }
        for j in 0..bpt {
            let lo = &lut.table[(2 * j) * 16..(2 * j) * 16 + 16];
            let hi = &lut.table[(2 * j + 1) * 16..(2 * j + 1) * 16 + 16];
            let dst = &mut self.table[j * 256..(j + 1) * 256];
            for b in 0..256 {
                dst[b] = lo[b & 0x0f] + hi[b >> 4];
            }
        }
    }
}

/// Optimized scorer: G/2 byte lookups per token, 4-token unrolled.
pub fn score_tokens_bytelut(
    blut: &ByteLut,
    packed: &[u8],
    n_tokens: usize,
    out: &mut Vec<f32>,
) {
    let bpt = blut.bytes_per_token;
    assert!(packed.len() >= n_tokens * bpt);
    out.clear();
    out.resize(n_tokens, 0.0);
    let table = &blut.table;

    let chunks = n_tokens / 4;
    for c in 0..chunks {
        let t0 = c * 4;
        let base = t0 * bpt;
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for j in 0..bpt {
            let tj = &table[j * 256..(j + 1) * 256];
            a0 += tj[packed[base + j] as usize];
            a1 += tj[packed[base + bpt + j] as usize];
            a2 += tj[packed[base + 2 * bpt + j] as usize];
            a3 += tj[packed[base + 3 * bpt + j] as usize];
        }
        out[t0] = a0;
        out[t0 + 1] = a1;
        out[t0 + 2] = a2;
        out[t0 + 3] = a3;
    }
    for t in chunks * 4..n_tokens {
        let row = &packed[t * bpt..(t + 1) * bpt];
        let mut acc = 0.0f32;
        for j in 0..bpt {
            acc += table[j * 256 + row[j] as usize];
        }
        out[t] = acc;
    }
}

/// Block scorer for the fused streaming pipeline (§Perf iteration 5):
/// scores `n_tokens` packed codes straight out of one cache block into a
/// caller-owned slice (no allocation, no Vec bookkeeping) and returns the
/// block maximum so the streaming selector can reject whole blocks below
/// its running k-th threshold. 8-token unroll: blocks are block-major
/// contiguous, so eight rows span 8·bpt consecutive bytes — enough
/// independent accumulator chains to hide the L1 load latency of the
/// table lookups.
pub fn score_block_bytelut(
    blut: &ByteLut,
    packed: &[u8],
    n_tokens: usize,
    out: &mut [f32],
) -> f32 {
    let bpt = blut.bytes_per_token;
    assert!(packed.len() >= n_tokens * bpt);
    assert!(out.len() >= n_tokens);
    let table = &blut.table;
    let mut bmax = f32::NEG_INFINITY;

    let chunks = n_tokens / 8;
    for c in 0..chunks {
        let t0 = c * 8;
        let base = t0 * bpt;
        let mut acc = [0.0f32; 8];
        for j in 0..bpt {
            let tj = &table[j * 256..(j + 1) * 256];
            for (u, a) in acc.iter_mut().enumerate() {
                *a += tj[packed[base + u * bpt + j] as usize];
            }
        }
        for (u, &a) in acc.iter().enumerate() {
            out[t0 + u] = a;
            bmax = bmax.max(a);
        }
    }
    for t in chunks * 8..n_tokens {
        let row = &packed[t * bpt..(t + 1) * bpt];
        let mut a = 0.0f32;
        for j in 0..bpt {
            a += table[j * 256 + row[j] as usize];
        }
        out[t] = a;
        bmax = bmax.max(a);
    }
    bmax
}

/// Scorer selection for the fused block-streaming pipeline
/// (`HeadCache::stream_scores` / `stream_select`): either the
/// byte-combined LUT (general magnitude-centroid scoring, the
/// conformance oracle) or XOR+popcount over word-packed sign codes
/// (sign-agreement scoring — the paper's "hardware-friendly bit
/// operation"; §Perf iteration 8). Both produce per-token scores plus a
/// block max, so block rejection and threshold semantics are identical.
pub enum BlockScorer<'a> {
    ByteLut(&'a ByteLut),
    Popcnt {
        /// the query's word-packed sign codes
        /// (`quant::pack::pack_signs_u64`), `codes_words` long
        q_words: &'a [u64],
        /// head_dim — one sign bit per channel, so scores lie in [-dim, dim]
        dim: usize,
    },
}

impl BlockScorer<'_> {
    /// Score one block's first `n_tokens` into `out`, returning the block
    /// max. `codes` is the block's packed nibble bytes, `codes_w` its
    /// word-packed mirror — each variant reads only its own layout.
    #[inline]
    pub fn score_block(
        &self,
        codes: &[u8],
        codes_w: &[u64],
        n_tokens: usize,
        out: &mut [f32],
    ) -> f32 {
        match self {
            BlockScorer::ByteLut(blut) => score_block_bytelut(blut, codes, n_tokens, out),
            BlockScorer::Popcnt { q_words, dim } => {
                score_block_popcnt(q_words, codes_w, n_tokens, *dim, out)
            }
        }
    }
}

/// Popcount block scorer: `score(token) = dim − 2·popcount(q ⊕ k)` over
/// word-packed sign codes — the sign-agreement dot product
/// `Σ_j sign(q_j)·sign(k_j)` (paper Eq. 2: the compressed keys ARE the
/// retrieval index, and retrieval is an XNOR+popcount). Padding bits are
/// zero in both operands (`pack_signs_u64_into`), so the XOR contributes
/// nothing and no tail mask is needed. Scores are integers in
/// [−dim, dim], exact in f32, so every kernel below is bit-identical to
/// the others — and to the byte-LUT path over a sign-agreement LUT
/// (`Lut::sign_agreement`) — under any RUSTFLAGS (the CI parity matrix).
///
/// Runtime dispatch: AVX2 (Mula's `pshufb` nibble-LUT popcount) or
/// hardware `popcnt` on x86-64, NEON `cnt` on aarch64, with the unrolled
/// scalar loop always compiled as the fallback. Returns the block max.
pub fn score_block_popcnt(
    q_words: &[u64],
    words: &[u64],
    n_tokens: usize,
    dim: usize,
    out: &mut [f32],
) -> f32 {
    assert!(words.len() >= n_tokens * q_words.len());
    assert!(out.len() >= n_tokens);
    #[cfg(target_arch = "x86_64")]
    {
        let wpt = q_words.len();
        if (wpt == 1 || wpt == 2)
            && is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("popcnt")
        {
            // SAFETY: both features verified present at runtime.
            return unsafe { x86::block_avx2(q_words, words, n_tokens, dim, out) };
        }
        if is_x86_feature_detected!("popcnt") {
            // SAFETY: popcnt verified present at runtime.
            return unsafe { x86::block_popcnt(q_words, words, n_tokens, dim, out) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    return arm::block_neon(q_words, words, n_tokens, dim, out);
    #[cfg(not(target_arch = "aarch64"))]
    score_block_popcnt_scalar(q_words, words, n_tokens, dim, out)
}

/// The always-compiled scalar kernel behind [`score_block_popcnt`] —
/// public so the CI parity matrix can pin dispatched == scalar without
/// knowing which SIMD path the host selected.
pub fn score_block_popcnt_scalar(
    q_words: &[u64],
    words: &[u64],
    n_tokens: usize,
    dim: usize,
    out: &mut [f32],
) -> f32 {
    assert!(words.len() >= n_tokens * q_words.len());
    assert!(out.len() >= n_tokens);
    popcnt_body(q_words, words, n_tokens, dim, out)
}

/// Shared 8-token-unrolled loop body: eight independent XOR+popcount
/// chains per iteration hide the latency of `count_ones()` the same way
/// the byte-LUT unroll hides its L1 load latency. `#[inline(always)]` so
/// the `#[target_feature(enable = "popcnt")]` wrapper inlines it and the
/// compiler lowers `count_ones()` to the hardware instruction there
/// (baseline x86-64 compiles it to bit-twiddling otherwise).
#[inline(always)]
fn popcnt_body(
    q_words: &[u64],
    words: &[u64],
    n_tokens: usize,
    dim: usize,
    out: &mut [f32],
) -> f32 {
    let wpt = q_words.len();
    let d = dim as i32;
    let mut bmax = f32::NEG_INFINITY;
    let chunks = n_tokens / 8;
    for c in 0..chunks {
        let t0 = c * 8;
        let base = t0 * wpt;
        let mut cnt = [0u32; 8];
        for (w, &q) in q_words.iter().enumerate() {
            for (u, cn) in cnt.iter_mut().enumerate() {
                *cn += (q ^ words[base + u * wpt + w]).count_ones();
            }
        }
        for (u, &cn) in cnt.iter().enumerate() {
            let sc = (d - 2 * cn as i32) as f32;
            out[t0 + u] = sc;
            bmax = bmax.max(sc);
        }
    }
    for t in chunks * 8..n_tokens {
        let row = &words[t * wpt..(t + 1) * wpt];
        let mut cn = 0u32;
        for (w, &q) in q_words.iter().enumerate() {
            cn += (q ^ row[w]).count_ones();
        }
        let sc = (d - 2 * cn as i32) as f32;
        out[t] = sc;
        bmax = bmax.max(sc);
    }
    bmax
}

/// Which popcount kernel [`score_block_popcnt`] will dispatch to on this
/// host for a token width of `words_per_token` — bench/CI reporting only.
pub fn popcnt_kernel_name(words_per_token: usize) -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if (words_per_token == 1 || words_per_token == 2)
            && is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("popcnt")
        {
            return "avx2";
        }
        if is_x86_feature_detected!("popcnt") {
            return "popcnt";
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if words_per_token == 1 {
            return "neon";
        }
    }
    let _ = words_per_token;
    "scalar"
}

/// Sound upper bound on the popcount score of every token in a page,
/// from the page's bit-majority sketch `m` and Hamming radius
/// `r = max_t popcount(codes_t ⊕ m)` (`quant::pack::hamming_radius`).
/// By the Hamming triangle inequality,
///
/// ```text
/// popcount(q ⊕ t) ≥ popcount(q ⊕ m) − popcount(t ⊕ m) ≥ popcount(q ⊕ m) − r
/// ```
///
/// so `score(t) = dim − 2·popcount(q ⊕ t) ≤ dim − 2·(popcount(q ⊕ m) − r)`
/// for every token `t` the radius covers. The gap `popcount(q⊕m) − r` can
/// be negative — signed arithmetic keeps the bound valid (just loose).
/// The radius is monotone in its token set, so a bound over a page whose
/// scored suffix was clamped by `end` is still sound. All-integer
/// arithmetic cast to f32 once: bit-identical under any RUSTFLAGS, like
/// every kernel above, so page skipping preserves the CI parity matrix.
#[inline]
pub fn page_bound(q_words: &[u64], m: &[u64], r: u32, dim: usize) -> f32 {
    debug_assert_eq!(q_words.len(), m.len());
    let mut qm = 0u32;
    for (&q, &mw) in q_words.iter().zip(m) {
        qm += (q ^ mw).count_ones();
    }
    (dim as i64 - 2 * (qm as i64 - r as i64)) as f32
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::popcnt_body;
    use std::arch::x86_64::*;

    /// The scalar body compiled with POPCNT enabled, so `count_ones()`
    /// lowers to the hardware instruction even under baseline RUSTFLAGS.
    ///
    /// # Safety
    /// The caller must have verified `popcnt` via `is_x86_feature_detected!`.
    #[target_feature(enable = "popcnt")]
    pub unsafe fn block_popcnt(
        q_words: &[u64],
        words: &[u64],
        n_tokens: usize,
        dim: usize,
        out: &mut [f32],
    ) -> f32 {
        popcnt_body(q_words, words, n_tokens, dim, out)
    }

    /// Mula's AVX2 popcount: per-byte counts via a `pshufb` nibble LUT,
    /// summed into per-64-bit-lane totals with `psadbw` — one lane per
    /// token word, so a 256-bit vector scores 4 tokens at one word per
    /// token (head_dim 64) or 2 tokens at two (head_dim 128).
    ///
    /// # Safety
    /// The caller must have verified `avx2` and `popcnt` via
    /// `is_x86_feature_detected!`, and `q_words.len()` must be 1 or 2.
    #[target_feature(enable = "avx2", enable = "popcnt")]
    pub unsafe fn block_avx2(
        q_words: &[u64],
        words: &[u64],
        n_tokens: usize,
        dim: usize,
        out: &mut [f32],
    ) -> f32 {
        let wpt = q_words.len();
        debug_assert!(wpt == 1 || wpt == 2);
        let d = dim as i64;
        let mut bmax = f32::NEG_INFINITY;
        let tok_per_vec = 4 / wpt;
        let vecs = n_tokens / tok_per_vec;
        unsafe {
            #[rustfmt::skip]
            let nib_lut = _mm256_setr_epi8(
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            );
            let low_mask = _mm256_set1_epi8(0x0f);
            let qv = if wpt == 1 {
                _mm256_set1_epi64x(q_words[0] as i64)
            } else {
                _mm256_setr_epi64x(
                    q_words[0] as i64,
                    q_words[1] as i64,
                    q_words[0] as i64,
                    q_words[1] as i64,
                )
            };
            let mut lane_cnts = [0u64; 4];
            for v in 0..vecs {
                let ptr = words.as_ptr().add(v * 4) as *const __m256i;
                let x = _mm256_xor_si256(_mm256_loadu_si256(ptr), qv);
                let lo = _mm256_shuffle_epi8(nib_lut, _mm256_and_si256(x, low_mask));
                let hi = _mm256_shuffle_epi8(
                    nib_lut,
                    _mm256_and_si256(_mm256_srli_epi64::<4>(x), low_mask),
                );
                let sums = _mm256_sad_epu8(_mm256_add_epi8(lo, hi), _mm256_setzero_si256());
                _mm256_storeu_si256(lane_cnts.as_mut_ptr() as *mut __m256i, sums);
                let t0 = v * tok_per_vec;
                if wpt == 1 {
                    for (u, &cn) in lane_cnts.iter().enumerate() {
                        let sc = (d - 2 * cn as i64) as f32;
                        out[t0 + u] = sc;
                        bmax = bmax.max(sc);
                    }
                } else {
                    let s0 = (d - 2 * (lane_cnts[0] + lane_cnts[1]) as i64) as f32;
                    let s1 = (d - 2 * (lane_cnts[2] + lane_cnts[3]) as i64) as f32;
                    out[t0] = s0;
                    out[t0 + 1] = s1;
                    bmax = bmax.max(s0).max(s1);
                }
            }
        }
        // ragged tail through the (popcnt-lowered) scalar body
        let done = vecs * tok_per_vec;
        if done < n_tokens {
            let tail = popcnt_body(
                q_words,
                &words[done * wpt..],
                n_tokens - done,
                dim,
                &mut out[done..],
            );
            bmax = bmax.max(tail);
        }
        bmax
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::popcnt_body;
    use std::arch::aarch64::*;

    /// NEON popcount (`cnt` per byte + widening pair-adds): two tokens per
    /// 128-bit vector at one word per token. NEON is baseline on aarch64,
    /// so no runtime detection is needed; wider tokens use the scalar body
    /// (LLVM lowers `count_ones()` to `cnt`+`addv` there anyway).
    pub fn block_neon(
        q_words: &[u64],
        words: &[u64],
        n_tokens: usize,
        dim: usize,
        out: &mut [f32],
    ) -> f32 {
        if q_words.len() != 1 {
            return popcnt_body(q_words, words, n_tokens, dim, out);
        }
        let q = q_words[0];
        let d = dim as i32;
        let mut bmax = f32::NEG_INFINITY;
        let pairs = n_tokens / 2;
        // SAFETY: NEON is a baseline aarch64 feature; loads stay within
        // `words[..n_tokens]` (asserted by the dispatching caller).
        unsafe {
            let qv = vreinterpretq_u8_u64(vdupq_n_u64(q));
            for p in 0..pairs {
                let x = veorq_u8(vld1q_u8(words.as_ptr().add(p * 2) as *const u8), qv);
                let c64 = vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(x))));
                let s0 = (d - 2 * vgetq_lane_u64::<0>(c64) as i32) as f32;
                let s1 = (d - 2 * vgetq_lane_u64::<1>(c64) as i32) as f32;
                out[p * 2] = s0;
                out[p * 2 + 1] = s1;
                bmax = bmax.max(s0).max(s1);
            }
        }
        if pairs * 2 < n_tokens {
            let t = n_tokens - 1;
            let sc = (d - 2 * (q ^ words[t]).count_ones() as i32) as f32;
            out[t] = sc;
            bmax = bmax.max(sc);
        }
        bmax
    }
}

/// Full-precision scores q·K'ᵀ — the baseline LUT-GEMV replaces
/// (paper Table 4 "Full K·qᵀ" row).
pub fn exact_scores(query: &[f32], keys: &[f32], dim: usize, out: &mut Vec<f32>) {
    assert_eq!(keys.len() % dim, 0);
    out.clear();
    for row in keys.chunks_exact(dim) {
        out.push(crate::tensor::dot(query, row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selfindex::codebook::CodebookBuilder;
    use crate::selfindex::codes::encode_tokens_packed;
    use crate::substrate::rng::Rng;

    fn setup(seed: u64, tokens: usize, dim: usize) -> (Lut, Vec<u8>, Vec<f32>, Vec<f32>) {
        let mut r = Rng::new(seed);
        let keys: Vec<f32> = (0..tokens * dim).map(|_| r.normal_f32()).collect();
        let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let mut b = CodebookBuilder::new(dim / 4);
        b.accumulate(&keys);
        let cb = b.finalize();
        let packed = encode_tokens_packed(&keys, dim);
        (Lut::build(&q, &cb), packed, keys, q)
    }

    #[test]
    fn bytelut_matches_reference() {
        for (seed, tokens, dim) in [(1, 127, 64), (2, 4, 64), (3, 1000, 32), (4, 3, 8)] {
            let (lut, packed, _, _) = setup(seed, tokens, dim);
            let mut a = Vec::new();
            let mut b = Vec::new();
            score_tokens(&lut, &packed, tokens, &mut a);
            let blut = ByteLut::from_lut(&lut);
            score_tokens_bytelut(&blut, &packed, tokens, &mut b);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn block_scorer_matches_reference_and_max() {
        // covers: multiple-of-8, ragged tails, tiny blocks
        let cases = [(1, 128, 64), (2, 7, 64), (3, 1000, 32), (4, 8, 8), (9, 1, 64)];
        for (seed, tokens, dim) in cases {
            let (lut, packed, _, _) = setup(seed, tokens, dim);
            let mut expect = Vec::new();
            score_tokens(&lut, &packed, tokens, &mut expect);
            let blut = ByteLut::from_lut(&lut);
            let mut out = vec![0.0f32; tokens];
            let bmax = score_block_bytelut(&blut, &packed, tokens, &mut out);
            let mut emax = f32::NEG_INFINITY;
            for (x, y) in expect.iter().zip(&out) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
                emax = emax.max(*y);
            }
            assert_eq!(bmax, emax);
        }
        // n == 0: max is -inf, nothing written
        let (lut, packed, _, _) = setup(5, 8, 64);
        let blut = ByteLut::from_lut(&lut);
        let mut out = [0.0f32; 0];
        assert_eq!(
            score_block_bytelut(&blut, &packed, 0, &mut out),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn page_bound_dominates_every_token_score() {
        // random word rows: the sketch/radius bound must sit at or above
        // the popcount kernel's block max for any query, including ragged
        // word tails (dim 72 → 9 codes bytes → 2 words, 1-byte payload)
        let mut r = Rng::new(0xb0b);
        for &dim in &[8usize, 64, 72, 128] {
            let cb = dim / 8;
            let wpt = crate::quant::pack::words_per_token(cb);
            for &tokens in &[1usize, 5, 33] {
                let bytes: Vec<u8> = (0..tokens * cb).map(|_| r.below(256) as u8).collect();
                let words = crate::quant::pack::pack_signs_u64(&bytes, tokens, cb);
                let m = crate::quant::pack::majority_sketch(&words, wpt);
                let rad = crate::quant::pack::hamming_radius(&words, &m);
                let qb: Vec<u8> = (0..cb).map(|_| r.below(256) as u8).collect();
                let q_words = crate::quant::pack::pack_signs_u64(&qb, 1, cb);
                let mut out = vec![0.0f32; tokens];
                let bmax = score_block_popcnt(&q_words, &words, tokens, dim, &mut out);
                let bound = page_bound(&q_words, &m, rad, dim);
                assert!(bound >= bmax, "dim {dim} n {tokens}: bound {bound} < block max {bmax}");
            }
        }
        // the query exactly at the sketch with radius 0: bound == dim
        let m = vec![0xdead_beefu64];
        assert_eq!(page_bound(&m, &m, 0, 64), 64.0);
    }

    #[test]
    fn bytelut_rebuild_reuses_capacity() {
        let (lut, packed, _, _) = setup(6, 64, 64);
        let mut blut = ByteLut::from_lut(&lut);
        let cap = blut.table.capacity();
        blut.rebuild(&lut);
        assert_eq!(blut.table.capacity(), cap, "rebuild must not reallocate");
        let mut a = Vec::new();
        score_tokens(&lut, &packed, 64, &mut a);
        let mut b = vec![0.0f32; 64];
        score_block_bytelut(&blut, &packed, 64, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn scores_approximate_exact_dot() {
        // correlation between LUT scores and exact q·k must be strong
        let (lut, packed, keys, q) = setup(5, 2048, 64);
        let mut approx = Vec::new();
        score_tokens(&lut, &packed, 2048, &mut approx);
        let mut exact = Vec::new();
        exact_scores(&q, &keys, 64, &mut exact);
        let n = approx.len() as f32;
        let (ma, me) = (
            approx.iter().sum::<f32>() / n,
            exact.iter().sum::<f32>() / n,
        );
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut ve = 0.0;
        for i in 0..approx.len() {
            let (da, de) = (approx[i] - ma, exact[i] - me);
            cov += da * de;
            va += da * da;
            ve += de * de;
        }
        let corr = cov / (va.sqrt() * ve.sqrt());
        assert!(corr > 0.65, "correlation {corr}");
    }

    #[test]
    fn score_is_sum_of_lut_entries() {
        let (lut, packed, _, _) = setup(6, 16, 16);
        let g = lut.groups;
        let mut scores = Vec::new();
        score_tokens(&lut, &packed, 16, &mut scores);
        // recompute via unpacked codes
        let codes = crate::quant::pack::unpack_codes(&packed, 16 * g);
        for t in 0..16 {
            let expect: f32 = (0..g)
                .map(|gi| lut.get(gi, codes[t * g + gi] as usize))
                .sum();
            assert!((scores[t] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let (lut, packed, _, _) = setup(7, 8, 64);
        let mut out = Vec::new();
        // exact-length slices: score_tokens asserts packed == n_tokens*bpt
        score_tokens(&lut, &packed[..0], 0, &mut out);
        assert!(out.is_empty());
        let bpt = lut.groups / 2;
        score_tokens(&lut, &packed[..bpt], 1, &mut out);
        assert_eq!(out.len(), 1);
        let blut = ByteLut::from_lut(&lut);
        score_tokens_bytelut(&blut, &packed, 1, &mut out);
        assert_eq!(out.len(), 1);
    }

    /// naive integer sign-agreement score: Σ_j sign(q_j)·sign(k_j) from
    /// the unpacked nibble codes — the ground truth every popcount kernel
    /// and the sign-LUT path must match bit-for-bit
    fn naive_sign_agreement(q_codes: &[u8], packed: &[u8], n_tokens: usize) -> Vec<f32> {
        let g = q_codes.len();
        let codes = crate::quant::pack::unpack_codes(packed, n_tokens * g);
        (0..n_tokens)
            .map(|t| {
                let mut acc = 0i32;
                for (gi, &qc) in q_codes.iter().enumerate() {
                    let kc = codes[t * g + gi];
                    // 4 agreements − 4 disagreements per nibble
                    acc += 4 - 2 * (qc ^ kc).count_ones() as i32;
                }
                acc as f32
            })
            .collect()
    }

    #[test]
    fn popcnt_matches_naive_sign_agreement() {
        use crate::quant::pack::{pack_signs_u64, words_per_token};
        let mut r = Rng::new(20);
        // dims cover wpt==1 (64), wpt==2 (128), and sub-word tails (8..56)
        for &dim in &[8usize, 16, 24, 32, 40, 56, 64, 72, 96, 120, 128] {
            for &tokens in &[0usize, 1, 5, 8, 17, 64, 257] {
                let keys: Vec<f32> =
                    (0..tokens * dim).map(|_| r.normal_f32()).collect();
                let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
                let packed = encode_tokens_packed(&keys, dim);
                let q_codes: Vec<u8> = q
                    .chunks_exact(4)
                    .map(crate::selfindex::codes::sign_code)
                    .collect();
                let cb = dim / 8;
                let words = pack_signs_u64(&packed, tokens, cb);
                let q_packed = crate::quant::pack::pack_codes(&q_codes);
                let q_words = pack_signs_u64(&q_packed, 1, cb);
                assert_eq!(q_words.len(), words_per_token(cb));

                let expect = naive_sign_agreement(&q_codes, &packed, tokens);
                let mut out = vec![f32::NAN; tokens];
                let bmax = score_block_popcnt(&q_words, &words, tokens, dim, &mut out);
                let mut smax = f32::NEG_INFINITY;
                for (t, (&a, &e)) in out.iter().zip(&expect).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        e.to_bits(),
                        "dim {dim} t {t}: {a} vs {e}"
                    );
                    smax = smax.max(e);
                }
                assert_eq!(bmax.to_bits(), smax.to_bits(), "dim {dim} block max");

                let mut out2 = vec![f32::NAN; tokens];
                let bmax2 =
                    score_block_popcnt_scalar(&q_words, &words, tokens, dim, &mut out2);
                assert_eq!(bmax.to_bits(), bmax2.to_bits());
                for (a, b) in out.iter().zip(&out2) {
                    assert_eq!(a.to_bits(), b.to_bits(), "dispatched vs scalar");
                }
            }
        }
    }

    #[test]
    fn popcnt_score_range_and_extremes() {
        use crate::quant::pack::pack_signs_u64;
        // identical codes → score == +dim; complemented → −dim
        for &dim in &[64usize, 128] {
            let cb = dim / 8;
            let token: Vec<u8> = (0..cb).map(|i| (i * 41 + 3) as u8).collect();
            let anti: Vec<u8> = token.iter().map(|b| !b).collect();
            let mut both = token.clone();
            both.extend_from_slice(&anti);
            let words = pack_signs_u64(&both, 2, cb);
            let q_words = pack_signs_u64(&token, 1, cb);
            let mut out = [0.0f32; 2];
            let bmax = score_block_popcnt(&q_words, &words, 2, dim, &mut out);
            assert_eq!(out[0], dim as f32);
            assert_eq!(out[1], -(dim as f32));
            assert_eq!(bmax, dim as f32);
        }
        // n == 0: nothing written, max is -inf
        let q_words = [0u64];
        let mut empty: [f32; 0] = [];
        assert_eq!(
            score_block_popcnt(&q_words, &[], 0, 64, &mut empty),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn block_scorer_dispatch_matches_direct_calls() {
        use crate::quant::pack::pack_signs_u64;
        let dim = 64;
        let tokens = 37;
        let (lut, packed, _, _) = setup(21, tokens, dim);
        let blut = ByteLut::from_lut(&lut);
        let mut a = vec![0.0f32; tokens];
        let mut b = vec![0.0f32; tokens];
        let m1 = BlockScorer::ByteLut(&blut).score_block(&packed, &[], tokens, &mut a);
        let m2 = score_block_bytelut(&blut, &packed, tokens, &mut b);
        assert_eq!(m1.to_bits(), m2.to_bits());
        assert_eq!(a, b);

        let words = pack_signs_u64(&packed, tokens, dim / 8);
        let q_words = vec![0x5a5a_5a5a_5a5a_5a5au64];
        let sc = BlockScorer::Popcnt { q_words: &q_words, dim };
        let m3 = sc.score_block(&[], &words, tokens, &mut a);
        let m4 = score_block_popcnt(&q_words, &words, tokens, dim, &mut b);
        assert_eq!(m3.to_bits(), m4.to_bits());
        assert_eq!(a, b);
    }
}
