//! Partial top-k selection over retrieval scores.
//!
//! Contract (shared with `ref.topk_indices`, pinned by golden vectors):
//! returns the indices of the k largest scores in descending score order,
//! ties broken by the smaller index. Implementation: bounded binary heap
//! of (score, index) — O(L log k), no allocation beyond the k-slot heap,
//! which beats a full sort at the paper's regime (k = 96, L = tens of
//! thousands).

use std::cmp::Ordering;

/// (score, index) with total order: higher score first, then lower index.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Entry {
    score: f32,
    index: u32,
}

impl Entry {
    /// `self` ranks better than `other`?
    #[inline(always)]
    fn beats(&self, other: &Entry) -> bool {
        match self.score.partial_cmp(&other.score) {
            Some(Ordering::Greater) => true,
            Some(Ordering::Less) => false,
            _ => self.index < other.index,
        }
    }
}

/// Top-k indices of `scores`, descending; ties -> smaller index first.
/// NaN scores rank last (never selected unless k exceeds finite count).
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(scores.len());
    if k == 0 {
        return vec![];
    }
    // min-heap of the current best k: root = worst of the kept set
    let mut heap: Vec<Entry> = Vec::with_capacity(k);

    let worse = |a: &Entry, b: &Entry| !a.beats(b); // a ranks worse-or-equal

    for (i, &s) in scores.iter().enumerate() {
        let s = if s.is_nan() { f32::NEG_INFINITY } else { s };
        let e = Entry { score: s, index: i as u32 };
        if heap.len() < k {
            heap.push(e);
            // sift up
            let mut c = heap.len() - 1;
            while c > 0 {
                let p = (c - 1) / 2;
                if worse(&heap[c], &heap[p]) {
                    heap.swap(c, p);
                    c = p;
                } else {
                    break;
                }
            }
        } else if e.beats(&heap[0]) {
            heap[0] = e;
            // sift down
            let mut p = 0;
            loop {
                let (l, r) = (2 * p + 1, 2 * p + 2);
                let mut worst = p;
                if l < k && worse(&heap[l], &heap[worst]) {
                    worst = l;
                }
                if r < k && worse(&heap[r], &heap[worst]) {
                    worst = r;
                }
                if worst == p {
                    break;
                }
                heap.swap(p, worst);
                p = worst;
            }
        }
    }

    let mut entries = heap;
    entries.sort_unstable_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(Ordering::Equal)
            .then(a.index.cmp(&b.index))
    });
    entries.into_iter().map(|e| e.index).collect()
}

/// Reference implementation (full sort) for property tests.
pub fn top_k_indices_sort(scores: &[f32], k: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        let (sa, sb) = (scores[a as usize], scores[b as usize]);
        let (sa, sb) = (
            if sa.is_nan() { f32::NEG_INFINITY } else { sa },
            if sb.is_nan() { f32::NEG_INFINITY } else { sb },
        );
        sb.partial_cmp(&sa).unwrap().then(a.cmp(&b))
    });
    idx.truncate(k.min(scores.len()));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::check;

    #[test]
    fn basic_selection() {
        let s = [1.0, 5.0, 3.0, 5.0, -2.0];
        assert_eq!(top_k_indices(&s, 3), vec![1, 3, 2]); // tie: idx 1 < 3
        assert_eq!(top_k_indices(&s, 0), Vec::<u32>::new());
        assert_eq!(top_k_indices(&s, 99), vec![1, 3, 2, 0, 4]);
    }

    #[test]
    fn nan_ranks_last() {
        let s = [f32::NAN, 1.0, 2.0];
        assert_eq!(top_k_indices(&s, 2), vec![2, 1]);
        assert_eq!(top_k_indices(&s, 3), vec![2, 1, 0]);
    }

    #[test]
    fn prop_matches_sort_reference() {
        check(
            21,
            300,
            |r| {
                let n = r.below(200) as usize;
                let k = r.below(64) as usize;
                let v: Vec<f32> = (0..n)
                    .map(|_| {
                        // coarse values to force plenty of ties
                        (r.below(20) as f32) - 10.0
                    })
                    .collect();
                (v, k)
            },
            |(v, k)| {
                let heap = top_k_indices(v, *k);
                let sorted = top_k_indices_sort(v, *k);
                if heap == sorted {
                    Ok(())
                } else {
                    Err(format!("heap {heap:?} != sort {sorted:?}"))
                }
            },
        );
    }

    #[test]
    fn descending_and_distinct() {
        check(
            22,
            200,
            |r| {
                (0..r.below(500))
                    .map(|_| r.normal_f32())
                    .collect::<Vec<f32>>()
            },
            |v| {
                let k = (v.len() / 3).max(1);
                let sel = top_k_indices(v, k);
                let set: std::collections::HashSet<_> = sel.iter().collect();
                if set.len() != sel.len() {
                    return Err("duplicate indices".into());
                }
                for w in sel.windows(2) {
                    if v[w[0] as usize] < v[w[1] as usize] {
                        return Err("not descending".into());
                    }
                }
                // every selected >= every unselected
                if let Some(&min_sel) = sel
                    .iter()
                    .map(|&i| &v[i as usize])
                    .min_by(|a, b| a.partial_cmp(b).unwrap())
                {
                    for (i, &s) in v.iter().enumerate() {
                        if !sel.contains(&(i as u32)) && s > min_sel {
                            return Err(format!("missed better index {i}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
