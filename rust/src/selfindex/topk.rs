//! Partial top-k selection over retrieval scores.
//!
//! Contract (shared with `ref.topk_indices`, pinned by golden vectors):
//! returns the indices of the k largest scores in descending score order,
//! ties broken by the smaller index. Implementation: bounded binary heap
//! of (score, index) — O(L log k), no allocation beyond the k-slot heap,
//! which beats a full sort at the paper's regime (k = 96, L = tens of
//! thousands).

use std::cmp::Ordering;

/// (score, index) with total order: higher score first, then lower index.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Entry {
    score: f32,
    index: u32,
}

impl Entry {
    /// `self` ranks better than `other`?
    #[inline(always)]
    fn beats(&self, other: &Entry) -> bool {
        match self.score.partial_cmp(&other.score) {
            Some(Ordering::Greater) => true,
            Some(Ordering::Less) => false,
            _ => self.index < other.index,
        }
    }
}

/// `a` ranks worse-or-equal than `b` (min-heap order: root = worst kept).
#[inline(always)]
fn worse(a: &Entry, b: &Entry) -> bool {
    !a.beats(b)
}

/// Streaming threshold-aware top-k selector — the selection stage of the
/// fused block pipeline (DESIGN.md §Perf iteration 5).
///
/// Scores are pushed as they are produced (block by block, straight out
/// of the compressed cache); a running k-th-score bar rejects most pushes
/// with a single `f32` compare before any heap work, and
/// [`TopKStream::threshold`] lets callers skip *entire blocks* — or, with
/// the sketch bound of DESIGN.md §Perf iteration 9, entire pages — whose
/// maximum score cannot enter the kept set. Same contract as
/// [`top_k_indices`] (descending scores, ties → smaller index, NaN ranks
/// last), verified by an equivalence property test.
///
/// All state is reusable: `reset` + `finish_into` keep the heap and the
/// output vector at capacity, so a decode step performs zero allocations.
pub struct TopKStream {
    k: usize,
    heap: Vec<Entry>,
    /// k-th (worst kept) score once the heap is full; -inf before that.
    bar: f32,
}

impl TopKStream {
    pub fn new(k: usize) -> Self {
        Self { k, heap: Vec::with_capacity(k), bar: f32::NEG_INFINITY }
    }

    /// Clear and re-arm for a new pass (keeps the heap's capacity).
    pub fn reset(&mut self, k: usize) {
        self.heap.clear();
        self.heap.reserve(k);
        self.k = k;
        self.bar = f32::NEG_INFINITY;
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.heap.len() == self.k
    }

    /// Current admission bar: a block whose max score is *below* this can
    /// be skipped wholesale (for ascending index streams, `<=` is also
    /// safe: an equal score with a larger index never displaces the kept
    /// set). +inf when k == 0, -inf while the heap is filling.
    #[inline(always)]
    pub fn threshold(&self) -> f32 {
        if self.k == 0 {
            f32::INFINITY
        } else if self.is_full() {
            self.bar
        } else {
            f32::NEG_INFINITY
        }
    }

    /// Offer one (index, score). NaN is treated as -inf (ranks last).
    #[inline]
    pub fn push(&mut self, index: u32, score: f32) {
        let s = if score.is_nan() { f32::NEG_INFINITY } else { score };
        if self.heap.len() < self.k {
            self.heap.push(Entry { score: s, index });
            // sift up
            let mut c = self.heap.len() - 1;
            while c > 0 {
                let p = (c - 1) / 2;
                if worse(&self.heap[c], &self.heap[p]) {
                    self.heap.swap(c, p);
                    c = p;
                } else {
                    break;
                }
            }
            if self.heap.len() == self.k {
                self.bar = self.heap[0].score;
            }
            return;
        }
        // fast reject: strictly below the k-th score (the common case on
        // long contexts) costs one compare and no heap traversal
        if self.k == 0 || s < self.bar {
            return;
        }
        let e = Entry { score: s, index };
        if !e.beats(&self.heap[0]) {
            return;
        }
        self.heap[0] = e;
        // sift down
        let k = self.k;
        let mut p = 0;
        loop {
            let (l, r) = (2 * p + 1, 2 * p + 2);
            let mut worst = p;
            if l < k && worse(&self.heap[l], &self.heap[worst]) {
                worst = l;
            }
            if r < k && worse(&self.heap[r], &self.heap[worst]) {
                worst = r;
            }
            if worst == p {
                break;
            }
            self.heap.swap(p, worst);
            p = worst;
        }
        self.bar = self.heap[0].score;
    }

    /// Drain the kept set into `out` (cleared first): indices in
    /// descending score order, ties by smaller index. Leaves the selector
    /// empty (call `reset` before the next pass).
    pub fn finish_into(&mut self, out: &mut Vec<u32>) {
        self.heap.sort_unstable_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(Ordering::Equal)
                .then(a.index.cmp(&b.index))
        });
        out.clear();
        out.extend(self.heap.iter().map(|e| e.index));
        self.heap.clear();
        self.bar = f32::NEG_INFINITY;
    }
}

/// Top-k indices of `scores`, descending; ties -> smaller index first.
/// NaN scores rank last (never selected unless k exceeds finite count).
/// One-shot wrapper over [`TopKStream`] (same heap, same contract).
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(scores.len());
    if k == 0 {
        return vec![];
    }
    let mut sel = TopKStream::new(k);
    for (i, &s) in scores.iter().enumerate() {
        sel.push(i as u32, s);
    }
    let mut out = Vec::with_capacity(k);
    sel.finish_into(&mut out);
    out
}

/// Reference implementation (full sort) for property tests.
pub fn top_k_indices_sort(scores: &[f32], k: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        let (sa, sb) = (scores[a as usize], scores[b as usize]);
        let (sa, sb) = (
            if sa.is_nan() { f32::NEG_INFINITY } else { sa },
            if sb.is_nan() { f32::NEG_INFINITY } else { sb },
        );
        sb.partial_cmp(&sa).unwrap().then(a.cmp(&b))
    });
    idx.truncate(k.min(scores.len()));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::check;

    #[test]
    fn basic_selection() {
        let s = [1.0, 5.0, 3.0, 5.0, -2.0];
        assert_eq!(top_k_indices(&s, 3), vec![1, 3, 2]); // tie: idx 1 < 3
        assert_eq!(top_k_indices(&s, 0), Vec::<u32>::new());
        assert_eq!(top_k_indices(&s, 99), vec![1, 3, 2, 0, 4]);
    }

    #[test]
    fn nan_ranks_last() {
        let s = [f32::NAN, 1.0, 2.0];
        assert_eq!(top_k_indices(&s, 2), vec![2, 1]);
        assert_eq!(top_k_indices(&s, 3), vec![2, 1, 0]);
    }

    #[test]
    fn edge_cases_k_zero_and_k_past_len() {
        assert_eq!(top_k_indices(&[], 0), Vec::<u32>::new());
        assert_eq!(top_k_indices(&[], 5), Vec::<u32>::new());
        assert_eq!(top_k_indices(&[3.0], 0), Vec::<u32>::new());
        // k >= L returns every index, still fully ordered
        let s = [2.0, -1.0, 2.0, 0.5];
        assert_eq!(top_k_indices(&s, 4), vec![0, 2, 3, 1]);
        assert_eq!(top_k_indices(&s, 100), vec![0, 2, 3, 1]);
        // all-NaN input: ties at -inf break by index
        let nans = [f32::NAN; 3];
        assert_eq!(top_k_indices(&nans, 2), vec![0, 1]);
    }

    #[test]
    fn all_equal_ties_prefer_small_indices() {
        let s = [7.0f32; 10];
        assert_eq!(top_k_indices(&s, 3), vec![0, 1, 2]);
        let mut sel = TopKStream::new(3);
        for (i, &v) in s.iter().enumerate() {
            sel.push(i as u32, v);
        }
        let mut out = Vec::new();
        sel.finish_into(&mut out);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn stream_threshold_tracks_kth_score() {
        let mut sel = TopKStream::new(2);
        assert_eq!(sel.threshold(), f32::NEG_INFINITY);
        sel.push(0, 1.0);
        assert!(!sel.is_full());
        sel.push(1, 5.0);
        assert!(sel.is_full());
        assert_eq!(sel.threshold(), 1.0);
        sel.push(2, 0.5); // below the bar: rejected, bar unchanged
        assert_eq!(sel.threshold(), 1.0);
        sel.push(3, 3.0); // displaces the 1.0
        assert_eq!(sel.threshold(), 3.0);
        let mut out = Vec::new();
        sel.finish_into(&mut out);
        assert_eq!(out, vec![1, 3]);
        // k == 0: always "full", +inf bar (blocks skip wholesale)
        sel.reset(0);
        assert_eq!(sel.threshold(), f32::INFINITY);
        sel.push(9, 100.0);
        sel.finish_into(&mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn stream_reset_and_finish_do_not_reallocate() {
        let mut sel = TopKStream::new(16);
        let mut out = Vec::with_capacity(16);
        for round in 0..4u32 {
            sel.reset(16);
            for i in 0..500u32 {
                sel.push(i, ((i * 7919 + round) % 1000) as f32);
            }
            let cap = out.capacity();
            sel.finish_into(&mut out);
            assert_eq!(out.len(), 16);
            assert_eq!(out.capacity(), cap, "finish_into must reuse out");
        }
    }

    #[test]
    fn prop_stream_matches_heap_selector() {
        // streaming selector == one-shot heap selector == sort reference,
        // under NaN injections and heavy ties, any k (incl. 0 and > L)
        check(
            23,
            400,
            |r| {
                let n = r.below(300) as usize;
                let k = r.below(80) as usize;
                let v: Vec<f32> = (0..n)
                    .map(|_| match r.below(10) {
                        0 => f32::NAN,
                        1 => f32::NEG_INFINITY,
                        _ => (r.below(25) as f32) - 12.0, // coarse: many ties
                    })
                    .collect();
                (v, k)
            },
            |(v, k)| {
                let heap = top_k_indices(v, *k);
                let sorted = top_k_indices_sort(v, *k);
                let mut sel = TopKStream::new(k.min(v.len()));
                for (i, &s) in v.iter().enumerate() {
                    sel.push(i as u32, s);
                }
                let mut stream = Vec::new();
                sel.finish_into(&mut stream);
                if heap != sorted {
                    return Err(format!("heap {heap:?} != sort {sorted:?}"));
                }
                if stream != sorted {
                    return Err(format!("stream {stream:?} != sort {sorted:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_stream_block_skip_is_lossless() {
        // feeding scores block-wise and skipping blocks whose max is
        // below the running threshold must select the same set (ascending
        // index streams)
        check(
            24,
            300,
            |r| {
                let n = r.below(400) as usize;
                let k = 1 + r.below(48) as usize;
                let bs = 1 + r.below(64) as usize;
                let v: Vec<f32> = (0..n).map(|_| (r.below(30) as f32) - 15.0).collect();
                ((v, k), bs)
            },
            |((v, k), bs)| {
                let expect = top_k_indices(v, *k);
                let mut sel = TopKStream::new((*k).min(v.len()));
                for (bi, block) in v.chunks(*bs).enumerate() {
                    let bmax = block.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    if sel.is_full() && bmax <= sel.threshold() {
                        continue; // whole-block skip
                    }
                    for (o, &s) in block.iter().enumerate() {
                        sel.push((bi * bs + o) as u32, s);
                    }
                }
                let mut got = Vec::new();
                sel.finish_into(&mut got);
                if got != expect {
                    return Err(format!("skip {got:?} != full {expect:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_matches_sort_reference() {
        check(
            21,
            300,
            |r| {
                let n = r.below(200) as usize;
                let k = r.below(64) as usize;
                let v: Vec<f32> = (0..n)
                    .map(|_| {
                        // coarse values to force plenty of ties
                        (r.below(20) as f32) - 10.0
                    })
                    .collect();
                (v, k)
            },
            |(v, k)| {
                let heap = top_k_indices(v, *k);
                let sorted = top_k_indices_sort(v, *k);
                if heap == sorted {
                    Ok(())
                } else {
                    Err(format!("heap {heap:?} != sort {sorted:?}"))
                }
            },
        );
    }

    #[test]
    fn descending_and_distinct() {
        check(
            22,
            200,
            |r| {
                (0..r.below(500))
                    .map(|_| r.normal_f32())
                    .collect::<Vec<f32>>()
            },
            |v| {
                let k = (v.len() / 3).max(1);
                let sel = top_k_indices(v, k);
                let set: std::collections::HashSet<_> = sel.iter().collect();
                if set.len() != sel.len() {
                    return Err("duplicate indices".into());
                }
                for w in sel.windows(2) {
                    if v[w[0] as usize] < v[w[1] as usize] {
                        return Err("not descending".into());
                    }
                }
                // every selected >= every unselected
                if let Some(&min_sel) = sel
                    .iter()
                    .map(|&i| &v[i as usize])
                    .min_by(|a, b| a.partial_cmp(b).unwrap())
                {
                    for (i, &s) in v.iter().enumerate() {
                        if !sel.contains(&(i as u32)) && s > min_sel {
                            return Err(format!("missed better index {i}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
