//! LUT construction (paper Fig. 3, left half): per query, dot each of the
//! G query subvectors with its group's 16 centroids → a G×16 table of
//! partial scores. O(G·16·4) = O(16·D) flops — tiny, once per (query,
//! head, step); the per-token work is then pure lookups ([`super::score`]).

use super::codebook::Codebook;

/// Per-query lookup table: `groups × 16` partial scores, g-major.
#[derive(Clone, Debug)]
pub struct Lut {
    pub groups: usize,
    pub table: Vec<f32>, // flat [g][c]
}

impl Lut {
    /// Empty (zeroed) table — a reusable arena for [`Lut::rebuild`].
    pub fn empty(groups: usize) -> Self {
        Self { groups, table: vec![0.0f32; groups * 16] }
    }

    /// Build from a (rotated, *not* centered) query — centering keys does
    /// not require centering queries (Eq. 7); the LUT absorbs everything.
    pub fn build(query: &[f32], codebook: &Codebook) -> Self {
        let mut lut = Lut::empty(codebook.groups);
        lut.rebuild(query, codebook);
        lut
    }

    /// Rebuild in place (decode hot path: no per-step allocation once the
    /// table has its capacity, and no redundant zero-fill — the loop
    /// below overwrites every slot).
    pub fn rebuild(&mut self, query: &[f32], codebook: &Codebook) {
        assert_eq!(query.len(), codebook.groups * 4);
        self.groups = codebook.groups;
        let needed = codebook.groups * 16;
        if self.table.len() != needed {
            self.table.clear();
            self.table.resize(needed, 0.0);
        }
        for (g, qsub) in query.chunks_exact(4).enumerate() {
            for c in 0..16 {
                let cent = codebook.centroid(g, c);
                self.table[g * 16 + c] = qsub[0] * cent[0]
                    + qsub[1] * cent[1]
                    + qsub[2] * cent[2]
                    + qsub[3] * cent[3];
            }
        }
    }

    /// Accumulate another query's table into this one (GQA: the R query
    /// heads sharing a KV head sum their tables, equivalent to scoring
    /// with the summed query — one LUT-GEMV pass instead of R). In-place:
    /// no temporary table.
    pub fn add_query(&mut self, query: &[f32], codebook: &Codebook) {
        assert_eq!(query.len(), codebook.groups * 4);
        assert_eq!(self.groups, codebook.groups);
        for (g, qsub) in query.chunks_exact(4).enumerate() {
            for c in 0..16 {
                let cent = codebook.centroid(g, c);
                self.table[g * 16 + c] += qsub[0] * cent[0]
                    + qsub[1] * cent[1]
                    + qsub[2] * cent[2]
                    + qsub[3] * cent[3];
            }
        }
    }

    #[inline(always)]
    pub fn get(&self, g: usize, c: usize) -> f32 {
        self.table[g * 16 + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selfindex::codebook::CodebookBuilder;
    use crate::substrate::rng::Rng;

    #[test]
    fn lut_entries_are_dot_products() {
        let mut r = Rng::new(1);
        let dim = 16;
        let keys: Vec<f32> = (0..dim * 256).map(|_| r.normal_f32()).collect();
        let mut b = CodebookBuilder::new(dim / 4);
        b.accumulate(&keys);
        let cb = b.finalize();
        let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let lut = Lut::build(&q, &cb);
        for g in 0..cb.groups {
            for c in 0..16 {
                let cent = cb.centroid(g, c);
                let expect: f32 = (0..4).map(|i| q[g * 4 + i] * cent[i]).sum();
                assert!((lut.get(g, c) - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn add_query_is_sum_of_luts() {
        let mut r = Rng::new(2);
        let dim = 8;
        let keys: Vec<f32> = (0..dim * 64).map(|_| r.normal_f32()).collect();
        let mut b = CodebookBuilder::new(dim / 4);
        b.accumulate(&keys);
        let cb = b.finalize();
        let q1: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let q2: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let mut acc = Lut::build(&q1, &cb);
        acc.add_query(&q2, &cb);
        let l1 = Lut::build(&q1, &cb);
        let l2 = Lut::build(&q2, &cb);
        for i in 0..acc.table.len() {
            assert!((acc.table[i] - (l1.table[i] + l2.table[i])).abs() < 1e-6);
        }
    }
}
