//! LUT construction (paper Fig. 3, left half): per query, dot each of the
//! G query subvectors with its group's 16 centroids → a G×16 table of
//! partial scores. O(G·16·4) = O(16·D) flops — tiny, once per (query,
//! head, step); the per-token work is then pure lookups ([`super::score`]).

use super::codebook::Codebook;

/// Per-query lookup table: `groups × 16` partial scores, g-major.
#[derive(Clone, Debug)]
pub struct Lut {
    pub groups: usize,
    pub table: Vec<f32>, // flat [g][c]
}

impl Lut {
    /// Empty (zeroed) table — a reusable arena for [`Lut::rebuild`].
    pub fn empty(groups: usize) -> Self {
        Self { groups, table: vec![0.0f32; groups * 16] }
    }

    /// Build from a (rotated, *not* centered) query — centering keys does
    /// not require centering queries (Eq. 7); the LUT absorbs everything.
    pub fn build(query: &[f32], codebook: &Codebook) -> Self {
        let mut lut = Lut::empty(codebook.groups);
        lut.rebuild(query, codebook);
        lut
    }

    /// Rebuild in place (decode hot path: no per-step allocation once the
    /// table has its capacity, and no redundant zero-fill — the loop
    /// below overwrites every slot).
    pub fn rebuild(&mut self, query: &[f32], codebook: &Codebook) {
        assert_eq!(query.len(), codebook.groups * 4);
        self.groups = codebook.groups;
        let needed = codebook.groups * 16;
        if self.table.len() != needed {
            self.table.clear();
            self.table.resize(needed, 0.0);
        }
        for (g, qsub) in query.chunks_exact(4).enumerate() {
            for c in 0..16 {
                let cent = codebook.centroid(g, c);
                self.table[g * 16 + c] = qsub[0] * cent[0]
                    + qsub[1] * cent[1]
                    + qsub[2] * cent[2]
                    + qsub[3] * cent[3];
            }
        }
    }

    /// Accumulate another query's table into this one (GQA: the R query
    /// heads sharing a KV head sum their tables, equivalent to scoring
    /// with the summed query — one LUT-GEMV pass instead of R). In-place:
    /// no temporary table.
    pub fn add_query(&mut self, query: &[f32], codebook: &Codebook) {
        assert_eq!(query.len(), codebook.groups * 4);
        assert_eq!(self.groups, codebook.groups);
        for (g, qsub) in query.chunks_exact(4).enumerate() {
            for c in 0..16 {
                let cent = codebook.centroid(g, c);
                self.table[g * 16 + c] += qsub[0] * cent[0]
                    + qsub[1] * cent[1]
                    + qsub[2] * cent[2]
                    + qsub[3] * cent[3];
            }
        }
    }

    #[inline(always)]
    pub fn get(&self, g: usize, c: usize) -> f32 {
        self.table[g * 16 + c]
    }

    /// Sign-agreement LUT for a query's own nibble codes: entry
    /// `[g][c] = 4 − 2·popcount(q_code_g ⊕ c)` — the number of agreeing
    /// sign bits minus disagreeing ones, an integer in [−4, 4]. Scoring
    /// packed codes with this table is *exactly* the popcount scorer's
    /// `dim − 2·popcount(q ⊕ k)` (every partial sum is a small integer,
    /// exact in f32 under any summation order), which is what lets the CI
    /// parity matrix pin byte-LUT, reference, and popcount kernels
    /// bit-identical. Equivalently: `Lut::build` of the ±1-expanded query
    /// over `Codebook::sign_only` (asserted in tests).
    pub fn sign_agreement(q_codes: &[u8]) -> Self {
        let groups = q_codes.len();
        let mut lut = Lut::empty(groups);
        for (g, &qc) in q_codes.iter().enumerate() {
            debug_assert!(qc < 16, "4-bit code out of range: {qc}");
            for c in 0..16u8 {
                lut.table[g * 16 + c as usize] =
                    (4 - 2 * (qc ^ c).count_ones() as i32) as f32;
            }
        }
        lut
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selfindex::codebook::CodebookBuilder;
    use crate::substrate::rng::Rng;

    #[test]
    fn lut_entries_are_dot_products() {
        let mut r = Rng::new(1);
        let dim = 16;
        let keys: Vec<f32> = (0..dim * 256).map(|_| r.normal_f32()).collect();
        let mut b = CodebookBuilder::new(dim / 4);
        b.accumulate(&keys);
        let cb = b.finalize();
        let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let lut = Lut::build(&q, &cb);
        for g in 0..cb.groups {
            for c in 0..16 {
                let cent = cb.centroid(g, c);
                let expect: f32 = (0..4).map(|i| q[g * 4 + i] * cent[i]).sum();
                assert!((lut.get(g, c) - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn sign_agreement_equals_pm1_query_over_sign_codebook() {
        use crate::selfindex::codebook::Codebook;
        use crate::selfindex::codes::{code_signs, sign_code};
        let mut r = Rng::new(3);
        let dim = 32;
        let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let q_codes: Vec<u8> = q.chunks_exact(4).map(sign_code).collect();
        let sa = Lut::sign_agreement(&q_codes);
        // the ±1-expanded query dotted with ±1 sign centroids gives the
        // same integers — bit-exact, since every product is ±1
        let pm1: Vec<f32> = q_codes.iter().flat_map(|&c| code_signs(c)).collect();
        let reference = Lut::build(&pm1, &Codebook::sign_only(dim / 4));
        assert_eq!(sa.table.len(), reference.table.len());
        for i in 0..sa.table.len() {
            assert_eq!(
                sa.table[i].to_bits(),
                reference.table[i].to_bits(),
                "entry {i}: {} vs {}",
                sa.table[i],
                reference.table[i]
            );
            assert!((-4.0..=4.0).contains(&sa.table[i]));
            assert_eq!(sa.table[i], sa.table[i].trunc(), "integer entries");
        }
    }

    #[test]
    fn add_query_is_sum_of_luts() {
        let mut r = Rng::new(2);
        let dim = 8;
        let keys: Vec<f32> = (0..dim * 64).map(|_| r.normal_f32()).collect();
        let mut b = CodebookBuilder::new(dim / 4);
        b.accumulate(&keys);
        let cb = b.finalize();
        let q1: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let q2: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let mut acc = Lut::build(&q1, &cb);
        acc.add_query(&q2, &cb);
        let l1 = Lut::build(&q1, &cb);
        let l2 = Lut::build(&q2, &cb);
        for i in 0..acc.table.len() {
            assert!((acc.table[i] - (l1.table[i] + l2.table[i])).abs() < 1e-6);
        }
    }
}
