//! PJRT runtime: load AOT artifacts (`*.hlo.txt`), compile once, execute
//! from the serving loop. Python never runs here — the HLO text was
//! produced at build time by `python/compile/aot.py`.
//!
//! * [`PjrtRuntime`] — CPU PJRT client + compiled-executable cache keyed
//!   by artifact name; weight tensors are uploaded once as device
//!   buffers and reused by every call (`execute_b`).
//! * [`HostTensor`] — typed host-side staging for inputs/outputs.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The PJRT bridge needs the vendored `xla` crate, which only resolves in
//! environments that ship it. It is therefore gated behind the `pjrt`
//! feature; the default build substitutes a stub whose `load` reports the
//! missing backend, so every native-path test, bench, and example builds
//! and runs with zero external dependencies (artifact-driven tests skip,
//! exactly as they do when `make artifacts` has not been run).

#[cfg(not(feature = "pjrt"))]
use std::path::Path;

#[cfg(not(feature = "pjrt"))]
use crate::model::manifest::Manifest;
#[cfg(not(feature = "pjrt"))]
use crate::substrate::error as anyhow;

/// Host-side tensor for staging PJRT inputs/outputs.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U8(Vec<u8>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) | HostTensor::U8(_, s) => s,
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32(d, _) => d,
            _ => panic!("not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostTensor::I32(d, _) => d,
            _ => panic!("not i32"),
        }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32(vec![v], vec![])
    }
}

/// Stub runtime (default build, no `pjrt` feature): carries the manifest
/// type so the engine API is identical, but `load` always fails with a
/// clear message. Artifact-driven tests check for `manifest.json` first
/// and skip, so the stub is never constructed in practice.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    pub fn load(artifact_dir: &Path) -> anyhow::Result<Self> {
        // Parse the manifest anyway so configuration errors surface first.
        let _ = Manifest::load(artifact_dir).map_err(anyhow::Error::msg)?;
        Err(anyhow::anyhow!(
            "built without the `pjrt` feature: PJRT artifacts in {} cannot \
             be executed (rebuild with `--features pjrt` in an environment \
             that vendors the xla crate)",
            artifact_dir.display()
        ))
    }

    pub fn warmup(&mut self, _names: &[&str]) -> anyhow::Result<()> {
        Err(anyhow::anyhow!("pjrt feature disabled"))
    }

    pub fn run(
        &mut self,
        name: &str,
        _layer: Option<usize>,
        _inputs: &[HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        Err(anyhow::anyhow!("pjrt feature disabled: cannot execute {name}"))
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::PjrtRuntime;

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use std::collections::HashMap;
    use std::path::Path;

    use super::HostTensor;
    use crate::model::manifest::Manifest;
    use crate::model::weights::WeightStore;
    use crate::substrate::error as anyhow;

    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
        /// uploaded weight buffers by parameter name ("emb", "l0.wq", ...)
        weights: HashMap<String, xla::PjRtBuffer>,
        pub manifest: Manifest,
    }

    impl PjrtRuntime {
        /// Create the CPU client, load the manifest, upload weights.
        pub fn load(artifact_dir: &Path) -> anyhow::Result<Self> {
            let manifest =
                Manifest::load(artifact_dir).map_err(anyhow::Error::msg)?;
            let client = xla::PjRtClient::cpu()?;
            let store = WeightStore::load(&artifact_dir.join("weights.bin"))?;
            let mut weights = HashMap::new();
            for name in store.names() {
                let (shape, data) = store.get(name).unwrap();
                let buf = client.buffer_from_host_buffer::<f32>(data, shape, None)?;
                weights.insert(name.clone(), buf);
            }
            eprintln!(
                "pjrt: platform={} weights={} params",
                client.platform_name(),
                store.total_params()
            );
            Ok(Self { client, executables: HashMap::new(), weights, manifest })
        }

        /// Compile (or fetch) an artifact by name.
        pub fn executable(
            &mut self,
            name: &str,
        ) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
            if !self.executables.contains_key(name) {
                let spec = self.manifest.artifact(name).map_err(anyhow::Error::msg)?;
                let t = std::time::Instant::now();
                let proto = xla::HloModuleProto::from_text_file(
                    spec.file.to_str().expect("utf8 path"),
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self.client.compile(&comp)?;
                eprintln!("pjrt: compiled {name} in {:?}", t.elapsed());
                self.executables.insert(name.to_string(), exe);
            }
            Ok(&self.executables[name])
        }

        /// Eagerly compile a set of artifacts (startup warmup).
        pub fn warmup(&mut self, names: &[&str]) -> anyhow::Result<()> {
            for n in names {
                self.executable(n)?;
            }
            Ok(())
        }

        fn upload(&self, t: &HostTensor) -> anyhow::Result<xla::PjRtBuffer> {
            Ok(match t {
                HostTensor::F32(d, s) => {
                    self.client.buffer_from_host_buffer::<f32>(d, s, None)?
                }
                HostTensor::I32(d, s) => {
                    self.client.buffer_from_host_buffer::<i32>(d, s, None)?
                }
                HostTensor::U8(d, s) => {
                    self.client.buffer_from_host_buffer::<u8>(d, s, None)?
                }
            })
        }

        /// Execute an artifact. `inputs` supplies the non-weight args in spec
        /// order; args named `param:<name>` are taken from the weight buffers
        /// (`layer:<field>` args are supplied by the caller via `layer_params`,
        /// mapped as `l{layer}.{field}`).
        pub fn run(
            &mut self,
            name: &str,
            layer: Option<usize>,
            inputs: &[HostTensor],
        ) -> anyhow::Result<Vec<HostTensor>> {
            // compile first (needs &mut self), then stage buffers
            self.executable(name)?;
            let spec = self
                .manifest
                .artifact(name)
                .map_err(anyhow::Error::msg)?
                .clone();
            let mut bufs: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(spec.inputs.len());
            let mut staged: Vec<xla::PjRtBuffer> = Vec::new();
            let mut next_input = 0usize;

            // two passes: first create all staged buffers, then collect refs
            let mut plan: Vec<Result<String, usize>> =
                Vec::with_capacity(spec.inputs.len());
            for io in &spec.inputs {
                if let Some(pname) = io.name.strip_prefix("param:") {
                    plan.push(Ok(pname.to_string()));
                } else if let Some(field) = io.name.strip_prefix("layer:") {
                    let l = layer.expect("layer-parameterized artifact needs layer idx");
                    plan.push(Ok(format!("l{l}.{field}")));
                } else {
                    let t = inputs
                        .get(next_input)
                        .unwrap_or_else(|| panic!("{name}: missing input '{}'", io.name));
                    debug_assert_eq!(
                        t.shape(),
                        &io.shape[..],
                        "{name}: shape mismatch on '{}'",
                        io.name
                    );
                    staged.push(self.upload(t)?);
                    plan.push(Err(staged.len() - 1));
                    next_input += 1;
                }
            }
            assert_eq!(next_input, inputs.len(), "{name}: unused inputs");
            for p in &plan {
                match p {
                    Ok(wname) => bufs.push(
                        self.weights
                            .get(wname)
                            .unwrap_or_else(|| panic!("weight '{wname}' missing")),
                    ),
                    Err(i) => bufs.push(&staged[*i]),
                }
            }

            let exe = &self.executables[name];
            let result = exe.execute_b(&bufs)?;
            let tuple = result[0][0].to_literal_sync()?;
            let parts = tuple.to_tuple()?;
            assert_eq!(
                parts.len(),
                spec.outputs.len(),
                "{name}: output arity mismatch"
            );
            let mut out = Vec::with_capacity(parts.len());
            for (lit, ospec) in parts.into_iter().zip(&spec.outputs) {
                out.push(literal_to_host(&lit, ospec)?);
            }
            Ok(out)
        }
    }

    fn literal_to_host(
        lit: &xla::Literal,
        spec: &crate::model::manifest::IoSpec,
    ) -> anyhow::Result<HostTensor> {
        let shape = spec.shape.clone();
        Ok(match spec.dtype.as_str() {
            "float32" => HostTensor::F32(lit.to_vec::<f32>()?, shape),
            "int32" => HostTensor::I32(lit.to_vec::<i32>()?, shape),
            "uint8" => HostTensor::U8(lit.to_vec::<u8>()?, shape),
            other => anyhow::bail!("unsupported output dtype {other}"),
        })
    }
}
