//! IEEE 754 half-precision conversions (storage format for quantization
//! scales/zero-points and sink tokens, matching the paper's 16-bit
//! parameter accounting). Software conversion, round-to-nearest-even.

/// f32 -> f16 bits (round-to-nearest-even, IEEE 754 binary16).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x7f_ffff;

    if exp == 0xff {
        // inf / nan
        let mant = if frac != 0 { 0x200 | (frac >> 13) as u16 } else { 0 };
        return sign | 0x7c00 | mant;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // normal
        let exp16 = (unbiased + 15) as u32;
        let mant = frac >> 13;
        let rest = frac & 0x1fff;
        let mut h = (exp16 << 10) | mant;
        // round to nearest even
        if rest > 0x1000 || (rest == 0x1000 && (mant & 1) == 1) {
            h += 1; // may carry into exponent — that's correct behaviour
        }
        return sign | h as u16;
    }
    if unbiased >= -25 {
        // subnormal: value = m · 2⁻²⁴ with m = round(1.f · 2^(e+24)),
        // i.e. drop s = -e-1 bits of the 24-bit significand (e=-15 → 14)
        let s = (-unbiased - 1) as u32; // 14..=24
        let mant_full = frac | 0x80_0000;
        let mant = mant_full >> s;
        let rest = mant_full & ((1u32 << s) - 1);
        let half = 1u32 << (s - 1);
        let mut h = mant;
        if rest > half || (rest == half && (mant & 1) == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    sign // underflow -> ±0
}

/// f16 bits -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = match (exp, frac) {
        (0, 0) => sign,
        (0, f) => {
            // subnormal: normalize
            let mut e = 127 - 15 + 1;
            let mut m = f;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x3ff) << 13)
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, f) => sign | 0x7f80_0000 | (f << 13),
        (e, f) => sign | ((e + 127 - 15) << 23) | (f << 13),
    };
    f32::from_bits(bits)
}

/// Round-trip through storage precision.
#[inline]
pub fn round_f16(x: f32) -> f32 {
    f16_to_f32(f32_to_f16(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for x in [0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.099975586] {
            assert_eq!(f16_to_f32(f32_to_f16(x)), x, "{x}");
        }
    }

    #[test]
    fn relative_error_bounded() {
        // half has 11 bits of significand -> rel err <= 2^-11
        let mut r = crate::substrate::rng::Rng::new(5);
        for _ in 0..10_000 {
            let x = r.uniform(-1000.0, 1000.0);
            let y = round_f16(x);
            if x != 0.0 {
                assert!(((y - x) / x).abs() <= 1.0 / 2048.0, "{x} -> {y}");
            }
        }
    }

    #[test]
    fn specials() {
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f32_to_f16(1e10), 0x7c00); // overflow
        assert_eq!(round_f16(1e-10), 0.0); // underflow
    }

    #[test]
    fn subnormals() {
        let tiny = 6.0e-5f32; // just below the normal/subnormal boundary
        let y = round_f16(tiny);
        assert!((y - tiny).abs() / tiny < 1e-2, "{tiny} -> {y}");
        let sub = 3.0e-6f32;
        let y = round_f16(sub);
        assert!(y > 0.0 && (y - sub).abs() / sub < 0.2, "{sub} -> {y}");
        // monotonic across the boundary
        let a = round_f16(6.2e-5);
        let b = round_f16(6.0e-5);
        assert!(a >= b, "{a} {b}");
    }
}
