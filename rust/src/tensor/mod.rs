//! Minimal dense tensor support: shape-tracked `f32` arrays plus fp16
//! conversions. Deliberately tiny — the heavy compute runs either in the
//! PJRT executables or in the specialized selfindex/attention kernels;
//! this type exists for I/O, tests, and glue.

pub mod fp16;

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2);
        let d = self.shape[1];
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.ndim(), 2);
        let d = self.shape[1];
        &mut self.data[i * d..(i + 1) * d]
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(x < d, "index {x} out of bound {d} at dim {i}");
            off = off * d + x;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }
}

/// Dot product (used everywhere; kept free-standing for inlining).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.offset(&[1, 0]), 3);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn dot_axpy() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }
}
