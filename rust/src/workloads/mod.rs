//! Synthetic workload generators for the evaluation suite.
//!
//! * [`corpus`]    — the byte-level generator grammar shared with
//!   `python/compile/train.py` (kv pairs, span copies, filler): prompts
//!   drawn from the training distribution so the tiny model's behaviour
//!   is meaningful.
//! * [`longbench`] — six-category LongBench-proxy task suite (Table 1).
//! * [`ruler`]     — RULER-like task taxonomy at scaled context (Table 2,
//!   Fig. 4): needle single/multi, multi-query, value tracking, CWE/FWE.
//! * [`trace`]     — request-arrival traces for the serving benches
//!   (Table 3, Fig. 5): open-loop Poisson-ish arrivals, mixed lengths.

pub mod corpus;
pub mod longbench;
pub mod ruler;
pub mod trace;

/// A single evaluation item: feed `prompt`, generate
/// `expected.len()` (+ slack) bytes greedily, score with `metric`.
#[derive(Clone, Debug)]
pub struct EvalItem {
    pub prompt: Vec<u8>,
    pub expected: Vec<u8>,
    pub metric: Metric,
    /// task label for aggregation ("NS1", "qasper-proxy", ...)
    pub task: &'static str,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    ExactMatch,
    Contains,
    PrefixAccuracy,
}

impl EvalItem {
    pub fn score(&self, generated: &[u8]) -> f64 {
        match self.metric {
            Metric::ExactMatch => crate::eval::exact_match(generated, &self.expected),
            Metric::Contains => crate::eval::contains(generated, &self.expected),
            Metric::PrefixAccuracy => {
                crate::eval::prefix_accuracy(generated, &self.expected)
            }
        }
    }
}
