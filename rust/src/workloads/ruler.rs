//! RULER-like task taxonomy (Table 2, Fig. 4) at scaled context length.
//!
//! The 13 paper tasks map onto the corpus grammar:
//!   NS1/NS2/NS3  needle single: word / word-in-noise / long-value needles
//!   NM1/NM2/NM3  needle multi: 2/4/8 needles, query one
//!   NV           needle multi-value: one key, several values, recall all
//!   NQ           needle multi-query: several keys queried in sequence
//!               (scored on the first — single-step decode protocol)
//!   VT           variable tracking: chained assignments a=..; b=a;
//!   CWE          common-word extraction proxy: most-planted key
//!   FWE          frequent-word extraction proxy
//!   QA1/QA2      QA with distractor facts

use super::corpus::{context_with_facts, pad_filler, rand_word, KvFact};
use super::{EvalItem, Metric};
use crate::substrate::rng::Rng;

pub const TASKS: &[&str] = &[
    "NS1", "NS2", "NS3", "NM1", "NM2", "NM3", "NV", "NQ", "VT", "CWE",
    "FWE", "QA1", "QA2",
];

#[derive(Clone, Copy, Debug)]
pub struct RulerConfig {
    pub context: usize,
    pub items: usize,
    pub seed: u64,
}

impl Default for RulerConfig {
    fn default() -> Self {
        Self { context: 2048, items: 6, seed: 99 }
    }
}

pub fn generate(cfg: &RulerConfig) -> Vec<EvalItem> {
    let mut out = Vec::new();
    for (t, &task) in TASKS.iter().enumerate() {
        let mut r = Rng::new(cfg.seed ^ ((t as u64 + 1) * 0xA5A5));
        for _ in 0..cfg.items {
            out.push(make_item(task, cfg.context, &mut r));
        }
    }
    out
}

fn needle_item(
    task: &'static str,
    ctx: usize,
    r: &mut Rng,
    n_needles: usize,
    long_vals: bool,
) -> EvalItem {
    // NS3 uses longer values (the paper's "hard type" needle; digits are
    // out of the byte-LM's training distribution, so length is the
    // difficulty axis here — documented in DESIGN.md §Substitutions)
    let facts: Vec<KvFact> = (0..n_needles)
        .map(|_| {
            let mut f = KvFact::random(r);
            if long_vals {
                f.val = super::corpus::rand_word(r, 4, 4);
            }
            f
        })
        .collect();
    let positions: Vec<f64> = (0..n_needles)
        .map(|i| 0.08 + 0.84 * (i as f64 + r.f64() * 0.5) / n_needles as f64)
        .collect();
    let target = r.below(n_needles as u64) as usize;
    let mut prompt = context_with_facts(r, ctx, &facts, &positions);
    prompt.extend_from_slice(&facts[target].query());
    EvalItem {
        prompt,
        expected: facts[target].val.clone(),
        metric: Metric::PrefixAccuracy,
        task,
    }
}

fn make_item(task: &'static str, ctx: usize, r: &mut Rng) -> EvalItem {
    match task {
        "NS1" => needle_item(task, ctx, r, 1, false),
        "NS2" => needle_item(task, ctx, r, 1, false),
        "NS3" => needle_item(task, ctx, r, 1, true),
        "NM1" => needle_item(task, ctx, r, 2, false),
        "NM2" => needle_item(task, ctx, r, 4, false),
        "NM3" => needle_item(task, ctx, r, 8, false),
        "NV" => {
            // one key planted twice with the same value (redundancy)
            let f = KvFact::random(r);
            let mut prompt =
                context_with_facts(r, ctx, &[f.clone(), f.clone()], &[0.2, 0.6]);
            prompt.extend_from_slice(&f.query());
            EvalItem { prompt, expected: f.val, metric: Metric::PrefixAccuracy, task }
        }
        "NQ" => {
            let facts: Vec<KvFact> = (0..3).map(|_| KvFact::random(r)).collect();
            let mut prompt =
                context_with_facts(r, ctx, &facts, &[0.15, 0.5, 0.8]);
            prompt.extend_from_slice(&facts[1].query());
            EvalItem {
                prompt,
                expected: facts[1].val.clone(),
                metric: Metric::PrefixAccuracy,
                task,
            }
        }
        "VT" => {
            // chain: @a=VAL; @b=VAL; (b mirrors a) query b
            let val = rand_word(r, 3, 4);
            let a = KvFact { key: rand_word(r, 2, 3), val: val.clone() };
            let b = KvFact { key: rand_word(r, 2, 3), val: val.clone() };
            let mut prompt = context_with_facts(
                r, ctx, &[a, b.clone()], &[0.25, 0.55]);
            prompt.extend_from_slice(&b.query());
            EvalItem { prompt, expected: val, metric: Metric::PrefixAccuracy, task }
        }
        "CWE" | "FWE" => {
            // the same fact planted many times among distractors; recall it
            let common = KvFact::random(r);
            let reps = if task == "CWE" { 6 } else { 4 };
            let mut facts = vec![common.clone(); reps];
            for _ in 0..3 {
                facts.push(KvFact::random(r));
            }
            let positions: Vec<f64> = (0..facts.len())
                .map(|i| 0.08 + 0.84 * i as f64 / facts.len() as f64)
                .collect();
            let mut prompt = context_with_facts(r, ctx, &facts, &positions);
            prompt.extend_from_slice(&common.query());
            EvalItem {
                prompt,
                expected: common.val.clone(),
                metric: Metric::PrefixAccuracy,
                task,
            }
        }
        _ /* QA1 | QA2 */ => {
            // QA with heavy distractor load
            let target = KvFact::random(r);
            let mut facts = vec![target.clone()];
            for _ in 0..7 {
                facts.push(KvFact::random(r));
            }
            let positions: Vec<f64> = (0..facts.len())
                .map(|i| 0.05 + 0.9 * i as f64 / facts.len() as f64)
                .collect();
            let mut prompt = context_with_facts(r, ctx, &facts, &positions);
            pad_filler(r, &mut prompt, ctx);
            prompt.extend_from_slice(&target.query());
            EvalItem {
                prompt,
                expected: target.val.clone(),
                metric: Metric::PrefixAccuracy,
                task,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_13_tasks_generate() {
        let items = generate(&RulerConfig { context: 512, items: 2, seed: 5 });
        assert_eq!(items.len(), 26);
        let tasks: std::collections::HashSet<_> =
            items.iter().map(|i| i.task).collect();
        assert_eq!(tasks.len(), 13);
    }

    #[test]
    fn needles_present_in_context() {
        let items = generate(&RulerConfig { context: 1024, items: 3, seed: 6 });
        for it in items.iter().filter(|i| i.task.starts_with("NS")) {
            assert!(
                crate::eval::contains(&it.prompt, &it.expected) > 0.0,
                "{}: needle value must be planted",
                it.task
            );
        }
    }

    #[test]
    fn context_scales() {
        for ctx in [512usize, 2048] {
            let items = generate(&RulerConfig { context: ctx, items: 1, seed: 7 });
            for it in &items {
                assert!(it.prompt.len() >= ctx, "{} {}", it.task, it.prompt.len());
                assert!(it.prompt.len() < ctx + 64);
            }
        }
    }
}
