//! Byte-level corpus grammar — MUST mirror python/compile/train.py so
//! evaluation prompts come from the training distribution.
//!
//! Productions:
//!   kv-plant   `@<key>=<val>;`     key 2-3 a-z, val 3-4 a-z
//!   kv-query   `?<key>:<val>;`     queries a previously planted pair
//!   span-copy  `[<span>|<span>]`   span 4-8 a-z
//!   filler     word + space        from FILLER_WORDS

use crate::substrate::rng::Rng;

pub const FILLER_WORDS: &[&str] = &[
    "the", "of", "and", "to", "in", "is", "that", "for", "as", "with", "on",
    "by", "at", "from", "system", "cache", "token", "memory", "sparse",
    "attention", "index", "query", "model",
];

pub fn rand_word(r: &mut Rng, lo: usize, hi: usize) -> Vec<u8> {
    let n = lo + r.below((hi - lo + 1) as u64) as usize;
    (0..n).map(|_| 97 + r.below(26) as u8).collect()
}

pub fn filler(r: &mut Rng) -> Vec<u8> {
    let w = FILLER_WORDS[r.below(FILLER_WORDS.len() as u64) as usize];
    let mut v = w.as_bytes().to_vec();
    v.push(b' ');
    v
}

/// A planted key-value fact: the bytes `@k=v;` and the query `?k:`.
#[derive(Clone, Debug)]
pub struct KvFact {
    pub key: Vec<u8>,
    pub val: Vec<u8>,
}

impl KvFact {
    pub fn random(r: &mut Rng) -> Self {
        Self { key: rand_word(r, 2, 3), val: rand_word(r, 3, 4) }
    }

    pub fn plant(&self) -> Vec<u8> {
        let mut v = vec![b'@'];
        v.extend_from_slice(&self.key);
        v.push(b'=');
        v.extend_from_slice(&self.val);
        v.push(b';');
        v
    }

    /// The query prefix whose continuation should be `val` + `;`.
    pub fn query(&self) -> Vec<u8> {
        let mut v = vec![b'?'];
        v.extend_from_slice(&self.key);
        v.push(b':');
        v
    }
}

/// Fill `out` with filler words up to `target` bytes.
pub fn pad_filler(r: &mut Rng, out: &mut Vec<u8>, target: usize) {
    while out.len() < target {
        out.extend_from_slice(&filler(r));
    }
    out.truncate(target);
}

/// Build a context of `len` bytes with `facts` planted at the fractional
/// `positions` (0.0 = start .. 1.0 = end), filler elsewhere.
pub fn context_with_facts(
    r: &mut Rng,
    len: usize,
    facts: &[KvFact],
    positions: &[f64],
) -> Vec<u8> {
    assert_eq!(facts.len(), positions.len());
    let mut out = Vec::with_capacity(len + 16);
    let mut planted = facts
        .iter()
        .zip(positions)
        .map(|(f, &p)| (((len as f64 * p) as usize).min(len.saturating_sub(16)), f))
        .collect::<Vec<_>>();
    planted.sort_by_key(|(at, _)| *at);
    for (at, fact) in planted {
        let target = at.max(out.len());
        pad_filler(r, &mut out, target);
        out.extend_from_slice(&fact.plant());
    }
    pad_filler(r, &mut out, len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fact_format_matches_training_grammar() {
        let mut r = Rng::new(1);
        let f = KvFact::random(&mut r);
        let p = f.plant();
        assert_eq!(p[0], b'@');
        assert!(p.contains(&b'='));
        assert_eq!(*p.last().unwrap(), b';');
        let q = f.query();
        assert_eq!(q[0], b'?');
        assert_eq!(*q.last().unwrap(), b':');
        assert!((2..=3).contains(&f.key.len()));
        assert!((3..=4).contains(&f.val.len()));
        assert!(f.key.iter().all(|&b| (b'a'..=b'z').contains(&b)));
    }

    #[test]
    fn context_contains_facts_near_positions() {
        let mut r = Rng::new(2);
        let facts = vec![KvFact::random(&mut r), KvFact::random(&mut r)];
        let ctx = context_with_facts(&mut r, 1000, &facts, &[0.2, 0.8]);
        assert_eq!(ctx.len(), 1000);
        for f in &facts {
            let plant = f.plant();
            let pos = ctx
                .windows(plant.len())
                .position(|w| w == plant.as_slice())
                .expect("fact present");
            assert!(pos < 990);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let gen = |seed| {
            let mut r = Rng::new(seed);
            let f = vec![KvFact::random(&mut r)];
            context_with_facts(&mut r, 300, &f, &[0.5])
        };
        assert_eq!(gen(7), gen(7));
        assert_ne!(gen(7), gen(8));
    }
}
