//! Request-arrival traces for the serving benches (Table 3, Fig. 5) and
//! the end-to-end example: open-loop arrivals with exponential gaps,
//! mixed prompt lengths, per-request decode budgets.

use super::corpus::{context_with_facts, KvFact};
use crate::substrate::rng::Rng;

#[derive(Clone, Debug)]
pub struct TraceRequest {
    /// arrival time offset from trace start
    pub at: std::time::Duration,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// per-request wall-clock SLO (deadline = arrival + slo), if any
    pub slo: Option<std::time::Duration>,
}

#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub requests: usize,
    /// mean inter-arrival gap (open loop)
    pub mean_gap_ms: f64,
    pub prompt_lens: &'static [usize],
    pub decode_tokens: usize,
    pub seed: u64,
    /// wall-clock SLO stamped on every request (None = no deadline)
    pub slo_ms: Option<f64>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            requests: 32,
            mean_gap_ms: 50.0,
            prompt_lens: &[256, 512, 1024],
            decode_tokens: 16,
            seed: 42,
            slo_ms: None,
        }
    }
}

pub fn generate(cfg: &TraceConfig) -> Vec<TraceRequest> {
    let mut r = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    (0..cfg.requests)
        .map(|_| {
            // exponential inter-arrival
            let u = r.f64().max(1e-12);
            t += -cfg.mean_gap_ms * u.ln();
            let len = cfg.prompt_lens[r.below(cfg.prompt_lens.len() as u64) as usize];
            let fact = KvFact::random(&mut r);
            let mut prompt =
                context_with_facts(&mut r, len - 8, &[fact.clone()], &[0.4]);
            prompt.extend_from_slice(&fact.query());
            TraceRequest {
                at: std::time::Duration::from_micros((t * 1000.0) as u64),
                prompt,
                max_new_tokens: cfg.decode_tokens,
                slo: cfg
                    .slo_ms
                    .map(|ms| std::time::Duration::from_micros((ms * 1000.0) as u64)),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_lengths_valid() {
        let cfg = TraceConfig::default();
        let trace = generate(&cfg);
        assert_eq!(trace.len(), cfg.requests);
        for w in trace.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        for req in &trace {
            assert!(cfg
                .prompt_lens
                .iter()
                .any(|&l| req.prompt.len() >= l - 8 && req.prompt.len() <= l));
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(&TraceConfig::default());
        let b = generate(&TraceConfig::default());
        assert_eq!(a.len(), b.len());
        assert_eq!(a[0].prompt, b[0].prompt);
        assert_eq!(a[5].at, b[5].at);
    }
}
