//! LongBench-proxy suite (Table 1): six categories × two datasets each,
//! mapped onto the tiny model's trained capabilities (DESIGN.md
//! §Substitutions). Dataset names keep the paper's labels with a
//! `-proxy` suffix in the docs; tasks here use the paper's short names.
//!
//! | paper category    | proxy mechanics                                  |
//! |-------------------|--------------------------------------------------|
//! | Single-doc QA     | one planted fact, query at the end               |
//! | Multi-doc QA      | several facts far apart, query one ("hop")       |
//! | Summarization     | span-copy completion of a long span              |
//! | Few-shot          | unseen separator pattern shown k times in-ctx    |
//! | Synthetic (PR-en) | passkey retrieval: digit value                   |
//! | Code (Lcc/RB-P)   | bracketed span completion mid-context            |

use super::corpus::{context_with_facts, pad_filler, rand_word, KvFact};
use super::{EvalItem, Metric};
use crate::substrate::rng::Rng;

/// Generator configuration: context bytes per item + items per task.
#[derive(Clone, Copy, Debug)]
pub struct LongBenchConfig {
    pub context: usize,
    pub items: usize,
    pub seed: u64,
}

impl Default for LongBenchConfig {
    fn default() -> Self {
        Self { context: 1024, items: 8, seed: 1234 }
    }
}

pub const TASKS: &[&str] = &[
    "Qasper", "MF-en", "HPQA", "2WQA", "GVRpt", "QMSum", "TREC", "TrivQA",
    "PR-en", "Lcc", "RB-P",
];

/// Category of each task (for the table layout).
pub fn category(task: &str) -> &'static str {
    match task {
        "Qasper" | "MF-en" => "SD-QA",
        "HPQA" | "2WQA" => "MD-QA",
        "GVRpt" | "QMSum" => "Summ",
        "TREC" | "TrivQA" => "Few-shot",
        "PR-en" => "Synthetic",
        "Lcc" | "RB-P" => "Code",
        _ => "?",
    }
}

pub fn generate(cfg: &LongBenchConfig) -> Vec<EvalItem> {
    let mut out = Vec::new();
    for (t, &task) in TASKS.iter().enumerate() {
        let mut r = Rng::new(cfg.seed ^ ((t as u64 + 1) * 0x9E37));
        for i in 0..cfg.items {
            out.push(make_item(task, cfg.context, &mut r, i));
        }
    }
    out
}

fn make_item(task: &'static str, ctx: usize, r: &mut Rng, _i: usize) -> EvalItem {
    match category(task) {
        "SD-QA" => {
            let f = KvFact::random(r);
            let pos = r.uniform(0.1, 0.8) as f64;
            let mut prompt = context_with_facts(r, ctx, &[f.clone()], &[pos]);
            prompt.extend_from_slice(&f.query());
            EvalItem { prompt, expected: f.val, metric: Metric::PrefixAccuracy, task }
        }
        "MD-QA" => {
            let facts: Vec<KvFact> = (0..4).map(|_| KvFact::random(r)).collect();
            let positions = [0.1, 0.35, 0.6, 0.85];
            let target = r.below(4) as usize;
            let mut prompt =
                context_with_facts(r, ctx, &facts, &positions[..facts.len()]);
            prompt.extend_from_slice(&facts[target].query());
            EvalItem {
                prompt,
                expected: facts[target].val.clone(),
                metric: Metric::PrefixAccuracy,
                task,
            }
        }
        "Summ" => {
            // long span planted mid-context; completion asked at the end
            let span = rand_word(r, 6, 8);
            let mut prompt = Vec::new();
            pad_filler(r, &mut prompt, ctx / 2);
            prompt.push(b'[');
            prompt.extend_from_slice(&span);
            prompt.push(b'|');
            prompt.extend_from_slice(&span);
            prompt.push(b']');
            pad_filler(r, &mut prompt, ctx);
            prompt.push(b'[');
            prompt.extend_from_slice(&span);
            prompt.push(b'|');
            let mut expected = span;
            expected.push(b']');
            EvalItem { prompt, expected, metric: Metric::PrefixAccuracy, task }
        }
        "Few-shot" => {
            // k in-context examples of `key->val` with a fixed mapping rule
            // (val = key reversed); model must apply it to a new key.
            let mut prompt = Vec::new();
            pad_filler(r, &mut prompt, ctx / 3);
            for _ in 0..6 {
                let k = rand_word(r, 3, 3);
                let mut v = k.clone();
                v.reverse();
                prompt.extend_from_slice(b"@");
                prompt.extend_from_slice(&k);
                prompt.push(b'=');
                prompt.extend_from_slice(&v);
                prompt.push(b';');
            }
            pad_filler(r, &mut prompt, ctx);
            let k = rand_word(r, 3, 3);
            let mut v = k.clone();
            v.reverse();
            prompt.extend_from_slice(b"@");
            prompt.extend_from_slice(&k);
            prompt.push(b'=');
            EvalItem { prompt, expected: v, metric: Metric::PrefixAccuracy, task }
        }
        "Synthetic" => {
            // passkey retrieval (letter passkey — digits are outside the
            // byte-LM's corpus; see DESIGN.md §Substitutions)
            let passkey = rand_word(r, 4, 4);
            let f = KvFact { key: b"pk".to_vec(), val: passkey };
            let pos = r.uniform(0.2, 0.7) as f64;
            let mut prompt = context_with_facts(r, ctx, &[f.clone()], &[pos]);
            prompt.extend_from_slice(&f.query());
            EvalItem { prompt, expected: f.val, metric: Metric::PrefixAccuracy, task }
        }
        _ /* Code */ => {
            // bracketed copy with code-ish tokens
            let span = rand_word(r, 5, 7);
            let mut prompt = Vec::new();
            pad_filler(r, &mut prompt, ctx * 2 / 3);
            prompt.push(b'[');
            prompt.extend_from_slice(&span);
            prompt.push(b'|');
            prompt.extend_from_slice(&span);
            prompt.push(b']');
            pad_filler(r, &mut prompt, ctx);
            prompt.push(b'[');
            prompt.extend_from_slice(&span);
            prompt.push(b'|');
            EvalItem {
                prompt,
                expected: span,
                metric: Metric::PrefixAccuracy,
                task,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_all_tasks() {
        let items = generate(&LongBenchConfig { context: 512, items: 2, seed: 1 });
        assert_eq!(items.len(), TASKS.len() * 2);
        for it in &items {
            assert!(it.prompt.len() >= 512, "{}: {}", it.task, it.prompt.len());
            assert!(!it.expected.is_empty());
        }
    }

    #[test]
    fn sdqa_query_matches_planted_fact() {
        let items = generate(&LongBenchConfig { context: 600, items: 3, seed: 2 });
        let sd: Vec<_> = items.iter().filter(|i| i.task == "Qasper").collect();
        for it in sd {
            // the expected value must appear in the context (planted)
            assert!(crate::eval::contains(&it.prompt, &it.expected) > 0.0);
        }
    }

    #[test]
    fn categories_cover_paper_table() {
        let cats: std::collections::HashSet<_> =
            TASKS.iter().map(|t| category(t)).collect();
        assert_eq!(cats.len(), 6);
    }
}
