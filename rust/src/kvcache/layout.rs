//! Byte-level record layout + the paper's memory accounting.
//!
//! Paper §Overhead Analysis (head_dim 128, fp16 baseline):
//!   sign bits 128 b + K mags 256 b + V 256 b + params 2·4·2·16 b = 256 b
//!   → 896 b/token vs 4096 b full fp16 → 78% savings (~4.6×).
//! The same formulas parameterized over head_dim/bits/groups live here and
//! are unit-tested against those numbers.

use crate::selfindex::SelfIndexConfig;

/// Sizes (bytes per token per head) of every field of a cache record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecordLayout {
    pub head_dim: usize,
    pub quant_bits: u32,
    pub quant_group: usize,
    /// packed 4-bit sign codes: head_dim/4 nibbles
    pub codes_bytes: usize,
    /// packed B-bit magnitudes / values
    pub payload_bytes: usize,
    /// quant params: (head_dim/group) × 2 fields × fp16
    pub params_bytes: usize,
}

impl RecordLayout {
    pub fn new(head_dim: usize, cfg: &SelfIndexConfig) -> Self {
        assert_eq!(head_dim % 8, 0);
        assert_eq!(head_dim % cfg.quant_group, 0);
        let groups = head_dim / cfg.vq_group;
        Self {
            head_dim,
            quant_bits: cfg.quant_bits,
            quant_group: cfg.quant_group,
            codes_bytes: groups / 2,
            payload_bytes: head_dim * cfg.quant_bits as usize / 8,
            params_bytes: (head_dim / cfg.quant_group) * 2 * 2,
        }
    }

    pub fn groups(&self) -> usize {
        self.head_dim / 4
    }

    /// `u64` words per token in the block's word-packed sign-code mirror
    /// (`Block::codes_w`) — derived, not stored, so the paper's
    /// byte-accounting ([`Self::bytes_per_token`]) is untouched.
    pub fn codes_words(&self) -> usize {
        crate::quant::pack::words_per_token(self.codes_bytes)
    }

    pub fn param_groups(&self) -> usize {
        self.head_dim / self.quant_group
    }

    /// Compressed bytes per token per head (K side: codes + mags + params;
    /// V side: values + params).
    pub fn bytes_per_token(&self) -> usize {
        self.codes_bytes + 2 * self.payload_bytes + 2 * self.params_bytes
    }

    /// Full-precision baseline bytes per token per head (K+V at `bits`).
    pub fn baseline_bytes_per_token(bits_per_elem: usize, head_dim: usize) -> usize {
        2 * head_dim * bits_per_elem / 8
    }

    /// Memory saving ratio vs an fp16 cache — the paper's 78% claim.
    pub fn savings_vs_fp16(&self) -> f64 {
        let full = Self::baseline_bytes_per_token(16, self.head_dim) as f64;
        1.0 - self.bytes_per_token() as f64 / full
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_accounting_head_dim_128() {
        // exactly the paper's numbers: L×128 head, 2-bit K/V, groups of 32
        let cfg = SelfIndexConfig::default();
        let l = RecordLayout::new(128, &cfg);
        assert_eq!(l.codes_bytes * 8, 128); // sign bits: 128 b/token
        assert_eq!(l.payload_bytes * 8, 256); // 2-bit × 128
        // params: 4 groups × 2 × 16 b = 128 b per tensor
        assert_eq!(l.params_bytes * 8, 128);
        // total: 128 + 2·256 + 2·128 = 896 bits = paper's 768+128 (the
        // paper folds K's sign bits out of its "768L" quant term)
        assert_eq!(l.bytes_per_token() * 8, 896);
        let savings = l.savings_vs_fp16();
        assert!((savings - 0.78125).abs() < 1e-6, "{savings}");
        // ≈ 4.57× compression — the paper's "nearly 5×"
        let ratio = RecordLayout::baseline_bytes_per_token(16, 128) as f64
            / l.bytes_per_token() as f64;
        assert!(ratio > 4.5 && ratio < 4.7, "{ratio}");
    }

    #[test]
    fn our_model_head_dim_64() {
        let cfg = SelfIndexConfig::default();
        let l = RecordLayout::new(64, &cfg);
        assert_eq!(l.codes_bytes, 8);
        assert_eq!(l.payload_bytes, 16);
        assert_eq!(l.params_bytes, 8);
        assert_eq!(l.bytes_per_token(), 8 + 32 + 16);
        assert!(l.savings_vs_fp16() > 0.7);
    }

    #[test]
    fn codes_words_rounds_up_to_whole_words() {
        let cfg = SelfIndexConfig::default();
        // head_dim 64 → 8 code bytes → one word; 128 → 16 bytes → two
        assert_eq!(RecordLayout::new(64, &cfg).codes_words(), 1);
        assert_eq!(RecordLayout::new(128, &cfg).codes_words(), 2);
        // sub-word tail still occupies a full (zero-padded) word
        assert_eq!(RecordLayout::new(32, &cfg).codes_words(), 1);
    }

    #[test]
    fn higher_bits_larger_records() {
        let mut cfg = SelfIndexConfig::default();
        let b2 = RecordLayout::new(64, &cfg).bytes_per_token();
        cfg.quant_bits = 4;
        let b4 = RecordLayout::new(64, &cfg).bytes_per_token();
        assert!(b4 > b2);
    }
}
