//! A cache block: SoA storage for `block_tokens` compressed records.

use super::layout::RecordLayout;
use crate::quant::int2::QuantParams;

/// Index into the pool's block table.
pub type BlockId = u32;

/// Fixed-capacity structure-of-arrays block. All fields are token-major;
/// field sizes derive from [`RecordLayout`].
#[derive(Clone, Debug)]
pub struct Block {
    /// packed sign codes, block-major contiguous — the streaming scorer
    /// (`selfindex::score::score_block_bytelut`) reads this as one
    /// sequential byte streak per block, which is what keeps the fused
    /// score→select pass prefetch-friendly (DESIGN.md §Perf iteration 5)
    pub codes: Vec<u8>,
    /// word-packed mirror of `codes` for the popcount scorer
    /// (`score_block_popcnt`): `codes_words()` little-endian `u64`s per
    /// token, tail bytes zero-padded at write time so XOR-based scoring
    /// needs no mask (§Perf iteration 8). Written in lockstep with
    /// `codes` by `HeadCache::push_record`; token-major like every field
    pub codes_w: Vec<u64>,
    pub k_mag: Vec<u8>,
    pub k_prm: Vec<QuantParams>,
    pub v_val: Vec<u8>,
    pub v_prm: Vec<QuantParams>,
    /// tokens currently stored (append cursor)
    pub used: usize,
}

impl Block {
    pub fn new(layout: &RecordLayout, block_tokens: usize) -> Self {
        Self {
            codes: vec![0; block_tokens * layout.codes_bytes],
            codes_w: vec![0; block_tokens * layout.codes_words()],
            k_mag: vec![0; block_tokens * layout.payload_bytes],
            k_prm: vec![
                QuantParams { scale: 0, zero: 0 };
                block_tokens * layout.param_groups()
            ],
            v_val: vec![0; block_tokens * layout.payload_bytes],
            v_prm: vec![
                QuantParams { scale: 0, zero: 0 };
                block_tokens * layout.param_groups()
            ],
            used: 0,
        }
    }

    pub fn reset(&mut self) {
        self.used = 0;
    }

    /// Heap bytes held by this block (the Fig. 5 memory accounting).
    pub fn bytes(&self) -> usize {
        self.codes.len()
            + self.codes_w.len() * std::mem::size_of::<u64>()
            + self.k_mag.len()
            + self.v_val.len()
            + (self.k_prm.len() + self.v_prm.len()) * std::mem::size_of::<QuantParams>()
    }

    /// FNV-1a-64 over the full payload (codes, magnitudes, values, quant
    /// params, append cursor). The prefix registry records this at
    /// registration and re-verifies it at adoption: a frozen shared block
    /// whose bytes drifted (injected bit-flip, or a real aliasing bug in
    /// the unsafe tail-writer discipline) fails adoption and falls back to
    /// fresh prefill instead of silently corrupting an adopter's output.
    /// The host tier reuses the same digest end-to-end: `swap_out`
    /// captures it per block and `swap_in` re-verifies it after restore,
    /// so a corrupted host copy is detected at re-admission. Because the
    /// `codes_w` mirror is a pure repack of `codes`, the cold sub-tier's
    /// drop-and-rehydrate round trip leaves this checksum unchanged
    /// (property-tested in `substrate/prop.rs`).
    pub fn checksum(&self) -> u64 {
        const OFFSET: u64 = 0xcbf29ce484222325;
        const PRIME: u64 = 0x00000100000001b3;
        let mut h = OFFSET;
        let mut fold = |bytes: &[u8]| {
            for &b in bytes {
                h = (h ^ b as u64).wrapping_mul(PRIME);
            }
        };
        fold(&(self.used as u64).to_le_bytes());
        fold(&self.codes);
        for w in &self.codes_w {
            fold(&w.to_le_bytes());
        }
        fold(&self.k_mag);
        fold(&self.v_val);
        for p in self.k_prm.iter().chain(self.v_prm.iter()) {
            fold(&p.scale.to_le_bytes());
            fold(&p.zero.to_le_bytes());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selfindex::SelfIndexConfig;

    #[test]
    fn sizes_follow_layout() {
        let layout = RecordLayout::new(64, &SelfIndexConfig::default());
        let b = Block::new(&layout, 16);
        assert_eq!(b.codes.len(), 16 * 8);
        assert_eq!(b.codes_w.len(), 16, "one word per token at head_dim 64");
        assert_eq!(b.k_mag.len(), 16 * 16);
        assert_eq!(b.k_prm.len(), 16 * 2);
        assert_eq!(b.used, 0);
        // QuantParams is 2×u16
        assert_eq!(std::mem::size_of::<QuantParams>(), 4);
    }

    #[test]
    fn checksum_sees_every_field() {
        let layout = RecordLayout::new(64, &SelfIndexConfig::default());
        let mut b = Block::new(&layout, 16);
        let base = b.checksum();
        assert_eq!(b.checksum(), base, "pure function of content");
        b.codes[0] ^= 1;
        assert_ne!(b.checksum(), base, "single bit flip must change it");
        b.codes[0] ^= 1;
        assert_eq!(b.checksum(), base);
        b.used = 3;
        assert_ne!(b.checksum(), base, "append cursor is covered");
        b.used = 0;
        b.v_prm[0].scale = 7;
        assert_ne!(b.checksum(), base, "quant params are covered");
        b.v_prm[0].scale = 0;
        b.codes_w[0] ^= 1 << 63;
        assert_ne!(b.checksum(), base, "word-packed mirror is covered");
    }
}
