//! Host tier: block-granular swap-to-host with a recompressed cold
//! sub-tier (DESIGN.md §Tiered storage).
//!
//! Preemption used to drop a sequence's blocks and re-prefill from the
//! prompt on resume — correct, but it burns exactly the work chunked
//! prefill protects. The compressed block is already a checksummed,
//! self-contained unit of storage, so spilling it to host memory is
//! cheap: [`HostTier`] copies the payloads of a preempted sequence's
//! blocks out of the device pool, the device references are released,
//! and resume allocates fresh blocks and copies the payloads back —
//! bit-exact versus never having been evicted, verified per block by
//! re-computing [`Block::checksum`] against the value captured at
//! swap-out (a corrupt host copy is *detected*, and the caller falls
//! back to re-prefill).
//!
//! Cold sub-tier (PackKV-style): a block idle in host memory past a
//! configurable sweep age is recompressed by dropping its word-packed
//! `codes_w` mirror — the mirror is a pure function of the packed nibble
//! codes (written lockstep by `HeadCache::push_record`, zero where codes
//! are zero), so rehydration at swap-in re-packs it losslessly via
//! [`pack::pack_signs_u64`] and the device checksum still matches. Byte
//! accounting is exact: [`HostTier::bytes`] drops by precisely
//! `codes_w.len() * 8` per recompressed block.
//!
//! Residency state machine, per swapped sequence:
//!
//! ```text
//! Device --swap_out--> SwappingOut --copy done--> Host
//!   ^                      | (swap.out fault: entry discarded)
//!   |                      v
//!   +--restore+verify-- SwappingIn <--swap_in-- Host
//!        | checksum mismatch / swap.in fault: entry discarded,
//!        v caller re-prefills
//!      (gone)
//! ```
//!
//! Blocks never swapped have no entry here — absence means
//! [`Residency::Device`]. The transient states are observable only
//! across a failed transition (e.g. `NoCapacity` parks the entry back
//! at `Host`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::block::{Block, BlockId};
use super::pool::BlockPool;
use crate::quant::int2::QuantParams;
use crate::quant::pack;
use crate::substrate::faults::FaultPoint;

/// Where a (sequence's) block currently lives in the two-tier store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// In the device pool (the default — such blocks have no tier entry).
    Device,
    /// Mid swap-out copy.
    SwappingOut,
    /// Payload rests in host memory; device references released.
    Host,
    /// Mid swap-in restore.
    SwappingIn,
}

/// One block's payload resting in host memory.
struct HostBlock {
    codes: Vec<u8>,
    /// word-packed mirror of `codes`; `None` once the cold sweep
    /// recompressed this block (losslessly re-packed at swap-in)
    codes_w: Option<Vec<u64>>,
    k_mag: Vec<u8>,
    k_prm: Vec<QuantParams>,
    v_val: Vec<u8>,
    v_prm: Vec<QuantParams>,
    used: usize,
    /// device-side [`Block::checksum`] captured at swap-out, re-verified
    /// after the swap-in restore lands in the fresh device block
    checksum: u64,
}

impl HostBlock {
    fn capture(b: &Block) -> Self {
        Self {
            codes: b.codes.clone(),
            codes_w: Some(b.codes_w.clone()),
            k_mag: b.k_mag.clone(),
            k_prm: b.k_prm.clone(),
            v_val: b.v_val.clone(),
            v_prm: b.v_prm.clone(),
            used: b.used,
            checksum: b.checksum(),
        }
    }

    /// Exact host bytes this copy occupies right now — mirrors
    /// [`Block::bytes`], minus the mirror once recompressed.
    fn bytes(&self) -> usize {
        self.codes.len()
            + self.codes_w.as_ref().map_or(0, |w| w.len() * std::mem::size_of::<u64>())
            + self.k_mag.len()
            + self.v_val.len()
            + (self.k_prm.len() + self.v_prm.len()) * std::mem::size_of::<QuantParams>()
    }

    fn is_cold(&self) -> bool {
        self.codes_w.is_none()
    }
}

/// A preempted sequence's swapped block set.
struct SwappedSeq {
    blocks: Vec<HostBlock>,
    residency: Residency,
    /// sweep ticks spent at `Host` (resets never — one-way aging)
    age: u64,
}

/// How a [`HostTier::swap_in`] attempt ended.
#[derive(Debug)]
pub enum SwapIn {
    /// Payloads restored bit-exact into these freshly allocated device
    /// blocks (in swap-out order); the tier entry is gone.
    Restored(Vec<BlockId>),
    /// The pool cannot hold the working set right now; the entry is
    /// parked back at `Host` — retry on a later step.
    NoCapacity,
    /// An injected `swap.in` fault (or a vanished entry) aborted the
    /// restore before any device state changed; the entry is discarded
    /// and the caller must re-prefill.
    Faulted,
    /// The host copy failed checksum verification after restore; all
    /// restored device blocks were released, the entry is discarded, and
    /// the caller must re-prefill (and bump the integrity counter).
    Corrupt,
}

/// Engine-wide host tier for swapped-out block payloads, keyed by the
/// owning request id. Interior mutability (one `Mutex`) so it can sit
/// inside the `Arc<KvManager>` every head shares.
#[derive(Default)]
pub struct HostTier {
    inner: Mutex<HashMap<u64, SwappedSeq>>,
    /// entries discarded by [`Self::enforce_budget`] (`tier.host_evictions`)
    evictions: AtomicU64,
}

/// Swap-out aborted by an injected `swap.out` fault; nothing was copied
/// and no device state changed.
#[derive(Debug, PartialEq, Eq)]
pub struct SwapOutFault;

impl HostTier {
    pub fn new() -> Self {
        Self::default()
    }

    /// Copy `blocks`' payloads (and per-block checksums) into host
    /// memory under `key`. Device references are **not** released here —
    /// the caller drops them after this returns `Ok`, so an aborted
    /// swap-out leaves the device side untouched.
    pub fn swap_out(
        &self,
        key: u64,
        pool: &BlockPool,
        blocks: &[BlockId],
    ) -> Result<(), SwapOutFault> {
        if pool.faults().should_fire(FaultPoint::SwapOut) {
            return Err(SwapOutFault);
        }
        let mut seq = SwappedSeq {
            blocks: Vec::with_capacity(blocks.len()),
            residency: Residency::SwappingOut,
            age: 0,
        };
        for &id in blocks {
            seq.blocks.push(HostBlock::capture(pool.get(id)));
        }
        seq.residency = Residency::Host;
        let prev = self.inner.lock().unwrap().insert(key, seq);
        debug_assert!(prev.is_none(), "sequence {key} swapped out twice");
        Ok(())
    }

    /// Restore `key`'s payloads into freshly allocated device blocks,
    /// rehydrating recompressed cold blocks and verifying every block's
    /// captured checksum against the restored device bytes.
    pub fn swap_in(&self, key: u64, pool: &BlockPool) -> SwapIn {
        let mut inner = self.inner.lock().unwrap();
        let Some(mut seq) = inner.remove(&key) else {
            return SwapIn::Faulted;
        };
        seq.residency = Residency::SwappingIn;
        if pool.faults().should_fire(FaultPoint::SwapIn) {
            return SwapIn::Faulted;
        }
        if pool.faults().should_fire(FaultPoint::TierCorrupt) {
            // the fault models silent host-memory rot: flip one payload
            // byte so the verification below must catch it
            if let Some(hb) = seq.blocks.first_mut() {
                hb.k_mag[0] ^= 0x01;
            }
        }
        let mut ids: Vec<BlockId> = Vec::with_capacity(seq.blocks.len());
        for _ in 0..seq.blocks.len() {
            match pool.alloc() {
                Some(id) => ids.push(id),
                None => {
                    for id in ids {
                        pool.release(id);
                    }
                    seq.residency = Residency::Host;
                    inner.insert(key, seq);
                    return SwapIn::NoCapacity;
                }
            }
        }
        for (hb, &id) in seq.blocks.iter_mut().zip(&ids) {
            let codes_w = hb.codes_w.take().unwrap_or_else(|| {
                pack::pack_signs_u64(&hb.codes, pool.block_tokens, pool.layout.codes_bytes)
            });
            // SAFETY: `id` was just allocated (refcount 1) and its table
            // entry exists nowhere else yet; no other borrow is live.
            let blk = unsafe { pool.block_mut(id) };
            blk.codes.copy_from_slice(&hb.codes);
            blk.codes_w.copy_from_slice(&codes_w);
            blk.k_mag.copy_from_slice(&hb.k_mag);
            blk.k_prm.copy_from_slice(&hb.k_prm);
            blk.v_val.copy_from_slice(&hb.v_val);
            blk.v_prm.copy_from_slice(&hb.v_prm);
            blk.used = hb.used;
            if blk.checksum() != hb.checksum {
                for &id in &ids {
                    pool.release(id);
                }
                return SwapIn::Corrupt;
            }
        }
        SwapIn::Restored(ids)
    }

    /// Drop `key`'s host copy (request finished or fell back while
    /// swapped).
    pub fn discard(&self, key: u64) {
        self.inner.lock().unwrap().remove(&key);
    }

    /// Age every resident entry by one tick; entries at or past
    /// `cold_after` sweeps are recompressed (the `codes_w` mirror is
    /// dropped). Returns how many blocks went cold this sweep.
    pub fn sweep(&self, cold_after: u64) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let mut chilled = 0;
        for seq in inner.values_mut() {
            seq.age += 1;
            if seq.age >= cold_after {
                for hb in seq.blocks.iter_mut() {
                    if hb.codes_w.take().is_some() {
                        chilled += 1;
                    }
                }
            }
        }
        chilled
    }

    /// Residency of `key`'s block set (`None` = never swapped / already
    /// restored, i.e. [`Residency::Device`]).
    pub fn residency(&self, key: u64) -> Option<Residency> {
        self.inner.lock().unwrap().get(&key).map(|s| s.residency)
    }

    /// Blocks a restore of `key` would need from the device pool.
    pub fn blocks_of(&self, key: u64) -> usize {
        self.inner.lock().unwrap().get(&key).map_or(0, |s| s.blocks.len())
    }

    /// Swapped block copies resident in host memory (`tier.host_blocks`).
    pub fn host_blocks(&self) -> usize {
        self.inner.lock().unwrap().values().map(|s| s.blocks.len()).sum()
    }

    /// Exact host bytes held across all entries (`tier.host_bytes`).
    pub fn bytes(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .values()
            .flat_map(|s| s.blocks.iter())
            .map(HostBlock::bytes)
            .sum()
    }

    /// Bytes held by recompressed (cold) blocks (`tier.cold_bytes`).
    pub fn cold_bytes(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .values()
            .flat_map(|s| s.blocks.iter())
            .filter(|hb| hb.is_cold())
            .map(HostBlock::bytes)
            .sum()
    }

    /// Entries currently swapped out.
    pub fn entries(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// Enforce `swap.max_host_bytes`: while the tier holds more than
    /// `max_bytes`, discard whole `Host`-resident entries — coldest
    /// first (recompressed entries, then oldest by sweep age) — so the
    /// host tier is bounded instead of growing with every preemption. An
    /// evicted sequence's next `swap_in` finds no entry and returns
    /// [`SwapIn::Faulted`]; the caller re-prefills from the prompt — the
    /// already-hardened fallback path doubles as the budget's relief
    /// valve. `max_bytes == 0` means unbounded (no-op). Returns how many
    /// entries were evicted (also summed into [`Self::host_evictions`]).
    pub fn enforce_budget(&self, max_bytes: usize) -> usize {
        if max_bytes == 0 {
            return 0;
        }
        let mut inner = self.inner.lock().unwrap();
        let mut held: usize = inner
            .values()
            .flat_map(|s| s.blocks.iter())
            .map(HostBlock::bytes)
            .sum();
        if held <= max_bytes {
            return 0;
        }
        let mut order: Vec<(bool, u64, u64, usize)> = inner
            .iter()
            .filter(|(_, s)| s.residency == Residency::Host)
            .map(|(&key, s)| {
                let cold = !s.blocks.is_empty() && s.blocks.iter().all(HostBlock::is_cold);
                let bytes = s.blocks.iter().map(HostBlock::bytes).sum::<usize>();
                (cold, s.age, key, bytes)
            })
            .collect();
        // eviction order: cold before warm, then descending age (LRU —
        // age only grows while resident), then key for determinism
        order.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(b.1.cmp(&a.1)).then(a.2.cmp(&b.2)));
        let mut evicted = 0usize;
        for (_, _, key, bytes) in order {
            if held <= max_bytes {
                break;
            }
            inner.remove(&key);
            held -= bytes;
            evicted += 1;
        }
        self.evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        evicted
    }

    /// Entries discarded by [`Self::enforce_budget`] over this tier's
    /// lifetime (`tier.host_evictions`).
    pub fn host_evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::layout::RecordLayout;
    use crate::selfindex::SelfIndexConfig;
    use crate::substrate::faults::FaultInjector;
    use std::sync::Arc;

    const BT: usize = 16;

    fn pool(cap: usize) -> BlockPool {
        BlockPool::new(RecordLayout::new(64, &SelfIndexConfig::default()), BT, cap)
    }

    fn pool_with(cap: usize, spec: &str) -> BlockPool {
        BlockPool::with_faults(
            RecordLayout::new(64, &SelfIndexConfig::default()),
            BT,
            cap,
            Arc::new(FaultInjector::parse(spec, 0).unwrap()),
        )
    }

    /// Fill a block with a deterministic pattern, keeping the
    /// `codes_w == pack(codes)` lockstep invariant `push_record` upholds.
    fn fill(p: &BlockPool, id: BlockId, salt: u8, used: usize) {
        let cb = p.layout.codes_bytes;
        // SAFETY: test-owned block, refcount 1.
        let b = unsafe { p.block_mut(id) };
        for (i, x) in b.codes.iter_mut().enumerate() {
            *x = (i as u8).wrapping_mul(31).wrapping_add(salt);
        }
        let w = pack::pack_signs_u64(&b.codes, BT, cb);
        b.codes_w.copy_from_slice(&w);
        for (i, x) in b.k_mag.iter_mut().enumerate() {
            *x = (i as u8).wrapping_add(salt).wrapping_mul(7);
        }
        for (i, x) in b.v_val.iter_mut().enumerate() {
            *x = (i as u8).wrapping_mul(13) ^ salt;
        }
        for (i, q) in b.k_prm.iter_mut().enumerate() {
            q.scale = i as u16 + salt as u16;
            q.zero = 3 * i as u16;
        }
        b.used = used;
    }

    fn swap_out_and_release(p: &BlockPool, tier: &HostTier, key: u64, ids: &[BlockId]) {
        tier.swap_out(key, p, ids).unwrap();
        for &id in ids {
            p.release(id);
        }
    }

    #[test]
    fn roundtrip_is_bit_exact_and_leak_free() {
        let p = pool(4);
        let tier = HostTier::new();
        let ids: Vec<BlockId> = (0..3).map(|_| p.alloc().unwrap()).collect();
        for (i, &id) in ids.iter().enumerate() {
            fill(&p, id, i as u8 * 17 + 1, if i == 2 { 5 } else { BT });
        }
        let sums: Vec<u64> = ids.iter().map(|&id| p.get(id).checksum()).collect();
        swap_out_and_release(&p, &tier, 7, &ids);
        assert_eq!(p.free_blocks(), 4, "device side fully released");
        assert_eq!(tier.residency(7), Some(Residency::Host));
        assert_eq!(tier.host_blocks(), 3);
        assert_eq!(tier.blocks_of(7), 3);

        let SwapIn::Restored(back) = tier.swap_in(7, &p) else {
            panic!("clean swap-in restores");
        };
        assert_eq!(back.len(), 3);
        for (&id, &sum) in back.iter().zip(&sums) {
            assert_eq!(p.get(id).checksum(), sum, "restored block bit-exact");
        }
        assert_eq!(tier.residency(7), None, "entry consumed");
        assert_eq!(tier.entries(), 0);
        for id in back {
            p.release(id);
        }
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    fn cold_sweep_saves_exactly_the_mirror_and_rehydrates_bit_exact() {
        let p = pool(2);
        let tier = HostTier::new();
        let id = p.alloc().unwrap();
        fill(&p, id, 5, BT);
        let sum = p.get(id).checksum();
        let device_bytes = p.get(id).bytes();
        let mirror_bytes = p.get(id).codes_w.len() * 8;
        swap_out_and_release(&p, &tier, 1, &[id]);
        assert_eq!(tier.bytes(), device_bytes, "warm copy matches device accounting");
        assert_eq!(tier.cold_bytes(), 0);

        assert_eq!(tier.sweep(2), 0, "not old enough yet");
        assert_eq!(tier.sweep(2), 1, "second sweep crosses the age threshold");
        assert_eq!(
            tier.bytes(),
            device_bytes - mirror_bytes,
            "recompression saves exactly the codes_w mirror"
        );
        assert_eq!(tier.cold_bytes(), device_bytes - mirror_bytes);
        assert_eq!(tier.sweep(2), 0, "already cold");

        let SwapIn::Restored(back) = tier.swap_in(1, &p) else {
            panic!("cold swap-in rehydrates");
        };
        assert_eq!(p.get(back[0]).checksum(), sum, "rehydrated mirror bit-exact");
        p.release(back[0]);
    }

    #[test]
    fn corrupt_host_copy_is_detected_and_leaks_nothing() {
        let p = pool_with(2, "tier.corrupt=nth:1");
        let tier = HostTier::new();
        let id = p.alloc().unwrap();
        fill(&p, id, 9, BT);
        swap_out_and_release(&p, &tier, 3, &[id]);
        assert!(matches!(tier.swap_in(3, &p), SwapIn::Corrupt));
        assert_eq!(p.free_blocks(), 2, "restored blocks released on corrupt");
        assert_eq!(tier.entries(), 0, "corrupt entry discarded");
    }

    #[test]
    fn swap_faults_abort_cleanly() {
        let p = pool_with(2, "swap.out=nth:1,swap.in=nth:1");
        let tier = HostTier::new();
        let id = p.alloc().unwrap();
        fill(&p, id, 2, BT);
        assert_eq!(tier.swap_out(5, &p, &[id]), Err(SwapOutFault));
        assert_eq!(tier.entries(), 0, "aborted swap-out stores nothing");
        // device side untouched: the caller keeps its reference
        assert_eq!(p.free_blocks(), 1);

        tier.swap_out(5, &p, &[id]).unwrap();
        p.release(id);
        assert!(matches!(tier.swap_in(5, &p), SwapIn::Faulted));
        assert_eq!(p.free_blocks(), 2, "faulted swap-in allocates nothing");
        assert_eq!(tier.entries(), 0);
    }

    #[test]
    fn budget_evicts_coldest_first_and_counts() {
        let p = pool(8);
        let tier = HostTier::new();
        // three single-block entries; each sweep ages everything resident,
        // so entry 1 ends oldest (age 3), entry 3 youngest (age 1)
        for key in [1u64, 2, 3] {
            let id = p.alloc().unwrap();
            fill(&p, id, key as u8 * 11, BT);
            swap_out_and_release(&p, &tier, key, &[id]);
            tier.sweep(u64::MAX); // age only — nothing recompresses
        }
        let warm = tier.bytes() / 3; // identical layouts → equal sizes
        assert_eq!(tier.enforce_budget(0), 0, "0 = unbounded");
        assert_eq!(tier.enforce_budget(3 * warm), 0, "under budget");
        tier.sweep(4); // entry 1 crosses the age-4 threshold: goes cold
        assert_eq!(tier.host_blocks(), 3);

        // budget of one warm entry: evict cold entry 1 first, then the
        // oldest warm entry 2; entry 3 fits and survives
        assert_eq!(tier.enforce_budget(warm), 2);
        assert_eq!(tier.residency(1), None, "cold entry evicted first");
        assert_eq!(tier.residency(2), None, "then the oldest warm entry");
        assert_eq!(tier.residency(3), Some(Residency::Host));
        assert_eq!(tier.host_evictions(), 2);
        assert!(tier.bytes() <= warm);

        // an evicted sequence's swap-in takes the re-prefill path
        assert!(matches!(tier.swap_in(1, &p), SwapIn::Faulted));
        assert_eq!(p.free_blocks(), 8, "faulted swap-in allocates nothing");
    }

    #[test]
    fn no_capacity_parks_the_entry_for_retry() {
        let p = pool(2);
        let tier = HostTier::new();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        fill(&p, a, 1, BT);
        fill(&p, b, 2, BT);
        swap_out_and_release(&p, &tier, 11, &[a, b]);
        // another tenant takes one block: only 1 of the 2 needed are free
        let hog = p.alloc().unwrap();
        assert!(matches!(tier.swap_in(11, &p), SwapIn::NoCapacity));
        assert_eq!(p.free_blocks(), 1, "partial allocation rolled back");
        assert_eq!(tier.residency(11), Some(Residency::Host), "entry parked");
        p.release(hog);
        let SwapIn::Restored(back) = tier.swap_in(11, &p) else {
            panic!("retry succeeds once capacity returns");
        };
        assert_eq!(back.len(), 2);
        for id in back {
            p.release(id);
        }
        assert_eq!(p.free_blocks(), 2);
    }
}
