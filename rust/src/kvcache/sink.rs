//! Sink tokens: SnapKV-style selection at prefill + full-precision store.
//!
//! The paper keeps 64 tokens full precision, selected with SnapKV (Li et
//! al. 2024): score each prefix token by the attention mass it receives
//! from the queries in an observation window at the end of the prompt
//! (pooled over window positions and heads), and keep the top-n. These
//! tokens always participate in sparse attention and are excluded from
//! dynamic top-k.

use crate::tensor::fp16::{f16_to_f32, f32_to_f16};

/// SnapKV selection for one kv-head.
///
/// `q_window`: (W × R × dim) — the last-W prefill queries of the R query
/// heads sharing this kv head (post-RoPE). `keys`: (L × dim) this head's
/// (post-RoPE, uncentered) prefill keys. Returns up to `n_sinks` indices,
/// ascending, always including token 0 (the attention-sink position).
pub fn snapkv_select(
    q_window: &[f32],
    r_heads: usize,
    keys: &[f32],
    dim: usize,
    n_sinks: usize,
) -> Vec<u32> {
    assert_eq!(keys.len() % dim, 0);
    let l = keys.len() / dim;
    let n = n_sinks.min(l);
    if n == 0 {
        return vec![];
    }
    assert_eq!(q_window.len() % (r_heads * dim), 0);
    let w = q_window.len() / (r_heads * dim);
    let scale = 1.0 / (dim as f32).sqrt();

    // attention mass per token, pooled over window queries × heads
    let mut mass = vec![0.0f32; l];
    let mut logits = vec![0.0f32; l];
    for wi in 0..w {
        for h in 0..r_heads {
            let q = &q_window[(wi * r_heads + h) * dim..][..dim];
            let mut max = f32::NEG_INFINITY;
            for (t, krow) in keys.chunks_exact(dim).enumerate() {
                let s = crate::tensor::dot(q, krow) * scale;
                logits[t] = s;
                max = max.max(s);
            }
            let mut denom = 0.0f32;
            for t in 0..l {
                logits[t] = (logits[t] - max).exp();
                denom += logits[t];
            }
            for t in 0..l {
                mass[t] += logits[t] / denom;
            }
        }
    }

    let mut sel = crate::selfindex::topk::top_k_indices(&mass, n);
    if !sel.contains(&0) {
        // token 0 is the canonical attention sink; force-include it
        sel.pop();
        sel.push(0);
    }
    sel.sort_unstable();
    sel
}

/// Full-precision (fp16-stored) K/V rows for the sink set of one head.
#[derive(Clone, Debug, Default)]
pub struct SinkStore {
    pub dim: usize,
    pub indices: Vec<u32>,
    k: Vec<u16>, // n × dim fp16 (centered keys K')
    v: Vec<u16>,
}

impl SinkStore {
    /// Build from selected indices over the prefill K'(centered)/V rows.
    pub fn build(
        dim: usize,
        indices: &[u32],
        centered_keys: &[f32],
        vals: &[f32],
    ) -> Self {
        let mut k = Vec::with_capacity(indices.len() * dim);
        let mut v = Vec::with_capacity(indices.len() * dim);
        for &i in indices {
            let i = i as usize;
            for j in 0..dim {
                k.push(f32_to_f16(centered_keys[i * dim + j]));
                v.push(f32_to_f16(vals[i * dim + j]));
            }
        }
        Self { dim, indices: indices.to_vec(), k, v }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Decode row `i` into f32 buffers.
    pub fn row(&self, i: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        for j in 0..self.dim {
            k_out[j] = f16_to_f32(self.k[i * self.dim + j]);
            v_out[j] = f16_to_f32(self.v[i * self.dim + j]);
        }
    }

    /// All rows as f32 (PJRT literal staging).
    pub fn rows_f32(&self) -> (Vec<f32>, Vec<f32>) {
        let n = self.len() * self.dim;
        let mut k = Vec::with_capacity(n);
        let mut v = Vec::with_capacity(n);
        for &h in &self.k {
            k.push(f16_to_f32(h));
        }
        for &h in &self.v {
            v.push(f16_to_f32(h));
        }
        (k, v)
    }

    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 2 + self.indices.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    #[test]
    fn snapkv_finds_heavy_hitters() {
        // construct keys where tokens {5, 20} match the window queries
        let (dim, l, w, r_heads) = (32, 64, 4, 2);
        let mut r = Rng::new(1);
        let target: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let mut keys: Vec<f32> = (0..l * dim).map(|_| r.normal_f32() * 0.3).collect();
        for &t in &[5usize, 20] {
            for j in 0..dim {
                keys[t * dim + j] = target[j] * 3.0;
            }
        }
        let mut qw = Vec::new();
        for _ in 0..w * r_heads {
            for j in 0..dim {
                qw.push(target[j] + 0.1 * r.normal_f32());
            }
        }
        let sel = snapkv_select(&qw, r_heads, &keys, dim, 4);
        assert!(sel.contains(&5) && sel.contains(&20), "{sel:?}");
        assert!(sel.contains(&0), "token 0 forced: {sel:?}");
        assert!(sel.windows(2).all(|w| w[0] < w[1]), "sorted: {sel:?}");
    }

    #[test]
    fn sink_store_roundtrip() {
        let mut r = Rng::new(2);
        let dim = 16;
        let keys: Vec<f32> = (0..8 * dim).map(|_| r.normal_f32()).collect();
        let vals: Vec<f32> = (0..8 * dim).map(|_| r.normal_f32()).collect();
        let st = SinkStore::build(dim, &[1, 4, 7], &keys, &vals);
        assert_eq!(st.len(), 3);
        let mut k = vec![0.0; dim];
        let mut v = vec![0.0; dim];
        st.row(1, &mut k, &mut v);
        for j in 0..dim {
            assert!((k[j] - keys[4 * dim + j]).abs() < 2e-3);
            assert!((v[j] - vals[4 * dim + j]).abs() < 2e-3);
        }
        assert_eq!(st.bytes(), 3 * dim * 2 * 2 + 3 * 4);
    }

    #[test]
    fn sink_count_clamped_to_len() {
        let sel = snapkv_select(&[1.0; 2 * 8], 1, &[0.5; 4 * 8], 8, 64);
        assert!(sel.len() <= 4);
    }
}
