//! Refcounted paged block allocator (vLLM-style).
//!
//! Blocks are preallocated up to `capacity_blocks`; `alloc` returns `None`
//! under pressure, which the scheduler turns into admission backpressure
//! or preemption. Refcounts make sequence forking / prefix sharing
//! possible; `release` returns a block to the free list only at zero.

use super::block::{Block, BlockId};
use super::layout::RecordLayout;

pub struct BlockPool {
    pub layout: RecordLayout,
    pub block_tokens: usize,
    blocks: Vec<Block>,
    refs: Vec<u32>,
    free: Vec<BlockId>,
}

impl BlockPool {
    pub fn new(layout: RecordLayout, block_tokens: usize, capacity_blocks: usize) -> Self {
        assert!(
            block_tokens.is_multiple_of(8),
            "block_tokens % 8 == 0 (block scorer 8-token unroll)"
        );
        let blocks = (0..capacity_blocks)
            .map(|_| Block::new(&layout, block_tokens))
            .collect();
        Self {
            layout,
            block_tokens,
            blocks,
            refs: vec![0; capacity_blocks],
            free: (0..capacity_blocks as BlockId).rev().collect(),
        }
    }

    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refs[id as usize], 0);
        self.refs[id as usize] = 1;
        self.blocks[id as usize].reset();
        Some(id)
    }

    pub fn retain(&mut self, id: BlockId) {
        assert!(self.refs[id as usize] > 0, "retain of free block {id}");
        self.refs[id as usize] += 1;
    }

    pub fn release(&mut self, id: BlockId) {
        let r = &mut self.refs[id as usize];
        assert!(*r > 0, "double free of block {id}");
        *r -= 1;
        if *r == 0 {
            self.free.push(id);
        }
    }

    pub fn get(&self, id: BlockId) -> &Block {
        debug_assert!(self.refs[id as usize] > 0, "use of free block {id}");
        &self.blocks[id as usize]
    }

    pub fn get_mut(&mut self, id: BlockId) -> &mut Block {
        debug_assert!(self.refs[id as usize] > 0, "use of free block {id}");
        &mut self.blocks[id as usize]
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn capacity_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.capacity_blocks() - self.free_blocks()
    }

    /// Bytes held by allocated blocks (memory-footprint metric).
    pub fn used_bytes(&self) -> usize {
        self.refs
            .iter()
            .enumerate()
            .filter(|(_, &r)| r > 0)
            .map(|(i, _)| self.blocks[i].bytes())
            .sum()
    }

    /// Can `tokens` more tokens be stored (worst case, fresh blocks)?
    pub fn can_fit(&self, tokens: usize) -> bool {
        self.free.len() * self.block_tokens >= tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selfindex::SelfIndexConfig;
    use crate::substrate::prop::check;
    use crate::substrate::rng::Rng;

    fn pool(cap: usize) -> BlockPool {
        let layout = RecordLayout::new(64, &SelfIndexConfig::default());
        BlockPool::new(layout, 16, cap)
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = pool(4);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.used_blocks(), 2);
        p.release(a);
        assert_eq!(p.used_blocks(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "freed block is reused");
        p.release(b);
        p.release(c);
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = pool(2);
        assert!(p.alloc().is_some());
        assert!(p.alloc().is_some());
        assert!(p.alloc().is_none());
        assert!(!p.can_fit(1));
    }

    #[test]
    fn refcounts_delay_free() {
        let mut p = pool(1);
        let a = p.alloc().unwrap();
        p.retain(a);
        p.release(a);
        assert!(p.alloc().is_none(), "still referenced");
        p.release(a);
        assert!(p.alloc().is_some());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = pool(1);
        let a = p.alloc().unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    fn prop_refcount_conservation() {
        // random alloc/retain/release interleavings: free+used == capacity,
        // and a block is in the free list iff its refcount is zero.
        check(
            31,
            100,
            |r| {
                let ops: Vec<u8> = (0..r.below(200)).map(|_| r.below(3) as u8).collect();
                (r.next_u64(), ops)
            },
            |(seed, ops)| {
                let mut r = Rng::new(*seed);
                let mut p = pool(8);
                let mut live: Vec<BlockId> = vec![];
                let mut counts: std::collections::HashMap<BlockId, u32> =
                    Default::default();
                for &op in ops {
                    match op {
                        0 => {
                            if let Some(id) = p.alloc() {
                                live.push(id);
                                *counts.entry(id).or_insert(0) += 1;
                            }
                        }
                        1 if !live.is_empty() => {
                            let id = live[r.below(live.len() as u64) as usize];
                            p.retain(id);
                            live.push(id);
                            *counts.get_mut(&id).unwrap() += 1;
                        }
                        2 if !live.is_empty() => {
                            let i = r.below(live.len() as u64) as usize;
                            let id = live.swap_remove(i);
                            p.release(id);
                            *counts.get_mut(&id).unwrap() -= 1;
                        }
                        _ => {}
                    }
                }
                let used_expected =
                    counts.values().filter(|&&c| c > 0).count();
                if p.used_blocks() != used_expected {
                    return Err(format!(
                        "used {} != expected {}",
                        p.used_blocks(),
                        used_expected
                    ));
                }
                if p.used_blocks() + p.free_blocks() != p.capacity_blocks() {
                    return Err("blocks leaked".into());
                }
                Ok(())
            },
        );
    }
}
