//! Refcounted paged block allocator (vLLM-style) — **one per engine**.
//!
//! Since the memory-manager inversion (DESIGN.md §Memory manager) the pool
//! is no longer owned by a per-head cache: every sequence's every
//! (layer, kv-head) [`super::store::HeadCache`] borrows blocks from one
//! engine-wide pool and holds only a block table (`Vec<BlockId>`). That
//! makes the refcounts load-bearing — prefix blocks are shared across
//! sequences (`retain`/`release`), and exact free-block accounting drives
//! admission and preemption in the scheduler.
//!
//! Concurrency model (the decode fan-out appends from worker threads):
//!
//! * allocation metadata — free list, refcounts, epochs — lives behind a
//!   `Mutex`, taken once per `alloc`/`retain`/`release` (an append locks
//!   it once every `block_tokens` tokens; scoring never locks);
//! * block payloads live in `UnsafeCell` slots. A block is written only
//!   through [`BlockPool::block_mut`] by its **exclusive owner** — the
//!   one head cache holding it as its partially-filled tail. Shared
//!   (prefix-registered) blocks are always full and therefore frozen:
//!   readers never race a writer. The work queue's completion barrier
//!   publishes writes between steps.
//!
//! `alloc` returns `None` under pressure, which the scheduler turns into
//! admission backpressure or preemption. Each (re)allocation bumps the
//! block's *epoch*; the prefix registry stores the epoch it observed, so
//! a stale entry (block freed and reused) can never be adopted.

use std::cell::UnsafeCell;
use std::sync::{Arc, Mutex};

use super::block::{Block, BlockId};
use super::layout::RecordLayout;
use crate::substrate::faults::{FaultInjector, FaultPoint};

struct PoolMeta {
    refs: Vec<u32>,
    /// bumped on every (re)allocation — validates prefix-registry entries
    epochs: Vec<u64>,
    free: Vec<BlockId>,
}

pub struct BlockPool {
    pub layout: RecordLayout,
    pub block_tokens: usize,
    blocks: Vec<UnsafeCell<Block>>,
    meta: Mutex<PoolMeta>,
    /// chaos probes (`pool.alloc` here; downstream layers reach it via
    /// [`Self::faults`]) — disarmed in production, one branch per probe
    faults: Arc<FaultInjector>,
}

// SAFETY: all mutation of shared state goes through the meta Mutex except
// block payloads, whose aliasing discipline is documented on `block_mut`
// (exclusive tail-owner writes; shared blocks are frozen).
unsafe impl Send for BlockPool {}
unsafe impl Sync for BlockPool {}

impl BlockPool {
    pub fn new(layout: RecordLayout, block_tokens: usize, capacity_blocks: usize) -> Self {
        Self::with_faults(
            layout,
            block_tokens,
            capacity_blocks,
            Arc::new(FaultInjector::disarmed()),
        )
    }

    pub fn with_faults(
        layout: RecordLayout,
        block_tokens: usize,
        capacity_blocks: usize,
        faults: Arc<FaultInjector>,
    ) -> Self {
        assert!(
            block_tokens.is_multiple_of(8),
            "block_tokens % 8 == 0 (block scorer 8-token unroll)"
        );
        assert!(capacity_blocks > 0, "empty pool");
        let blocks = (0..capacity_blocks)
            .map(|_| UnsafeCell::new(Block::new(&layout, block_tokens)))
            .collect();
        Self {
            layout,
            block_tokens,
            blocks,
            meta: Mutex::new(PoolMeta {
                refs: vec![0; capacity_blocks],
                epochs: vec![0; capacity_blocks],
                free: (0..capacity_blocks as BlockId).rev().collect(),
            }),
            faults,
        }
    }

    /// The engine's fault injector (disarmed unless chaos-armed). Layers
    /// above the pool probe their own points through this handle so one
    /// spec string arms the whole stack.
    pub fn faults(&self) -> &Arc<FaultInjector> {
        &self.faults
    }

    /// Allocate a fresh (reset) block with refcount 1, or `None` when the
    /// pool is exhausted — the caller's signal to backpressure or preempt.
    /// An armed `pool.alloc` fault reports exhaustion without touching the
    /// free list, exercising exactly the paths real pressure would.
    pub fn alloc(&self) -> Option<BlockId> {
        if self.faults.should_fire(FaultPoint::PoolAlloc) {
            return None;
        }
        let mut m = self.meta.lock().unwrap();
        let id = m.free.pop()?;
        debug_assert_eq!(m.refs[id as usize], 0);
        m.refs[id as usize] = 1;
        m.epochs[id as usize] += 1;
        // SAFETY: the block was on the free list (refcount 0), so no
        // borrow of it exists; we hold the meta lock, so no concurrent
        // alloc can hand it out while we reset it.
        unsafe { (*self.blocks[id as usize].get()).reset() };
        Some(id)
    }

    /// Take another reference on a live block (prefix sharing, forking).
    pub fn retain(&self, id: BlockId) {
        let mut m = self.meta.lock().unwrap();
        assert!(m.refs[id as usize] > 0, "retain of free block {id}");
        m.refs[id as usize] += 1;
    }

    /// `retain`, but only if the block is still the allocation the caller
    /// observed (live AND at `epoch`). The prefix registry's adoption
    /// primitive: a block that was freed — even if since reallocated with
    /// different content — fails the epoch check and cannot be adopted.
    pub fn try_retain_at_epoch(&self, id: BlockId, epoch: u64) -> bool {
        let mut m = self.meta.lock().unwrap();
        if m.refs[id as usize] > 0 && m.epochs[id as usize] == epoch {
            m.refs[id as usize] += 1;
            true
        } else {
            false
        }
    }

    /// Current epoch of a live block (captured by the prefix registry at
    /// registration time).
    pub fn epoch_of(&self, id: BlockId) -> u64 {
        let m = self.meta.lock().unwrap();
        debug_assert!(m.refs[id as usize] > 0, "epoch of free block {id}");
        m.epochs[id as usize]
    }

    /// Drop one reference; the block returns to the free list at zero.
    pub fn release(&self, id: BlockId) {
        let mut m = self.meta.lock().unwrap();
        let r = &mut m.refs[id as usize];
        assert!(*r > 0, "double free of block {id}");
        *r -= 1;
        if *r == 0 {
            m.free.push(id);
        }
    }

    /// Shared read access to a live block.
    ///
    /// Soundness relies on the pool-wide aliasing discipline: the only
    /// writer of a block is the head cache holding it as its tail
    /// (see [`Self::block_mut`]), and a task only reads blocks its own
    /// sequence holds (its tail included — same thread) or shared prefix
    /// blocks, which are full and frozen.
    pub fn get(&self, id: BlockId) -> &Block {
        #[cfg(debug_assertions)]
        {
            let m = self.meta.lock().unwrap();
            debug_assert!(m.refs[id as usize] > 0, "use of free block {id}");
        }
        // SAFETY: see doc comment — no `&mut` to this block is live on
        // another thread while a holder reads it.
        unsafe { &*self.blocks[id as usize].get() }
    }

    /// Exclusive write access to a block **the caller exclusively owns**.
    ///
    /// # Safety
    ///
    /// The caller must be the only holder of `id` (refcount 1, the id in
    /// exactly one block table) and must not let the returned borrow
    /// overlap any other `get`/`block_mut` of the same id. The append
    /// path upholds this: only the partially-filled tail block is ever
    /// written, and tail blocks are never registered for sharing. The
    /// tier's swap-in restore upholds it the same way: it writes only
    /// into blocks it just allocated and has not yet handed to any
    /// block table (`HostTier::swap_in`).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn block_mut(&self, id: BlockId) -> &mut Block {
        #[cfg(debug_assertions)]
        {
            let m = self.meta.lock().unwrap();
            debug_assert!(m.refs[id as usize] > 0, "write to free block {id}");
            debug_assert_eq!(m.refs[id as usize], 1, "write to shared block {id}");
        }
        &mut *self.blocks[id as usize].get()
    }

    pub fn free_blocks(&self) -> usize {
        self.meta.lock().unwrap().free.len()
    }

    pub fn capacity_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.capacity_blocks() - self.free_blocks()
    }

    /// Bytes held by allocated blocks — each block counted **once** no
    /// matter how many sequences share it (the Fig. 5 engine metric).
    pub fn used_bytes(&self) -> usize {
        let m = self.meta.lock().unwrap();
        m.refs
            .iter()
            .enumerate()
            .filter(|(_, &r)| r > 0)
            // SAFETY: shared read of a live block; `bytes()` touches only
            // the (fixed) buffer lengths, never the payload.
            .map(|(i, _)| unsafe { &*self.blocks[i].get() }.bytes())
            .sum()
    }

    /// Can `tokens` more tokens be stored (worst case, fresh blocks)?
    pub fn can_fit(&self, tokens: usize) -> bool {
        self.free_blocks() * self.block_tokens >= tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selfindex::SelfIndexConfig;
    use crate::substrate::prop::check;
    use crate::substrate::rng::Rng;

    fn pool(cap: usize) -> BlockPool {
        let layout = RecordLayout::new(64, &SelfIndexConfig::default());
        BlockPool::new(layout, 16, cap)
    }

    #[test]
    fn alloc_release_cycle() {
        let p = pool(4);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.used_blocks(), 2);
        p.release(a);
        assert_eq!(p.used_blocks(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "freed block is reused");
        p.release(b);
        p.release(c);
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    fn injected_alloc_fault_mimics_exhaustion_without_leaking() {
        let layout = RecordLayout::new(64, &SelfIndexConfig::default());
        let inj = Arc::new(FaultInjector::parse("pool.alloc=nth:2", 0).unwrap());
        let p = BlockPool::with_faults(layout, 16, 4, Arc::clone(&inj));
        let a = p.alloc().expect("1st alloc clean");
        assert!(p.alloc().is_none(), "2nd alloc faulted");
        assert_eq!(p.free_blocks(), 3, "faulted alloc touched no free-list state");
        let b = p.alloc().expect("3rd alloc clean again (nth fires once)");
        p.release(a);
        p.release(b);
        assert_eq!(p.free_blocks(), 4);
        assert_eq!(inj.fired(FaultPoint::PoolAlloc), 1);
    }

    #[test]
    fn exhaustion_returns_none() {
        let p = pool(2);
        assert!(p.alloc().is_some());
        assert!(p.alloc().is_some());
        assert!(p.alloc().is_none());
        assert!(!p.can_fit(1));
    }

    #[test]
    fn refcounts_delay_free() {
        let p = pool(1);
        let a = p.alloc().unwrap();
        p.retain(a);
        p.release(a);
        assert!(p.alloc().is_none(), "still referenced");
        p.release(a);
        assert!(p.alloc().is_some());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let p = pool(1);
        let a = p.alloc().unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    fn epochs_invalidate_reallocated_blocks() {
        let p = pool(1);
        let a = p.alloc().unwrap();
        let ep = p.epoch_of(a);
        assert!(p.try_retain_at_epoch(a, ep), "live block at its epoch");
        p.release(a);
        p.release(a);
        assert!(!p.try_retain_at_epoch(a, ep), "freed block must not adopt");
        let b = p.alloc().unwrap();
        assert_eq!(a, b, "same slot reused");
        assert!(
            !p.try_retain_at_epoch(b, ep),
            "reallocated block has a new epoch"
        );
        assert!(p.try_retain_at_epoch(b, p.epoch_of(b)));
    }

    #[test]
    fn shared_pool_allocs_across_threads() {
        // the engine fan-out shape: worker threads alloc/release
        // concurrently; conservation must hold afterwards
        let p = std::sync::Arc::new(pool(64));
        let mut handles = vec![];
        for _ in 0..4 {
            let p = std::sync::Arc::clone(&p);
            handles.push(std::thread::spawn(move || {
                for _ in 0..200 {
                    if let Some(id) = p.alloc() {
                        p.retain(id);
                        p.release(id);
                        p.release(id);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(p.free_blocks(), 64);
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn prop_refcount_conservation() {
        // random alloc/retain/release interleavings: free+used == capacity,
        // and a block is in the free list iff its refcount is zero.
        check(
            31,
            100,
            |r| {
                let ops: Vec<u8> = (0..r.below(200)).map(|_| r.below(3) as u8).collect();
                (r.next_u64(), ops)
            },
            |(seed, ops)| {
                let mut r = Rng::new(*seed);
                let p = pool(8);
                let mut live: Vec<BlockId> = vec![];
                let mut counts: std::collections::HashMap<BlockId, u32> =
                    Default::default();
                for &op in ops {
                    match op {
                        0 => {
                            if let Some(id) = p.alloc() {
                                live.push(id);
                                *counts.entry(id).or_insert(0) += 1;
                            }
                        }
                        1 if !live.is_empty() => {
                            let id = live[r.below(live.len() as u64) as usize];
                            p.retain(id);
                            live.push(id);
                            *counts.get_mut(&id).unwrap() += 1;
                        }
                        2 if !live.is_empty() => {
                            let i = r.below(live.len() as u64) as usize;
                            let id = live.swap_remove(i);
                            p.release(id);
                            *counts.get_mut(&id).unwrap() -= 1;
                        }
                        _ => {}
                    }
                }
                let used_expected =
                    counts.values().filter(|&&c| c > 0).count();
                if p.used_blocks() != used_expected {
                    return Err(format!(
                        "used {} != expected {}",
                        p.used_blocks(),
                        used_expected
                    ));
                }
                if p.used_blocks() + p.free_blocks() != p.capacity_blocks() {
                    return Err("blocks leaked".into());
                }
                Ok(())
            },
        );
    }
}
