//! The engine-wide KV memory manager: one shared [`BlockPool`] plus the
//! prefix-block registry that deduplicates identical compressed blocks
//! across sequences (DESIGN.md §Memory manager).
//!
//! ## Prefix reuse, content-addressed
//!
//! A compressed record depends only on (a) the raw K/V rows of its token
//! and (b) the head's frozen encode parameters (mu, alpha, quant geometry)
//! — the paper freezes those after prefill, so a *full* block's bytes are
//! a pure function of its inputs. The registry therefore keys blocks by a
//! 128-bit FNV hash over `(params signature ‖ raw K rows ‖ raw V rows)`:
//! two sequences prefilled with an identical prompt produce identical
//! keys and share the physical blocks (`retain`d per holder), which is
//! strictly more general than positional prefix matching — identical
//! content dedups across heads and across block positions too. Sequences
//! whose prompts share only a *proper* prefix freeze different stats, get
//! different params signatures, and correctly do **not** share: the
//! soundness boundary is the paper's whole-prompt normalization.
//!
//! Partially-filled tail blocks are never registered (decode appends
//! mutate them), so every shared block is full and frozen — the
//! copy-on-write of the tail degenerates to "the tail is always private".
//!
//! ## Trust boundary
//!
//! Adoption trusts the 128-bit key: the raw K/V rows are not kept after
//! encoding, so a colliding pair of inputs would silently share a block.
//! FNV-1a-128 is non-cryptographic — accidental collisions are
//! negligible (~2^-64 birthday bound at the entry cap) — so the
//! remaining exposure is an adversary who *constructs* a collision
//! offline. Two hardenings close the practical gap:
//!
//! * **keyed hashing** — every manager draws a random 128-bit
//!   [`KvManager::hash_seed`] at construction ([`random_seed128`], OS
//!   entropy via `RandomState`) and all content chains start from it, so
//!   key values are unpredictable outside the process and differ across
//!   engine runs. FNV's xor-multiply core is not a PRF, so this is
//!   collision *obscurity*, not a cryptographic guarantee — a truly
//!   adversarial multi-tenant deployment should still substitute a keyed
//!   cryptographic hash (the registry only needs the 128-bit key type to
//!   stay fixed);
//! * **content checksums** — registration records a checksum of the
//!   frozen block ([`super::block::Block::checksum`]) and adoption
//!   re-verifies it, so post-registration byte drift (bit rot, an
//!   aliasing bug in the unsafe tail-writer discipline, an injected
//!   `block.corrupt` fault) fails adoption and falls back to fresh
//!   prefill instead of silently serving corrupt KV state
//!   (`pool.integrity_failures` counts these).
//!
//! ## Staleness without leaks
//!
//! The registry holds **no** refcounts: entries record `(block, epoch)`
//! and adoption goes through [`BlockPool::try_retain_at_epoch`], so a
//! block freed (and possibly reallocated) after its last holder finished
//! simply fails validation and is lazily re-registered. When every
//! sequence is gone, `free_blocks == capacity_blocks` by construction.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::block::BlockId;
use super::layout::RecordLayout;
use super::pool::BlockPool;
use super::tier::HostTier;
use crate::selfindex::SelfIndexConfig;
use crate::substrate::faults::{FaultInjector, FaultPoint};

/// 128-bit content key of one full prefix block (FNV-1a).
pub type PrefixKey = u128;

const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV128_PRIME: u128 = 0x0000000001000000000000000000013b;

/// Fold raw bytes into a running FNV-1a-128 state.
#[inline]
pub fn fnv128_bytes(mut h: u128, bytes: &[u8]) -> u128 {
    for &b in bytes {
        h ^= b as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    h
}

/// Fold an `f32` slice (bit patterns, so -0.0 and 0.0 stay distinct
/// encodings of distinct inputs — hashing must follow the bits the
/// encoder sees, not float equality).
///
/// This is the prefill hot path — every full block's raw K/V rows pass
/// through it — so it folds 8 bytes (two f32s) per multiply instead of
/// FNV's canonical byte-at-a-time schedule: ~8x fewer serial u128
/// multiplies, same 128-bit key type, and single-word differences still
/// always produce distinct keys (xor-then-multiply by an odd constant is
/// injective per step). Not byte-compatible with [`fnv128_bytes`].
#[inline]
pub fn fnv128_f32s(mut h: u128, xs: &[f32]) -> u128 {
    let mut it = xs.chunks_exact(2);
    for pair in it.by_ref() {
        let w = pair[0].to_bits() as u64 | ((pair[1].to_bits() as u64) << 32);
        h ^= w as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    if let [x] = it.remainder() {
        h ^= x.to_bits() as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    h
}

#[inline]
pub fn fnv128_u64(h: u128, x: u64) -> u128 {
    fnv128_bytes(h, &x.to_le_bytes())
}

/// Start a hash chain.
#[inline]
pub fn fnv128_seed() -> u128 {
    FNV128_OFFSET
}

/// A random 128 bits from OS entropy, via the std hasher's per-instance
/// keying (`RandomState`) — the only randomness source available without
/// external crates. Used to key per-engine content hashes so registry
/// keys are unpredictable outside the process.
pub fn random_seed128() -> u128 {
    use std::collections::hash_map::RandomState;
    use std::hash::{BuildHasher, Hasher};
    let s = RandomState::new();
    let mut a = s.build_hasher();
    a.write_u64(0x5eed_0001);
    let mut b = s.build_hasher();
    b.write_u64(0x5eed_0002);
    ((a.finish() as u128) << 64) | b.finish() as u128
}

struct PrefixEntry {
    block: BlockId,
    epoch: u64,
    /// payload checksum at registration — re-verified at adoption
    checksum: u64,
}

/// Bound on registered entries; past it the map is cleared outright
/// (safe: entries are revalidated at adoption, so dropping them only
/// costs future hits, never correctness).
const PREFIX_ENTRY_CAP: usize = 1 << 14;

/// Bound on memoized content keys (same clear-on-overflow policy; a memo
/// drop only costs re-hashing a prompt block, never correctness).
const KEY_MEMO_CAP: usize = 1 << 14;

pub struct KvManager {
    pool: BlockPool,
    /// host tier for swapped-out sequences (empty unless the serving
    /// layer's swap policy is enabled)
    tier: HostTier,
    prefix: Mutex<HashMap<PrefixKey, PrefixEntry>>,
    /// `(prompt_hash, params_sig, block_idx) → content key` — lets a
    /// re-prefill of an already-hashed prompt (preemption restart, shared
    /// submit) skip re-hashing the raw K/V rows of full blocks. Sound for
    /// the same reason prefix reuse is: under a fixed `params_sig` (which
    /// folds the head's frozen encode stats) the compressed block is a
    /// pure function of the prompt, which `prompt_hash` identifies —
    /// the same FNV trust boundary documented above, not a new one.
    key_memo: Mutex<HashMap<(u128, u128, u32), PrefixKey>>,
    hits: AtomicU64,
    misses: AtomicU64,
    integrity_failures: AtomicU64,
    /// per-engine random key for all content-hash chains (see module doc)
    hash_seed: u128,
}

impl KvManager {
    pub fn new(layout: RecordLayout, block_tokens: usize, capacity_blocks: usize) -> Self {
        Self::with_faults(
            layout,
            block_tokens,
            capacity_blocks,
            Arc::new(FaultInjector::disarmed()),
        )
    }

    pub fn with_faults(
        layout: RecordLayout,
        block_tokens: usize,
        capacity_blocks: usize,
        faults: Arc<FaultInjector>,
    ) -> Self {
        Self {
            pool: BlockPool::with_faults(layout, block_tokens, capacity_blocks, faults),
            tier: HostTier::new(),
            prefix: Mutex::new(HashMap::new()),
            key_memo: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            integrity_failures: AtomicU64::new(0),
            hash_seed: random_seed128(),
        }
    }

    /// Convenience constructor for standalone (single-head / bench / test)
    /// use: derives the record layout from `(dim, cfg)`.
    pub fn for_head(
        dim: usize,
        cfg: &SelfIndexConfig,
        block_tokens: usize,
        capacity_blocks: usize,
    ) -> Self {
        Self::new(RecordLayout::new(dim, cfg), block_tokens, capacity_blocks)
    }

    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// The engine-wide host tier for swapped-out block payloads.
    pub fn tier(&self) -> &HostTier {
        &self.tier
    }

    /// Per-engine random key that every content-hash chain starts from
    /// (replaces the fixed `fnv128_seed` offset for registry keys).
    pub fn hash_seed(&self) -> u128 {
        self.hash_seed
    }

    /// Adopt the registered block for `key`, taking a reference on it.
    /// Returns `None` (and prunes the entry) when nothing is registered,
    /// the registration went stale — freed, or freed-and-reallocated —
    /// or the block's bytes no longer match the checksum captured at
    /// registration (corruption: counted in `integrity_failures`). All
    /// three fall back the same way: the caller re-encodes from raw rows
    /// and re-registers, self-healing the registry.
    pub fn adopt(&self, key: PrefixKey) -> Option<BlockId> {
        let mut map = self.prefix.lock().unwrap();
        if let Some(e) = map.get(&key) {
            if self.pool.try_retain_at_epoch(e.block, e.epoch) {
                if self.pool.get(e.block).checksum() == e.checksum {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Some(e.block);
                }
                // corrupt: drop the reference we just took, prune, and
                // make the caller rebuild from source
                self.pool.release(e.block);
                self.integrity_failures.fetch_add(1, Ordering::Relaxed);
            }
            map.remove(&key);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Register a **full, henceforth frozen** block under its content key.
    /// Takes no reference — liveness is revalidated at adoption time.
    /// Captures the payload checksum first; an armed `block.corrupt` fault
    /// then flips one payload bit *after* capture, so the corruption is
    /// detectable (the chaos suite asserts adopters fall back cleanly —
    /// the donor itself reads its own flipped block and is counted as
    /// fault-touched).
    pub fn register(&self, key: PrefixKey, block: BlockId) {
        let checksum = self.pool.get(block).checksum();
        if self.pool.faults().should_fire(FaultPoint::BlockCorrupt) {
            // SAFETY: at registration the block is held only by the
            // registering head cache (refcount 1 — `block_mut` debug-
            // asserts this) and no other borrow is live on this thread.
            unsafe { self.pool.block_mut(block).codes[0] ^= 1 };
        }
        let epoch = self.pool.epoch_of(block);
        let mut map = self.prefix.lock().unwrap();
        if map.len() >= PREFIX_ENTRY_CAP {
            map.clear();
        }
        map.insert(key, PrefixEntry { block, epoch, checksum });
    }

    /// Memoized content key for block `block_idx` of a prompt already
    /// hashed under this manager's seed (see `key_memo` field doc).
    pub fn memo_lookup(
        &self,
        prompt_hash: u128,
        params_sig: u128,
        block_idx: u32,
    ) -> Option<PrefixKey> {
        self.key_memo
            .lock()
            .unwrap()
            .get(&(prompt_hash, params_sig, block_idx))
            .copied()
    }

    /// Remember a computed content key for [`Self::memo_lookup`].
    pub fn memo_store(
        &self,
        prompt_hash: u128,
        params_sig: u128,
        block_idx: u32,
        key: PrefixKey,
    ) {
        let mut memo = self.key_memo.lock().unwrap();
        if memo.len() >= KEY_MEMO_CAP {
            memo.clear();
        }
        memo.insert((prompt_hash, params_sig, block_idx), key);
    }

    /// Prefix-block adoptions served so far (`pool.prefix_hits` gauge).
    pub fn prefix_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Prefix lookups that fell through to a fresh encode.
    pub fn prefix_misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Registered (not necessarily still live) prefix entries.
    pub fn prefix_entries(&self) -> usize {
        self.prefix.lock().unwrap().len()
    }

    /// Adoptions rejected because the block's bytes no longer matched the
    /// registration checksum (`pool.integrity_failures` gauge).
    pub fn integrity_failures(&self) -> u64 {
        self.integrity_failures.load(Ordering::Relaxed)
    }

    /// Record an integrity failure detected outside the prefix registry —
    /// the tier's swap-in checksum verification reports through the same
    /// counter, so `pool.integrity_failures` covers every detected-
    /// corruption fallback in the engine.
    pub fn note_integrity_failure(&self) {
        self.integrity_failures.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(cap: usize) -> KvManager {
        KvManager::for_head(64, &SelfIndexConfig::default(), 16, cap)
    }

    #[test]
    fn adopt_hits_then_survives_donor_release() {
        let m = mgr(4);
        let id = m.pool().alloc().unwrap();
        let key = fnv128_f32s(fnv128_seed(), &[1.0, 2.0]);
        m.register(key, id);
        let adopted = m.adopt(key).expect("registered block adopts");
        assert_eq!(adopted, id);
        assert_eq!(m.prefix_hits(), 1);
        // donor releases; the adopter's reference keeps the block live
        m.pool().release(id);
        assert_eq!(m.pool().used_blocks(), 1);
        m.pool().release(id);
        assert_eq!(m.pool().free_blocks(), 4, "no registry leak");
    }

    #[test]
    fn stale_entries_fail_and_prune() {
        let m = mgr(2);
        let id = m.pool().alloc().unwrap();
        let key = fnv128_u64(fnv128_seed(), 7);
        m.register(key, id);
        m.pool().release(id); // freed: entry is now stale
        assert!(m.adopt(key).is_none(), "freed block must not adopt");
        // slot reused by unrelated content: still must not adopt
        let id2 = m.pool().alloc().unwrap();
        assert_eq!(id2, id);
        m.register(key, id2);
        m.pool().release(id2);
        let id3 = m.pool().alloc().unwrap();
        assert!(m.adopt(key).is_none(), "reallocated epoch must not adopt");
        m.pool().release(id3);
        assert_eq!(m.pool().free_blocks(), 2);
    }

    #[test]
    fn corrupted_block_fails_adoption_and_prunes() {
        let m = mgr(4);
        let id = m.pool().alloc().unwrap();
        let key = fnv128_u64(m.hash_seed(), 11);
        m.register(key, id);
        // flip one payload bit after registration (what block.corrupt does)
        // SAFETY: sole holder, no other borrow live
        unsafe { m.pool().block_mut(id).codes[0] ^= 1 };
        assert!(m.adopt(key).is_none(), "corrupt block must not adopt");
        assert_eq!(m.integrity_failures(), 1);
        assert_eq!(m.prefix_hits(), 0);
        assert_eq!(m.prefix_entries(), 0, "corrupt entry pruned");
        // the failed adoption released its trial reference: donor's
        // release drains the pool completely
        m.pool().release(id);
        assert_eq!(m.pool().free_blocks(), 4, "no leak on integrity failure");
        // re-registration with the corrected content self-heals
        let id2 = m.pool().alloc().unwrap();
        m.register(key, id2);
        assert_eq!(m.adopt(key), Some(id2));
        m.pool().release(id2);
        m.pool().release(id2);
    }

    #[test]
    fn hash_seed_is_per_manager_random() {
        assert_ne!(mgr(1).hash_seed(), mgr(1).hash_seed());
        assert_ne!(random_seed128(), random_seed128());
    }

    #[test]
    fn key_memo_roundtrip_and_bound() {
        let m = mgr(1);
        assert_eq!(m.memo_lookup(1, 2, 0), None);
        m.memo_store(1, 2, 0, 0xabc);
        assert_eq!(m.memo_lookup(1, 2, 0), Some(0xabc));
        assert_eq!(m.memo_lookup(1, 2, 1), None, "per-block-index");
        assert_eq!(m.memo_lookup(1, 3, 0), None, "per-params-sig");
        for i in 0..(super::KEY_MEMO_CAP as u32 + 8) {
            m.memo_store(9, 9, i, i as u128);
        }
        assert!(
            m.key_memo.lock().unwrap().len() <= super::KEY_MEMO_CAP,
            "memo stays bounded"
        );
    }

    #[test]
    fn fnv128_distinguishes_inputs() {
        let a = fnv128_f32s(fnv128_seed(), &[1.0, 2.0, 3.0]);
        let b = fnv128_f32s(fnv128_seed(), &[1.0, 2.0, 3.0000002]);
        let c = fnv128_f32s(fnv128_seed(), &[1.0, 2.0, 3.0]);
        assert_ne!(a, b);
        assert_eq!(a, c);
        assert_ne!(
            fnv128_f32s(fnv128_seed(), &[0.0]),
            fnv128_f32s(fnv128_seed(), &[-0.0]),
            "bit-pattern hashing, not float equality"
        );
    }
}
