//! The engine-wide KV memory manager: one shared [`BlockPool`] plus the
//! prefix-block registry that deduplicates identical compressed blocks
//! across sequences (DESIGN.md §Memory manager).
//!
//! ## Prefix reuse, content-addressed
//!
//! A compressed record depends only on (a) the raw K/V rows of its token
//! and (b) the head's frozen encode parameters (mu, alpha, quant geometry)
//! — the paper freezes those after prefill, so a *full* block's bytes are
//! a pure function of its inputs. The registry therefore keys blocks by a
//! 128-bit FNV hash over `(params signature ‖ raw K rows ‖ raw V rows)`:
//! two sequences prefilled with an identical prompt produce identical
//! keys and share the physical blocks (`retain`d per holder), which is
//! strictly more general than positional prefix matching — identical
//! content dedups across heads and across block positions too. Sequences
//! whose prompts share only a *proper* prefix freeze different stats, get
//! different params signatures, and correctly do **not** share: the
//! soundness boundary is the paper's whole-prompt normalization.
//!
//! Partially-filled tail blocks are never registered (decode appends
//! mutate them), so every shared block is full and frozen — the
//! copy-on-write of the tail degenerates to "the tail is always private".
//!
//! ## Trust boundary
//!
//! Adoption trusts the 128-bit key: the raw K/V rows are not kept after
//! encoding, so a colliding pair of inputs would silently share a block.
//! FNV-1a-128 is non-cryptographic — accidental collisions are
//! negligible (~2^-64 birthday bound at the entry cap), but an adversary
//! who controls prompt bytes AND knows another tenant's exact prompt
//! could in principle construct one. Single-tenant / trusted-prompt
//! serving (this engine's scope) is fine; a multi-tenant deployment
//! should swap `fnv128_*` for a keyed or cryptographic hash — the
//! registry only needs the 128-bit key type to stay fixed.
//!
//! ## Staleness without leaks
//!
//! The registry holds **no** refcounts: entries record `(block, epoch)`
//! and adoption goes through [`BlockPool::try_retain_at_epoch`], so a
//! block freed (and possibly reallocated) after its last holder finished
//! simply fails validation and is lazily re-registered. When every
//! sequence is gone, `free_blocks == capacity_blocks` by construction.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::block::BlockId;
use super::layout::RecordLayout;
use super::pool::BlockPool;
use crate::selfindex::SelfIndexConfig;

/// 128-bit content key of one full prefix block (FNV-1a).
pub type PrefixKey = u128;

const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV128_PRIME: u128 = 0x0000000001000000000000000000013b;

/// Fold raw bytes into a running FNV-1a-128 state.
#[inline]
pub fn fnv128_bytes(mut h: u128, bytes: &[u8]) -> u128 {
    for &b in bytes {
        h ^= b as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    h
}

/// Fold an `f32` slice (bit patterns, so -0.0 and 0.0 stay distinct
/// encodings of distinct inputs — hashing must follow the bits the
/// encoder sees, not float equality).
///
/// This is the prefill hot path — every full block's raw K/V rows pass
/// through it — so it folds 8 bytes (two f32s) per multiply instead of
/// FNV's canonical byte-at-a-time schedule: ~8x fewer serial u128
/// multiplies, same 128-bit key type, and single-word differences still
/// always produce distinct keys (xor-then-multiply by an odd constant is
/// injective per step). Not byte-compatible with [`fnv128_bytes`].
#[inline]
pub fn fnv128_f32s(mut h: u128, xs: &[f32]) -> u128 {
    let mut it = xs.chunks_exact(2);
    for pair in it.by_ref() {
        let w = pair[0].to_bits() as u64 | ((pair[1].to_bits() as u64) << 32);
        h ^= w as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    if let [x] = it.remainder() {
        h ^= x.to_bits() as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    h
}

#[inline]
pub fn fnv128_u64(h: u128, x: u64) -> u128 {
    fnv128_bytes(h, &x.to_le_bytes())
}

/// Start a hash chain.
#[inline]
pub fn fnv128_seed() -> u128 {
    FNV128_OFFSET
}

struct PrefixEntry {
    block: BlockId,
    epoch: u64,
}

/// Bound on registered entries; past it the map is cleared outright
/// (safe: entries are revalidated at adoption, so dropping them only
/// costs future hits, never correctness).
const PREFIX_ENTRY_CAP: usize = 1 << 14;

pub struct KvManager {
    pool: BlockPool,
    prefix: Mutex<HashMap<PrefixKey, PrefixEntry>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl KvManager {
    pub fn new(layout: RecordLayout, block_tokens: usize, capacity_blocks: usize) -> Self {
        Self {
            pool: BlockPool::new(layout, block_tokens, capacity_blocks),
            prefix: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Convenience constructor for standalone (single-head / bench / test)
    /// use: derives the record layout from `(dim, cfg)`.
    pub fn for_head(
        dim: usize,
        cfg: &SelfIndexConfig,
        block_tokens: usize,
        capacity_blocks: usize,
    ) -> Self {
        Self::new(RecordLayout::new(dim, cfg), block_tokens, capacity_blocks)
    }

    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// Adopt the registered block for `key`, taking a reference on it.
    /// Returns `None` (and prunes the entry) when nothing is registered or
    /// the registration went stale — freed, or freed-and-reallocated.
    pub fn adopt(&self, key: PrefixKey) -> Option<BlockId> {
        let mut map = self.prefix.lock().unwrap();
        if let Some(e) = map.get(&key) {
            if self.pool.try_retain_at_epoch(e.block, e.epoch) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(e.block);
            }
            map.remove(&key);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Register a **full, henceforth frozen** block under its content key.
    /// Takes no reference — liveness is revalidated at adoption time.
    pub fn register(&self, key: PrefixKey, block: BlockId) {
        let epoch = self.pool.epoch_of(block);
        let mut map = self.prefix.lock().unwrap();
        if map.len() >= PREFIX_ENTRY_CAP {
            map.clear();
        }
        map.insert(key, PrefixEntry { block, epoch });
    }

    /// Prefix-block adoptions served so far (`pool.prefix_hits` gauge).
    pub fn prefix_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Prefix lookups that fell through to a fresh encode.
    pub fn prefix_misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Registered (not necessarily still live) prefix entries.
    pub fn prefix_entries(&self) -> usize {
        self.prefix.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(cap: usize) -> KvManager {
        KvManager::for_head(64, &SelfIndexConfig::default(), 16, cap)
    }

    #[test]
    fn adopt_hits_then_survives_donor_release() {
        let m = mgr(4);
        let id = m.pool().alloc().unwrap();
        let key = fnv128_f32s(fnv128_seed(), &[1.0, 2.0]);
        m.register(key, id);
        let adopted = m.adopt(key).expect("registered block adopts");
        assert_eq!(adopted, id);
        assert_eq!(m.prefix_hits(), 1);
        // donor releases; the adopter's reference keeps the block live
        m.pool().release(id);
        assert_eq!(m.pool().used_blocks(), 1);
        m.pool().release(id);
        assert_eq!(m.pool().free_blocks(), 4, "no registry leak");
    }

    #[test]
    fn stale_entries_fail_and_prune() {
        let m = mgr(2);
        let id = m.pool().alloc().unwrap();
        let key = fnv128_u64(fnv128_seed(), 7);
        m.register(key, id);
        m.pool().release(id); // freed: entry is now stale
        assert!(m.adopt(key).is_none(), "freed block must not adopt");
        // slot reused by unrelated content: still must not adopt
        let id2 = m.pool().alloc().unwrap();
        assert_eq!(id2, id);
        m.register(key, id2);
        m.pool().release(id2);
        let id3 = m.pool().alloc().unwrap();
        assert!(m.adopt(key).is_none(), "reallocated epoch must not adopt");
        m.pool().release(id3);
        assert_eq!(m.pool().free_blocks(), 2);
    }

    #[test]
    fn fnv128_distinguishes_inputs() {
        let a = fnv128_f32s(fnv128_seed(), &[1.0, 2.0, 3.0]);
        let b = fnv128_f32s(fnv128_seed(), &[1.0, 2.0, 3.0000002]);
        let c = fnv128_f32s(fnv128_seed(), &[1.0, 2.0, 3.0]);
        assert_ne!(a, b);
        assert_eq!(a, c);
        assert_ne!(
            fnv128_f32s(fnv128_seed(), &[0.0]),
            fnv128_f32s(fnv128_seed(), &[-0.0]),
            "bit-pattern hashing, not float equality"
        );
    }
}
