//! Paged, compressed KV-cache substrate.
//!
//! The authoritative cache lives here, in the coordinator's memory, in the
//! paper's self-indexing format — per token and head:
//!
//! ```text
//! codes   G/2 bytes   packed 4-bit sign codes  (index AND sign plane)
//! k_mag   D·B/8 bytes packed B-bit key magnitudes (|K'|/α, token-wise)
//! k_prm   D/32 × 2×fp16   scale/zero-point
//! v_val   D·B/8 bytes packed B-bit values
//! v_prm   D/32 × 2×fp16
//! ```
//!
//! * [`layout`] — the byte-level record layout + the paper's §Overhead
//!   memory accounting (the 78%-savings derivation, re-derived in tests).
//! * [`block`]/[`pool`] — vLLM-style paged allocation: fixed-token blocks,
//!   refcounted, O(1) alloc/free. **One pool per engine**: sequences hold
//!   block tables over the shared pool, enabling exact-occupancy
//!   admission, preemption, and prefix sharing.
//! * [`manager`] — the engine-wide memory manager: the shared pool plus
//!   the content-addressed prefix-block registry that dedups identical
//!   compressed blocks across sequences.
//! * [`store`] — per-(layer, kv-head) [`store::HeadCache`]: streaming
//!   prefill compression (stats → freeze → encode), decode-time append,
//!   LUT-GEMV scoring over the packed blocks, gather + dequantize — a
//!   *view* over borrowed pool blocks, not a pool owner.
//! * [`sink`] — SnapKV-style sink-token selection + full-precision store.
//! * [`tier`] — the host tier: block-granular swap-to-host for preempted
//!   sequences, with checksum-verified swap-in and a PackKV-style
//!   recompressed cold sub-tier.

pub mod block;
pub mod layout;
pub mod manager;
pub mod pool;
pub mod sink;
pub mod store;
pub mod tier;

pub use block::BlockId;
pub use layout::RecordLayout;
pub use manager::{fnv128_bytes, random_seed128, KvManager, PrefixKey};
pub use pool::BlockPool;
pub use sink::{snapkv_select, SinkStore};
pub use store::{CacheFull, GatheredQuant, HeadCache};
pub use tier::{HostTier, Residency, SwapIn};
