//! Per-(layer, kv-head) compressed cache: the paper's pipeline end-to-end.
//!
//! Prefill: accumulate channel stats → freeze (mu, alpha) → one-pass
//! encode: sign codes + codebook + 2-bit magnitudes/values into pool
//! blocks. Decode: append single tokens reusing the frozen parameters
//! (paper: "the per-channel scaling factors α are also reused during the
//! decoding stage"); score all cached tokens via LUT-GEMV over packed
//! codes; gather + dequantize the top-k for attention.
//!
//! Since the memory-manager inversion the cache is a **view over borrowed
//! pool blocks**: it owns only its block table (plus frozen stats and
//! scratch arenas) and every operation takes the engine-wide shared
//! [`BlockPool`] by `&` reference. Prefill goes through the
//! [`KvManager`] so full blocks are content-addressed — an identical
//! block already registered by another sequence is `retain`ed instead of
//! re-encoded (prefix reuse; DESIGN.md §Memory manager).

use std::sync::atomic::{AtomicU64, Ordering};

use super::block::BlockId;
use super::manager::{fnv128_f32s, fnv128_u64, KvManager};
use super::pool::BlockPool;
use crate::substrate::faults::FaultPoint;
use crate::quant::int2::{QuantParams, TokenQuant};
use crate::quant::pack;
use crate::selfindex::codebook::{Codebook, CodebookBuilder};
use crate::selfindex::codes::code_signs;
use crate::selfindex::normalize::ChannelStats;
use crate::selfindex::score::{page_bound, score_tokens_bytelut, BlockScorer, ByteLut};
use crate::selfindex::topk::TopKStream;
use crate::selfindex::SelfIndexConfig;

/// One attention head's compressed cache.
pub struct HeadCache {
    pub dim: usize,
    pub cfg: SelfIndexConfig,
    stats: ChannelStats,
    builder: CodebookBuilder,
    codebook: Option<Codebook>,
    blocks: Vec<BlockId>,
    len: usize,
    /// scratch for centering a token during append
    scratch: Vec<f32>,
    /// scratch for the normalized magnitudes |K'|/alpha during append
    khat: Vec<f32>,
    /// single-token quantization arenas (decode append reuses them so the
    /// steady-state append performs zero heap allocations)
    kq_scratch: TokenQuant,
    vq_scratch: TokenQuant,
    /// encode arenas shared by prefill + append record writes
    enc_codes: Vec<u8>,
    enc_packed_codes: Vec<u8>,
    /// word-packed mirror of `enc_packed_codes` (one token) for the
    /// block's `codes_w` field
    enc_words: Vec<u64>,
    enc_packed_k: Vec<u8>,
    enc_packed_v: Vec<u8>,
    /// hierarchical page tier (DESIGN.md §Perf iteration 9): per CLOSED
    /// page of `cfg.page_blocks` full blocks, the bit-majority sketch of
    /// the page's sign codes — `codes_words()` u64s per page, page-major,
    /// same word layout as `Block::codes_w` rows
    page_m: Vec<u64>,
    /// per closed page, the Hamming radius `max_t popcount(codes_t ⊕ m)`
    /// over every token in the page; together with `page_m` it yields a
    /// sound upper bound on any token score (see `score::page_bound`)
    page_r: Vec<u32>,
    /// per-bit vote counter arena reused by `close_page`
    page_counts: Vec<u32>,
    /// retrieval instrumentation: closed pages bounded / skipped by the
    /// paged fast path. Atomics because `stream_select` takes `&self`;
    /// Relaxed ordering — these are counters, not synchronization.
    pages_scanned: AtomicU64,
    pages_skipped: AtomicU64,
}

fn empty_token_quant(dim: usize, group: usize, bits: u32) -> TokenQuant {
    TokenQuant {
        values: vec![],
        params: vec![],
        dim,
        group,
        bits,
    }
}

/// Raw quantized fields for a gathered token set, shaped for the PJRT
/// `sparse_attn_b{B}` executable inputs (unpacked u8 payloads).
#[derive(Clone, Debug, Default)]
pub struct GatheredQuant {
    pub codes_i32: Vec<i32>,  // S × G
    pub k_q: Vec<u8>,         // S × D
    pub k_qs: Vec<f32>,       // S × D/32
    pub k_zp: Vec<f32>,       // S × D/32
    pub v_q: Vec<u8>,         // S × D
    pub v_qs: Vec<f32>,       // S × D/32
    pub v_zp: Vec<f32>,       // S × D/32
}

impl HeadCache {
    pub fn new(dim: usize, cfg: SelfIndexConfig) -> Self {
        cfg.validate(dim).expect("invalid selfindex config");
        Self {
            dim,
            stats: ChannelStats::new(dim),
            builder: CodebookBuilder::new(dim / cfg.vq_group),
            codebook: None,
            blocks: vec![],
            len: 0,
            scratch: vec![0.0; dim],
            khat: vec![0.0; dim],
            kq_scratch: empty_token_quant(dim, cfg.quant_group, cfg.quant_bits),
            vq_scratch: empty_token_quant(dim, cfg.quant_group, cfg.quant_bits),
            enc_codes: vec![],
            enc_packed_codes: vec![],
            enc_words: vec![],
            enc_packed_k: vec![],
            enc_packed_v: vec![],
            page_m: vec![],
            page_r: vec![],
            page_counts: vec![],
            pages_scanned: AtomicU64::new(0),
            pages_skipped: AtomicU64::new(0),
            cfg,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn codebook(&self) -> &Codebook {
        self.codebook.as_ref().expect("prefill not ingested")
    }

    pub fn alpha(&self) -> &[f32] {
        &self.stats.frozen().expect("prefill not ingested").alpha
    }

    pub fn mu(&self) -> &[f32] {
        &self.stats.frozen().expect("prefill not ingested").mu
    }

    /// Content signature of everything that determines this head's encoded
    /// record bytes: the frozen (mu, alpha) plus the quantization geometry.
    /// Two heads with equal signatures encode equal inputs to equal bytes,
    /// which is what makes prefix-block adoption bit-exact. The chain
    /// starts from the manager's per-engine random [`KvManager::hash_seed`]
    /// so registry keys are unpredictable outside the process (the
    /// manager's trust-boundary hardening).
    fn params_sig(&self, mgr: &KvManager) -> u128 {
        let frozen = self.stats.frozen().expect("prefill first");
        let mut h = mgr.hash_seed();
        h = fnv128_u64(h, self.dim as u64);
        h = fnv128_u64(h, mgr.pool().block_tokens as u64);
        h = fnv128_u64(h, self.cfg.quant_bits as u64);
        h = fnv128_u64(h, self.cfg.quant_group as u64);
        h = fnv128_u64(h, self.cfg.vq_group as u64);
        h = fnv128_f32s(h, &frozen.mu);
        h = fnv128_f32s(h, &frozen.alpha);
        h
    }

    /// Ingest the whole prefill for this head: keys/vals are (tokens × dim)
    /// row-major f32 (the PJRT prefill outputs). Returns tokens stored.
    ///
    /// One pass over the data for stats (cheap vector ops), then one
    /// encode pass — matching the paper's prefill cost model (quantization
    /// + codebook are ~5% of TT2T, measured in table3). Full blocks are
    /// content-addressed through the manager's prefix registry: a block
    /// whose (params, raw K/V) hash is already registered is adopted
    /// (refcount bump, no encode, no second copy); otherwise it is encoded
    /// and registered for later sequences. The ragged tail block is always
    /// private — decode appends mutate it.
    ///
    /// `prompt_hash` (0 = disabled) is the router's interned content hash
    /// of the prompt these rows derive from: when set, full-block content
    /// keys are memoized in the manager under
    /// `(prompt_hash, params_sig, block_idx)`, so a re-prefill of the same
    /// prompt (preemption restart) skips re-hashing the raw K/V rows.
    pub fn ingest_prefill(
        &mut self,
        mgr: &KvManager,
        keys: &[f32],
        vals: &[f32],
        prompt_hash: u128,
    ) -> Result<usize, CacheFull> {
        assert_eq!(keys.len(), vals.len());
        assert_eq!(keys.len() % self.dim, 0);
        let tokens = keys.len() / self.dim;
        self.ingest_prefill_range(mgr, keys, vals, 0, tokens, prompt_hash)
    }

    /// Chunked variant of [`Self::ingest_prefill`]: ingest prompt tokens
    /// `[start, end)` out of the FULL prompt rows (`keys`/`vals` always
    /// hold every token). The first chunk (`start == 0`) freezes the
    /// channel stats and codebook over the **whole** prompt — exactly
    /// what the one-shot path freezes — so however the prompt is sliced,
    /// every encoded record, content key, and adopted prefix block is
    /// bit-identical to a one-shot ingest. `start` must equal the tokens
    /// ingested so far and be block-aligned (chunk boundaries are block
    /// boundaries: a full block never spans chunks, so prefix-block
    /// registration/adoption is untouched by chunking).
    pub fn ingest_prefill_range(
        &mut self,
        mgr: &KvManager,
        keys: &[f32],
        vals: &[f32],
        start: usize,
        end: usize,
        prompt_hash: u128,
    ) -> Result<usize, CacheFull> {
        assert_eq!(keys.len(), vals.len());
        assert_eq!(keys.len() % self.dim, 0);
        let tokens = keys.len() / self.dim;
        assert!(
            start < end && end <= tokens,
            "bad prefill chunk [{start}, {end}) of {tokens} tokens"
        );
        assert_eq!(self.len, start, "prefill chunks must arrive in order");
        let dim = self.dim;

        // chunk-local centered copy (K'); chunk 0 also feeds the codebook
        // builder with the FULL prompt before truncating to its own slice
        let centered: Vec<f32>;
        if start == 0 {
            assert!(self.codebook.is_none(), "prefill already ingested");
            self.stats.accumulate(keys);
            self.stats.freeze(keys);
            let mu = &self.stats.frozen().unwrap().mu;
            let mut full = keys.to_vec();
            for row in full.chunks_exact_mut(dim) {
                for (j, v) in row.iter_mut().enumerate() {
                    *v -= mu[j];
                }
            }
            self.builder.accumulate(&full);
            self.codebook = Some(if self.cfg.magnitude_centroids {
                self.builder.finalize()
            } else {
                Codebook::sign_only(dim / self.cfg.vq_group)
            });
            full.truncate(end * dim);
            centered = full;
        } else {
            assert!(
                self.codebook.is_some(),
                "later prefill chunks need chunk 0's frozen stats/codebook"
            );
            assert!(
                start.is_multiple_of(mgr.pool().block_tokens),
                "prefill chunk start {start} must be block-aligned"
            );
            let mu = &self.stats.frozen().expect("prefill first").mu;
            let mut c = keys[start * dim..end * dim].to_vec();
            for row in c.chunks_exact_mut(dim) {
                for (j, v) in row.iter_mut().enumerate() {
                    *v -= mu[j];
                }
            }
            centered = c;
        }
        let alpha = self.stats.frozen().unwrap().alpha.clone();

        // quantize magnitudes (|K'|/alpha) and values token-wise — both
        // per-token-independent, so chunk-local arrays quantize to the
        // same bytes as the one-shot full arrays
        let mut khat = centered.clone();
        for row in khat.chunks_exact_mut(dim) {
            for (j, v) in row.iter_mut().enumerate() {
                *v = v.abs() / alpha[j];
            }
        }
        let kq = crate::quant::int2::quantize_tokens(
            &khat,
            dim,
            self.cfg.quant_group,
            self.cfg.quant_bits,
        );
        let vq = crate::quant::int2::quantize_tokens(
            &vals[start * dim..end * dim],
            dim,
            self.cfg.quant_group,
            self.cfg.quant_bits,
        );

        let pool = mgr.pool();
        debug_assert_eq!(
            pool.layout,
            crate::kvcache::layout::RecordLayout::new(self.dim, &self.cfg),
            "shared pool layout must match this head's record layout"
        );
        let bt = pool.block_tokens;
        let sig = self.params_sig(mgr);
        let mut t = start;
        while t < end {
            if end - t >= bt {
                debug_assert!(self.len.is_multiple_of(bt));
                let block_idx = (t / bt) as u32;
                let memoized = if prompt_hash != 0 {
                    mgr.memo_lookup(prompt_hash, sig, block_idx)
                } else {
                    None
                };
                let key = memoized.unwrap_or_else(|| {
                    let mut key = sig;
                    key = fnv128_f32s(key, &keys[t * dim..(t + bt) * dim]);
                    key = fnv128_f32s(key, &vals[t * dim..(t + bt) * dim]);
                    if prompt_hash != 0 {
                        mgr.memo_store(prompt_hash, sig, block_idx, key);
                    }
                    key
                });
                if let Some(id) = mgr.adopt(key) {
                    // identical block already in the pool: share it
                    debug_assert_eq!(pool.get(id).used, bt);
                    self.blocks.push(id);
                    self.len += bt;
                    self.maybe_close_page(pool);
                } else {
                    for i in t..t + bt {
                        let local = i - start;
                        self.push_record(
                            pool,
                            &centered[local * dim..(local + 1) * dim],
                            &kq,
                            &vq,
                            local,
                        )?;
                    }
                    // full now — frozen forever, safe to share
                    mgr.register(key, *self.blocks.last().unwrap());
                }
                t += bt;
            } else {
                let local = t - start;
                self.push_record(
                    pool,
                    &centered[local * dim..(local + 1) * dim],
                    &kq,
                    &vq,
                    local,
                )?;
                t += 1;
            }
        }
        Ok(end - start)
    }

    /// Append one decode-time token (k/v rows, dim each), reusing frozen
    /// mu/alpha and the prefill codebook. Every buffer the encode touches
    /// is a reusable arena on `self`, so the steady-state decode append
    /// performs zero heap allocations (asserted by
    /// `baselines::ours::tests::decode_step_is_allocation_free`).
    pub fn append(
        &mut self,
        pool: &BlockPool,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<(), CacheFull> {
        assert_eq!(k_row.len(), self.dim);
        if pool.faults().should_fire(FaultPoint::AppendCacheFull) {
            // chaos probe: report mid-decode exhaustion before touching
            // any cache state — the caller's CacheFull path must cope
            return Err(CacheFull);
        }
        let dim = self.dim;
        {
            let frozen = self.stats.frozen().expect("prefill first");
            self.scratch.resize(dim, 0.0);
            self.khat.resize(dim, 0.0);
            for j in 0..dim {
                let c = k_row[j] - frozen.mu[j];
                self.scratch[j] = c;
                self.khat[j] = c.abs() / frozen.alpha[j];
            }
        }
        let khat = std::mem::take(&mut self.khat);
        let placeholder = || empty_token_quant(dim, self.cfg.quant_group, self.cfg.quant_bits);
        let mut kq = std::mem::replace(&mut self.kq_scratch, placeholder());
        let mut vq = std::mem::replace(&mut self.vq_scratch, placeholder());
        crate::quant::int2::quantize_tokens_into(
            &khat,
            dim,
            self.cfg.quant_group,
            self.cfg.quant_bits,
            &mut kq,
        );
        crate::quant::int2::quantize_tokens_into(
            v_row,
            dim,
            self.cfg.quant_group,
            self.cfg.quant_bits,
            &mut vq,
        );
        let centered = std::mem::take(&mut self.scratch);
        let res = self.push_record(pool, &centered, &kq, &vq, 0);
        self.scratch = centered;
        self.khat = khat;
        self.kq_scratch = kq;
        self.vq_scratch = vq;
        res
    }

    /// Write token `t` of the (already quantized) batch into the cache.
    fn push_record(
        &mut self,
        pool: &BlockPool,
        centered_key: &[f32],
        kq: &TokenQuant,
        vq: &TokenQuant,
        t: usize,
    ) -> Result<(), CacheFull> {
        let bt = pool.block_tokens;
        let layout = pool.layout;
        if self.len % bt == 0 {
            let id = pool.alloc().ok_or(CacheFull)?;
            self.blocks.push(id);
        }
        let slot = self.len % bt;
        let block_id = *self.blocks.last().unwrap();
        let dim = self.dim;
        let ng = layout.param_groups();

        // encode codes from the centered key (with or without the sign
        // plane doubling as quant signs — the storage is the same; the
        // ablation switch changes reconstruction, not encoding) — all
        // through reusable arenas, so per-token encode never allocates
        self.enc_codes.clear();
        self.enc_codes.extend(
            centered_key
                .chunks_exact(4)
                .map(crate::selfindex::codes::sign_code),
        );
        pack::pack_codes_into(&self.enc_codes, &mut self.enc_packed_codes);
        pack::pack_signs_u64_into(
            &self.enc_packed_codes,
            1,
            layout.codes_bytes,
            &mut self.enc_words,
        );
        let bits = self.cfg.quant_bits;
        pack::pack_bits_into(&kq.values[t * dim..(t + 1) * dim], bits, &mut self.enc_packed_k);
        pack::pack_bits_into(&vq.values[t * dim..(t + 1) * dim], bits, &mut self.enc_packed_v);

        // SAFETY: the written block is always this cache's partially
        // filled tail — freshly allocated above or mid-fill, refcount 1.
        // Blocks only become shareable (prefix-registered) once full, and
        // full blocks are never written again, so no other borrow of this
        // block can exist.
        let block = unsafe { pool.block_mut(block_id) };
        let cb = layout.codes_bytes;
        block.codes[slot * cb..(slot + 1) * cb].copy_from_slice(&self.enc_packed_codes);
        let wpt = layout.codes_words();
        block.codes_w[slot * wpt..(slot + 1) * wpt].copy_from_slice(&self.enc_words);
        let pb = layout.payload_bytes;
        block.k_mag[slot * pb..(slot + 1) * pb].copy_from_slice(&self.enc_packed_k);
        block.v_val[slot * pb..(slot + 1) * pb].copy_from_slice(&self.enc_packed_v);
        block.k_prm[slot * ng..(slot + 1) * ng]
            .copy_from_slice(&kq.params[t * ng..(t + 1) * ng]);
        block.v_prm[slot * ng..(slot + 1) * ng]
            .copy_from_slice(&vq.params[t * ng..(t + 1) * ng]);
        block.used = block.used.max(slot + 1);
        self.len += 1;
        self.maybe_close_page(pool);
        Ok(())
    }

    /// Close the retrieval page that `self.len` just completed, if any
    /// (the hierarchical tier of DESIGN.md §Perf iteration 9). Runs after
    /// every token write AND after adopting a shared prefix block —
    /// adoption bypasses `push_record`, but the sketch is a pure function
    /// of the pool blocks' `codes_w`, so summarizing from the pool covers
    /// both paths identically (and keeps adopted summaries equal to the
    /// encoder's, see `adopted_prefix_blocks_feed_the_page_index`).
    fn maybe_close_page(&mut self, pool: &BlockPool) {
        let pb = self.cfg.page_blocks;
        if pb == 0 {
            return;
        }
        let page_tokens = pb * pool.block_tokens;
        if self.len == 0 || !self.len.is_multiple_of(page_tokens) {
            return;
        }
        let page = self.len / page_tokens - 1;
        debug_assert_eq!(page, self.page_r.len(), "pages close in order");
        self.close_page(pool, page);
    }

    /// Summarize closed page `page` — `cfg.page_blocks` consecutive full
    /// blocks — into its bit-majority sketch (appended to `page_m`) and
    /// Hamming radius (appended to `page_r`). Two passes over the page's
    /// `codes_w` words: amortized O(dim) per token, only at page close,
    /// through the reusable `page_counts` arena.
    fn close_page(&mut self, pool: &BlockPool, page: usize) {
        let pb = self.cfg.page_blocks;
        let bt = pool.block_tokens;
        let wpt = pool.layout.codes_words();
        let mut counts = std::mem::take(&mut self.page_counts);
        counts.clear();
        counts.resize(wpt * 64, 0);
        for &id in &self.blocks[page * pb..(page + 1) * pb] {
            let block = pool.get(id);
            debug_assert_eq!(block.used, bt, "closed pages hold only full blocks");
            pack::count_sign_bits(&block.codes_w, wpt, &mut counts);
        }
        let m_start = self.page_m.len();
        debug_assert_eq!(m_start, page * wpt, "sketches are page-major");
        pack::majority_from_counts(&counts, pb * bt, &mut self.page_m);
        self.page_counts = counts;
        let m = &self.page_m[m_start..];
        let mut r = 0u32;
        for &id in &self.blocks[page * pb..(page + 1) * pb] {
            r = r.max(pack::hamming_radius(&pool.get(id).codes_w, m));
        }
        self.page_r.push(r);
    }

    /// Rebuild every closed page's sketch/radius from the current block
    /// table. Used after a tier swap-in: the host tier restores payloads
    /// bit-exactly (checksum-verified), so the rebuilt summaries equal the
    /// pre-swap ones without the tier ever storing sketch state — and
    /// `Block::checksum` stays a pure payload function.
    fn rebuild_page_index(&mut self, pool: &BlockPool) {
        self.page_m.clear();
        self.page_r.clear();
        if self.cfg.page_blocks == 0 {
            return;
        }
        let pages = self.len / (self.cfg.page_blocks * pool.block_tokens);
        for page in 0..pages {
            self.close_page(pool, page);
        }
    }

    /// LUT-GEMV scores of every cached token (appends to `out`, which is
    /// cleared first; `out.len() == self.len` afterwards).
    pub fn scores(&self, pool: &BlockPool, blut: &ByteLut, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.len);
        let bt = pool.block_tokens;
        let mut remaining = self.len;
        let mut tmp = Vec::new();
        for &id in &self.blocks {
            let block = pool.get(id);
            let n = remaining.min(bt);
            score_tokens_bytelut(blut, &block.codes, n, &mut tmp);
            out.extend_from_slice(&tmp);
            remaining -= n;
            if remaining == 0 {
                break;
            }
        }
    }

    /// Stream per-block scores — the fused one-pass decode pipeline
    /// (DESIGN.md §Perf iteration 5). Scores tokens `0..end` straight out
    /// of each pool block (block-major contiguous reads, no flat
    /// per-sequence score vector) and hands every block to `f` as
    /// `(base_index, scores, block_max)` while it is still L1-hot, so the
    /// caller's selector consumes it in the same pass. `scorer` picks the
    /// kernel — byte-LUT over `codes` or popcount over the `codes_w`
    /// word mirror (§Perf iteration 8); block max/threshold semantics
    /// are identical either way. `scratch` is a reusable per-block arena
    /// (resized once to `block_tokens`).
    pub fn stream_scores<F: FnMut(usize, &[f32], f32)>(
        &self,
        pool: &BlockPool,
        scorer: &BlockScorer,
        end: usize,
        scratch: &mut Vec<f32>,
        mut f: F,
    ) {
        let bt = pool.block_tokens;
        if scratch.len() < bt {
            scratch.resize(bt, 0.0);
        }
        let end = end.min(self.len);
        let mut base = 0usize;
        for &id in &self.blocks {
            if base >= end {
                break;
            }
            let n = (end - base).min(bt);
            let block = pool.get(id);
            let bmax = scorer.score_block(&block.codes, &block.codes_w, n, &mut scratch[..n]);
            f(base, &scratch[..n], bmax);
            base += n;
        }
    }

    /// The fused one-pass score→select (DESIGN.md §Perf iteration 5):
    /// stream blocks through [`Self::stream_scores`] into a threshold
    /// [`TopKStream`], skipping the ascending `sink_ids` by walking a
    /// cursor alongside the stream (index arithmetic, no -inf writes) and
    /// rejecting whole blocks whose max cannot enter the kept set. The
    /// top-`k` selection lands in `selected` (descending score). This is
    /// the single implementation both the serving path
    /// (`baselines::ours`) and the benches measure — they cannot drift.
    /// All buffers are caller-owned arenas: zero allocations at steady
    /// state.
    ///
    /// When the popcount scorer is active and closed-page summaries exist
    /// (`cfg.page_blocks > 0`), selection takes the hierarchical fast
    /// path ([`Self::stream_select_paged`]) — bit-identical output,
    /// O(L/page) memory touched for pages the sketch bound rejects.
    #[allow(clippy::too_many_arguments)]
    pub fn stream_select(
        &self,
        pool: &BlockPool,
        scorer: &BlockScorer,
        end: usize,
        sink_ids: &[u32],
        k: usize,
        block_scores: &mut Vec<f32>,
        selector: &mut TopKStream,
        selected: &mut Vec<u32>,
    ) {
        if let BlockScorer::Popcnt { q_words, dim } = scorer {
            if self.cfg.page_blocks > 0 && !self.page_r.is_empty() {
                return self.stream_select_paged(
                    pool,
                    q_words,
                    *dim,
                    end,
                    sink_ids,
                    k,
                    block_scores,
                    selector,
                    selected,
                );
            }
        }
        selector.reset(k);
        let mut si = 0usize; // cursor into the ascending sink list
        self.stream_scores(pool, scorer, end, block_scores, |base, scores, bmax| {
            while si < sink_ids.len() && (sink_ids[si] as usize) < base {
                si += 1;
            }
            // whole-block rejection: nothing in this block can enter the
            // kept set (safe for ascending index streams — equal scores
            // with larger indices never displace kept entries)
            if selector.is_full() && bmax <= selector.threshold() {
                return;
            }
            let mut next_sink = sink_ids.get(si).map_or(usize::MAX, |&s| s as usize);
            for (o, &s) in scores.iter().enumerate() {
                let idx = base + o;
                if idx == next_sink {
                    si += 1;
                    next_sink =
                        sink_ids.get(si).map_or(usize::MAX, |&s| s as usize);
                    continue;
                }
                selector.push(idx as u32, s);
            }
        });
        selector.finish_into(selected);
    }

    /// The hierarchical fast path behind [`Self::stream_select`]
    /// (DESIGN.md §Perf iteration 9): bound each closed page with
    /// `score::page_bound` over its bit-majority sketch + radius and
    /// descend into the page's blocks only when the bound can still beat
    /// the selector threshold. Block and token handling inside a
    /// descended page is the flat pipeline's exact logic (same kernels,
    /// same sink cursor, same `<=` rejection), and the bound
    /// over-approximates every skipped token's score, so the kept set —
    /// and therefore `selected` — is bit-identical to the flat sweep
    /// (asserted by `paged_stream_select_is_bit_identical_to_flat` here
    /// and `tests/score_parity.rs` in the CI RUSTFLAGS matrix). The
    /// open/partial tail page has no sketch yet and is always descended.
    #[allow(clippy::too_many_arguments)]
    fn stream_select_paged(
        &self,
        pool: &BlockPool,
        q_words: &[u64],
        dim: usize,
        end: usize,
        sink_ids: &[u32],
        k: usize,
        block_scores: &mut Vec<f32>,
        selector: &mut TopKStream,
        selected: &mut Vec<u32>,
    ) {
        let bt = pool.block_tokens;
        if block_scores.len() < bt {
            block_scores.resize(bt, 0.0);
        }
        let end = end.min(self.len);
        let page_tokens = self.cfg.page_blocks * bt;
        let wpt = pool.layout.codes_words();
        let scorer = BlockScorer::Popcnt { q_words, dim };
        selector.reset(k);
        let mut si = 0usize; // cursor into the ascending sink list
        let mut base = 0usize;
        let mut page = 0usize;
        while base < end {
            let page_end = (base + page_tokens).min(end);
            if page < self.page_r.len() {
                self.pages_scanned.fetch_add(1, Ordering::Relaxed);
                let m = &self.page_m[page * wpt..(page + 1) * wpt];
                let bound = page_bound(q_words, m, self.page_r[page], dim);
                // whole-page rejection: the radius covers every token in
                // the page (a superset of the `end`-clamped range scored
                // here), so nothing below can enter the kept set — same
                // `<=` semantics as the flat path's block rejection
                if selector.is_full() && bound <= selector.threshold() {
                    self.pages_skipped.fetch_add(1, Ordering::Relaxed);
                    base = page_end;
                    page += 1;
                    continue;
                }
            }
            // descend: stream this page's blocks exactly like the flat path
            while base < page_end {
                let n = (page_end - base).min(bt);
                let block = pool.get(self.blocks[base / bt]);
                let bmax =
                    scorer.score_block(&block.codes, &block.codes_w, n, &mut block_scores[..n]);
                while si < sink_ids.len() && (sink_ids[si] as usize) < base {
                    si += 1;
                }
                if selector.is_full() && bmax <= selector.threshold() {
                    base += n;
                    continue;
                }
                let mut next_sink = sink_ids.get(si).map_or(usize::MAX, |&s| s as usize);
                for (o, &s) in block_scores[..n].iter().enumerate() {
                    let idx = base + o;
                    if idx == next_sink {
                        si += 1;
                        next_sink =
                            sink_ids.get(si).map_or(usize::MAX, |&s| s as usize);
                        continue;
                    }
                    selector.push(idx as u32, s);
                }
                base += n;
            }
            page += 1;
        }
        selector.finish_into(selected);
    }

    /// `(pages bounded, pages skipped)` by the hierarchical fast path
    /// since the last [`Self::reset_page_stats`] — the benches'
    /// `page_skip_rate` denominator/numerator. Interior atomics because
    /// `stream_select` takes `&self`.
    pub fn page_stats(&self) -> (u64, u64) {
        (self.pages_scanned.load(Ordering::Relaxed), self.pages_skipped.load(Ordering::Relaxed))
    }

    pub fn reset_page_stats(&self) {
        self.pages_scanned.store(0, Ordering::Relaxed);
        self.pages_skipped.store(0, Ordering::Relaxed);
    }

    /// Closed pages currently summarized.
    pub fn pages(&self) -> usize {
        self.page_r.len()
    }

    /// Heap bytes held by the page tier (sketches + radii): O(L/page),
    /// counted into [`Self::fixed_overhead_bytes`].
    pub fn page_index_bytes(&self) -> usize {
        self.page_m.len() * std::mem::size_of::<u64>()
            + self.page_r.len() * std::mem::size_of::<u32>()
    }

    /// Dequantize token `idx`'s key (K') and value rows into `k_out`/`v_out`.
    pub fn dequant_token(
        &self,
        pool: &BlockPool,
        idx: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        assert!(idx < self.len);
        let bt = pool.block_tokens;
        let layout = pool.layout;
        let block = pool.get(self.blocks[idx / bt]);
        let slot = idx % bt;
        let dim = self.dim;
        let ng = layout.param_groups();
        let group = self.cfg.quant_group;
        let alpha = self.alpha();

        let kp = &block.k_prm[slot * ng..(slot + 1) * ng];
        let vp = &block.v_prm[slot * ng..(slot + 1) * ng];
        let kmag = &block.k_mag[slot * layout.payload_bytes..];
        let vval = &block.v_val[slot * layout.payload_bytes..];
        let codes = &block.codes[slot * layout.codes_bytes..];

        if self.cfg.quant_bits == 2 && self.cfg.sign_plane_quant {
            // hot path (§Perf iteration 2): byte-level unpack — one payload
            // byte = 4 channels, one code nibble = 4 signs; quant params
            // stay in registers across their 32-channel group. No
            // per-element division, array construction, or dynamic shifts.
            let mut j = 0usize;
            for pg in 0..ng {
                let (kqs, kzp) = (kp[pg].scale_f32(), kp[pg].zero_f32());
                let (vqs, vzp) = (vp[pg].scale_f32(), vp[pg].zero_f32());
                for _ in 0..group / 4 {
                    let nib = j / 4;
                    let code = (codes[nib / 2] >> ((nib % 2) * 4)) & 0x0f;
                    let kb = kmag[j / 4];
                    let vb = vval[j / 4];
                    // channel b of the group is bit (3-b) of the code (MSB-first)
                    let mut bit = 0b1000u8;
                    for b in 0..4 {
                        let q = (kb >> (b * 2)) & 3;
                        let mag = (kqs * q as f32 + kzp) * alpha[j + b];
                        k_out[j + b] = if code & bit != 0 { mag } else { -mag };
                        let qv = (vb >> (b * 2)) & 3;
                        v_out[j + b] = vqs * qv as f32 + vzp;
                        bit >>= 1;
                    }
                    j += 4;
                }
            }
            return;
        }

        // generic path (other bit widths / ablations)
        for j in 0..dim {
            let p: QuantParams = kp[j / group];
            let mag = p.scale_f32()
                * pack::get_bits(kmag, j, self.cfg.quant_bits) as f32
                + p.zero_f32();
            let mag = mag * alpha[j];
            let sign = if self.cfg.sign_plane_quant {
                let code = pack::get_code(codes, j / 4);
                code_signs(code)[j % 4]
            } else {
                // ablation "w/o sign in quant": the stored magnitudes were
                // built from |K'| anyway, so reconstruct signless — this
                // degrades keys exactly as the paper's ablation intends.
                1.0
            };
            k_out[j] = sign * mag;
            let pv: QuantParams = vp[j / group];
            v_out[j] = pv.scale_f32()
                * pack::get_bits(vval, j, self.cfg.quant_bits) as f32
                + pv.zero_f32();
        }
    }

    /// Fused dequant + dot (§Perf iteration 3): returns q·K'[idx] while
    /// dequantizing only V into `v_out` — the key row never materializes.
    /// `q_alpha` must be the query pre-multiplied by this head's alpha
    /// (`q[j] * alpha[j]`), hoisting the per-channel normalizer out of the
    /// token loop. 2-bit sign-plane fast path only; callers fall back to
    /// `dequant_token` otherwise.
    pub fn dequant_dot(
        &self,
        pool: &BlockPool,
        idx: usize,
        q_alpha: &[f32],
        q_raw: &[f32],
        v_out: &mut [f32],
    ) -> f32 {
        debug_assert!(self.cfg.quant_bits == 2 && self.cfg.sign_plane_quant);
        debug_assert!(idx < self.len);
        let bt = pool.block_tokens;
        let layout = pool.layout;
        let block = pool.get(self.blocks[idx / bt]);
        let slot = idx % bt;
        let ng = layout.param_groups();
        let group = self.cfg.quant_group;

        let kp = &block.k_prm[slot * ng..(slot + 1) * ng];
        let vp = &block.v_prm[slot * ng..(slot + 1) * ng];
        let kmag = &block.k_mag[slot * layout.payload_bytes..];
        let vval = &block.v_val[slot * layout.payload_bytes..];
        let codes = &block.codes[slot * layout.codes_bytes..];

        // 4 independent accumulators (one per nibble lane) break the fp
        // dependency chain; signs come from a 16×4 table (±1.0, branchless).
        let mut acc = [0.0f32; 4];
        let mut j = 0usize;
        for pg in 0..ng {
            let (kqs, kzp) = (kp[pg].scale_f32(), kp[pg].zero_f32());
            let (vqs, vzp) = (vp[pg].scale_f32(), vp[pg].zero_f32());
            for _ in 0..group / 4 {
                let nib = j / 4;
                let code = (codes[nib / 2] >> ((nib % 2) * 4)) & 0x0f;
                let signs = &SIGN_TABLE[code as usize];
                let kb = kmag[j / 4];
                let vb = vval[j / 4];
                for b in 0..4 {
                    let qk = (kb >> (b * 2)) & 3;
                    acc[b] += q_alpha[j + b] * (kqs * qk as f32 + kzp) * signs[b];
                    let qv = (vb >> (b * 2)) & 3;
                    v_out[j + b] = vqs * qv as f32 + vzp;
                }
                j += 4;
            }
        }
        let _ = q_raw;
        (acc[0] + acc[1]) + (acc[2] + acc[3])
    }

    /// Score-only variant of [`Self::dequant_dot`]: q·K'[idx] without
    /// touching V (pass 1 of the two-pass fused attention, §Perf iter 4).
    pub fn dequant_dot_k(&self, pool: &BlockPool, idx: usize, q_alpha: &[f32]) -> f32 {
        debug_assert!(self.cfg.quant_bits == 2 && self.cfg.sign_plane_quant);
        let bt = pool.block_tokens;
        let layout = pool.layout;
        let block = pool.get(self.blocks[idx / bt]);
        let slot = idx % bt;
        let ng = layout.param_groups();
        let group = self.cfg.quant_group;
        let kp = &block.k_prm[slot * ng..(slot + 1) * ng];
        let kmag = &block.k_mag[slot * layout.payload_bytes..];
        let codes = &block.codes[slot * layout.codes_bytes..];

        let mut acc = [0.0f32; 4];
        let mut j = 0usize;
        for pg in 0..ng {
            let (kqs, kzp) = (kp[pg].scale_f32(), kp[pg].zero_f32());
            for _ in 0..group / 4 {
                let nib = j / 4;
                let code = (codes[nib / 2] >> ((nib % 2) * 4)) & 0x0f;
                let signs = &SIGN_TABLE[code as usize];
                let kb = kmag[j / 4];
                for b in 0..4 {
                    let qk = (kb >> (b * 2)) & 3;
                    acc[b] += q_alpha[j + b] * (kqs * qk as f32 + kzp) * signs[b];
                }
                j += 4;
            }
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3])
    }

    /// V-only dequantization into `v_out` (pass 2 of the fused attention).
    pub fn dequant_v(&self, pool: &BlockPool, idx: usize, v_out: &mut [f32]) {
        let bt = pool.block_tokens;
        let layout = pool.layout;
        let block = pool.get(self.blocks[idx / bt]);
        let slot = idx % bt;
        let ng = layout.param_groups();
        let group = self.cfg.quant_group;
        let vp = &block.v_prm[slot * ng..(slot + 1) * ng];
        let vval = &block.v_val[slot * layout.payload_bytes..];
        if self.cfg.quant_bits == 2 {
            let mut j = 0usize;
            for pg in 0..ng {
                let (vqs, vzp) = (vp[pg].scale_f32(), vp[pg].zero_f32());
                for _ in 0..group / 4 {
                    let vb = vval[j / 4];
                    for b in 0..4 {
                        v_out[j + b] = vqs * ((vb >> (b * 2)) & 3) as f32 + vzp;
                    }
                    j += 4;
                }
            }
        } else {
            for j in 0..self.dim {
                let p = vp[j / group];
                v_out[j] = p.scale_f32()
                    * pack::get_bits(vval, j, self.cfg.quant_bits) as f32
                    + p.zero_f32();
            }
        }
    }

    /// Gather raw quantized fields of `indices` for the PJRT sparse-attn
    /// executable (unpacked u8 payloads, i32 codes).
    pub fn gather_quant(
        &self,
        pool: &BlockPool,
        indices: &[u32],
        out: &mut GatheredQuant,
    ) {
        let layout = pool.layout;
        let dim = self.dim;
        let g = layout.groups();
        let ng = layout.param_groups();
        let s = indices.len();
        out.codes_i32.clear();
        out.codes_i32.reserve(s * g);
        out.k_q.clear();
        out.k_q.reserve(s * dim);
        out.k_qs.clear();
        out.k_zp.clear();
        out.v_q.clear();
        out.v_qs.clear();
        out.v_zp.clear();

        let bt = pool.block_tokens;
        for &idx in indices {
            let idx = idx as usize;
            assert!(idx < self.len);
            let block = pool.get(self.blocks[idx / bt]);
            let slot = idx % bt;
            let codes = &block.codes[slot * layout.codes_bytes..];
            for gi in 0..g {
                out.codes_i32.push(pack::get_code(codes, gi) as i32);
            }
            let kmag = &block.k_mag[slot * layout.payload_bytes..];
            let vval = &block.v_val[slot * layout.payload_bytes..];
            for j in 0..dim {
                out.k_q.push(pack::get_bits(kmag, j, self.cfg.quant_bits));
                out.v_q.push(pack::get_bits(vval, j, self.cfg.quant_bits));
            }
            for pi in 0..ng {
                let kp = block.k_prm[slot * ng + pi];
                out.k_qs.push(kp.scale_f32());
                out.k_zp.push(kp.zero_f32());
                let vp = block.v_prm[slot * ng + pi];
                out.v_qs.push(vp.scale_f32());
                out.v_zp.push(vp.zero_f32());
            }
        }
    }

    /// Release all block references back to the shared pool (sequence
    /// completion, preemption). Shared prefix blocks survive as long as
    /// any other holder remains; exclusive blocks return to the free list.
    pub fn free(&mut self, pool: &BlockPool) {
        for id in self.blocks.drain(..) {
            pool.release(id);
        }
        self.len = 0;
        self.page_m.clear();
        self.page_r.clear();
    }

    /// The block table (swap-out reads it to copy payloads to the host
    /// tier before the references are dropped).
    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Detach the block table for a tier swap-out, **keeping** `len`,
    /// frozen stats, and the codebook — everything a restored copy needs
    /// to keep scoring bit-exactly. The caller copies the payloads to the
    /// host tier and then releases the returned references; until
    /// [`Self::restore_blocks`] this cache holds tokens but no blocks
    /// (and `free`/`Drop` release nothing — no double free). Page
    /// summaries are derived state over `codes_w`: dropped here, rebuilt
    /// from the restored payloads by [`Self::restore_blocks`] — the host
    /// tier never carries them, so its cold sweep can keep dropping
    /// `codes_w` without touching the sketch path.
    pub fn take_blocks_for_swap(&mut self) -> Vec<BlockId> {
        self.page_m.clear();
        self.page_r.clear();
        std::mem::take(&mut self.blocks)
    }

    /// Re-attach freshly allocated device blocks after a tier swap-in.
    /// The restored payloads must be bit-exact copies of the swapped-out
    /// table, in the same order.
    pub fn restore_blocks(&mut self, blocks: Vec<BlockId>, pool: &BlockPool) {
        assert!(self.blocks.is_empty(), "restore over a live block table");
        assert_eq!(
            blocks.len(),
            self.len.div_ceil(pool.block_tokens),
            "restored table must cover exactly the swapped tokens"
        );
        self.blocks = blocks;
        self.rebuild_page_index(pool);
    }

    /// Pool blocks the **next** append will allocate (1 exactly at block
    /// boundaries, else 0) — the scheduler's exact preemption input.
    pub fn blocks_for_next_append(&self, pool: &BlockPool) -> usize {
        usize::from(self.len.is_multiple_of(pool.block_tokens))
    }

    /// Compressed bytes attributable to this head (token payload only;
    /// codebook/stats are O(1) fixed overhead reported separately).
    pub fn payload_bytes(&self, pool: &BlockPool) -> usize {
        self.blocks.len()
            * (pool.block_tokens
                * (pool.layout.codes_bytes
                    + 2 * pool.layout.payload_bytes
                    + 2 * pool.layout.params_bytes))
    }

    pub fn fixed_overhead_bytes(&self) -> usize {
        self.codebook.as_ref().map(|c| c.bytes()).unwrap_or(0)
            + 2 * self.dim * 4
            + self.page_index_bytes()
    }
}

/// Pool exhausted — scheduler must backpressure or preempt.
#[derive(Debug, Clone, Copy)]
pub struct CacheFull;

impl std::fmt::Display for CacheFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("kv cache pool exhausted")
    }
}

impl std::error::Error for CacheFull {}

/// ±1 signs of each 4-bit code, MSB-first (code_signs as a flat table).
static SIGN_TABLE: [[f32; 4]; 16] = {
    let mut t = [[0.0f32; 4]; 16];
    let mut c = 0;
    while c < 16 {
        let mut b = 0;
        while b < 4 {
            t[c][b] = if (c >> (3 - b)) & 1 == 1 { 1.0 } else { -1.0 };
            b += 1;
        }
        c += 1;
    }
    t
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    fn mk_mgr(cap: usize) -> KvManager {
        KvManager::for_head(64, &SelfIndexConfig::default(), 16, cap)
    }

    fn rand_rows(r: &mut Rng, tokens: usize, dim: usize) -> Vec<f32> {
        (0..tokens * dim).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn prefill_then_scores_and_dequant() {
        let mut r = Rng::new(1);
        let mgr = mk_mgr(64);
        let pool = mgr.pool();
        let mut hc = HeadCache::new(64, SelfIndexConfig::default());
        let keys = rand_rows(&mut r, 100, 64);
        let vals = rand_rows(&mut r, 100, 64);
        assert_eq!(hc.ingest_prefill(&mgr, &keys, &vals, 0).unwrap(), 100);
        assert_eq!(hc.len(), 100);

        let q: Vec<f32> = (0..64).map(|_| r.normal_f32()).collect();
        let lut = Lut::build(&q, hc.codebook());
        let blut = ByteLut::from_lut(&lut);
        let mut scores = Vec::new();
        hc.scores(pool, &blut, &mut scores);
        assert_eq!(scores.len(), 100);

        // dequantized keys reconstruct within the quant error bound
        let mut k_out = vec![0.0; 64];
        let mut v_out = vec![0.0; 64];
        let mu = hc.mu().to_vec();
        for t in [0usize, 31, 99] {
            hc.dequant_token(pool, t, &mut k_out, &mut v_out);
            for j in 0..64 {
                let truth = keys[t * 64 + j] - mu[j];
                assert!(
                    (k_out[j] - truth).abs() < 0.8 * hc.alpha()[j].max(0.1),
                    "t{t} j{j}: {} vs {truth}",
                    k_out[j]
                );
                // sign plane is exact
                if truth != 0.0 {
                    assert_eq!(k_out[j] >= 0.0, truth >= 0.0, "t{t} j{j}");
                }
                assert!((v_out[j] - vals[t * 64 + j]).abs() < 1.5);
            }
        }
    }

    #[test]
    fn decode_append_extends_scores() {
        let mut r = Rng::new(2);
        let mgr = mk_mgr(64);
        let pool = mgr.pool();
        let mut hc = HeadCache::new(64, SelfIndexConfig::default());
        hc.ingest_prefill(&mgr, &rand_rows(&mut r, 40, 64), &rand_rows(&mut r, 40, 64), 0)
            .unwrap();
        for _ in 0..10 {
            let k: Vec<f32> = (0..64).map(|_| r.normal_f32()).collect();
            let v: Vec<f32> = (0..64).map(|_| r.normal_f32()).collect();
            hc.append(pool, &k, &v).unwrap();
        }
        assert_eq!(hc.len(), 50);
        let q: Vec<f32> = (0..64).map(|_| r.normal_f32()).collect();
        let blut = ByteLut::from_lut(&Lut::build(&q, hc.codebook()));
        let mut scores = Vec::new();
        hc.scores(pool, &blut, &mut scores);
        assert_eq!(scores.len(), 50);
    }

    #[test]
    fn stream_scores_matches_flat_scores() {
        let mut r = Rng::new(9);
        let mgr = mk_mgr(64);
        let pool = mgr.pool();
        let mut hc = HeadCache::new(64, SelfIndexConfig::default());
        // 100 tokens over 16-token blocks: full blocks + a ragged tail
        hc.ingest_prefill(&mgr, &rand_rows(&mut r, 100, 64), &rand_rows(&mut r, 100, 64), 0)
            .unwrap();
        let q: Vec<f32> = (0..64).map(|_| r.normal_f32()).collect();
        let blut = ByteLut::from_lut(&Lut::build(&q, hc.codebook()));
        let mut flat = Vec::new();
        hc.scores(pool, &blut, &mut flat);

        for end in [100usize, 90, 16, 1, 0] {
            let mut streamed = vec![f32::NAN; end];
            let mut scratch = Vec::new();
            let mut blocks_seen = 0;
            let scorer = BlockScorer::ByteLut(&blut);
            hc.stream_scores(pool, &scorer, end, &mut scratch, |base, s, bmax| {
                let mut emax = f32::NEG_INFINITY;
                for (o, &v) in s.iter().enumerate() {
                    streamed[base + o] = v;
                    emax = emax.max(v);
                }
                assert_eq!(bmax, emax);
                blocks_seen += 1;
            });
            assert_eq!(blocks_seen, end.div_ceil(16));
            for (a, b) in streamed.iter().zip(&flat[..end]) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn chunked_ingest_is_bit_identical_to_one_shot() {
        // the chunked-prefill contract: block-aligned chunks over the full
        // prompt rows encode the SAME blocks as a one-shot ingest — same
        // frozen stats, same codebook, same record bytes, same content
        // keys (the second cache adopts every full block the first one
        // registered, proving key equality end-to-end)
        let mut r = Rng::new(11);
        let mgr = mk_mgr(64); // block_tokens = 16
        let pool = mgr.pool();
        let keys = rand_rows(&mut r, 72, 64); // 4 full blocks + ragged tail
        let vals = rand_rows(&mut r, 72, 64);

        let mut one = HeadCache::new(64, SelfIndexConfig::default());
        one.ingest_prefill(&mgr, &keys, &vals, 0).unwrap();

        let mut chunked = HeadCache::new(64, SelfIndexConfig::default());
        for (s, e) in [(0usize, 32usize), (32, 64), (64, 72)] {
            assert_eq!(
                chunked
                    .ingest_prefill_range(&mgr, &keys, &vals, s, e, 0)
                    .unwrap(),
                e - s
            );
        }
        assert_eq!(one.len(), chunked.len());
        assert_eq!(one.mu(), chunked.mu(), "chunk 0 froze full-prompt stats");
        assert_eq!(one.alpha(), chunked.alpha());
        assert_eq!(one.blocks.len(), chunked.blocks.len());
        let hits_before = mgr.prefix_hits();
        assert!(
            hits_before >= 4,
            "chunked full blocks adopt the one-shot registrations ({hits_before})"
        );
        for (&a, &b) in one.blocks.iter().zip(&chunked.blocks) {
            let (ba, bb) = (pool.get(a), pool.get(b));
            assert_eq!(ba.used, bb.used);
            assert_eq!(ba.checksum(), bb.checksum(), "record bytes differ");
        }
        // the ragged tails are private copies, never shared
        assert_ne!(one.blocks.last(), chunked.blocks.last());
        one.free(pool);
        chunked.free(pool);
        assert_eq!(pool.used_blocks(), 0);
    }

    #[test]
    fn gather_quant_shapes() {
        let mut r = Rng::new(3);
        let mgr = mk_mgr(64);
        let pool = mgr.pool();
        let mut hc = HeadCache::new(64, SelfIndexConfig::default());
        hc.ingest_prefill(&mgr, &rand_rows(&mut r, 50, 64), &rand_rows(&mut r, 50, 64), 0)
            .unwrap();
        let mut gq = GatheredQuant::default();
        hc.gather_quant(pool, &[0, 17, 49, 3], &mut gq);
        assert_eq!(gq.codes_i32.len(), 4 * 16);
        assert_eq!(gq.k_q.len(), 4 * 64);
        assert_eq!(gq.k_qs.len(), 4 * 2);
        assert!(gq.codes_i32.iter().all(|&c| (0..16).contains(&c)));
        assert!(gq.k_q.iter().all(|&v| v < 4));
    }

    #[test]
    fn pool_exhaustion_reported() {
        let mut r = Rng::new(4);
        let mgr = mk_mgr(2); // 32 tokens max
        let mut hc = HeadCache::new(64, SelfIndexConfig::default());
        let res =
            hc.ingest_prefill(&mgr, &rand_rows(&mut r, 100, 64), &rand_rows(&mut r, 100, 64), 0);
        assert!(res.is_err());
    }

    #[test]
    fn free_returns_blocks() {
        let mut r = Rng::new(5);
        let mgr = mk_mgr(8);
        let pool = mgr.pool();
        let mut hc = HeadCache::new(64, SelfIndexConfig::default());
        hc.ingest_prefill(&mgr, &rand_rows(&mut r, 64, 64), &rand_rows(&mut r, 64, 64), 0)
            .unwrap();
        assert_eq!(pool.used_blocks(), 4);
        hc.free(pool);
        assert_eq!(pool.used_blocks(), 0);
        assert_eq!(hc.len(), 0);
    }

    #[test]
    fn memory_accounting_matches_layout() {
        let mut r = Rng::new(6);
        let mgr = mk_mgr(16);
        let pool = mgr.pool();
        let mut hc = HeadCache::new(64, SelfIndexConfig::default());
        hc.ingest_prefill(&mgr, &rand_rows(&mut r, 64, 64), &rand_rows(&mut r, 64, 64), 0)
            .unwrap();
        let expect =
            4 * 16 * crate::kvcache::layout::RecordLayout::new(64, &hc.cfg).bytes_per_token();
        assert_eq!(hc.payload_bytes(pool), expect);
        assert!(hc.fixed_overhead_bytes() > 0);
    }

    fn paged_cfg(page_blocks: usize) -> SelfIndexConfig {
        SelfIndexConfig { page_blocks, ..Default::default() }
    }

    /// A popcount-scorer query in the serving path's exact form:
    /// random sign nibbles → packed bytes → word-packed u64 row.
    fn rand_q_words(r: &mut Rng, dim: usize) -> Vec<u64> {
        let codes: Vec<u8> = (0..dim / 4).map(|_| r.below(16) as u8).collect();
        let mut packed = Vec::new();
        pack::pack_codes_into(&codes, &mut packed);
        pack::pack_signs_u64(&packed, 1, dim / 8)
    }

    #[test]
    fn paged_stream_select_is_bit_identical_to_flat() {
        // the tentpole's hard guarantee: for ANY k / page size / sink
        // geometry / end clamp, sketch-bounded page skipping selects
        // exactly the flat sweep's set, in the same order
        let mut r = Rng::new(21);
        let mgr = mk_mgr(256); // block_tokens = 16
        let pool = mgr.pool();
        let keys = rand_rows(&mut r, 600, 64);
        let vals = rand_rows(&mut r, 600, 64);
        let mut flat = HeadCache::new(64, paged_cfg(0));
        flat.ingest_prefill(&mgr, &keys, &vals, 0).unwrap();
        let sink_sets: [Vec<u32>; 3] = [vec![], vec![0, 5, 31, 32, 100, 599], (0..64).collect()];
        let (mut scratch_a, mut scratch_b) = (Vec::new(), Vec::new());
        let (mut sel_a, mut sel_b) = (TopKStream::new(0), TopKStream::new(0));
        let (mut out_a, mut out_b) = (Vec::new(), Vec::new());
        for &pb in &[1usize, 2, 3, 5, 64] {
            let mut paged = HeadCache::new(64, paged_cfg(pb));
            paged.ingest_prefill(&mgr, &keys, &vals, 0).unwrap();
            assert_eq!(paged.pages(), 600 / (pb * 16), "closed pages at pb={pb}");
            for &k in &[0usize, 1, 17, 96, 600] {
                for &end in &[600usize, 599, 333, 16, 1] {
                    for sink_ids in &sink_sets {
                        let q_words = rand_q_words(&mut r, 64);
                        let scorer = BlockScorer::Popcnt {
                            q_words: &q_words,
                            dim: 64,
                        };
                        flat.stream_select(
                            pool,
                            &scorer,
                            end,
                            sink_ids,
                            k,
                            &mut scratch_a,
                            &mut sel_a,
                            &mut out_a,
                        );
                        paged.stream_select(
                            pool,
                            &scorer,
                            end,
                            sink_ids,
                            k,
                            &mut scratch_b,
                            &mut sel_b,
                            &mut out_b,
                        );
                        assert_eq!(out_a, out_b, "pb={pb} k={k} end={end}");
                    }
                }
            }
            let (scanned, skipped) = paged.page_stats();
            assert!(skipped <= scanned);
            paged.free(pool);
        }
        flat.free(pool);
    }

    #[test]
    fn adopted_prefix_blocks_feed_the_page_index() {
        // a second cache that ADOPTS registered full blocks (bypassing
        // push_record entirely) must build the same page summaries as the
        // cache that encoded them
        let mut r = Rng::new(22);
        let mgr = mk_mgr(64);
        let pool = mgr.pool();
        let keys = rand_rows(&mut r, 96, 64); // 6 full blocks = 3 pages of 2
        let vals = rand_rows(&mut r, 96, 64);
        let mut a = HeadCache::new(64, paged_cfg(2));
        a.ingest_prefill(&mgr, &keys, &vals, 0).unwrap();
        let mut b = HeadCache::new(64, paged_cfg(2));
        b.ingest_prefill(&mgr, &keys, &vals, 0).unwrap();
        assert!(mgr.prefix_hits() >= 6, "second ingest adopts every block");
        assert_eq!(a.pages(), 3);
        assert_eq!(a.page_m, b.page_m, "adopted sketches match encoded ones");
        assert_eq!(a.page_r, b.page_r);
        a.free(pool);
        b.free(pool);
    }

    #[test]
    fn page_index_rebuilds_after_swap_roundtrip() {
        use crate::kvcache::tier::{HostTier, SwapIn};
        let mut r = Rng::new(23);
        let mgr = mk_mgr(64);
        let pool = mgr.pool();
        let mut hc = HeadCache::new(64, paged_cfg(2));
        // 6 full blocks + a ragged tail → 3 closed pages + an open one
        hc.ingest_prefill(&mgr, &rand_rows(&mut r, 100, 64), &rand_rows(&mut r, 100, 64), 0)
            .unwrap();
        assert_eq!(hc.pages(), 3);
        let m0 = hc.page_m.clone();
        let r0 = hc.page_r.clone();
        let tier = HostTier::new();
        let blocks = hc.take_blocks_for_swap();
        assert_eq!(hc.pages(), 0, "derived summaries drop with the table");
        tier.swap_out(9, pool, &blocks).unwrap();
        for id in blocks {
            pool.release(id);
        }
        let SwapIn::Restored(back) = tier.swap_in(9, pool) else {
            panic!("clean swap-in restores");
        };
        hc.restore_blocks(back, pool);
        assert_eq!(hc.page_m, m0, "bit-exact restore rebuilds equal sketches");
        assert_eq!(hc.page_r, r0);
        hc.free(pool);
    }
}
