//! Shared trait-conformance suite, run over all seven methods through the
//! sequence-level [`SequenceCache`] API (see `tests/conformance.rs`).
//!
//! Per method it asserts:
//! * the registry-built [`SequenceCache`] is **bit-exact** with driving
//!   the same per-head leaves by hand, both through the serial
//!   `attend_step` entry and through the parallel
//!   `DecodeWorkQueue`/`ThreadPool::for_each_task` fan-out (the adapter
//!   and work queue add no arithmetic of their own);
//! * `memory_bytes` is monotone under decode appends at quant-group
//!   granularity (64-append windows — methods like KIVI transiently
//!   shrink when a residual group compresses);
//! * `attend` with budget ≥ len matches dense full attention within a
//!   per-method tolerance (lossless methods ≈ exactly, quantized ones
//!   within their quant-error bar);
//! * where appends are contractually equivalent to a longer prefill
//!   (full / quest / kivi), prefill(T)+append(m) equals prefill(T+m).

use std::sync::Arc;

use super::plan::{DecodePlan, DecodeWorkQueue, HeadTask};
use super::registry::{self, BuildCtx};
use super::SequenceCache;
use crate::baselines::AttentionMethod;
use crate::eval::cosine;
use crate::kvcache::manager::KvManager;
use crate::selfindex::SelfIndexConfig;
use crate::substrate::exec::ThreadPool;
use crate::substrate::rng::Rng;

const DIM: usize = 64;
const LAYERS: usize = 2;
const KVH: usize = 2;
const R: usize = 2;
/// prefill tokens per head
const T: usize = 192;
/// decode steps for the memory-monotonicity window check
const MEM_STEPS: usize = 96;
/// window at which memory must be monotone (≥ KIVI's 2× token group)
const MEM_WINDOW: usize = 64;

/// One method's conformance expectations.
pub struct Conformance {
    pub method: &'static str,
    /// cosine bar for budget ≥ len attention vs dense full attention
    pub dense_cosine: f64,
    /// prefill(T)+append(m) must equal prefill(T+m) exactly
    pub append_equiv_prefill: bool,
}

/// All seven methods.
pub const SUITE: &[Conformance] = &[
    Conformance {
        method: "selfindex",
        dense_cosine: 0.80,
        append_equiv_prefill: false, // mu/alpha/codebook freeze at prefill
    },
    Conformance {
        method: "full",
        dense_cosine: 0.999,
        append_equiv_prefill: true,
    },
    Conformance {
        method: "kivi",
        dense_cosine: 0.90,
        append_equiv_prefill: true, // identical token-group boundaries
    },
    Conformance {
        method: "snapkv",
        dense_cosine: 0.999, // suite builds with keep = prompt length
        append_equiv_prefill: false, // pruning is a prefill-time decision
    },
    Conformance {
        method: "quest",
        dense_cosine: 0.999,
        append_equiv_prefill: true, // incremental min/max == rebuilt index
    },
    Conformance {
        method: "doublesparse",
        dense_cosine: 0.999,
        append_equiv_prefill: false, // heavy channels freeze at prefill
    },
    Conformance {
        method: "kmeans",
        dense_cosine: 0.999,
        append_equiv_prefill: false, // codebook freezes at prefill
    },
];

/// Run the full suite for one method by registry name.
pub fn run_named(name: &str) {
    let case = SUITE
        .iter()
        .find(|c| c.method == name)
        .unwrap_or_else(|| panic!("no conformance case for '{name}'"));
    run(case);
}

/// Run every check for one method.
pub fn run(case: &Conformance) {
    adapter_is_exact(case);
    memory_monotone_under_append(case);
    full_budget_matches_dense(case);
    if case.append_equiv_prefill {
        append_equals_longer_prefill(case);
    }
}

/// One shared manager per built context — the suite exercises the
/// engine's ownership shape (seq cache and hand-driven leaves borrowing
/// the same pool; identical per-head prefills adopt each other's prefix
/// blocks, which the bit-exactness checks implicitly verify).
fn mgr() -> Arc<KvManager> {
    Arc::new(KvManager::for_head(DIM, &SelfIndexConfig::default(), 64, 1024))
}

fn ctx<'a>(
    si: &'a SelfIndexConfig,
    overlay: &'a [(String, crate::substrate::json::Json)],
    mgr: &'a Arc<KvManager>,
) -> BuildCtx<'a> {
    BuildCtx {
        dim: DIM,
        n_layers: LAYERS,
        kv_heads: KVH,
        gqa_ratio: R,
        budget_hint: T,
        mgr,
        selfindex: si,
        overlay,
        prompt_hash: 0,
    }
}

/// Clustered keys with three query-aligned needle rows (peaked attention,
/// so output-space comparisons are stable) and strong needle values.
fn head_state(seed: u64, tokens: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(seed);
    let n_dir = 8;
    let mag = 4.0f32;
    let mut dirs = vec![0.0f32; n_dir * DIM];
    for d in dirs.chunks_exact_mut(DIM) {
        let mut norm = 0.0;
        for x in d.iter_mut() {
            *x = r.normal_f32();
            norm += *x * *x;
        }
        let inv = 1.0 / norm.sqrt();
        for x in d.iter_mut() {
            *x *= inv;
        }
    }
    let mut keys = vec![0.0f32; tokens * DIM];
    for t in 0..tokens {
        let c = r.below(n_dir as u64) as usize;
        for j in 0..DIM {
            keys[t * DIM + j] = mag * dirs[c * DIM + j] + 0.5 * r.normal_f32();
        }
    }
    let mut vals: Vec<f32> = (0..tokens * DIM).map(|_| r.normal_f32()).collect();
    let query: Vec<f32> = (0..DIM)
        .map(|j| mag * dirs[j] + 0.3 * r.normal_f32())
        .collect();
    for needle in [tokens / 4, tokens / 2, 3 * tokens / 4] {
        for j in 0..DIM {
            keys[needle * DIM + j] = 2.5 * query[j];
            // strong structured values so 2-bit V quantization error stays
            // small relative to the signal
            vals[needle * DIM + j] = if j % 2 == 0 { 3.0 } else { -3.0 };
        }
    }
    (keys, vals, query)
}

/// kv-head-major prefill buffers for one layer + the per-head queries.
fn layer_state(layer: usize, tokens: usize) -> (Vec<f32>, Vec<f32>, Vec<Vec<f32>>) {
    let mut keys = Vec::with_capacity(KVH * tokens * DIM);
    let mut vals = Vec::with_capacity(KVH * tokens * DIM);
    let mut queries = Vec::with_capacity(KVH);
    for head in 0..KVH {
        let (k, v, q) = head_state(1000 + (layer * KVH + head) as u64, tokens);
        keys.extend_from_slice(&k);
        vals.extend_from_slice(&v);
        queries.push(q);
    }
    (keys, vals, queries)
}

/// One decode step's staged inputs for one layer: new K/V rows per head
/// and the GQA query groups (needle-aligned per head).
struct StepState {
    k_rows: Vec<f32>,
    v_rows: Vec<f32>,
    queries: Vec<f32>,
}

fn step_state(step: usize, layer: usize, head_queries: &[Vec<f32>]) -> StepState {
    let mut r = Rng::new(7000 + (step * LAYERS + layer) as u64);
    let k_rows: Vec<f32> = (0..KVH * DIM).map(|_| r.normal_f32()).collect();
    let v_rows: Vec<f32> = (0..KVH * DIM).map(|_| r.normal_f32()).collect();
    let mut queries = Vec::with_capacity(KVH * R * DIM);
    for q in head_queries.iter().take(KVH) {
        for _ in 0..R {
            queries.extend_from_slice(q);
        }
    }
    StepState {
        k_rows,
        v_rows,
        queries,
    }
}

fn plan<'a>(layer: usize, budget: usize, st: &'a StepState) -> DecodePlan<'a> {
    DecodePlan {
        layer,
        dim: DIM,
        kv_heads: KVH,
        gqa_ratio: R,
        budget,
        k_rows: &st.k_rows,
        v_rows: &st.v_rows,
        queries: &st.queries,
    }
}

/// Build a registry seq cache + hand-driven leaves over identical data;
/// prefill both.
fn build_pair(
    name: &str,
) -> (Box<dyn SequenceCache>, Vec<Box<dyn AttentionMethod>>, Vec<Vec<f32>>) {
    let si = SelfIndexConfig::default();
    let overlay = vec![];
    let entry = registry::lookup(name).expect("registered");
    let m = mgr();
    let c = ctx(&si, &overlay, &m);
    let mut seq = entry.build_seq(&c);
    assert_eq!(seq.method_name(), name);
    assert_eq!(seq.n_layers(), LAYERS);
    assert_eq!(seq.kv_heads(), KVH);

    let mut leaves: Vec<Box<dyn AttentionMethod>> = Vec::new();
    let mut all_queries = Vec::new();
    for layer in 0..LAYERS {
        let (keys, vals, queries) = layer_state(layer, T);
        seq.prefill_layer(layer, &keys, &vals, &[]);
        for head in 0..KVH {
            let mut leaf = entry.build_head(&c);
            leaf.prefill(
                &keys[head * T * DIM..(head + 1) * T * DIM],
                &vals[head * T * DIM..(head + 1) * T * DIM],
                &[],
                R,
            );
            leaves.push(leaf);
        }
        all_queries.extend(queries);
    }
    (seq, leaves, all_queries)
}

/// The adapter and the parallel work queue are bit-exact with driving the
/// per-head leaves by hand.
fn adapter_is_exact(case: &Conformance) {
    let (mut seq, mut leaves, queries) = build_pair(case.method);
    let (mut par_seq, _, _) = build_pair(case.method);
    let pool = ThreadPool::new(3);
    let mut wq = DecodeWorkQueue::new();
    let budget = 96;

    let mut seq_out = vec![0.0f32; KVH * R * DIM];
    let mut par_out = vec![0.0f32; KVH * R * DIM];
    let mut leaf_out = vec![0.0f32; KVH * R * DIM];
    for step in 0..4 {
        for layer in 0..LAYERS {
            let head_queries = &queries[layer * KVH..(layer + 1) * KVH];
            let st = step_state(step, layer, head_queries);

            seq_out.fill(0.0);
            seq.attend_step(&plan(layer, budget, &st), &mut seq_out);

            par_out.fill(0.0);
            let mut tasks: Vec<HeadTask<'_>> = wq.take();
            par_seq.push_tasks(&plan(layer, budget, &st), &mut par_out, &mut tasks);
            assert_eq!(tasks.len(), KVH, "one task per kv head");
            wq.dispatch(&pool, tasks);

            leaf_out.fill(0.0);
            for head in 0..KVH {
                let m = &mut leaves[layer * KVH + head];
                m.append(
                    &st.k_rows[head * DIM..(head + 1) * DIM],
                    &st.v_rows[head * DIM..(head + 1) * DIM],
                );
                m.attend_group(
                    &st.queries[head * R * DIM..(head + 1) * R * DIM],
                    DIM,
                    budget,
                    &mut leaf_out[head * R * DIM..(head + 1) * R * DIM],
                );
            }

            assert_eq!(
                seq_out, leaf_out,
                "[{}] attend_step must be bit-exact with hand-driven leaves \
                 (step {step}, layer {layer})",
                case.method
            );
            assert_eq!(
                par_out, leaf_out,
                "[{}] work-queue fan-out must be bit-exact with hand-driven \
                 leaves (step {step}, layer {layer})",
                case.method
            );
        }
    }
    let leaf_bytes: usize = leaves.iter().map(|m| m.memory_bytes()).sum();
    assert_eq!(seq.memory_bytes(), leaf_bytes, "[{}] memory", case.method);
}

/// `memory_bytes` is monotone under appends at 64-append windows (and
/// strictly grows end to end).
fn memory_monotone_under_append(case: &Conformance) {
    let (mut seq, _, queries) = build_pair(case.method);
    let mut out = vec![0.0f32; KVH * R * DIM];
    let mut mem = Vec::with_capacity(MEM_STEPS + 1);
    mem.push(seq.memory_bytes());
    assert!(mem[0] > 0, "[{}] empty accounting", case.method);
    for step in 0..MEM_STEPS {
        for layer in 0..LAYERS {
            let head_queries = &queries[layer * KVH..(layer + 1) * KVH];
            let st = step_state(step, layer, head_queries);
            seq.attend_step(&plan(layer, 96, &st), &mut out);
        }
        mem.push(seq.memory_bytes());
    }
    for i in 0..mem.len() - MEM_WINDOW {
        assert!(
            mem[i + MEM_WINDOW] >= mem[i],
            "[{}] memory shrank over a {MEM_WINDOW}-append window: \
             {} -> {} at step {i}",
            case.method,
            mem[i],
            mem[i + MEM_WINDOW]
        );
    }
    let last = *mem.last().unwrap();
    assert!(
        last > mem[0],
        "[{}] {MEM_STEPS} appends did not grow memory: {} -> {last}",
        case.method,
        mem[0]
    );
}

/// With budget ≥ context length, one decode step's attention matches
/// dense full attention within the method's tolerance.
fn full_budget_matches_dense(case: &Conformance) {
    let (mut seq, _, queries) = build_pair(case.method);
    let mut out = vec![0.0f32; KVH * R * DIM];
    for layer in 0..LAYERS {
        let head_queries = &queries[layer * KVH..(layer + 1) * KVH];
        let st = step_state(0, layer, head_queries);
        out.fill(0.0);
        seq.attend_step(&plan(layer, usize::MAX, &st), &mut out);

        // dense reference per head over the identical token stream
        let (keys, vals, _) = layer_state(layer, T);
        for head in 0..KVH {
            let mut full = crate::baselines::FullCache::new(DIM);
            full.prefill(
                &keys[head * T * DIM..(head + 1) * T * DIM],
                &vals[head * T * DIM..(head + 1) * T * DIM],
                &[],
                R,
            );
            full.append(
                &st.k_rows[head * DIM..(head + 1) * DIM],
                &st.v_rows[head * DIM..(head + 1) * DIM],
            );
            let mut reference = vec![0.0f32; DIM];
            for ri in 0..R {
                let q = &st.queries[(head * R + ri) * DIM..(head * R + ri + 1) * DIM];
                full.attend(q, usize::MAX, &mut reference);
                let got = &out[(head * R + ri) * DIM..(head * R + ri + 1) * DIM];
                let c = cosine(got, &reference);
                assert!(
                    c >= case.dense_cosine,
                    "[{}] budget≥len cosine {c:.4} < {:.4} \
                     (layer {layer}, head {head}, r {ri})",
                    case.method,
                    case.dense_cosine
                );
            }
        }
    }
}

/// prefill(T) + m appends ≡ prefill(T+m), for methods whose append is
/// contractually a longer prefill.
fn append_equals_longer_prefill(case: &Conformance) {
    let si = SelfIndexConfig::default();
    let overlay = vec![];
    let entry = registry::lookup(case.method).expect("registered");
    let m = mgr();
    let c = ctx(&si, &overlay, &m);
    let m = 24;
    let (keys, vals, query) = head_state(42, T + m);

    let mut a = entry.build_head(&c);
    a.prefill(&keys[..T * DIM], &vals[..T * DIM], &[], R);
    for t in T..T + m {
        a.append(&keys[t * DIM..(t + 1) * DIM], &vals[t * DIM..(t + 1) * DIM]);
    }
    let mut b = entry.build_head(&c);
    b.prefill(&keys, &vals, &[], R);

    assert_eq!(a.memory_bytes(), b.memory_bytes(), "[{}]", case.method);
    let mut out_a = vec![0.0f32; DIM];
    let mut out_b = vec![0.0f32; DIM];
    a.attend(&query, 96, &mut out_a);
    b.attend(&query, 96, &mut out_b);
    for (x, y) in out_a.iter().zip(&out_b) {
        assert!(
            (x - y).abs() <= 1e-5,
            "[{}] append≠re-prefill: {x} vs {y}",
            case.method
        );
    }
}
