//! [`PerHeadSeqCache`]: the mechanical migration path from the per-head
//! [`AttentionMethod`] trait to the sequence-level [`SequenceCache`] API.
//! Owns every (layer, kv-head) leaf in one layer-major arena and expands
//! decode plans into one [`HeadTask`] per kv head.

use super::plan::{DecodePlan, HeadTask};
use super::registry::BuildCtx;
use super::SequenceCache;
use crate::baselines::AttentionMethod;

/// All of one sequence's cache state for a per-head method: a layer-major
/// arena `heads[layer * kv_heads + head]` of independent leaves. Methods
/// that need cross-head state (shared page metadata, shared codebooks)
/// implement [`SequenceCache`] directly instead.
pub struct PerHeadSeqCache<M: AttentionMethod> {
    name: &'static str,
    dim: usize,
    n_layers: usize,
    kv_heads: usize,
    gqa_ratio: usize,
    heads: Vec<M>,
}

impl<M: AttentionMethod> PerHeadSeqCache<M> {
    /// Build one leaf per (layer, kv head) from `leaf`. `name` is the
    /// registry's canonical method name (leaves may report historical
    /// spellings, e.g. KIVI's "kivi2").
    pub fn build(name: &'static str, ctx: &BuildCtx, mut leaf: impl FnMut() -> M) -> Self {
        let n = ctx.n_layers * ctx.kv_heads;
        assert!(n > 0, "degenerate geometry: {n} heads");
        let mut heads = Vec::with_capacity(n);
        for _ in 0..n {
            heads.push(leaf());
        }
        Self {
            name,
            dim: ctx.dim,
            n_layers: ctx.n_layers,
            kv_heads: ctx.kv_heads,
            gqa_ratio: ctx.gqa_ratio,
            heads,
        }
    }

    pub fn head(&self, layer: usize, head: usize) -> &M {
        &self.heads[layer * self.kv_heads + head]
    }

    pub fn head_mut(&mut self, layer: usize, head: usize) -> &mut M {
        &mut self.heads[layer * self.kv_heads + head]
    }

    pub fn heads(&self) -> &[M] {
        &self.heads
    }
}

impl<M: AttentionMethod> SequenceCache for PerHeadSeqCache<M> {
    fn method_name(&self) -> &'static str {
        self.name
    }

    fn n_layers(&self) -> usize {
        self.n_layers
    }

    fn kv_heads(&self) -> usize {
        self.kv_heads
    }

    fn prefill_layer(&mut self, layer: usize, keys: &[f32], vals: &[f32], q_window: &[f32]) {
        let kvh = self.kv_heads;
        let r = self.gqa_ratio;
        assert_eq!(keys.len(), vals.len());
        assert_eq!(keys.len() % (kvh * self.dim), 0, "keys not (kvh × T × dim)");
        assert_eq!(q_window.len() % kvh, 0, "q_window not head-major");
        let per_head = keys.len() / kvh;
        let qw_per_head = q_window.len() / kvh;
        for (head, m) in self.heads[layer * kvh..(layer + 1) * kvh]
            .iter_mut()
            .enumerate()
        {
            m.prefill(
                &keys[head * per_head..(head + 1) * per_head],
                &vals[head * per_head..(head + 1) * per_head],
                &q_window[head * qw_per_head..(head + 1) * qw_per_head],
                r,
            );
        }
    }

    fn push_tasks<'t>(
        &'t mut self,
        plan: &DecodePlan<'t>,
        out: &'t mut [f32],
        tasks: &mut Vec<HeadTask<'t>>,
    ) {
        let dim = self.dim;
        let kvh = self.kv_heads;
        let r = plan.gqa_ratio;
        debug_assert_eq!(kvh, plan.kv_heads);
        debug_assert_eq!(r, self.gqa_ratio);
        assert_eq!(out.len(), kvh * r * dim, "out not (kvh × R × dim)");
        assert_eq!(plan.k_rows.len(), kvh * dim);
        assert_eq!(plan.queries.len(), kvh * r * dim);
        let heads_l = &mut self.heads[plan.layer * kvh..(plan.layer + 1) * kvh];
        for ((head, m), o) in heads_l
            .iter_mut()
            .enumerate()
            .zip(out.chunks_exact_mut(r * dim))
        {
            tasks.push(HeadTask {
                method: m,
                k_row: &plan.k_rows[head * dim..(head + 1) * dim],
                v_row: &plan.v_rows[head * dim..(head + 1) * dim],
                queries: &plan.queries[head * r * dim..(head + 1) * r * dim],
                dim,
                budget: plan.budget,
                out: o,
                failed: false,
                panicked: false,
            });
        }
    }

    fn memory_bytes(&self) -> usize {
        self.heads.iter().map(|m| m.memory_bytes()).sum()
    }

    fn step_blocks(&self) -> usize {
        self.heads.iter().map(|m| m.blocks_for_append()).sum()
    }

    fn pool_payload_bytes(&self) -> usize {
        self.heads.iter().map(|m| m.pool_payload_bytes()).sum()
    }
}
