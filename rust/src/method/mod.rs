//! The engine↔method boundary: sequence-level caches built by a method
//! registry, driven through a slice-based decode work queue.
//!
//! Two levels:
//!
//! * [`CacheMethod`] (registry, [`registry`]) — a method's identity
//!   (name + aliases), its config knobs, and its builders. The registry
//!   replaces the old hardcoded `MethodKind::make` match: lookup is
//!   case-insensitive and unknown names error with the known list.
//! * [`SequenceCache`] — owns **all** (layer, kv-head) cache state for
//!   one sequence. The engine talks only to this trait: `prefill_layer`
//!   per layer at admission, then per decode step a [`DecodePlan`] per
//!   sequence that `push_tasks` expands into [`HeadTask`]s executed over
//!   `ThreadPool::for_each_task` — an atomic cursor over the pre-built
//!   task slice, no per-job closure boxing, zero steady-state heap
//!   allocations in the engine layer (see [`DecodeWorkQueue`]).
//!
//! The per-head [`AttentionMethod`] trait stays as the leaf
//! implementation: all seven baselines migrate mechanically through
//! [`PerHeadSeqCache`], while methods that want cross-head state (shared
//! page metadata, shared codebooks — cf. Quest/DoubleSparse variants)
//! implement [`SequenceCache`] directly.
//!
//! [`AttentionMethod`]: crate::baselines::AttentionMethod

pub mod conformance;
pub mod per_head;
pub mod plan;
pub mod registry;

pub use per_head::PerHeadSeqCache;
pub use plan::{DecodePlan, DecodeWorkQueue, HeadTask};
pub use registry::{entries, lookup, BuildCtx, CacheMethod, Knob, UnknownMethod};

/// One sequence's whole cache: every (layer, kv-head)'s state behind one
/// object, stored layer-major. `Send` so the engine can move sequences
/// across steps while decode tasks fan out over the worker pool.
pub trait SequenceCache: Send {
    /// Canonical method name (matches the registry entry).
    fn method_name(&self) -> &'static str;

    fn n_layers(&self) -> usize;

    fn kv_heads(&self) -> usize;

    /// Ingest one layer of the prompt. `keys`/`vals` are kv-head-major
    /// `(kv_heads × tokens × dim)` post-RoPE rows; `q_window` is the
    /// head-major SnapKV observation window
    /// `(kv_heads × W·gqa_ratio × dim)` (may be empty).
    fn prefill_layer(&mut self, layer: usize, keys: &[f32], vals: &[f32], q_window: &[f32]);

    /// Expand one decode step's plan for one layer into per-head tasks
    /// (append + budgeted GQA attention into disjoint chunks of `out`,
    /// which is `(kv_heads × gqa_ratio × dim)`).
    fn push_tasks<'t>(
        &'t mut self,
        plan: &DecodePlan<'t>,
        out: &'t mut [f32],
        tasks: &mut Vec<HeadTask<'t>>,
    );

    /// Context-size-dependent cache bytes across every (layer, kv head).
    fn memory_bytes(&self) -> usize;

    /// Shared-pool blocks the next decode step will allocate across every
    /// (layer, kv head) — the exact-occupancy input the scheduler checks
    /// before fanning the step out (preempting when it cannot fit). 0 for
    /// methods that don't store into the engine pool.
    fn step_blocks(&self) -> usize {
        0
    }

    /// Bytes of [`Self::memory_bytes`] that live in the engine's shared
    /// block pool, counted per holder; the engine replaces the sum of
    /// these with `pool.used_bytes()` so prefix-shared blocks count once.
    fn pool_payload_bytes(&self) -> usize {
        0
    }

    /// Run one decode step's layer inline (the serial entry point used by
    /// tests and single-threaded callers; the engine fans the same tasks
    /// out over its worker pool instead).
    ///
    /// Panics on pool exhaustion: serial callers have no preemption path,
    /// so a failed append must surface loudly here — silently dropping a
    /// task's `failed` flag would desync head lengths across the sequence.
    /// Callers that preempt (the engine) inspect the flags themselves.
    fn attend_step(&mut self, plan: &DecodePlan<'_>, out: &mut [f32]) {
        let mut tasks = Vec::new();
        self.push_tasks(plan, out, &mut tasks);
        for t in &mut tasks {
            t.run();
            assert!(
                !t.failed,
                "kv pool exhausted in attend_step (layer {}) — check step_blocks() \
                 against free_blocks() and preempt before stepping",
                plan.layer
            );
        }
    }
}

/// Which attention/cache method the engine serves with. The closed enum
/// the benches/tests name directly; the open set lives in [`registry`] —
/// `parse` goes through it, so aliases and case-insensitivity (and the
/// helpful unknown-name error) come from one place.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    SelfIndex,
    Full,
    Kivi,
    SnapKv,
    Quest,
    DoubleSparse,
    KMeans,
}

impl MethodKind {
    pub const ALL: [MethodKind; 7] = [
        MethodKind::SelfIndex,
        MethodKind::Full,
        MethodKind::Kivi,
        MethodKind::SnapKv,
        MethodKind::Quest,
        MethodKind::DoubleSparse,
        MethodKind::KMeans,
    ];

    /// Canonical registry name.
    pub fn name(self) -> &'static str {
        match self {
            MethodKind::SelfIndex => "selfindex",
            MethodKind::Full => "full",
            MethodKind::Kivi => "kivi",
            MethodKind::SnapKv => "snapkv",
            MethodKind::Quest => "quest",
            MethodKind::DoubleSparse => "doublesparse",
            MethodKind::KMeans => "kmeans",
        }
    }

    /// Case-insensitive parse by name or alias; unknown names report the
    /// full known list. A method registered without a `MethodKind`
    /// variant (an out-of-enum `CacheMethod`) errors rather than panics —
    /// such methods are reachable through the registry API directly.
    pub fn parse(s: &str) -> Result<Self, UnknownMethod> {
        let entry = registry::lookup(s)?;
        Self::ALL
            .into_iter()
            .find(|k| k.name() == entry.name())
            .ok_or_else(|| UnknownMethod {
                query: format!("{} (registered, but not exposed as a MethodKind)", entry.name()),
            })
    }

    /// This kind's registry entry.
    pub fn entry(self) -> &'static dyn CacheMethod {
        registry::lookup(self.name()).expect("built-in method is registered")
    }
}

impl std::fmt::Display for MethodKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_kind() {
        for kind in MethodKind::ALL {
            assert_eq!(MethodKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(kind.entry().name(), kind.name());
        }
    }

    #[test]
    fn every_registry_entry_has_a_kind() {
        // guards the enum↔registry correspondence: adding a CacheMethod
        // without a MethodKind variant must be a conscious decision (the
        // method stays registry-only), not an accident that breaks parse
        for entry in registry::entries() {
            assert_eq!(
                MethodKind::parse(entry.name()).unwrap().name(),
                entry.name(),
                "registry entry '{}' has no MethodKind variant",
                entry.name()
            );
        }
    }

    #[test]
    fn parse_accepts_aliases_and_mixed_case() {
        assert_eq!(MethodKind::parse("Ours").unwrap(), MethodKind::SelfIndex);
        assert_eq!(MethodKind::parse("FA2").unwrap(), MethodKind::Full);
        assert_eq!(MethodKind::parse("ds").unwrap(), MethodKind::DoubleSparse);
        assert_eq!(MethodKind::parse("KMeans").unwrap(), MethodKind::KMeans);
    }

    #[test]
    fn parse_unknown_reports_known_list() {
        let err = MethodKind::parse("h2o").unwrap_err().to_string();
        assert!(err.contains("unknown method 'h2o'"), "{err}");
        assert!(err.contains("selfindex"), "{err}");
        assert!(err.contains("doublesparse"), "{err}");
    }
}
