//! The [`CacheMethod`] registry: methods register a canonical name,
//! aliases, and their config knobs, and build whole-sequence caches —
//! replacing the old hardcoded `MethodKind::make` match. Lookup is
//! case-insensitive and unknown names error with the full known list, so
//! a CLI typo tells the operator what exists instead of failing silently.

use std::fmt;
use std::sync::Arc;

use super::per_head::PerHeadSeqCache;
use super::SequenceCache;
use crate::baselines::{
    AttentionMethod, DoubleSparse, FullCache, KMeansCache, KiviCache, QuestCache, SelfIndexing,
    SnapKv,
};
use crate::kvcache::manager::KvManager;
use crate::selfindex::SelfIndexConfig;
use crate::substrate::json::Json;

/// One tunable a method exposes through the per-method config overlay
/// (`EngineConfig::method_overlay`).
pub struct Knob {
    pub name: &'static str,
    pub doc: &'static str,
    pub default: &'static str,
    pub kind: KnobKind,
}

/// What values a knob accepts — checked by [`validate_overlay`] so a
/// wrong-typed or out-of-range overlay value errors at config time
/// instead of silently falling back to the default.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnobKind {
    Usize,
    Bool,
    /// a quantization bit width the packers support
    Bits,
    /// a string drawn from a fixed set of variants
    Choice(&'static [&'static str]),
}

impl KnobKind {
    fn check(self, v: &Json) -> Result<(), String> {
        match self {
            KnobKind::Usize => v
                .as_usize()
                .map(|_| ())
                .ok_or_else(|| "expects a non-negative integer".to_string()),
            KnobKind::Bool => v
                .as_bool()
                .map(|_| ())
                .ok_or_else(|| "expects true/false".to_string()),
            KnobKind::Bits => match v.as_usize() {
                Some(2) | Some(4) | Some(8) => Ok(()),
                _ => Err("expects a bit width of 2, 4, or 8".to_string()),
            },
            KnobKind::Choice(variants) => match v.as_str() {
                Some(s) if variants.contains(&s) => Ok(()),
                _ => Err(format!("expects one of {}", variants.join(", "))),
            },
        }
    }
}

/// Everything a method needs to build one sequence's cache: the model
/// geometry, the engine's budget hint, the selfindex paper knobs, and the
/// validated per-method overlay.
pub struct BuildCtx<'a> {
    pub dim: usize,
    pub n_layers: usize,
    pub kv_heads: usize,
    pub gqa_ratio: usize,
    /// engine budget hint at prefill time (e.g. SnapKV's static keep set)
    pub budget_hint: usize,
    /// the engine-wide memory manager: ONE shared block pool (plus the
    /// prefix-block registry) serves every sequence, layer, and kv head —
    /// pool-backed methods clone this `Arc` into each leaf
    pub mgr: &'a Arc<KvManager>,
    pub selfindex: &'a SelfIndexConfig,
    /// validated `(knob, value)` overlay for the selected method
    pub overlay: &'a [(String, Json)],
    /// router-interned content hash of this sequence's prompt (0 = none):
    /// pool-backed methods pass it down so prefill can memoize full-block
    /// content keys across re-prefills of the same prompt
    pub prompt_hash: u128,
}

impl BuildCtx<'_> {
    fn overlay_get(&self, name: &str) -> Option<&Json> {
        self.overlay
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v)
    }

    pub fn knob_usize(&self, name: &str, default: usize) -> usize {
        self.overlay_get(name)
            .and_then(Json::as_usize)
            .unwrap_or(default)
    }

    pub fn knob_bool(&self, name: &str, default: bool) -> bool {
        self.overlay_get(name)
            .and_then(Json::as_bool)
            .unwrap_or(default)
    }
}

/// Apply the selfindex method's overlay knobs to a base config — shared
/// by `build_head` and by the engine, which must size the shared pool's
/// record layout from the *resolved* config (a `quant_bits` overlay
/// changes the payload bytes per token).
pub fn selfindex_overlayed(
    base: &SelfIndexConfig,
    overlay: &[(String, Json)],
) -> SelfIndexConfig {
    let get = |name: &str| overlay.iter().find(|(k, _)| k == name).map(|(_, v)| v);
    let mut si = base.clone();
    if let Some(b) = get("quant_bits").and_then(Json::as_usize) {
        si.quant_bits = b as u32;
    }
    if let Some(s) = get("sink_tokens").and_then(Json::as_usize) {
        si.sink_tokens = s;
    }
    if let Some(u) = get("use_sinks").and_then(Json::as_bool) {
        si.use_sinks = u;
    }
    if let Some(k) = get("sparse_k").and_then(Json::as_usize) {
        si.sparse_k = k;
    }
    if let Some(sc) = get("scorer").and_then(Json::as_str) {
        // validate_overlay already constrained the string to the knob's
        // Choice set, so parse can only fail for hand-built overlays —
        // keep the base scorer in that case rather than panicking
        if let Some(sc) = crate::selfindex::Scorer::parse(sc) {
            si.scorer = sc;
        }
    }
    if let Some(p) = get("page_blocks").and_then(Json::as_usize) {
        si.page_blocks = p;
    }
    si
}

/// A registered cache method: identity + knobs + builders. `build_head`
/// is the per-head leaf (the mechanical migration path for all seven
/// baselines, wrapped by [`PerHeadSeqCache`]); methods with cross-head
/// state override `build_seq` and own the whole sequence directly.
pub trait CacheMethod: Sync {
    fn name(&self) -> &'static str;

    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    fn knobs(&self) -> &'static [Knob] {
        &[]
    }

    /// Build one per-head leaf.
    fn build_head(&self, ctx: &BuildCtx) -> Box<dyn AttentionMethod>;

    /// Build one whole sequence's cache (default: per-head leaves in a
    /// layer-major [`PerHeadSeqCache`] arena).
    fn build_seq(&self, ctx: &BuildCtx) -> Box<dyn SequenceCache> {
        Box::new(PerHeadSeqCache::build(self.name(), ctx, || {
            self.build_head(ctx)
        }))
    }

    /// Shared-pool blocks one (layer, kv-head) leaf needs to ingest a
    /// `prompt_len`-token prompt — the engine multiplies by
    /// `n_layers × kv_heads` for its exact-occupancy admission check.
    /// 0 for methods that don't store into the engine pool.
    fn head_blocks_for_prompt(&self, prompt_len: usize, block_tokens: usize) -> usize {
        let _ = (prompt_len, block_tokens);
        0
    }
}

/// Unknown method name, with the full known list in the message.
#[derive(Debug, Clone)]
pub struct UnknownMethod {
    pub query: String,
}

impl fmt::Display for UnknownMethod {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown method '{}' (known: {})",
            self.query,
            known_methods()
        )
    }
}

impl std::error::Error for UnknownMethod {}

// ---- the built-in methods -------------------------------------------------

struct SelfIndexMethod;
struct FullMethod;
struct KiviMethod;
struct SnapKvMethod;
struct QuestMethod;
struct DoubleSparseMethod;
struct KMeansMethod;

static SELFINDEX: SelfIndexMethod = SelfIndexMethod;
static FULL: FullMethod = FullMethod;
static KIVI: KiviMethod = KiviMethod;
static SNAPKV: SnapKvMethod = SnapKvMethod;
static QUEST: QuestMethod = QuestMethod;
static DOUBLESPARSE: DoubleSparseMethod = DoubleSparseMethod;
static KMEANS: KMeansMethod = KMeansMethod;

impl CacheMethod for SelfIndexMethod {
    fn name(&self) -> &'static str {
        "selfindex"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["ours", "si"]
    }

    fn knobs(&self) -> &'static [Knob] {
        &[
            Knob {
                name: "quant_bits",
                doc: "bits per quantized magnitude/value element",
                default: "2",
                kind: KnobKind::Bits,
            },
            Knob {
                name: "sink_tokens",
                doc: "full-precision sink tokens kept from prefill",
                default: "64",
                kind: KnobKind::Usize,
            },
            Knob {
                name: "use_sinks",
                doc: "keep SnapKV-selected sink tokens",
                default: "true",
                kind: KnobKind::Bool,
            },
            Knob {
                name: "sparse_k",
                doc: "dynamically retrieved tokens per decode step",
                default: "96",
                kind: KnobKind::Usize,
            },
            Knob {
                name: "scorer",
                doc: "decode-retrieval score kernel (byte-LUT oracle or \
                      XOR+popcount over word-packed sign codes)",
                default: "bytelut",
                kind: KnobKind::Choice(&["bytelut", "popcnt"]),
            },
            Knob {
                name: "page_blocks",
                doc: "blocks per hierarchical retrieval page under the \
                      popcount scorer (0 = flat sweep)",
                default: "64",
                kind: KnobKind::Usize,
            },
        ]
    }

    fn build_head(&self, ctx: &BuildCtx) -> Box<dyn AttentionMethod> {
        let si = selfindex_overlayed(ctx.selfindex, ctx.overlay);
        let mut m = SelfIndexing::with_manager(ctx.dim, si, Arc::clone(ctx.mgr));
        m.set_prompt_hash(ctx.prompt_hash);
        Box::new(m)
    }

    fn head_blocks_for_prompt(&self, prompt_len: usize, block_tokens: usize) -> usize {
        prompt_len.div_ceil(block_tokens)
    }
}

impl CacheMethod for FullMethod {
    fn name(&self) -> &'static str {
        "full"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["fa2", "dense"]
    }

    fn build_head(&self, ctx: &BuildCtx) -> Box<dyn AttentionMethod> {
        Box::new(FullCache::new(ctx.dim))
    }
}

impl CacheMethod for KiviMethod {
    fn name(&self) -> &'static str {
        "kivi"
    }

    fn knobs(&self) -> &'static [Knob] {
        &[Knob {
            name: "bits",
            doc: "quantization bits for K and V payloads",
            default: "selfindex.quant_bits",
            kind: KnobKind::Bits,
        }]
    }

    fn build_head(&self, ctx: &BuildCtx) -> Box<dyn AttentionMethod> {
        let bits = ctx.knob_usize("bits", ctx.selfindex.quant_bits as usize) as u32;
        Box::new(KiviCache::new(ctx.dim, bits))
    }
}

impl CacheMethod for SnapKvMethod {
    fn name(&self) -> &'static str {
        "snapkv"
    }

    fn knobs(&self) -> &'static [Knob] {
        &[Knob {
            name: "keep",
            doc: "tokens kept at prefill (the static budget)",
            default: "engine budget hint",
            kind: KnobKind::Usize,
        }]
    }

    fn build_head(&self, ctx: &BuildCtx) -> Box<dyn AttentionMethod> {
        let keep = ctx.knob_usize("keep", ctx.budget_hint);
        Box::new(SnapKv::new(ctx.dim, keep))
    }
}

impl CacheMethod for QuestMethod {
    fn name(&self) -> &'static str {
        "quest"
    }

    fn build_head(&self, ctx: &BuildCtx) -> Box<dyn AttentionMethod> {
        Box::new(QuestCache::new(ctx.dim))
    }
}

impl CacheMethod for DoubleSparseMethod {
    fn name(&self) -> &'static str {
        "doublesparse"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["ds"]
    }

    fn build_head(&self, ctx: &BuildCtx) -> Box<dyn AttentionMethod> {
        Box::new(DoubleSparse::new(ctx.dim))
    }
}

impl CacheMethod for KMeansMethod {
    fn name(&self) -> &'static str {
        "kmeans"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["pq"]
    }

    fn knobs(&self) -> &'static [Knob] {
        &[Knob {
            name: "iters",
            doc: "Lloyd iterations for the prefill codebook",
            default: "8",
            kind: KnobKind::Usize,
        }]
    }

    fn build_head(&self, ctx: &BuildCtx) -> Box<dyn AttentionMethod> {
        let iters = ctx.knob_usize("iters", crate::baselines::kmeans::KMEANS_ITERS);
        Box::new(KMeansCache::with_iters(ctx.dim, iters))
    }
}

// ---- lookup ---------------------------------------------------------------

/// Every registered method.
pub fn entries() -> [&'static dyn CacheMethod; 7] {
    [
        &SELFINDEX,
        &FULL,
        &KIVI,
        &SNAPKV,
        &QUEST,
        &DOUBLESPARSE,
        &KMEANS,
    ]
}

/// Case-insensitive lookup by canonical name or alias.
pub fn lookup(name: &str) -> Result<&'static dyn CacheMethod, UnknownMethod> {
    let q = name.trim().to_ascii_lowercase();
    entries()
        .into_iter()
        .find(|m| m.name() == q || m.aliases().contains(&q.as_str()))
        .ok_or_else(|| UnknownMethod {
            query: name.to_string(),
        })
}

/// Human-readable list of every method (+aliases) for error messages,
/// `--help`, and config validation failures.
pub fn known_methods() -> String {
    let mut out = String::new();
    for (i, m) in entries().into_iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(m.name());
        if !m.aliases().is_empty() {
            out.push_str(&format!(" (aliases: {})", m.aliases().join("|")));
        }
    }
    out
}

/// Validate a per-method overlay: the method must exist, every key must
/// be one of its declared knobs, and every value must satisfy the knob's
/// [`KnobKind`] — a wrong-typed or out-of-range value errors here instead
/// of silently building with the default.
pub fn validate_overlay(method: &str, overlay: &[(String, Json)]) -> Result<(), String> {
    let m = lookup(method).map_err(|e| e.to_string())?;
    for (k, v) in overlay {
        let Some(knob) = m.knobs().iter().find(|kn| kn.name == k) else {
            let known: Vec<&str> = m.knobs().iter().map(|kn| kn.name).collect();
            return Err(format!(
                "method '{}' has no knob '{k}' (knobs: {})",
                m.name(),
                if known.is_empty() {
                    "none".to_string()
                } else {
                    known.join(", ")
                }
            ));
        };
        knob.kind
            .check(v)
            .map_err(|e| format!("method '{}' knob '{k}': {e}", m.name()))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr_for(si: &SelfIndexConfig, overlay: &[(String, Json)]) -> Arc<KvManager> {
        // size the layout from the *resolved* config, exactly as the
        // engine does — a quant_bits overlay changes record widths
        let eff = selfindex_overlayed(si, overlay);
        Arc::new(KvManager::for_head(64, &eff, 64, 64))
    }

    fn ctx<'a>(
        si: &'a SelfIndexConfig,
        overlay: &'a [(String, Json)],
        mgr: &'a Arc<KvManager>,
    ) -> BuildCtx<'a> {
        BuildCtx {
            dim: 64,
            n_layers: 2,
            kv_heads: 2,
            gqa_ratio: 2,
            budget_hint: 128,
            mgr,
            selfindex: si,
            overlay,
            prompt_hash: 0,
        }
    }

    #[test]
    fn lookup_is_case_insensitive_and_alias_aware() {
        for name in ["selfindex", "SelfIndex", "OURS", "si", " ours "] {
            assert_eq!(lookup(name).unwrap().name(), "selfindex", "{name}");
        }
        assert_eq!(lookup("DS").unwrap().name(), "doublesparse");
        assert_eq!(lookup("FA2").unwrap().name(), "full");
        assert_eq!(lookup("pq").unwrap().name(), "kmeans");
    }

    #[test]
    fn unknown_method_error_lists_known_names() {
        let err = lookup("flashinfer").unwrap_err().to_string();
        assert!(err.contains("unknown method 'flashinfer'"), "{err}");
        for m in entries() {
            assert!(err.contains(m.name()), "{err} missing {}", m.name());
        }
    }

    #[test]
    fn every_entry_builds_a_seq_cache() {
        let si = SelfIndexConfig::default();
        let overlay = vec![];
        let mgr = mgr_for(&si, &overlay);
        for m in entries() {
            let cache = m.build_seq(&ctx(&si, &overlay, &mgr));
            assert_eq!(cache.method_name(), m.name(), "name mismatch");
            assert_eq!(cache.n_layers(), 2);
            assert_eq!(cache.kv_heads(), 2);
        }
    }

    #[test]
    fn overlay_knobs_flow_into_builds() {
        let si = SelfIndexConfig::default();
        let overlay = vec![("quant_bits".to_string(), Json::Num(8.0))];
        let mgr = mgr_for(&si, &overlay);
        let head = lookup("ours").unwrap().build_head(&ctx(&si, &overlay, &mgr));
        assert_eq!(head.name(), "selfindex");
        let overlay = vec![("keep".to_string(), Json::Num(7.0))];
        let mgr = mgr_for(&si, &[]);
        let mut head = lookup("snapkv").unwrap().build_head(&ctx(&si, &overlay, &mgr));
        let keys = vec![0.5f32; 32 * 64];
        head.prefill(&keys, &keys.clone(), &[], 1);
        assert_eq!(head.memory_bytes(), 7 * 64 * 2 * 4, "keep knob applied");
    }

    #[test]
    fn overlay_validation_rejects_unknown_knobs() {
        assert!(validate_overlay("quest", &[]).is_ok());
        let bad = vec![("page".to_string(), Json::Num(32.0))];
        let err = validate_overlay("quest", &bad).unwrap_err();
        assert!(err.contains("no knob 'page'"), "{err}");
        let good = vec![("iters".to_string(), Json::Num(4.0))];
        assert!(validate_overlay("KMEANS", &good).is_ok());
        assert!(validate_overlay("nope", &[]).is_err());
    }

    #[test]
    fn overlay_validation_rejects_wrong_typed_values() {
        // string where a bit width is expected
        let bad = vec![("bits".to_string(), Json::Str("4".to_string()))];
        let err = validate_overlay("kivi", &bad).unwrap_err();
        assert!(err.contains("knob 'bits'"), "{err}");
        // unsupported bit width (packers handle 2/4/8 only)
        let bad = vec![("quant_bits".to_string(), Json::Num(3.0))];
        let err = validate_overlay("ours", &bad).unwrap_err();
        assert!(err.contains("2, 4, or 8"), "{err}");
        // bool knob given a number
        let bad = vec![("use_sinks".to_string(), Json::Num(1.0))];
        assert!(validate_overlay("ours", &bad).is_err());
        // well-typed values pass
        let good = vec![("bits".to_string(), Json::Num(4.0))];
        assert!(validate_overlay("kivi", &good).is_ok());
        let good = vec![
            ("use_sinks".to_string(), Json::Bool(false)),
            ("quant_bits".to_string(), Json::Num(8.0)),
        ];
        assert!(validate_overlay("ours", &good).is_ok());
    }

    #[test]
    fn choice_knob_validates_scorer_values() {
        for v in ["bytelut", "popcnt"] {
            let good = vec![("scorer".to_string(), Json::Str(v.to_string()))];
            assert!(validate_overlay("ours", &good).is_ok(), "{v}");
        }
        // unknown variant lists the valid set
        let bad = vec![("scorer".to_string(), Json::Str("gemv".to_string()))];
        let err = validate_overlay("ours", &bad).unwrap_err();
        assert!(err.contains("expects one of bytelut, popcnt"), "{err}");
        // wrong type (number where a choice string is expected)
        let bad = vec![("scorer".to_string(), Json::Num(1.0))];
        assert!(validate_overlay("ours", &bad).is_err());
    }

    #[test]
    fn page_blocks_overlay_flows_into_resolved_config() {
        let si = SelfIndexConfig::default();
        assert_eq!(selfindex_overlayed(&si, &[]).page_blocks, 64);
        let overlay = vec![("page_blocks".to_string(), Json::Num(0.0))];
        assert_eq!(selfindex_overlayed(&si, &overlay).page_blocks, 0);
        assert!(validate_overlay("ours", &overlay).is_ok());
        let bad = vec![("page_blocks".to_string(), Json::Str("big".to_string()))];
        assert!(validate_overlay("ours", &bad).is_err());
    }

    #[test]
    fn scorer_overlay_flows_into_resolved_config() {
        use crate::selfindex::Scorer;
        let si = SelfIndexConfig::default();
        let overlay = vec![("scorer".to_string(), Json::Str("popcnt".to_string()))];
        assert_eq!(selfindex_overlayed(&si, &overlay).scorer, Scorer::Popcnt);
        assert_eq!(selfindex_overlayed(&si, &[]).scorer, Scorer::ByteLut);
        // the overlaid method still builds and serves
        let mgr = mgr_for(&si, &overlay);
        let mut head = lookup("ours").unwrap().build_head(&ctx(&si, &overlay, &mgr));
        let keys = vec![0.5f32; 32 * 64];
        head.prefill(&keys, &keys.clone(), &[], 1);
        let mut out = vec![0.0f32; 64];
        head.attend(&keys[..64], 16, &mut out);
        assert!(out.iter().any(|&x| x != 0.0));
    }
}
