//! The slice-based decode work queue: [`DecodePlan`] describes one
//! sequence's share of a decode step for one layer, a [`SequenceCache`]
//! expands it into [`HeadTask`]s, and [`DecodeWorkQueue`] executes the
//! pre-built task slice over `ThreadPool::for_each_task` — an atomic
//! cursor over the slice, no per-job closure boxing, and (via a recycled
//! task arena) zero steady-state heap allocations in the engine layer.
//!
//! [`SequenceCache`]: super::SequenceCache

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::baselines::AttentionMethod;
use crate::substrate::exec::ThreadPool;
use crate::substrate::faults::{FaultInjector, FaultPoint};

/// One sequence's slice of a decode step for one layer: the freshly
/// projected K/V rows to append, the grouped queries, and the retrieval
/// budget. All slices borrow the engine's staging buffers for the layer.
pub struct DecodePlan<'a> {
    pub layer: usize,
    pub dim: usize,
    pub kv_heads: usize,
    pub gqa_ratio: usize,
    /// dynamic token budget for this sequence at this step
    pub budget: usize,
    /// the step's new key rows, kv-head-major: (kv_heads × dim)
    pub k_rows: &'a [f32],
    /// the step's new value rows, kv-head-major: (kv_heads × dim)
    pub v_rows: &'a [f32],
    /// query heads, kv-head-major: (kv_heads × gqa_ratio × dim)
    pub queries: &'a [f32],
}

/// One unit of decode work: append this head's K/V row, then GQA-grouped
/// budgeted attention into a disjoint output chunk. Tasks are plain data
/// over borrowed state — the work queue hands each one out exactly once,
/// so the `&mut` leaf never aliases.
pub struct HeadTask<'a> {
    pub method: &'a mut (dyn AttentionMethod + 'a),
    pub k_row: &'a [f32],
    pub v_row: &'a [f32],
    /// this kv head's query group: (gqa_ratio × dim)
    pub queries: &'a [f32],
    pub dim: usize,
    pub budget: usize,
    /// this head's output chunk: (gqa_ratio × dim)
    pub out: &'a mut [f32],
    /// set by [`Self::run`] when the append hit pool exhaustion — the
    /// engine maps failed tasks back to their sequence and preempts it
    /// (the belt-and-braces path; exact pre-step accounting normally
    /// preempts before any task can fail)
    pub failed: bool,
    /// set by [`Self::run_isolated`] when the task body panicked — unlike
    /// `failed` (transient pressure → preempt and retry), a panic means
    /// this sequence's in-memory state is suspect, so the engine fails
    /// the request outright (`Outcome::WorkerPanic`)
    pub panicked: bool,
}

impl HeadTask<'_> {
    pub fn run(&mut self) {
        if self.method.try_append(self.k_row, self.v_row).is_err() {
            // leave `out` zeroed: the sequence will be preempted and
            // recomputed, so this step's output is discarded anyway
            self.failed = true;
            return;
        }
        self.method
            .attend_group(self.queries, self.dim, self.budget, self.out);
    }

    /// [`Self::run`] with panic containment: a panicking task marks
    /// itself `failed` + `panicked` instead of unwinding into the worker
    /// pool, so one poisoned (sequence, kv-head) fails one request while
    /// the rest of the batch completes. The `worker.panic` chaos point
    /// fires *before* the body runs — an injected panic leaves the leaf's
    /// state untouched. Real mid-append panics are also safe to contain:
    /// the failed request's caches are dropped, and their `Drop` impls
    /// release every pool block the sequence held.
    pub fn run_isolated(&mut self, faults: &FaultInjector) {
        let body = catch_unwind(AssertUnwindSafe(|| {
            if faults.should_fire(FaultPoint::WorkerPanic) {
                panic!("injected worker panic (chaos)");
            }
            self.run();
        }));
        if body.is_err() {
            self.failed = true;
            self.panicked = true;
        }
    }
}

/// Reuse an **empty** `Vec`'s allocation for a same-layout element type
/// (here: `HeadTask` under different lifetimes, so the engine can bank
/// the task arena across decode steps without a per-step allocation).
fn recycle<A, B>(mut v: Vec<A>) -> Vec<B> {
    assert!(v.is_empty(), "recycle of a non-empty vec");
    assert_eq!(std::mem::size_of::<A>(), std::mem::size_of::<B>());
    assert_eq!(std::mem::align_of::<A>(), std::mem::align_of::<B>());
    let cap = v.capacity();
    let ptr = v.as_mut_ptr() as *mut B;
    std::mem::forget(v);
    // SAFETY: the vec is empty, so no values are reinterpreted; A and B
    // have identical size and alignment (asserted above), so the raw
    // allocation is valid for `cap` elements of B and its eventual
    // deallocation uses the same layout it was allocated with.
    unsafe { Vec::from_raw_parts(ptr, 0, cap) }
}

/// The engine's per-step task arena: `take` an empty task vec (reusing
/// the banked capacity), fill it via `SequenceCache::push_tasks`, then
/// `dispatch` it across the pool and bank the capacity back. At steady
/// state the whole cycle performs zero heap allocations (asserted by
/// `tests/engine_fanout_alloc.rs` under the counting global allocator).
#[derive(Default)]
pub struct DecodeWorkQueue {
    arena: Vec<HeadTask<'static>>,
}

impl DecodeWorkQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Borrow the banked arena as an empty task list for this step.
    pub fn take<'t>(&mut self) -> Vec<HeadTask<'t>> {
        recycle(std::mem::take(&mut self.arena))
    }

    /// Run every task on the pool (atomic-cursor fan-out; the caller
    /// participates) and bank the task list's capacity for the next step.
    pub fn dispatch(&mut self, workers: &ThreadPool, mut tasks: Vec<HeadTask<'_>>) {
        workers.for_each_task(&mut tasks, |t| t.run());
        self.bank(tasks);
    }

    /// Bank a task list's capacity without running it — for callers (the
    /// engine) that run the tasks themselves and inspect per-task state
    /// (the `failed` flags) before recycling the arena.
    pub fn bank(&mut self, mut tasks: Vec<HeadTask<'_>>) {
        tasks.clear();
        self.arena = recycle(tasks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::FullCache;

    #[test]
    fn work_queue_banks_capacity_across_steps() {
        let pool = ThreadPool::new(2);
        let mut queue = DecodeWorkQueue::new();
        let dim = 16;
        let mut heads: Vec<FullCache> = (0..8).map(|_| FullCache::new(dim)).collect();
        let keys = vec![0.5f32; 4 * dim];
        for h in heads.iter_mut() {
            h.prefill(&keys, &keys.clone(), &[], 1);
        }
        let k = vec![0.25f32; dim];
        let q = vec![1.0f32; dim];
        let mut outs = vec![0.0f32; 8 * dim];

        let mut cap_after_first = 0;
        for step in 0..3 {
            let mut tasks = queue.take();
            for (h, o) in heads.iter_mut().zip(outs.chunks_mut(dim)) {
                tasks.push(HeadTask {
                    method: h,
                    k_row: &k,
                    v_row: &k,
                    queries: &q,
                    dim,
                    budget: usize::MAX,
                    out: o,
                    failed: false,
                    panicked: false,
                });
            }
            let cap = tasks.capacity();
            if step == 1 {
                cap_after_first = cap;
            }
            if step == 2 {
                assert_eq!(cap, cap_after_first, "capacity must be banked");
            }
            queue.dispatch(&pool, tasks);
        }
        assert!(outs.iter().all(|&x| x != 0.0));
        assert_eq!(heads[0].len(), 4 + 3);
    }

    #[test]
    fn run_isolated_contains_injected_panic() {
        let dim = 16;
        let mut h = FullCache::new(dim);
        let keys = vec![0.5f32; 4 * dim];
        h.prefill(&keys, &keys.clone(), &[], 1);
        let k = vec![0.25f32; dim];
        let q = vec![1.0f32; dim];
        let mut out = vec![0.0f32; dim];
        let faults = FaultInjector::parse("worker.panic=nth:1", 0).unwrap();
        let mut task = HeadTask {
            method: &mut h,
            k_row: &k,
            v_row: &k,
            queries: &q,
            dim,
            budget: usize::MAX,
            out: &mut out,
            failed: false,
            panicked: false,
        };
        task.run_isolated(&faults);
        assert!(task.failed && task.panicked, "panic marks both flags");
        assert!(task.out.iter().all(|&x| x == 0.0), "fired before the body");
        // nth:1 is spent; the same task body now runs clean
        task.run_isolated(&faults);
        assert!(task.out.iter().any(|&x| x != 0.0));
        assert_eq!(h.len(), 4 + 1, "panicked run appended nothing");
    }
}
