//! Quest baseline (Tang et al. 2024): query-aware block-sparse attention.
//!
//! The cache is split into pages of 16 tokens; each page stores
//! element-wise min/max of its keys. At decode, a page's upper-bound score
//! is `Σ_j max(q_j·min_j, q_j·max_j)`; the top pages (by bound) covering
//! the token budget attend densely. Paper setting: page size 16, 2 extra
//! bits/parameter of index (min+max fp16 per channel per page ≈
//! 2×16/16 = 2 bits per cached parameter).

use super::AttentionMethod;
use crate::attention::dense::attend_dense;
use crate::selfindex::topk::TopKStream;

pub const PAGE: usize = 16;

pub struct QuestCache {
    pub dim: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
    /// per page: dim mins then dim maxs
    page_minmax: Vec<f32>,
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
    /// decode arenas: per-page bounds, the page selector, and the
    /// selected page list — reused every step so `attend` allocates
    /// nothing once warm (`attend_is_allocation_free_once_warm`)
    bounds: Vec<f32>,
    selector: TopKStream,
    sel_pages: Vec<u32>,
}

impl QuestCache {
    pub fn new(dim: usize) -> Self {
        Self {
            dim,
            keys: vec![],
            vals: vec![],
            page_minmax: vec![],
            scratch_k: vec![],
            scratch_v: vec![],
            bounds: vec![],
            selector: TopKStream::new(0),
            sel_pages: vec![],
        }
    }

    pub fn len(&self) -> usize {
        self.keys.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    fn pages(&self) -> usize {
        self.len().div_ceil(PAGE)
    }

    fn refresh_index(&mut self) {
        let dim = self.dim;
        let pages = self.pages();
        self.page_minmax.resize(pages * 2 * dim, 0.0);
        for p in 0..pages {
            let start = p * PAGE;
            let end = ((p + 1) * PAGE).min(self.len());
            let (mins, maxs) = self.page_minmax[p * 2 * dim..(p + 1) * 2 * dim]
                .split_at_mut(dim);
            mins.fill(f32::INFINITY);
            maxs.fill(f32::NEG_INFINITY);
            for t in start..end {
                for j in 0..dim {
                    let v = self.keys[t * dim + j];
                    if v < mins[j] {
                        mins[j] = v;
                    }
                    if v > maxs[j] {
                        maxs[j] = v;
                    }
                }
            }
        }
    }

    /// Upper-bound score of each page for `query` (Quest's criterion),
    /// written into the caller's arena — the repo's `*_into` discipline,
    /// so the decode hot path reuses one buffer instead of allocating a
    /// fresh vector per step.
    pub fn page_bounds_into(&self, query: &[f32], out: &mut Vec<f32>) {
        let dim = self.dim;
        out.clear();
        out.reserve(self.pages());
        for p in 0..self.pages() {
            let mins = &self.page_minmax[p * 2 * dim..p * 2 * dim + dim];
            let maxs = &self.page_minmax[p * 2 * dim + dim..(p + 1) * 2 * dim];
            let mut s = 0.0f32;
            for j in 0..dim {
                s += (query[j] * mins[j]).max(query[j] * maxs[j]);
            }
            out.push(s);
        }
    }

    /// Allocating convenience wrapper over [`Self::page_bounds_into`]
    /// (diagnostics/tests; `attend` uses the arena form).
    pub fn page_bounds(&self, query: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.page_bounds_into(query, &mut out);
        out
    }
}

impl AttentionMethod for QuestCache {
    fn name(&self) -> &'static str {
        "quest"
    }

    fn prefill(&mut self, keys: &[f32], vals: &[f32], _q: &[f32], _r: usize) {
        self.keys.extend_from_slice(keys);
        self.vals.extend_from_slice(vals);
        self.refresh_index();
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        self.keys.extend_from_slice(k_row);
        self.vals.extend_from_slice(v_row);
        // incremental: only the last page's min/max changes
        let dim = self.dim;
        let pages = self.pages();
        self.page_minmax.resize(pages * 2 * dim, 0.0);
        let p = pages - 1;
        let start = p * PAGE;
        let end = self.len();
        let (mins, maxs) =
            self.page_minmax[p * 2 * dim..(p + 1) * 2 * dim].split_at_mut(dim);
        mins.fill(f32::INFINITY);
        maxs.fill(f32::NEG_INFINITY);
        for t in start..end {
            for j in 0..dim {
                let v = self.keys[t * dim + j];
                if v < mins[j] {
                    mins[j] = v;
                }
                if v > maxs[j] {
                    maxs[j] = v;
                }
            }
        }
    }

    fn attend(&mut self, query: &[f32], budget: usize, out: &mut [f32]) {
        let dim = self.dim;
        let n_pages = budget.div_ceil(PAGE).max(1);
        let mut bounds = std::mem::take(&mut self.bounds);
        self.page_bounds_into(query, &mut bounds);
        // top pages by bound through the reusable threshold selector
        // (same descending-score order `top_k_indices` produced)
        let mut selector = std::mem::replace(&mut self.selector, TopKStream::new(0));
        selector.reset(n_pages.min(bounds.len()));
        for (p, &b) in bounds.iter().enumerate() {
            selector.push(p as u32, b);
        }
        selector.finish_into(&mut self.sel_pages);
        self.selector = selector;
        self.bounds = bounds;
        self.scratch_k.clear();
        self.scratch_v.clear();
        let mut tokens = 0;
        for &p in &self.sel_pages {
            let start = p as usize * PAGE;
            let end = ((p as usize + 1) * PAGE).min(self.len());
            self.scratch_k
                .extend_from_slice(&self.keys[start * dim..end * dim]);
            self.scratch_v
                .extend_from_slice(&self.vals[start * dim..end * dim]);
            tokens += end - start;
        }
        let sk = std::mem::take(&mut self.scratch_k);
        let sv = std::mem::take(&mut self.scratch_v);
        attend_dense(query, &sk, &sv, tokens, out);
        self.scratch_k = sk;
        self.scratch_v = sv;
    }

    fn memory_bytes(&self) -> usize {
        // fp16 K/V cache + fp16 min/max index (paper's accounting)
        (self.keys.len() + self.vals.len()) * 2 + self.page_minmax.len() * 2
    }

    fn retrieval_scores(&mut self, query: &[f32]) -> Option<Vec<f32>> {
        // token score = its page's bound (block granularity)
        let bounds = self.page_bounds(query);
        let mut out = Vec::with_capacity(self.len());
        for t in 0..self.len() {
            out.push(bounds[t / PAGE]);
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::clustered;
    use crate::selfindex::topk::top_k_indices;
    use crate::substrate::rng::Rng;

    #[test]
    fn bounds_are_upper_bounds() {
        let mut r = Rng::new(1);
        let dim = 32;
        let (keys, vals, query) = clustered(2, 256, dim, 3.0);
        let mut qc = QuestCache::new(dim);
        qc.prefill(&keys, &vals, &[], 1);
        let bounds = qc.page_bounds(&query);
        for t in 0..qc.len() {
            let s = crate::tensor::dot(&query, &keys[t * dim..(t + 1) * dim]);
            assert!(
                bounds[t / PAGE] >= s - 1e-4,
                "page bound {} < token score {s}",
                bounds[t / PAGE]
            );
        }
        let _ = r.next_u64();
    }

    #[test]
    fn selects_page_containing_best_token() {
        // bounds are loose (min/max boxes), so the guarantee is soft: the
        // best token's page bound must dominate its true score, and the
        // page must rank in the upper half of pages by bound.
        let (keys, vals, query) = clustered(3, 512, 32, 4.0);
        let mut qc = QuestCache::new(32);
        qc.prefill(&keys, &vals, &[], 1);
        let mut exact = Vec::new();
        crate::selfindex::score::exact_scores(&query, &keys, 32, &mut exact);
        let best = crate::selfindex::topk::top_k_indices(&exact, 1)[0] as usize;
        let bounds = qc.page_bounds(&query);
        assert!(bounds[best / PAGE] >= exact[best] - 1e-4);
        let sel = top_k_indices(&bounds, bounds.len() / 2);
        assert!(
            sel.contains(&((best / PAGE) as u32)),
            "best token's page must rank in the top half of pages"
        );
    }

    #[test]
    fn append_updates_last_page_only() {
        let mut r = Rng::new(4);
        let dim = 16;
        let keys: Vec<f32> = (0..40 * dim).map(|_| r.normal_f32()).collect();
        let mut qc = QuestCache::new(dim);
        qc.prefill(&keys, &keys.clone(), &[], 1);
        let before = qc.page_minmax.clone();
        let big = vec![100.0f32; dim];
        qc.append(&big, &big);
        // pages 0..2 unchanged, page 2 (tokens 32..41) updated
        assert_eq!(qc.page_minmax[..2 * 2 * dim], before[..2 * 2 * dim]);
        let p = 2;
        let maxs = &qc.page_minmax[p * 2 * dim + dim..(p + 1) * 2 * dim];
        assert!(maxs.iter().all(|&m| m == 100.0));
    }

    #[test]
    fn attend_is_allocation_free_once_warm() {
        // the decode step reuses the bounds/selector/page-list arenas —
        // the old `page_bounds` returned a fresh Vec per call
        use crate::substrate::metrics::thread_allocations;
        let dim = 32;
        let (keys, vals, query) = clustered(6, 512, dim, 3.0);
        let mut qc = QuestCache::new(dim);
        qc.prefill(&keys, &vals, &[], 1);
        let mut out = vec![0.0f32; dim];
        for _ in 0..4 {
            qc.attend(&query, 96, &mut out); // size every arena
        }
        let before = thread_allocations();
        for _ in 0..8 {
            qc.attend(&query, 96, &mut out);
        }
        let delta = thread_allocations() - before;
        assert_eq!(delta, 0, "quest attend allocated {delta} times");
        assert!(out.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn memory_includes_index() {
        let (keys, vals, _) = clustered(5, 160, 32, 3.0);
        let mut qc = QuestCache::new(32);
        qc.prefill(&keys, &vals, &[], 1);
        // 160 tokens fp16 K+V = 160*32*2*2; index = 10 pages × 2×32 × 2
        assert_eq!(qc.memory_bytes(), 160 * 32 * 2 * 2 + 10 * 2 * 32 * 2);
    }
}
