//! KIVI-style baseline (Liu et al. 2024): asymmetric 2-bit quantization,
//! **channel-wise for keys** (per channel, over groups of tokens) and
//! token-wise for values, with a full-precision residual window of the
//! most recent tokens. Decode = decompress-then-compute: the whole cache
//! is dequantized, then dense attention runs over it — the strategy whose
//! overhead Fig. 5 shows, and which our fused kernel avoids.

use super::AttentionMethod;
use crate::attention::dense::attend_dense;
use crate::tensor::fp16::{f16_to_f32, f32_to_f16};

/// tokens per channel-wise quant group (KIVI's G)
const TOKEN_GROUP: usize = 32;
/// full-precision residual window (KIVI keeps recent tokens fp)
const RESIDUAL: usize = 32;

pub struct KiviCache {
    pub dim: usize,
    pub bits: u32,
    // channel-wise quantized keys: groups of TOKEN_GROUP tokens
    k_q: Vec<u8>,            // quantized (full groups only), token-major
    k_prm: Vec<(u16, u16)>,  // (scale, zero) fp16 per (group, channel)
    // token-wise quantized values
    v_q: Vec<u8>,
    v_prm: Vec<(u16, u16)>, // per (token, channel-group of 32)
    // fp residual tail (recent tokens, both K and V)
    resid_k: Vec<f32>,
    resid_v: Vec<f32>,
    len: usize,
    // scratch for decompress-then-compute
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
}

impl KiviCache {
    pub fn new(dim: usize, bits: u32) -> Self {
        assert_eq!(dim % TOKEN_GROUP, 0);
        Self {
            dim,
            bits,
            k_q: vec![],
            k_prm: vec![],
            v_q: vec![],
            v_prm: vec![],
            resid_k: vec![],
            resid_v: vec![],
            len: 0,
            scratch_k: vec![],
            scratch_v: vec![],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn quantized_tokens(&self) -> usize {
        (self.k_q.len() / self.dim).min(self.v_q.len() / self.dim)
    }

    /// Compress the oldest full group out of the residual window.
    fn roll_residual(&mut self) {
        while self.resid_k.len() / self.dim >= RESIDUAL + TOKEN_GROUP {
            let dim = self.dim;
            let qmax = (1u32 << self.bits) - 1;
            // --- keys: channel-wise over this token group
            let group: Vec<f32> = self.resid_k.drain(..TOKEN_GROUP * dim).collect();
            let base_q = self.k_q.len();
            self.k_q.resize(base_q + TOKEN_GROUP * dim, 0);
            for c in 0..dim {
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for t in 0..TOKEN_GROUP {
                    let v = group[t * dim + c];
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                let mut qs = (hi - lo) / qmax as f32;
                if qs.is_nan() || qs <= 0.0 {
                    qs = 1.0;
                }
                let qs = f16_to_f32(f32_to_f16(qs));
                let zp = f16_to_f32(f32_to_f16(lo));
                let qs = if qs > 0.0 { qs } else { 1.0 };
                self.k_prm.push((f32_to_f16(qs), f32_to_f16(zp)));
                for t in 0..TOKEN_GROUP {
                    let v = group[t * dim + c];
                    let q = ((v - zp) / qs).round().clamp(0.0, qmax as f32);
                    self.k_q[base_q + t * dim + c] = q as u8;
                }
            }
            // --- values: token-wise
            let vgroup: Vec<f32> = self.resid_v.drain(..TOKEN_GROUP * dim).collect();
            let tq = crate::quant::int2::quantize_tokens(
                &vgroup, dim, TOKEN_GROUP.min(dim), self.bits);
            self.v_q.extend_from_slice(&tq.values);
            for p in &tq.params {
                self.v_prm.push((p.scale, p.zero));
            }
        }
    }

    /// Decompress the entire cache into scratch (KIVI's decode cost).
    fn decompress(&mut self) {
        let dim = self.dim;
        let qt = self.quantized_tokens();
        self.scratch_k.clear();
        self.scratch_k.reserve(self.len * dim);
        self.scratch_v.clear();
        self.scratch_v.reserve(self.len * dim);

        let groups = qt / TOKEN_GROUP;
        for g in 0..groups {
            for t in 0..TOKEN_GROUP {
                for c in 0..dim {
                    let (s16, z16) = self.k_prm[g * dim + c];
                    let q = self.k_q[(g * TOKEN_GROUP + t) * dim + c];
                    self.scratch_k
                        .push(f16_to_f32(s16) * q as f32 + f16_to_f32(z16));
                }
            }
        }
        let vg = TOKEN_GROUP.min(dim);
        let ng = dim / vg;
        for t in 0..qt {
            for c in 0..dim {
                let (s16, z16) = self.v_prm[t * ng + c / vg];
                let q = self.v_q[t * dim + c];
                self.scratch_v
                    .push(f16_to_f32(s16) * q as f32 + f16_to_f32(z16));
            }
        }
        self.scratch_k.extend_from_slice(&self.resid_k);
        self.scratch_v.extend_from_slice(&self.resid_v);
    }
}

impl AttentionMethod for KiviCache {
    fn name(&self) -> &'static str {
        "kivi2"
    }

    fn prefill(&mut self, keys: &[f32], vals: &[f32], _q: &[f32], _r: usize) {
        assert_eq!(keys.len() % self.dim, 0);
        self.resid_k.extend_from_slice(keys);
        self.resid_v.extend_from_slice(vals);
        self.len += keys.len() / self.dim;
        self.roll_residual();
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        self.resid_k.extend_from_slice(k_row);
        self.resid_v.extend_from_slice(v_row);
        self.len += 1;
        self.roll_residual();
    }

    fn attend(&mut self, query: &[f32], _budget: usize, out: &mut [f32]) {
        self.decompress();
        let n = self.len;
        // borrow dance: move scratch out to satisfy the borrow checker
        let sk = std::mem::take(&mut self.scratch_k);
        let sv = std::mem::take(&mut self.scratch_v);
        attend_dense(query, &sk, &sv, n, out);
        self.scratch_k = sk;
        self.scratch_v = sv;
    }

    fn memory_bytes(&self) -> usize {
        // 2-bit payloads are stored packed in a real deployment
        self.k_q.len() * self.bits as usize / 8
            + self.v_q.len() * self.bits as usize / 8
            + (self.k_prm.len() + self.v_prm.len()) * 4
            + (self.resid_k.len() + self.resid_v.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    #[test]
    fn reconstruction_error_bounded_elementwise() {
        // the hard guarantee 2-bit min/max quantization gives: every
        // decompressed element within (max-min)/3/2 of the original
        // (+fp16 slop). Output-space drift on *diffuse* gaussian
        // attention is unbounded in relative terms, so we check the
        // decompression contract directly.
        let mut r = Rng::new(1);
        let dim = 32;
        let n = 200;
        let keys: Vec<f32> = (0..n * dim).map(|_| r.normal_f32()).collect();
        let vals: Vec<f32> = (0..n * dim).map(|_| r.normal_f32()).collect();

        let mut kivi = KiviCache::new(dim, 2);
        kivi.prefill(&keys, &vals, &[], 1);
        assert_eq!(kivi.len(), n);
        kivi.decompress();
        assert_eq!(kivi.scratch_k.len(), n * dim);
        // channel-wise K bound: per (group, channel) qs/2
        let qt = kivi.quantized_tokens();
        for g in 0..qt / TOKEN_GROUP {
            for t in 0..TOKEN_GROUP {
                for c in 0..dim {
                    let (s16, _) = kivi.k_prm[g * dim + c];
                    let bound = f16_to_f32(s16) * 0.5 + 2e-2;
                    let i = (g * TOKEN_GROUP + t) * dim + c;
                    let err = (kivi.scratch_k[i] - keys[i]).abs();
                    assert!(err <= bound, "k[{i}]: err {err} > {bound}");
                }
            }
        }
        // residual tail is exact
        let tail = n - qt;
        for i in 0..tail * dim {
            assert_eq!(kivi.scratch_k[qt * dim + i], keys[qt * dim + i]);
        }
    }

    #[test]
    fn memory_far_below_full() {
        let mut r = Rng::new(2);
        let dim = 64;
        let n = 2048;
        let keys: Vec<f32> = (0..n * dim).map(|_| r.normal_f32()).collect();
        let mut kivi = KiviCache::new(dim, 2);
        kivi.prefill(&keys, &keys.clone(), &[], 1);
        let full_bytes = 2 * n * dim * 4;
        assert!(
            kivi.memory_bytes() < full_bytes / 4,
            "{} vs {}",
            kivi.memory_bytes(),
            full_bytes
        );
    }

    #[test]
    fn append_keeps_token_count() {
        let mut r = Rng::new(3);
        let dim = 32;
        let mut kivi = KiviCache::new(dim, 2);
        let keys: Vec<f32> = (0..100 * dim).map(|_| r.normal_f32()).collect();
        kivi.prefill(&keys, &keys.clone(), &[], 1);
        for _ in 0..50 {
            let k: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
            kivi.append(&k, &k);
        }
        assert_eq!(kivi.len(), 150);
        let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let mut out = vec![0.0; dim];
        kivi.attend(&q, usize::MAX, &mut out);
        assert!(out.iter().any(|&x| x != 0.0));
    }
}
