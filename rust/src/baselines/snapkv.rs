//! SnapKV baseline (Li et al. 2024): one-shot static pruning at prefill.
//!
//! Observation-window queries vote (pooled attention mass) for which
//! prefix tokens to keep; everything else is discarded permanently. Keeps
//! the budget in full precision. Fast and memory-light, but — as Tables
//! 1/2 show — brittle on tasks whose relevant tokens aren't known at
//! prefill time (its NS3/NM2/NM3 collapses in Table 2).

use super::AttentionMethod;
use crate::attention::dense::attend_dense;
use crate::kvcache::sink::snapkv_select;

pub struct SnapKv {
    pub dim: usize,
    /// tokens to keep at prefill (the method's *static* budget)
    pub keep: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
    kept: Vec<u32>,
}

impl SnapKv {
    pub fn new(dim: usize, keep: usize) -> Self {
        Self { dim, keep, keys: vec![], vals: vec![], kept: vec![] }
    }

    pub fn kept_indices(&self) -> &[u32] {
        &self.kept
    }

    pub fn len(&self) -> usize {
        self.keys.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

impl AttentionMethod for SnapKv {
    fn name(&self) -> &'static str {
        "snapkv"
    }

    fn prefill(&mut self, keys: &[f32], vals: &[f32], q_window: &[f32], r_heads: usize) {
        let l = keys.len() / self.dim;
        let keep = self.keep.min(l);
        self.kept = if q_window.is_empty() {
            // no window: keep the tail (recency prior)
            ((l - keep) as u32..l as u32).collect()
        } else {
            snapkv_select(q_window, r_heads, keys, self.dim, keep)
        };
        for &i in &self.kept {
            let i = i as usize;
            self.keys
                .extend_from_slice(&keys[i * self.dim..(i + 1) * self.dim]);
            self.vals
                .extend_from_slice(&vals[i * self.dim..(i + 1) * self.dim]);
        }
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        // decode tokens are always kept (standard SnapKV behaviour)
        self.keys.extend_from_slice(k_row);
        self.vals.extend_from_slice(v_row);
    }

    fn attend(&mut self, query: &[f32], _budget: usize, out: &mut [f32]) {
        attend_dense(query, &self.keys, &self.vals, self.len(), out);
    }

    fn memory_bytes(&self) -> usize {
        (self.keys.len() + self.vals.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    #[test]
    fn keeps_at_most_budget_plus_decode() {
        let mut r = Rng::new(1);
        let dim = 32;
        let keys: Vec<f32> = (0..100 * dim).map(|_| r.normal_f32()).collect();
        let vals = keys.clone();
        let qw: Vec<f32> = (0..4 * dim).map(|_| r.normal_f32()).collect();
        let mut s = SnapKv::new(dim, 20);
        s.prefill(&keys, &vals, &qw, 1);
        assert_eq!(s.len(), 20);
        let k = vec![0.0f32; dim];
        s.append(&k, &k);
        assert_eq!(s.len(), 21);
    }

    #[test]
    fn misses_needle_outside_window_focus() {
        // the failure mode the paper exploits: a token relevant only to a
        // FUTURE query is pruned if the observation window ignores it.
        let mut r = Rng::new(2);
        let dim = 32;
        let l = 128;
        let mut keys: Vec<f32> = (0..l * dim).map(|_| r.normal_f32() * 0.2).collect();
        // needle at 40 aligned with a direction the window never queries
        let needle: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        for j in 0..dim {
            keys[40 * dim + j] = needle[j] * 5.0;
        }
        // window queries aligned with a different direction
        let other: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let qw: Vec<f32> = (0..8)
            .flat_map(|_| other.iter().map(|&x| x + 0.01).collect::<Vec<_>>())
            .collect();
        let mut s = SnapKv::new(dim, 16);
        s.prefill(&keys, &keys.clone(), &qw, 1);
        assert!(
            !s.kept_indices().contains(&40),
            "needle should be pruned: {:?}",
            s.kept_indices()
        );
    }

    #[test]
    fn no_window_keeps_tail() {
        let dim = 8;
        let keys = vec![0.5f32; 50 * dim];
        let mut s = SnapKv::new(dim, 10);
        s.prefill(&keys, &keys.clone(), &[], 1);
        assert_eq!(s.kept_indices(), (40u32..50).collect::<Vec<_>>());
    }
}
