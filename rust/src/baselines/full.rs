//! Full-precision cache + dense attention — the FlashAttention-2 baseline
//! role in every table: maximal accuracy, maximal memory, O(L) attention.

use super::AttentionMethod;
use crate::attention::dense::attend_dense;

pub struct FullCache {
    pub dim: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
}

impl FullCache {
    pub fn new(dim: usize) -> Self {
        Self { dim, keys: vec![], vals: vec![] }
    }

    pub fn len(&self) -> usize {
        self.keys.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn keys(&self) -> &[f32] {
        &self.keys
    }

    pub fn vals(&self) -> &[f32] {
        &self.vals
    }
}

impl AttentionMethod for FullCache {
    fn name(&self) -> &'static str {
        "full"
    }

    fn prefill(&mut self, keys: &[f32], vals: &[f32], _q_window: &[f32], _r: usize) {
        assert_eq!(keys.len() % self.dim, 0);
        self.keys.extend_from_slice(keys);
        self.vals.extend_from_slice(vals);
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        self.keys.extend_from_slice(k_row);
        self.vals.extend_from_slice(v_row);
    }

    fn attend(&mut self, query: &[f32], _budget: usize, out: &mut [f32]) {
        attend_dense(query, &self.keys, &self.vals, self.len(), out);
    }

    fn memory_bytes(&self) -> usize {
        (self.keys.len() + self.vals.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    #[test]
    fn prefill_append_attend() {
        let mut r = Rng::new(1);
        let dim = 16;
        let mut fc = FullCache::new(dim);
        let keys: Vec<f32> = (0..10 * dim).map(|_| r.normal_f32()).collect();
        let vals: Vec<f32> = (0..10 * dim).map(|_| r.normal_f32()).collect();
        fc.prefill(&keys, &vals, &[], 1);
        let k: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        fc.append(&k, &k);
        assert_eq!(fc.len(), 11);
        assert_eq!(fc.memory_bytes(), 11 * dim * 2 * 4);
        let q: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let mut out = vec![0.0; dim];
        fc.attend(&q, usize::MAX, &mut out);
        assert!(out.iter().any(|&x| x != 0.0));
    }
}
