//! The paper's method behind the same [`AttentionMethod`] trait, so the
//! accuracy/efficiency tables drive everything through one protocol.
//!
//! Composition: [`HeadCache`] (compressed store + LUT-GEMV scoring) +
//! SnapKV-selected [`SinkStore`] + fused sparse attention. The ablation
//! switches of [`SelfIndexConfig`] (sign plane, magnitude centroids,
//! sinks) flow straight through — Table 5 is a config sweep.

use std::sync::Arc;

use super::AttentionMethod;
use crate::attention::sparse::{attend_sparse_fused, SparseAttnScratch};
use crate::kvcache::layout::RecordLayout;
use crate::kvcache::manager::KvManager;
use crate::kvcache::pool::BlockPool;
use crate::kvcache::sink::{snapkv_select, SinkStore};
use crate::kvcache::store::{CacheFull, HeadCache};
use crate::quant::pack;
use crate::selfindex::codes::sign_code;
use crate::selfindex::lut::Lut;
use crate::selfindex::score::{BlockScorer, ByteLut};
use crate::selfindex::topk::TopKStream;
use crate::selfindex::{Scorer, SelfIndexConfig};

/// Per-head scratch arenas for the fused one-pass retrieval pipeline.
/// Everything a decode step touches is preallocated here and reused, so
/// the steady-state hot path performs zero heap allocations (asserted by
/// `decode_step_is_allocation_free` below).
struct RetrievalScratch {
    lut: Lut,
    blut: ByteLut,
    /// popcount-scorer arenas (only touched when `cfg.scorer` is
    /// `Popcnt`): summed GQA query, its nibble sign codes, the packed
    /// bytes, and the word-packed form the kernel XORs against
    q_sum: Vec<f32>,
    q_codes: Vec<u8>,
    q_packed: Vec<u8>,
    q_words: Vec<u64>,
    /// one block's worth of scores (sized to the pool's block_tokens)
    block_scores: Vec<f32>,
    selector: TopKStream,
    selected: Vec<u32>,
}

impl RetrievalScratch {
    fn new(groups: usize) -> Self {
        Self {
            lut: Lut::empty(groups),
            blut: ByteLut::empty(),
            q_sum: vec![],
            q_codes: vec![],
            q_packed: vec![],
            q_words: vec![],
            block_scores: vec![],
            selector: TopKStream::new(0),
            selected: vec![],
        }
    }
}

pub struct SelfIndexing {
    pub dim: usize,
    pub cfg: SelfIndexConfig,
    /// the engine-wide memory manager this head borrows blocks from —
    /// every head of every sequence holds the same `Arc` when built
    /// through the registry, so exactly one `BlockPool` exists per engine
    mgr: Arc<KvManager>,
    cache: HeadCache,
    sinks: SinkStore,
    /// sink token indices, ascending — masking during selection is index
    /// arithmetic over this list, not a -inf sweep of the score vector
    sink_ids: Vec<u32>,
    scratch: SparseAttnScratch,
    retrieval: RetrievalScratch,
    scores: Vec<f32>,
    /// decode-time fp rows that always attend ([k, v] interleaved)
    recent: Vec<f32>,
    /// cap on `recent` before folding into the compressed cache only
    recent_cap: usize,
    /// router-interned content hash of the prompt (0 = not set): lets
    /// prefill memoize full-block content keys in the manager so a
    /// re-prefill after preemption skips re-hashing the raw rows
    prompt_hash: u128,
}

impl SelfIndexing {
    pub fn new(dim: usize, cfg: SelfIndexConfig) -> Self {
        Self::with_capacity(dim, cfg, 4096)
    }

    /// Standalone (single-head / bench / test) constructor: builds a
    /// private manager of `capacity_blocks`. Serving goes through
    /// [`Self::with_manager`] with the engine's shared manager instead.
    pub fn with_capacity(dim: usize, cfg: SelfIndexConfig, capacity_blocks: usize) -> Self {
        let mgr = Arc::new(KvManager::for_head(dim, &cfg, 64, capacity_blocks));
        Self::with_manager(dim, cfg, mgr)
    }

    /// Build over a shared memory manager (the engine path). The manager's
    /// record layout must match this head's `(dim, cfg)` — one engine-wide
    /// layout serves every sequence, layer, and kv head.
    pub fn with_manager(dim: usize, cfg: SelfIndexConfig, mgr: Arc<KvManager>) -> Self {
        assert_eq!(
            mgr.pool().layout,
            RecordLayout::new(dim, &cfg),
            "shared pool layout does not match this head's record layout"
        );
        Self {
            dim,
            mgr,
            cache: HeadCache::new(dim, cfg.clone()),
            sinks: SinkStore::default(),
            sink_ids: vec![],
            scratch: SparseAttnScratch::new(dim),
            retrieval: RetrievalScratch::new(dim / 4),
            scores: vec![],
            recent: vec![],
            recent_cap: 64,
            prompt_hash: 0,
            cfg,
        }
    }

    /// Set the router-interned prompt hash before `prefill` (engine path;
    /// standalone users leave it 0 = key memoization off).
    pub fn set_prompt_hash(&mut self, h: u128) {
        self.prompt_hash = h;
    }

    /// The fused one-pass decode retrieval (DESIGN.md §Perf iteration 5):
    /// build the (summed, for GQA groups) LUT once, then stream packed
    /// codes block-by-block out of the pool — scoring, sink/recent
    /// masking, and threshold top-k selection all happen in the same pass
    /// while each block's scores are L1-hot. No flat score vector, no
    /// -inf masking sweep, no second O(L) selection scan. Under the
    /// popcount scorer the cache additionally consults its page sketches
    /// (§Perf iteration 9) to skip whole pages the top-k threshold
    /// already rules out — same selection, O(L/page) memory touched.
    ///
    /// `queries` is one or more concatenated query heads (R × dim); the
    /// selection is written to `self.retrieval.selected`.
    fn fused_select(&mut self, queries: &[f32], k: usize) {
        let dim = self.dim;
        let pool = self.mgr.pool();
        let cache = &self.cache;
        let r = &mut self.retrieval;
        match self.cfg.scorer {
            Scorer::ByteLut => {
                r.lut.rebuild(&queries[..dim], cache.codebook());
                for q in queries[dim..].chunks_exact(dim) {
                    r.lut.add_query(q, cache.codebook());
                }
                r.blut.rebuild(&r.lut);
            }
            Scorer::Popcnt => {
                // GQA analogue of summed LUTs: sum the R query heads,
                // then take the sign plane of the sum — one XOR+popcount
                // pass for the whole group
                r.q_sum.clear();
                r.q_sum.extend_from_slice(&queries[..dim]);
                for q in queries[dim..].chunks_exact(dim) {
                    for (a, &b) in r.q_sum.iter_mut().zip(q) {
                        *a += b;
                    }
                }
                r.q_codes.clear();
                r.q_codes.extend(r.q_sum.chunks_exact(4).map(sign_code));
                pack::pack_codes_into(&r.q_codes, &mut r.q_packed);
                pack::pack_signs_u64_into(
                    &r.q_packed,
                    1,
                    pool.layout.codes_bytes,
                    &mut r.q_words,
                );
            }
        }

        // recent fp rows always attend: exclude them by scoring only the
        // prefix (index arithmetic, pass 0 work)
        let recent_rows = self.recent.len() / (2 * dim);
        let end = cache.len().saturating_sub(recent_rows);

        // sinks always attend via the fp sink store — stream_select skips
        // them by index arithmetic over the sorted id list
        let RetrievalScratch { blut, q_words, block_scores, selector, selected, .. } = r;
        let scorer = match self.cfg.scorer {
            Scorer::ByteLut => BlockScorer::ByteLut(blut),
            Scorer::Popcnt => BlockScorer::Popcnt { q_words: q_words.as_slice(), dim },
        };
        cache.stream_select(
            pool,
            &scorer,
            end,
            &self.sink_ids,
            k,
            block_scores,
            selector,
            selected,
        );
    }

    /// Chunked prefill (the serving path): ingest prompt tokens
    /// `[start, end)`. `keys`/`vals`/`q_window` are the FULL prompt
    /// arrays on every call — chunk 0 freezes stats and codebook over the
    /// whole prompt (see [`HeadCache::ingest_prefill_range`]), so the
    /// result is bit-identical to a one-shot [`Self::prefill`] regardless
    /// of slicing. Sinks build on the final chunk only: SnapKV selection
    /// needs every key, and mu has been frozen since chunk 0.
    pub fn prefill_chunk(
        &mut self,
        keys: &[f32],
        vals: &[f32],
        q_window: &[f32],
        r_heads: usize,
        start: usize,
        end: usize,
    ) {
        self.cache
            .ingest_prefill_range(&self.mgr, keys, vals, start, end, self.prompt_hash)
            .expect("shared kv pool exhausted at prefill (admission must check free blocks first)");
        let tokens = keys.len() / self.dim;
        if end == tokens && self.cfg.use_sinks && self.cfg.sink_tokens > 0 {
            let sel = if q_window.is_empty() {
                // degenerate: first tokens (StreamingLLM-style)
                (0..self.cfg.sink_tokens.min(tokens) as u32).collect::<Vec<_>>()
            } else {
                snapkv_select(q_window, r_heads, keys, self.dim, self.cfg.sink_tokens)
            };
            // sink store holds CENTERED keys (K'), matching the compressed
            // cache's reconstruction target
            let mu = self.cache.mu().to_vec();
            let mut centered = keys.to_vec();
            for row in centered.chunks_exact_mut(self.dim) {
                for (j, v) in row.iter_mut().enumerate() {
                    *v -= mu[j];
                }
            }
            self.sinks = SinkStore::build(self.dim, &sel, &centered, vals);
            let mut ids = sel;
            ids.sort_unstable();
            self.sink_ids = ids;
        }
    }

    pub fn len(&self) -> usize {
        self.cache.len() + self.recent.len() / (2 * self.dim)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn cache(&self) -> &HeadCache {
        &self.cache
    }

    pub fn pool(&self) -> &BlockPool {
        self.mgr.pool()
    }

    pub fn manager(&self) -> &Arc<KvManager> {
        &self.mgr
    }

    pub fn sinks(&self) -> &SinkStore {
        &self.sinks
    }

    /// Tier swap-out, step 2 (after the payloads were copied to the host
    /// tier via [`HeadCache::blocks`]): detach the block table and
    /// release every device reference. The head keeps its length, frozen
    /// stats, codebook, sinks, and fp recent window, so a later
    /// [`Self::attach_blocks`] resumes decoding bit-exactly.
    pub fn detach_blocks(&mut self) {
        for id in self.cache.take_blocks_for_swap() {
            self.mgr.pool().release(id);
        }
    }

    /// Tier swap-in: re-attach freshly allocated device blocks holding
    /// bit-exact copies of the swapped-out payloads, in swap-out order.
    pub fn attach_blocks(&mut self, blocks: Vec<crate::kvcache::BlockId>) {
        self.cache.restore_blocks(blocks, self.mgr.pool());
    }

    /// LUT-GEMV scores with sinks masked out (−inf), ready for top-k.
    /// (Diagnostic path; the decode hot path is [`Self::fused_select`],
    /// which never materializes this vector.)
    pub fn masked_scores(&mut self, query: &[f32]) -> &[f32] {
        let lut = Lut::build(query, self.cache.codebook());
        let blut = ByteLut::from_lut(&lut);
        let scores = &mut self.scores;
        self.cache.scores(self.mgr.pool(), &blut, scores);
        for &s in &self.sink_ids {
            if (s as usize) < scores.len() {
                scores[s as usize] = f32::NEG_INFINITY;
            }
        }
        scores
    }
}

impl AttentionMethod for SelfIndexing {
    fn name(&self) -> &'static str {
        "selfindex"
    }

    fn prefill(&mut self, keys: &[f32], vals: &[f32], q_window: &[f32], r_heads: usize) {
        // one-shot == a single chunk spanning the whole prompt: the
        // serving layer's chunked path and this one are the same code
        let tokens = keys.len() / self.dim;
        self.prefill_chunk(keys, vals, q_window, r_heads, 0, tokens);
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        self.try_append(k_row, v_row)
            .expect("shared kv pool exhausted mid-decode (scheduler must preempt first)");
    }

    /// Fallible append — the engine's entry point: a `CacheFull` here is
    /// the scheduler's signal to preempt instead of panicking. Nothing is
    /// recorded on failure (the compressed record never lands and the fp
    /// recent window is untouched), so a preempted sequence can be
    /// recomputed from its prompt with no residue.
    fn try_append(&mut self, k_row: &[f32], v_row: &[f32]) -> Result<(), CacheFull> {
        // compressed append (future retrievability) + fp recent window
        self.cache.append(self.mgr.pool(), k_row, v_row)?;
        let mu = self.cache.mu();
        let dim = self.dim;
        let start = self.recent.len();
        self.recent.extend_from_slice(k_row);
        for j in 0..dim {
            self.recent[start + j] -= mu[j]; // store centered like the cache
        }
        self.recent.extend_from_slice(v_row);
        // fold oldest recent rows once over cap (they remain compressed)
        let rows = self.recent.len() / (2 * dim);
        if rows > self.recent_cap {
            self.recent.drain(..(rows - self.recent_cap) * 2 * dim);
        }
        Ok(())
    }

    fn blocks_for_append(&self) -> usize {
        self.cache.blocks_for_next_append(self.mgr.pool())
    }

    fn pool_payload_bytes(&self) -> usize {
        self.cache.payload_bytes(self.mgr.pool())
    }

    fn attend(&mut self, query: &[f32], budget: usize, out: &mut [f32]) {
        let dyn_budget = budget.min(self.cache.len());
        self.fused_select(query, dyn_budget);
        let recent = std::mem::take(&mut self.recent);
        attend_sparse_fused(
            query,
            &self.cache,
            self.mgr.pool(),
            &self.retrieval.selected,
            &self.sinks,
            &recent,
            &mut self.scratch,
            out,
        );
        self.recent = recent;
    }

    fn memory_bytes(&self) -> usize {
        self.cache.payload_bytes(self.mgr.pool())
            + self.cache.fixed_overhead_bytes()
            + self.sinks.bytes()
            + self.recent.len() * 4
    }

    fn retrieval_scores(&mut self, query: &[f32]) -> Option<Vec<f32>> {
        let lut = Lut::build(query, self.cache.codebook());
        let blut = ByteLut::from_lut(&lut);
        let mut out = Vec::new();
        self.cache.scores(self.mgr.pool(), &blut, &mut out);
        Some(out)
    }

    /// GQA aggregation (paper): sum the R query heads' LUTs — one fused
    /// score→select pass and ONE top-k for the whole group — then attend
    /// each head over the shared selection.
    fn attend_group(&mut self, queries: &[f32], dim: usize, budget: usize, outs: &mut [f32]) {
        assert_eq!(dim, self.dim);
        let r = queries.len() / dim;
        self.fused_select(queries, budget.min(self.cache.len()));
        let recent = std::mem::take(&mut self.recent);
        for i in 0..r {
            let q = &queries[i * dim..(i + 1) * dim];
            let out = &mut outs[i * dim..(i + 1) * dim];
            attend_sparse_fused(
                q,
                &self.cache,
                self.mgr.pool(),
                &self.retrieval.selected,
                &self.sinks,
                &recent,
                &mut self.scratch,
                out,
            );
        }
        self.recent = recent;
    }
}

/// Every exit path — completion, preemption, panic unwind — returns this
/// head's block references to the shared pool; with the prefix registry
/// holding no refcounts, all sequences finishing means
/// `free_blocks == capacity_blocks` (leak-checked in
/// `tests/memory_manager.rs`).
impl Drop for SelfIndexing {
    fn drop(&mut self) {
        self.cache.free(self.mgr.pool());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::full::FullCache;
    use crate::baselines::testutil::clustered;

    #[test]
    fn output_tracks_full_attention() {
        // Decomposed guarantees (cf. python test_kernels.py):
        //  * at 8-bit payloads, quantization error is negligible and the
        //    whole pipeline (retrieval + fused attention) must track full
        //    attention closely;
        //  * at the paper's 2-bit setting, unstructured gaussian V is the
        //    worst case (errors don't cancel against structure), so the
        //    bar is lower — and 8-bit must strictly beat 2-bit.
        let dim = 64;
        let (mut keys, vals, query) = clustered(1, 1024, dim, 4.0);
        // plant dominant needles aligned with the query (peaked attention)
        for t in [100usize, 400, 700] {
            for j in 0..dim {
                keys[t * dim + j] = 2.5 * query[j];
            }
        }
        let mut full = FullCache::new(dim);
        full.prefill(&keys, &vals, &[], 1);
        let mut b = vec![0.0; dim];
        full.attend(&query, usize::MAX, &mut b);

        let cos_at_bits = |bits: u32| {
            let mut cfg = SelfIndexConfig::default();
            cfg.quant_bits = bits;
            let mut ours = SelfIndexing::new(dim, cfg);
            ours.prefill(&keys, &vals, &[], 1);
            let mut a = vec![0.0; dim];
            ours.attend(&query, 96, &mut a);
            crate::eval::cosine(&a, &b)
        };
        let c8 = cos_at_bits(8);
        let c2 = cos_at_bits(2);
        assert!(c8 > 0.95, "8-bit cosine {c8}");
        assert!(c2 > 0.8, "2-bit cosine {c2}");
        assert!(c8 > c2, "more bits must help: {c8} vs {c2}");
    }

    #[test]
    fn retrieval_recall_high_in_peaked_regime() {
        let dim = 64;
        let (keys, _vals, query) = clustered(1, 1024, dim, 9.0);
        let vals = vec![0.0f32; keys.len()];
        let mut ours = SelfIndexing::new(dim, SelfIndexConfig::default());
        ours.prefill(&keys, &vals, &[], 1);
        let approx = ours.retrieval_scores(&query).unwrap();
        let mu = ours.cache().mu().to_vec();
        let centered: Vec<f32> = keys
            .iter()
            .enumerate()
            .map(|(i, &v)| v - mu[i % dim])
            .collect();
        let mut exact = Vec::new();
        crate::selfindex::score::exact_scores(&query, &centered, dim, &mut exact);
        let recall = crate::eval::recall_at_k(&approx, &exact, 96);
        assert!(recall > 0.55, "recall {recall}");
    }

    #[test]
    fn memory_below_quarter_of_full() {
        let dim = 64;
        let (keys, vals, _) = clustered(2, 4096, dim, 3.0);
        let mut ours = SelfIndexing::new(dim, SelfIndexConfig::default());
        ours.prefill(&keys, &vals, &[], 1);
        let full_bytes = 2 * 4096 * dim * 4;
        assert!(
            ours.memory_bytes() < full_bytes / 4,
            "{} vs full {}",
            ours.memory_bytes(),
            full_bytes
        );
    }

    #[test]
    fn decode_append_and_attend() {
        let dim = 64;
        let (keys, vals, query) = clustered(3, 256, dim, 4.0);
        let mut ours = SelfIndexing::new(dim, SelfIndexConfig::default());
        ours.prefill(&keys, &vals, &[], 1);
        for i in 0..10 {
            let k = &keys[i * dim..(i + 1) * dim];
            ours.append(k, k);
        }
        assert_eq!(ours.cache().len(), 266);
        let mut out = vec![0.0; dim];
        ours.attend(&query, 32, &mut out);
        assert!(out.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn fused_select_matches_masked_scores_plus_topk() {
        // the one-pass pipeline must select exactly what the seed's
        // three-pass path (flat scores → -inf sweep → heap top-k) selects
        let dim = 64;
        let (keys, vals, query) = clustered(7, 777, dim, 4.0); // ragged last block
        let mut ours = SelfIndexing::new(dim, SelfIndexConfig::default());
        ours.prefill(&keys, &vals, &[], 1);
        for i in 0..5 {
            let k = &keys[i * dim..(i + 1) * dim];
            ours.append(k, k); // nonzero fp recent tail to mask
        }
        for budget in [1usize, 17, 96, 512, 10_000] {
            let reference = {
                let scores = ours.masked_scores(&query).to_vec();
                // reference masks the compressed copies of the recent tail
                let recent_rows = 5;
                let mut s = scores;
                let n = s.len();
                for t in n - recent_rows..n {
                    s[t] = f32::NEG_INFINITY;
                }
                crate::selfindex::topk::top_k_indices(&s, budget.min(n))
            };
            let dyn_budget = budget.min(ours.cache().len());
            ours.fused_select(&query, dyn_budget);
            let fused = ours.retrieval.selected.clone();
            // the fused path never emits masked entries; the reference
            // includes them (ranked last, at -inf) when k exceeds the
            // unmasked count — compare the meaningful prefix
            assert_eq!(fused[..], reference[..fused.len()], "budget {budget}");
            let masked = ours.sink_ids.len() + 5;
            assert_eq!(
                fused.len(),
                dyn_budget.min(ours.cache().len() - masked),
                "budget {budget}"
            );
        }
    }

    #[test]
    fn decode_step_is_allocation_free() {
        // the FULL decode step — append (compressed encode + fp recent
        // window) AND budgeted attention — allocates nothing once warm
        use crate::substrate::metrics::thread_allocations;
        let dim = 64;
        let (keys, vals, query) = clustered(8, 2048, dim, 4.0);
        let mut ours = SelfIndexing::new(dim, SelfIndexConfig::default());
        ours.prefill(&keys, &vals, &[], 1);
        let r = 4; // GQA group
        let queries: Vec<f32> = (0..r).flat_map(|_| query.clone()).collect();
        let mut outs = vec![0.0f32; r * dim];
        let mut out = vec![0.0f32; dim];
        // warmup sizes every scratch arena: selector heap, block buffer,
        // LUTs, softmax score list, the encode/quantize arenas, AND the
        // fp recent window, which only stops growing once it hits its
        // fold cap (64 rows) — so warm past that point, landing between
        // 64-token block-allocation boundaries
        for i in 0..72 {
            let k = &keys[(i % 256) * dim..(i % 256 + 1) * dim];
            ours.append(k, k);
            ours.attend_group(&queries, dim, 96, &mut outs);
            ours.attend(&query, 96, &mut out);
        }
        let before = thread_allocations();
        for i in 0..8 {
            let k = &keys[(i % 256) * dim..(i % 256 + 1) * dim];
            ours.append(k, k);
            ours.attend_group(&queries, dim, 96, &mut outs);
            ours.attend(&query, 96, &mut out);
        }
        let delta = thread_allocations() - before;
        assert_eq!(delta, 0, "fused decode step allocated {delta} times");
        assert!(outs.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn popcnt_scorer_retrieves_planted_needles() {
        // end-to-end through the popcount kernel: needles aligned with
        // the query at 10× magnitude keep their sign plane through
        // channel-mean centering, so sign-agreement scoring must rank
        // them above gaussian background keys
        let dim = 64;
        let (mut keys, vals, query) = clustered(11, 1024, dim, 4.0);
        let needles = [33usize, 500, 900];
        for &t in &needles {
            for j in 0..dim {
                keys[t * dim + j] = 10.0 * query[j];
            }
        }
        let mut cfg = SelfIndexConfig::default();
        cfg.scorer = Scorer::Popcnt;
        let mut ours = SelfIndexing::new(dim, cfg);
        ours.prefill(&keys, &vals, &[], 1);
        ours.fused_select(&query, 96);
        let selected = ours.retrieval.selected.clone();
        for &t in &needles {
            assert!(
                selected.contains(&(t as u32)) || ours.sink_ids.contains(&(t as u32)),
                "needle {t} missing from popcnt selection {selected:?}"
            );
        }
        // and the full attend path runs on the same kernel
        let mut out = vec![0.0; dim];
        ours.attend(&query, 96, &mut out);
        assert!(out.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn popcnt_decode_step_is_allocation_free() {
        // same guarantee as `decode_step_is_allocation_free`, through the
        // popcount scorer: the q_sum/q_codes/q_packed/q_words arenas must
        // reach steady-state capacity during warmup and never reallocate
        use crate::substrate::metrics::thread_allocations;
        let dim = 64;
        let (keys, vals, query) = clustered(12, 2048, dim, 4.0);
        let mut cfg = SelfIndexConfig::default();
        cfg.scorer = Scorer::Popcnt;
        let mut ours = SelfIndexing::new(dim, cfg);
        ours.prefill(&keys, &vals, &[], 1);
        let r = 4;
        let queries: Vec<f32> = (0..r).flat_map(|_| query.clone()).collect();
        let mut outs = vec![0.0f32; r * dim];
        let mut out = vec![0.0f32; dim];
        for i in 0..72 {
            let k = &keys[(i % 256) * dim..(i % 256 + 1) * dim];
            ours.append(k, k);
            ours.attend_group(&queries, dim, 96, &mut outs);
            ours.attend(&query, 96, &mut out);
        }
        let before = thread_allocations();
        for i in 0..8 {
            let k = &keys[(i % 256) * dim..(i % 256 + 1) * dim];
            ours.append(k, k);
            ours.attend_group(&queries, dim, 96, &mut outs);
            ours.attend(&query, 96, &mut out);
        }
        let delta = thread_allocations() - before;
        assert_eq!(delta, 0, "popcnt decode step allocated {delta} times");
        assert!(outs.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn paged_popcnt_selection_is_bit_identical() {
        // the hierarchical page tier (DESIGN.md §Perf iteration 9) is an
        // internal fast path: selection through the full method must not
        // change by a single index when it engages, needles included
        let dim = 64;
        let (mut keys, vals, query) = clustered(13, 1024, dim, 4.0);
        for &t in &[40usize, 777] {
            for j in 0..dim {
                keys[t * dim + j] = 10.0 * query[j];
            }
        }
        let run = |page_blocks: usize| {
            let mut cfg = SelfIndexConfig::default();
            cfg.scorer = Scorer::Popcnt;
            cfg.page_blocks = page_blocks;
            let mut m = SelfIndexing::new(dim, cfg);
            m.prefill(&keys, &vals, &[], 1);
            for i in 0..5 {
                let k = &keys[i * dim..(i + 1) * dim];
                m.append(k, k); // fp recent tail + a ragged open page
            }
            m.fused_select(&query, 96);
            m.retrieval.selected.clone()
        };
        let flat = run(0);
        for pb in [1usize, 2, 7] {
            assert_eq!(run(pb), flat, "page_blocks={pb}");
        }
    }

    #[test]
    fn ablation_switches_change_behaviour() {
        let dim = 64;
        let (keys, vals, query) = clustered(4, 512, dim, 4.0);
        let run = |cfg: SelfIndexConfig| {
            let mut m = SelfIndexing::new(dim, cfg);
            m.prefill(&keys, &vals, &[], 1);
            let mut out = vec![0.0; dim];
            m.attend(&query, 64, &mut out);
            out
        };
        let base = run(SelfIndexConfig::default());
        let mut no_sign = SelfIndexConfig::default();
        no_sign.sign_plane_quant = false;
        let mut sign_only = SelfIndexConfig::default();
        sign_only.magnitude_centroids = false;
        let a = run(no_sign);
        let b = run(sign_only);
        let d1: f32 = base.iter().zip(&a).map(|(x, y)| (x - y).abs()).sum();
        let d2: f32 = base.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(d1 > 1e-4, "w/o sign must differ");
        assert!(d2 > 1e-4, "sign-only retrieval must differ");
    }
}
