//! Iterative k-means codebook construction — the clustering baseline of
//! Table 4 (the paper reports one-pass sign clustering at 20×+ faster
//! than 20-iteration k-means at equal codebook size).
//!
//! Same geometry as the sign codebook: per 4-channel group, 16 centroids
//! over the group's subvectors. Lloyd's algorithm with k-means++-lite
//! seeding (random distinct points), fixed iteration count as in prior KV
//! clustering work (PQCache uses 20-50).

use crate::selfindex::codebook::Codebook;
use crate::substrate::rng::Rng;

/// Run k-means over each group's subvectors; returns a [`Codebook`]
/// shaped exactly like the sign-based one (16 centroids × dim-4).
pub fn kmeans_codebook(
    centered_keys: &[f32],
    dim: usize,
    iters: usize,
    seed: u64,
) -> Codebook {
    assert_eq!(dim % 4, 0);
    let groups = dim / 4;
    let tokens = centered_keys.len() / dim;
    let k = 16usize;
    let mut rng = Rng::new(seed);
    let mut centroids = vec![0.0f32; groups * k * 4];

    let mut assign = vec![0u8; tokens];
    let mut sums = vec![0.0f32; k * 4];
    let mut counts = vec![0u32; k];

    for g in 0..groups {
        // seed: k distinct tokens' subvectors
        let seeds = rng.choose_distinct(tokens.max(k), k);
        for (c, &t) in seeds.iter().enumerate() {
            let t = t.min(tokens - 1);
            let src = &centered_keys[t * dim + g * 4..t * dim + g * 4 + 4];
            centroids[(g * k + c) * 4..(g * k + c) * 4 + 4].copy_from_slice(src);
        }
        for _ in 0..iters {
            // assignment
            for t in 0..tokens {
                let sub = &centered_keys[t * dim + g * 4..t * dim + g * 4 + 4];
                let mut best = 0u8;
                let mut best_d = f32::INFINITY;
                for c in 0..k {
                    let cent = &centroids[(g * k + c) * 4..(g * k + c) * 4 + 4];
                    let mut d = 0.0;
                    for i in 0..4 {
                        let x = sub[i] - cent[i];
                        d += x * x;
                    }
                    if d < best_d {
                        best_d = d;
                        best = c as u8;
                    }
                }
                assign[t] = best;
            }
            // update
            sums.fill(0.0);
            counts.fill(0);
            for t in 0..tokens {
                let c = assign[t] as usize;
                let sub = &centered_keys[t * dim + g * 4..t * dim + g * 4 + 4];
                for i in 0..4 {
                    sums[c * 4 + i] += sub[i];
                }
                counts[c] += 1;
            }
            for c in 0..k {
                if counts[c] > 0 {
                    for i in 0..4 {
                        centroids[(g * k + c) * 4 + i] =
                            sums[c * 4 + i] / counts[c] as f32;
                    }
                }
            }
        }
    }
    Codebook { groups, centroids }
}

/// Mean squared reconstruction error of assigning each subvector to its
/// nearest centroid (codebook quality metric for the Table-4 comparison).
pub fn quantization_mse(codebook: &Codebook, centered_keys: &[f32], dim: usize) -> f64 {
    let groups = dim / 4;
    let tokens = centered_keys.len() / dim;
    let mut total = 0.0f64;
    for t in 0..tokens {
        for g in 0..groups {
            let sub = &centered_keys[t * dim + g * 4..t * dim + g * 4 + 4];
            let mut best = f32::INFINITY;
            for c in 0..16 {
                let cent = codebook.centroid(g, c);
                let mut d = 0.0;
                for i in 0..4 {
                    let x = sub[i] - cent[i];
                    d += x * x;
                }
                best = best.min(d);
            }
            total += best as f64;
        }
    }
    total / (tokens * groups * 4) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selfindex::codebook::CodebookBuilder;
    use crate::substrate::rng::Rng;

    fn keys(seed: u64, tokens: usize, dim: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..tokens * dim).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn kmeans_reduces_mse_over_iterations() {
        let dim = 16;
        let k = keys(1, 512, dim);
        let cb1 = kmeans_codebook(&k, dim, 1, 7);
        let cb10 = kmeans_codebook(&k, dim, 10, 7);
        let e1 = quantization_mse(&cb1, &k, dim);
        let e10 = quantization_mse(&cb10, &k, dim);
        assert!(e10 <= e1 + 1e-9, "{e10} vs {e1}");
    }

    #[test]
    fn sign_codebook_quality_comparable_to_kmeans() {
        // the paper's claim: one-pass sign clustering preserves "sufficient
        // representational quality". On gaussian subvectors k-means wins on
        // MSE, but sign clustering must be within a modest factor.
        let dim = 32;
        let k = keys(2, 2048, dim);
        let mut b = CodebookBuilder::new(dim / 4);
        b.accumulate(&k);
        let sign_cb = b.finalize();
        let km_cb = kmeans_codebook(&k, dim, 20, 3);
        let e_sign = quantization_mse(&sign_cb, &k, dim);
        let e_km = quantization_mse(&km_cb, &k, dim);
        assert!(e_sign < e_km * 2.5, "sign {e_sign} vs kmeans {e_km}");
    }
}
