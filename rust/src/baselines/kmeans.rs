//! Iterative k-means codebook construction — the clustering baseline of
//! Table 4 (the paper reports one-pass sign clustering at 20×+ faster
//! than 20-iteration k-means at equal codebook size).
//!
//! Same geometry as the sign codebook: per 4-channel group, 16 centroids
//! over the group's subvectors. Lloyd's algorithm with k-means++-lite
//! seeding (random distinct points), fixed iteration count as in prior KV
//! clustering work (PQCache uses 20-50).
//!
//! [`KMeansCache`] serves the codebook behind [`AttentionMethod`]
//! (PQCache-style): prefill builds the k-means codebook over centered
//! keys and assigns every token a packed 4-bit centroid id per group;
//! decode retrieves by LUT-GEMV over those ids (same scorer as ours) and
//! attends densely over the top-k in full precision.

use super::AttentionMethod;
use crate::attention::dense::attend_dense;
use crate::selfindex::codebook::Codebook;
use crate::selfindex::lut::Lut;
use crate::selfindex::score::{score_tokens_bytelut, ByteLut};
use crate::selfindex::topk::{top_k_indices, TopKStream};
use crate::substrate::rng::Rng;

/// Run k-means over each group's subvectors; returns a [`Codebook`]
/// shaped exactly like the sign-based one (16 centroids × dim-4).
pub fn kmeans_codebook(
    centered_keys: &[f32],
    dim: usize,
    iters: usize,
    seed: u64,
) -> Codebook {
    assert_eq!(dim % 4, 0);
    let groups = dim / 4;
    let tokens = centered_keys.len() / dim;
    let k = 16usize;
    let mut rng = Rng::new(seed);
    let mut centroids = vec![0.0f32; groups * k * 4];

    let mut assign = vec![0u8; tokens];
    let mut sums = vec![0.0f32; k * 4];
    let mut counts = vec![0u32; k];

    for g in 0..groups {
        // seed: k distinct tokens' subvectors
        let seeds = rng.choose_distinct(tokens.max(k), k);
        for (c, &t) in seeds.iter().enumerate() {
            let t = t.min(tokens - 1);
            let src = &centered_keys[t * dim + g * 4..t * dim + g * 4 + 4];
            centroids[(g * k + c) * 4..(g * k + c) * 4 + 4].copy_from_slice(src);
        }
        for _ in 0..iters {
            // assignment
            for t in 0..tokens {
                let sub = &centered_keys[t * dim + g * 4..t * dim + g * 4 + 4];
                let mut best = 0u8;
                let mut best_d = f32::INFINITY;
                for c in 0..k {
                    let cent = &centroids[(g * k + c) * 4..(g * k + c) * 4 + 4];
                    let mut d = 0.0;
                    for i in 0..4 {
                        let x = sub[i] - cent[i];
                        d += x * x;
                    }
                    if d < best_d {
                        best_d = d;
                        best = c as u8;
                    }
                }
                assign[t] = best;
            }
            // update
            sums.fill(0.0);
            counts.fill(0);
            for t in 0..tokens {
                let c = assign[t] as usize;
                let sub = &centered_keys[t * dim + g * 4..t * dim + g * 4 + 4];
                for i in 0..4 {
                    sums[c * 4 + i] += sub[i];
                }
                counts[c] += 1;
            }
            for c in 0..k {
                if counts[c] > 0 {
                    for i in 0..4 {
                        centroids[(g * k + c) * 4 + i] =
                            sums[c * 4 + i] / counts[c] as f32;
                    }
                }
            }
        }
    }
    Codebook { groups, centroids }
}

/// Mean squared reconstruction error of assigning each subvector to its
/// nearest centroid (codebook quality metric for the Table-4 comparison).
pub fn quantization_mse(codebook: &Codebook, centered_keys: &[f32], dim: usize) -> f64 {
    let groups = dim / 4;
    let tokens = centered_keys.len() / dim;
    let mut total = 0.0f64;
    for t in 0..tokens {
        for g in 0..groups {
            let sub = &centered_keys[t * dim + g * 4..t * dim + g * 4 + 4];
            let mut best = f32::INFINITY;
            for c in 0..16 {
                let cent = codebook.centroid(g, c);
                let mut d = 0.0;
                for i in 0..4 {
                    let x = sub[i] - cent[i];
                    d += x * x;
                }
                best = best.min(d);
            }
            total += best as f64;
        }
    }
    total / (tokens * groups * 4) as f64
}

/// Default Lloyd iterations for the serving-path codebook (PQCache-range,
/// low end: the comparison point is construction cost, Table 4).
pub const KMEANS_ITERS: usize = 8;

/// The k-means clustering baseline behind [`AttentionMethod`]: f32 K/V
/// store (fp16-accounted) + per-token packed centroid ids as the
/// retrieval index, scored with the same byte-LUT GEMV as Self-Indexing.
pub struct KMeansCache {
    pub dim: usize,
    pub iters: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
    /// frozen per-channel means (retrieval operates on centered keys)
    mu: Vec<f32>,
    codebook: Option<Codebook>,
    /// packed 4-bit centroid assignments, token-major (dim/4 nibbles/token)
    codes: Vec<u8>,
    code_scratch: Vec<u8>,
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
    scores: Vec<f32>,
    /// retrieval arenas mirroring `SelfIndexing`'s `RetrievalScratch`:
    /// the LUT pair rebuilds in place and selection streams through a
    /// reusable heap, so a steady-state attend allocates nothing
    lut: Lut,
    blut: ByteLut,
    selector: TopKStream,
    selected: Vec<u32>,
}

impl KMeansCache {
    pub fn new(dim: usize) -> Self {
        Self::with_iters(dim, KMEANS_ITERS)
    }

    pub fn with_iters(dim: usize, iters: usize) -> Self {
        assert_eq!(dim % 4, 0);
        Self {
            dim,
            iters: iters.max(1),
            keys: vec![],
            vals: vec![],
            mu: vec![],
            codebook: None,
            codes: vec![],
            code_scratch: vec![],
            scratch_k: vec![],
            scratch_v: vec![],
            scores: vec![],
            lut: Lut::empty(dim / 4),
            blut: ByteLut::empty(),
            selector: TopKStream::new(0),
            selected: vec![],
        }
    }

    pub fn len(&self) -> usize {
        self.keys.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn codebook(&self) -> &Codebook {
        self.codebook.as_ref().expect("prefill not ingested")
    }

    /// Assign one centered key row to its nearest centroid per group and
    /// append the packed nibble codes.
    fn encode_row(&mut self, centered_row: &[f32]) {
        let groups = self.dim / 4;
        let cb = self.codebook.as_ref().expect("prefill first");
        self.code_scratch.clear();
        for g in 0..groups {
            let sub = &centered_row[g * 4..(g + 1) * 4];
            let mut best = 0u8;
            let mut best_d = f32::INFINITY;
            for c in 0..16 {
                let cent = cb.centroid(g, c);
                let mut d = 0.0;
                for i in 0..4 {
                    let x = sub[i] - cent[i];
                    d += x * x;
                }
                if d < best_d {
                    best_d = d;
                    best = c as u8;
                }
            }
            self.code_scratch.push(best);
        }
        let start = self.codes.len();
        self.codes.resize(start + groups.div_ceil(2), 0);
        for (i, &c) in self.code_scratch.iter().enumerate() {
            self.codes[start + i / 2] |= (c & 0x0f) << ((i % 2) * 4);
        }
    }

    /// LUT-GEMV scores of every cached token over the centroid ids.
    pub fn approx_scores(&self, query: &[f32], out: &mut Vec<f32>) {
        let lut = Lut::build(query, self.codebook());
        let blut = ByteLut::from_lut(&lut);
        score_tokens_bytelut(&blut, &self.codes, self.len(), out);
    }
}

impl AttentionMethod for KMeansCache {
    fn name(&self) -> &'static str {
        "kmeans"
    }

    fn prefill(&mut self, keys: &[f32], vals: &[f32], _q: &[f32], _r: usize) {
        assert_eq!(keys.len() % self.dim, 0);
        let dim = self.dim;
        let tokens = keys.len() / dim;
        if tokens == 0 {
            return;
        }
        // center like the compressed cache: retrieval targets K' = K - mu
        self.mu = vec![0.0; dim];
        for row in keys.chunks_exact(dim) {
            for (j, &v) in row.iter().enumerate() {
                self.mu[j] += v;
            }
        }
        for m in self.mu.iter_mut() {
            *m /= tokens as f32;
        }
        let mut centered = keys.to_vec();
        for row in centered.chunks_exact_mut(dim) {
            for (j, v) in row.iter_mut().enumerate() {
                *v -= self.mu[j];
            }
        }
        self.codebook = Some(kmeans_codebook(&centered, dim, self.iters, 0x5EED));
        self.keys.extend_from_slice(keys);
        self.vals.extend_from_slice(vals);
        for t in 0..tokens {
            self.encode_row(&centered[t * dim..(t + 1) * dim]);
        }
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        // frozen codebook + mu, like the paper's decode-time reuse
        let centered: Vec<f32> = k_row
            .iter()
            .zip(&self.mu)
            .map(|(&v, &m)| v - m)
            .collect();
        self.keys.extend_from_slice(k_row);
        self.vals.extend_from_slice(v_row);
        self.encode_row(&centered);
    }

    fn attend(&mut self, query: &[f32], budget: usize, out: &mut [f32]) {
        let dim = self.dim;
        // in-place LUT rebuild + reusable score/selection arenas (the
        // ROADMAP open item: no per-call Lut/ByteLut construction)
        let cb = self.codebook.as_ref().expect("prefill not ingested");
        self.lut.rebuild(query, cb);
        self.blut.rebuild(&self.lut);
        let scores = &mut self.scores;
        score_tokens_bytelut(&self.blut, &self.codes, self.keys.len() / dim, scores);
        self.selector.reset(budget.min(scores.len()));
        for (t, &s) in scores.iter().enumerate() {
            self.selector.push(t as u32, s);
        }
        let mut sel = std::mem::take(&mut self.selected);
        self.selector.finish_into(&mut sel);
        self.scratch_k.clear();
        self.scratch_v.clear();
        for &t in &sel {
            let t = t as usize;
            self.scratch_k
                .extend_from_slice(&self.keys[t * dim..(t + 1) * dim]);
            self.scratch_v
                .extend_from_slice(&self.vals[t * dim..(t + 1) * dim]);
        }
        attend_dense(query, &self.scratch_k, &self.scratch_v, sel.len(), out);
        self.selected = sel;
    }

    fn memory_bytes(&self) -> usize {
        // fp16 K/V + packed 4-bit ids + the codebook (fixed overhead)
        (self.keys.len() + self.vals.len()) * 2
            + self.codes.len()
            + self.codebook.as_ref().map(|c| c.bytes()).unwrap_or(0)
    }

    fn retrieval_scores(&mut self, query: &[f32]) -> Option<Vec<f32>> {
        let mut out = Vec::new();
        self.approx_scores(query, &mut out);
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selfindex::codebook::CodebookBuilder;
    use crate::substrate::rng::Rng;

    fn keys(seed: u64, tokens: usize, dim: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..tokens * dim).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn kmeans_reduces_mse_over_iterations() {
        let dim = 16;
        let k = keys(1, 512, dim);
        let cb1 = kmeans_codebook(&k, dim, 1, 7);
        let cb10 = kmeans_codebook(&k, dim, 10, 7);
        let e1 = quantization_mse(&cb1, &k, dim);
        let e10 = quantization_mse(&cb10, &k, dim);
        assert!(e10 <= e1 + 1e-9, "{e10} vs {e1}");
    }

    #[test]
    fn kmeans_cache_retrieves_and_attends() {
        use crate::baselines::testutil::clustered;
        let dim = 64;
        let (keys, vals, query) = clustered(5, 512, dim, 4.0);
        let mut m = KMeansCache::new(dim);
        m.prefill(&keys, &vals, &[], 1);
        assert_eq!(m.len(), 512);
        for i in 0..8 {
            let k = &keys[i * dim..(i + 1) * dim];
            m.append(k, k);
        }
        assert_eq!(m.len(), 520);
        // approximate top-k overlaps exact top-k on clustered keys
        let approx = m.retrieval_scores(&query).unwrap();
        assert_eq!(approx.len(), 520);
        let mu = m.mu.clone();
        let centered: Vec<f32> = m
            .keys
            .iter()
            .enumerate()
            .map(|(i, &v)| v - mu[i % dim])
            .collect();
        let mut exact = Vec::new();
        crate::selfindex::score::exact_scores(&query, &centered, dim, &mut exact);
        let k = 64;
        let sa: std::collections::HashSet<u32> =
            top_k_indices(&approx, k).into_iter().collect();
        let se: std::collections::HashSet<u32> =
            top_k_indices(&exact, k).into_iter().collect();
        let recall = sa.intersection(&se).count() as f32 / k as f32;
        assert!(recall > 0.3, "recall {recall}");
        let mut out = vec![0.0; dim];
        m.attend(&query, 96, &mut out);
        assert!(out.iter().any(|&x| x != 0.0));
        // fp16 K/V + 4-bit ids: well under the fp32 full cache
        assert!(m.memory_bytes() < 520 * dim * 2 * 4);
    }

    #[test]
    fn attend_is_allocation_free_once_warm() {
        // the scratch-arena satellite: LUT pair, score vector, selector
        // heap, gather buffers — all reused, so a steady-state attend
        // (the conformance-suite shape) performs zero heap allocations
        use crate::baselines::testutil::clustered;
        use crate::substrate::metrics::thread_allocations;
        let dim = 64;
        let (keys, vals, query) = clustered(6, 512, dim, 4.0);
        let mut m = KMeansCache::new(dim);
        m.prefill(&keys, &vals, &[], 1);
        let mut out = vec![0.0; dim];
        for _ in 0..4 {
            m.attend(&query, 96, &mut out); // warm every arena
        }
        let before = thread_allocations();
        for _ in 0..8 {
            m.attend(&query, 96, &mut out);
        }
        let delta = thread_allocations() - before;
        assert_eq!(delta, 0, "kmeans attend allocated {delta} times");
        assert!(out.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn arena_selection_matches_top_k_indices() {
        use crate::baselines::testutil::clustered;
        let dim = 64;
        let (keys, vals, query) = clustered(9, 300, dim, 4.0);
        let mut m = KMeansCache::new(dim);
        m.prefill(&keys, &vals, &[], 1);
        let mut out = vec![0.0; dim];
        m.attend(&query, 64, &mut out);
        let scores = m.retrieval_scores(&query).unwrap();
        assert_eq!(m.selected, top_k_indices(&scores, 64));
    }

    #[test]
    fn sign_codebook_quality_comparable_to_kmeans() {
        // the paper's claim: one-pass sign clustering preserves "sufficient
        // representational quality". On gaussian subvectors k-means wins on
        // MSE, but sign clustering must be within a modest factor.
        let dim = 32;
        let k = keys(2, 2048, dim);
        let mut b = CodebookBuilder::new(dim / 4);
        b.accumulate(&k);
        let sign_cb = b.finalize();
        let km_cb = kmeans_codebook(&k, dim, 20, 3);
        let e_sign = quantization_mse(&sign_cb, &k, dim);
        let e_km = quantization_mse(&km_cb, &k, dim);
        assert!(e_sign < e_km * 2.5, "sign {e_sign} vs kmeans {e_km}");
    }
}
