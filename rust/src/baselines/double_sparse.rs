//! DoubleSparse baseline (Yang et al. 2024): token-level sparsity via a
//! reduced-channel ("label") index.
//!
//! At prefill, pick the 16 heaviest channels (by aggregate |K| magnitude —
//! the post-training offline calibration of the paper, done online here);
//! the index stores only those channels of each key. Decode: approximate
//! scores = dot over the 16 label channels → token top-k → dense attend.
//! Paper setting: 16 channels ≈ a 2-bit/parameter index.

use super::AttentionMethod;
use crate::attention::dense::attend_dense;
use crate::selfindex::topk::top_k_indices;

pub const LABEL_CHANNELS: usize = 16;

pub struct DoubleSparse {
    pub dim: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
    /// the heavy channel ids (chosen at prefill)
    channels: Vec<u32>,
    /// label index: len × LABEL_CHANNELS
    labels: Vec<f32>,
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
}

impl DoubleSparse {
    pub fn new(dim: usize) -> Self {
        assert!(dim >= LABEL_CHANNELS);
        Self {
            dim,
            keys: vec![],
            vals: vec![],
            channels: vec![],
            labels: vec![],
            scratch_k: vec![],
            scratch_v: vec![],
        }
    }

    pub fn len(&self) -> usize {
        self.keys.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn channels(&self) -> &[u32] {
        &self.channels
    }

    fn label_of(&mut self, k_row: &[f32]) {
        for &c in &self.channels {
            self.labels.push(k_row[c as usize]);
        }
    }

    /// Approximate token scores over the label channels.
    pub fn approx_scores(&self, query: &[f32]) -> Vec<f32> {
        let qc: Vec<f32> = self
            .channels
            .iter()
            .map(|&c| query[c as usize])
            .collect();
        self.labels
            .chunks_exact(LABEL_CHANNELS)
            .map(|lab| crate::tensor::dot(&qc, lab))
            .collect()
    }
}

impl AttentionMethod for DoubleSparse {
    fn name(&self) -> &'static str {
        "doublesparse"
    }

    fn prefill(&mut self, keys: &[f32], vals: &[f32], _q: &[f32], _r: usize) {
        let dim = self.dim;
        // heavy channels: largest mean |K| (outlier channels dominate qk)
        let l = keys.len() / dim;
        let mut mass = vec![0.0f32; dim];
        for row in keys.chunks_exact(dim) {
            for (j, &v) in row.iter().enumerate() {
                mass[j] += v.abs();
            }
        }
        let _ = l;
        self.channels = top_k_indices(&mass, LABEL_CHANNELS);
        self.channels.sort_unstable();

        self.keys.extend_from_slice(keys);
        self.vals.extend_from_slice(vals);
        let rows: Vec<Vec<f32>> = keys.chunks_exact(dim).map(|r| r.to_vec()).collect();
        for row in rows {
            self.label_of(&row);
        }
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        self.keys.extend_from_slice(k_row);
        self.vals.extend_from_slice(v_row);
        let row = k_row.to_vec();
        self.label_of(&row);
    }

    fn attend(&mut self, query: &[f32], budget: usize, out: &mut [f32]) {
        let dim = self.dim;
        let scores = self.approx_scores(query);
        let sel = top_k_indices(&scores, budget.min(self.len()));
        self.scratch_k.clear();
        self.scratch_v.clear();
        for &t in &sel {
            let t = t as usize;
            self.scratch_k
                .extend_from_slice(&self.keys[t * dim..(t + 1) * dim]);
            self.scratch_v
                .extend_from_slice(&self.vals[t * dim..(t + 1) * dim]);
        }
        let sk = std::mem::take(&mut self.scratch_k);
        let sv = std::mem::take(&mut self.scratch_v);
        attend_dense(query, &sk, &sv, sel.len(), out);
        self.scratch_k = sk;
        self.scratch_v = sv;
    }

    fn memory_bytes(&self) -> usize {
        // fp16 K/V + fp16 label index (16/dim of K = the "2-bit" index)
        (self.keys.len() + self.vals.len()) * 2 + self.labels.len() * 2
    }

    fn retrieval_scores(&mut self, query: &[f32]) -> Option<Vec<f32>> {
        Some(self.approx_scores(query))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::clustered;
    use crate::substrate::rng::Rng;

    #[test]
    fn picks_outlier_channels() {
        let mut r = Rng::new(1);
        let dim = 64;
        let mut keys: Vec<f32> = (0..256 * dim).map(|_| r.normal_f32()).collect();
        for row in keys.chunks_exact_mut(dim) {
            row[7] *= 20.0;
            row[42] *= 15.0;
        }
        let mut ds = DoubleSparse::new(dim);
        ds.prefill(&keys, &keys.clone(), &[], 1);
        assert!(ds.channels().contains(&7));
        assert!(ds.channels().contains(&42));
    }

    #[test]
    fn approx_topk_overlaps_exact() {
        let dim = 64;
        let (keys, vals, query) = clustered(2, 1024, dim, 4.0);
        let mut ds = DoubleSparse::new(dim);
        ds.prefill(&keys, &vals, &[], 1);
        let approx = ds.approx_scores(&query);
        let mut exact = Vec::new();
        crate::selfindex::score::exact_scores(&query, &keys, dim, &mut exact);
        let k = 64;
        let sa: std::collections::HashSet<u32> =
            top_k_indices(&approx, k).into_iter().collect();
        let se: std::collections::HashSet<u32> =
            top_k_indices(&exact, k).into_iter().collect();
        let recall = sa.intersection(&se).count() as f32 / k as f32;
        assert!(recall > 0.25, "recall {recall}");
    }

    #[test]
    fn attend_respects_budget() {
        let dim = 32;
        let (keys, vals, query) = clustered(3, 300, dim, 3.0);
        let mut ds = DoubleSparse::new(dim);
        ds.prefill(&keys, &vals, &[], 1);
        let mut out = vec![0.0; dim];
        ds.attend(&query, 10, &mut out);
        assert!(out.iter().any(|&x| x != 0.0));
        assert!(ds.scratch_k.capacity() >= 10 * dim);
    }
}
