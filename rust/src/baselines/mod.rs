//! Baseline KV-cache methods over the same substrate, for every
//! comparison row in the paper's tables:
//!
//! * [`full`]         — full-precision cache + dense attention (the
//!   FlashAttention-2 role).
//! * [`kivi`]         — KIVI-style 2-bit channel-wise quantization with
//!   decompress-then-compute decode (Table 1/3, Fig. 5).
//! * [`snapkv`]       — one-shot observation-window pruning (Table 1/2).
//! * [`quest`]        — page-granular (16) min/max bounding-box index +
//!   page-level top-k (Table 1/2/4).
//! * [`double_sparse`]— heavy-channel (16) token-level approximate top-k
//!   (Table 1/2).
//! * [`kmeans`]       — iterative k-means codebook construction, the
//!   clustering baseline of Table 4, served as [`KMeansCache`] (PQCache-
//!   style codebook retrieval behind the same trait).
//! * [`ours`]         — the Self-Indexing method behind the same trait.
//!
//! All seven methods implement [`AttentionMethod`]: per-head prefill →
//! (optional) decode appends → budgeted attention, plus byte-exact memory
//! accounting — which is precisely the protocol the benches drive. The
//! engine consumes them through the sequence-level [`crate::method`] API
//! (`CacheMethod` registry → `SequenceCache`), with the per-head trait as
//! the leaf implementation.

pub mod double_sparse;
pub mod full;
pub mod kivi;
pub mod kmeans;
pub mod ours;
pub mod quest;
pub mod snapkv;

use crate::kvcache::store::CacheFull;

pub use double_sparse::DoubleSparse;
pub use full::FullCache;
pub use kivi::KiviCache;
pub use kmeans::KMeansCache;
pub use ours::SelfIndexing;
pub use quest::QuestCache;
pub use snapkv::SnapKv;

/// One attention head's cache + attention policy under test.
///
/// The contract mirrors the evaluation protocol: `prefill` once (with the
/// SnapKV observation-window queries available, as in the paper's setup),
/// then any number of `append`/`attend` decode steps. `budget` is the
/// number of context tokens the method may involve in attention (methods
/// with coarser granularity, e.g. page-based Quest, round up internally;
/// static methods like SnapKV fix their budget at prefill).
///
/// `Send` so the engine can fan decode steps out across its worker pool
/// at (sequence, kv-head) granularity — each head's method (and its
/// scratch arenas) is owned by exactly one job per step.
pub trait AttentionMethod: Send {
    fn name(&self) -> &'static str;

    /// Ingest the prompt: keys/vals (tokens × dim) f32 post-RoPE rows;
    /// `q_window` = (W × R × dim) observation queries (may be empty).
    fn prefill(&mut self, keys: &[f32], vals: &[f32], q_window: &[f32], r_heads: usize);

    /// Append one decode-time token.
    fn append(&mut self, k_row: &[f32], v_row: &[f32]);

    /// Fallible decode append — the engine's entry point. Methods backed
    /// by the shared block pool report [`CacheFull`] (the scheduler's
    /// preemption signal) instead of panicking; everything else appends
    /// infallibly. A failed append must leave the cache unchanged so a
    /// preempted sequence can be recomputed from its prompt cleanly.
    fn try_append(&mut self, k_row: &[f32], v_row: &[f32]) -> Result<(), CacheFull> {
        self.append(k_row, v_row);
        Ok(())
    }

    /// Shared-pool blocks the next append will allocate (0 for methods
    /// that don't store into the engine pool) — the exact-occupancy input
    /// to the scheduler's admission/preemption accounting.
    fn blocks_for_append(&self) -> usize {
        0
    }

    /// Bytes of [`Self::memory_bytes`] that live in the engine's shared
    /// block pool, counted per holder. The engine subtracts these and adds
    /// `pool.used_bytes()` instead, so blocks shared across sequences via
    /// the prefix registry are counted once.
    fn pool_payload_bytes(&self) -> usize {
        0
    }

    /// Single-query attention with a dynamic-token budget.
    fn attend(&mut self, query: &[f32], budget: usize, out: &mut [f32]);

    /// Context-size-dependent cache bytes (the Fig. 5 metric).
    fn memory_bytes(&self) -> usize;

    /// Approximate-retrieval scores over all cached tokens (None for
    /// dense / static methods); used by retrieval-fidelity evaluations.
    fn retrieval_scores(&mut self, query: &[f32]) -> Option<Vec<f32>> {
        let _ = query;
        None
    }

    /// GQA group attention: R query heads sharing this kv head attend in
    /// one call. `queries`/`outs` are (R × dim). Default: R independent
    /// `attend` calls straight into the disjoint `outs` chunks (no temp
    /// buffer); Self-Indexing overrides with the paper's aggregated-LUT
    /// retrieval (one top-k for the group).
    fn attend_group(&mut self, queries: &[f32], dim: usize, budget: usize, outs: &mut [f32]) {
        assert_eq!(queries.len(), outs.len());
        assert_eq!(queries.len() % dim, 0);
        for (q, out) in queries.chunks_exact(dim).zip(outs.chunks_exact_mut(dim)) {
            self.attend(q, budget, out);
        }
    }
}

/// Forwarding impl so registry-built leaves (`Box<dyn AttentionMethod>`)
/// slot into generic adapters like `method::PerHeadSeqCache<M>` without a
/// second code path. Every method forwards — including the overridable
/// `attend_group`/`retrieval_scores`, so concrete overrides (e.g.
/// Self-Indexing's one-top-k GQA group) are preserved through the box.
impl AttentionMethod for Box<dyn AttentionMethod> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn prefill(&mut self, keys: &[f32], vals: &[f32], q_window: &[f32], r_heads: usize) {
        (**self).prefill(keys, vals, q_window, r_heads)
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        (**self).append(k_row, v_row)
    }

    fn try_append(&mut self, k_row: &[f32], v_row: &[f32]) -> Result<(), CacheFull> {
        (**self).try_append(k_row, v_row)
    }

    fn blocks_for_append(&self) -> usize {
        (**self).blocks_for_append()
    }

    fn pool_payload_bytes(&self) -> usize {
        (**self).pool_payload_bytes()
    }

    fn attend(&mut self, query: &[f32], budget: usize, out: &mut [f32]) {
        (**self).attend(query, budget, out)
    }

    fn memory_bytes(&self) -> usize {
        (**self).memory_bytes()
    }

    fn retrieval_scores(&mut self, query: &[f32]) -> Option<Vec<f32>> {
        (**self).retrieval_scores(query)
    }

    fn attend_group(&mut self, queries: &[f32], dim: usize, budget: usize, outs: &mut [f32]) {
        (**self).attend_group(queries, dim, budget, outs)
    }
}

/// Shared helper: exact top-k token set under a budget via full scores
/// (the oracle selector used by fidelity evaluations and tests).
pub fn exact_topk(
    query: &[f32],
    keys: &[f32],
    dim: usize,
    budget: usize,
) -> Vec<u32> {
    let mut scores = Vec::new();
    crate::selfindex::score::exact_scores(query, keys, dim, &mut scores);
    crate::selfindex::topk::top_k_indices(&scores, budget)
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::substrate::rng::Rng;

    /// Clustered keys + query aligned with cluster 0 (the
    /// retrieval-friendly regime; mirrors python test_kernels.py).
    pub fn clustered(
        seed: u64,
        tokens: usize,
        dim: usize,
        mag: f32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut r = Rng::new(seed);
        let n_dir = 8;
        let mut dirs = vec![0.0f32; n_dir * dim];
        for d in dirs.chunks_exact_mut(dim) {
            let mut norm = 0.0;
            for x in d.iter_mut() {
                *x = r.normal_f32();
                norm += *x * *x;
            }
            let inv = 1.0 / norm.sqrt();
            for x in d.iter_mut() {
                *x *= inv;
            }
        }
        let mut keys = vec![0.0f32; tokens * dim];
        for t in 0..tokens {
            let c = r.below(n_dir as u64) as usize;
            for j in 0..dim {
                keys[t * dim + j] = mag * dirs[c * dim + j] + 0.5 * r.normal_f32();
            }
        }
        let vals: Vec<f32> = (0..tokens * dim).map(|_| r.normal_f32()).collect();
        let query: Vec<f32> = (0..dim)
            .map(|j| mag * dirs[j] + 0.3 * r.normal_f32())
            .collect();
        (keys, vals, query)
    }
}
